//! Microbenchmarks + ablations of the paper's substrates:
//!
//! * sample-tree `update`/`sample` vs the linear-scan oracle (the data
//!   structure that makes Algorithm 2 `O(log n)`);
//! * multi-tree build + `MultiTreeOpen` amortized cost (Lemma 4.1);
//! * LSH insert/query throughput;
//! * the native `d2` distance kernel;
//! * `--ablation trees`: cost/distortion vs number of trees (the paper
//!   fixes 3 — this justifies that choice);
//! * `--ablation lsh-c`: rejection proposals/center and cost vs `c`
//!   (the Lemma 5.3 / Theorem 5.4 trade-off).
//!
//! ```bash
//! cargo bench --bench micro_substrates
//! cargo bench --bench micro_substrates -- --ablation trees
//! cargo bench --bench micro_substrates -- --ablation lsh-c
//! ```

use std::time::Instant;

use fastkmeanspp::cli::Args;
use fastkmeanspp::data::matrix::d2;
use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::embed::multitree::{MultiTree, MultiTreeConfig};
use fastkmeanspp::lloyd::cost_native;
use fastkmeanspp::lsh::multiscale::{LshParams, MonotoneLsh};
use fastkmeanspp::lsh::NnOracle;
use fastkmeanspp::rng::Pcg64;
use fastkmeanspp::sampletree::SampleTree;
use fastkmeanspp::seeding::rejection::{rejection_sampling, RejectionConfig};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let unit = if per < 1e-6 {
        format!("{:.1}ns", per * 1e9)
    } else if per < 1e-3 {
        format!("{:.2}us", per * 1e6)
    } else if per < 1.0 {
        format!("{:.3}ms", per * 1e3)
    } else {
        format!("{per:.3}s")
    };
    println!("{name:<52} {unit}/iter  ({iters} iters)");
}

fn main() -> fastkmeanspp::error::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&std::iter::once("bench".to_string()).chain(argv).collect::<Vec<_>>())?;

    match args.get("ablation") {
        Some("trees") => return ablation_trees(),
        Some("lsh-c") => return ablation_lsh_c(),
        Some(other) => fastkmeanspp::bail!("unknown ablation {other:?} (trees|lsh-c)"),
        None => {}
    }

    println!("== micro: substrates ==\n");

    // ---- sample tree ------------------------------------------------
    let n = 1_000_000;
    let mut rng = Pcg64::seed_from(1);
    let mut st = SampleTree::with_uniform_weight(n, 1.0);
    bench("sampletree.update (n=1e6)", 2_000_000, || {
        let i = rng.index(n);
        st.update(i, rng.next_f64());
    });
    bench("sampletree.sample (n=1e6)", 2_000_000, || {
        std::hint::black_box(st.sample(&mut rng));
    });
    // linear-scan oracle for contrast (what Theta(ndk) k-means++ does)
    let weights: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
    bench("linear-scan weighted sample (n=1e6)", 50, || {
        std::hint::black_box(rng.weighted_index(&weights));
    });

    // ---- distance kernel --------------------------------------------
    let ps = gaussian_mixture(
        &SynthSpec {
            n: 10_000,
            d: 96,
            k_true: 16,
            ..Default::default()
        },
        2,
    );
    let q = ps.row(0).to_vec();
    let mut acc = 0.0f32;
    bench("d2 kernel (d=96)", 2_000_000, || {
        let i = rng.index(ps.len());
        acc += d2(ps.row(i), &q);
    });
    std::hint::black_box(acc);

    // ---- multitree --------------------------------------------------
    let big = gaussian_mixture(
        &SynthSpec {
            n: 100_000,
            d: 24,
            k_true: 200,
            center_spread: 15.0,
            ..Default::default()
        },
        3,
    );
    let t0 = Instant::now();
    let mut mt = MultiTree::init(&big, &MultiTreeConfig::default(), &mut rng);
    println!(
        "{:<52} {:.3}s",
        "multitree.init (n=1e5, d=24, 3 trees)",
        t0.elapsed().as_secs_f64()
    );
    let t0 = Instant::now();
    let mut opened = 0;
    while opened < 2000 {
        if let Some(x) = mt.sample(&mut rng) {
            mt.open(x);
            opened += 1;
        } else {
            break;
        }
    }
    println!(
        "{:<52} {:.2}us/center ({} opened)",
        "multitree sample+open amortized",
        t0.elapsed().as_secs_f64() / opened as f64 * 1e6,
        opened
    );

    // ---- LSH ----------------------------------------------------------
    let params = LshParams::default();
    let mut lsh = MonotoneLsh::practical(24, &params, &mut rng);
    let mut next = 0u32;
    bench("lsh.insert (d=24, 8 tables x 15 hashes)", 20_000, || {
        lsh.insert(&big, next % big.len() as u32);
        next += 1;
    });
    bench("lsh.query (20k inserted)", 100_000, || {
        let i = rng.index(big.len());
        std::hint::black_box(lsh.query(&big, big.row(i)));
    });

    Ok(())
}

/// Number-of-trees ablation: distortion of the multi-tree distance and
/// end-to-end FastKMeans++ cost vs tree count (paper fixes 3).
fn ablation_trees() -> fastkmeanspp::error::Result<()> {
    println!("== ablation: number of trees in the multi-tree embedding ==\n");
    let ps = gaussian_mixture(
        &SynthSpec {
            n: 20_000,
            d: 24,
            k_true: 100,
            center_spread: 12.0,
            ..Default::default()
        },
        7,
    );
    println!("| trees | median sq-distortion | init seconds | FastKMeans++ cost (k=100) |");
    println!("|---|---|---|---|");
    for trees in [1usize, 2, 3, 5, 8] {
        let mut rng = Pcg64::seed_from(100 + trees as u64);
        let t0 = Instant::now();
        let mt = MultiTree::init(&ps, &MultiTreeConfig { num_trees: trees }, &mut rng);
        let init_secs = t0.elapsed().as_secs_f64();
        // distortion over random pairs
        let mut ratios = Vec::new();
        for _ in 0..3000 {
            let (i, j) = (rng.index(ps.len()), rng.index(ps.len()));
            let dd = d2(ps.row(i), ps.row(j)) as f64;
            if dd > 0.0 {
                let md = mt.multi_tree_dist(i, j);
                ratios.push(md * md / dd);
            }
        }
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = ratios[ratios.len() / 2];
        // end-to-end cost
        let cfg = fastkmeanspp::seeding::fastkmeanspp::FastConfig {
            multitree: MultiTreeConfig { num_trees: trees },
        };
        let mut cost = 0.0;
        for rep in 0..3u64 {
            let mut r = Pcg64::seed_from(200 + rep);
            let s = fastkmeanspp::seeding::fastkmeanspp::fast_kmeanspp(&ps, 100, &cfg, &mut r);
            cost += cost_native(&ps, &s.centers) / 3.0;
        }
        println!("| {trees} | {median:.0} | {init_secs:.3} | {cost:.4e} |");
    }
    println!("\nShape: distortion drops steeply 1->3 trees then flattens; init cost is\nlinear in trees — 3 is the sweet spot the paper picked.");
    Ok(())
}

/// `c` ablation: Lemma 5.3 (proposals ∝ c^2) vs Theorem 5.4 (cost ∝ c^6
/// in the worst case; flat in practice until the oracle's error exceeds c).
fn ablation_lsh_c() -> fastkmeanspp::error::Result<()> {
    println!("== ablation: rejection-sampling approximation factor c ==\n");
    let ps = gaussian_mixture(
        &SynthSpec {
            n: 20_000,
            d: 48,
            k_true: 100,
            center_spread: 12.0,
            ..Default::default()
        },
        9,
    );
    let k = 200;
    println!("| c | proposals/center | seconds | seeding cost |");
    println!("|---|---|---|---|");
    for &c in &[1.1f32, 1.25, 1.5, 2.0, 3.0] {
        let cfg = RejectionConfig {
            c,
            ..Default::default()
        };
        let mut props = 0u64;
        let mut secs = 0.0;
        let mut cost = 0.0;
        for rep in 0..3u64 {
            let mut r = Pcg64::seed_from(300 + rep);
            let t0 = Instant::now();
            let s = rejection_sampling(&ps, k, &cfg, &mut r);
            secs += t0.elapsed().as_secs_f64() / 3.0;
            props += s.stats.proposals;
            cost += cost_native(&ps, &s.centers) / 3.0;
        }
        println!(
            "| {c} | {:.0} | {secs:.3} | {cost:.4e} |",
            props as f64 / (3 * k) as f64
        );
    }
    println!("\nShape: proposals/center grows ~c^2 (Lemma 5.3); cost stays flat while\nthe LSH error remains within c, then degrades (Theorem 5.4's c^6 is worst-case).");
    Ok(())
}
