//! Regenerates the paper's **Tables 1–3** (running time of each seeding
//! algorithm divided by FASTK-MEANS++'s, per dataset) plus the
//! Lemma-5.3 rejection-loop diagnostics.
//!
//! ```bash
//! cargo bench --bench table_runtime                      # all 3 tables, scaled profile
//! cargo bench --bench table_runtime -- --table 1         # KDD only
//! cargo bench --bench table_runtime -- --profile smoke --reps 2
//! cargo bench --bench table_runtime -- --profile paper   # full-size n (slow!)
//! ```
//!
//! Absolute times are machine-specific; the table reports *ratios*, the
//! same normalization the paper uses. Expected shape: K-MEANS++ and
//! AFKMC2 ratios grow ~linearly in k (order of magnitude at the top of
//! the grid), REJECTIONSAMPLING stays within a small factor of 1.

use fastkmeanspp::cli::Args;
use fastkmeanspp::coordinator::config::{bench_default_k_grid, k_grid_for, ExperimentConfig};
use fastkmeanspp::coordinator::{run_grid, tables};
use fastkmeanspp::data::registry::{DatasetId, Profile};
use fastkmeanspp::seeding::SeedingAlgorithm;

fn main() -> fastkmeanspp::error::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&std::iter::once("bench".to_string()).chain(argv).collect::<Vec<_>>())?;

    let profile = Profile::parse(args.get("profile").unwrap_or("scaled"))?;
    let datasets: Vec<DatasetId> = match args.get("table") {
        Some(t) => {
            let t: u8 = t.parse()?;
            vec![DatasetId::all()
                .into_iter()
                .find(|d| d.runtime_table() == t)
                .ok_or_else(|| fastkmeanspp::anyhow!("runtime tables are 1..3"))?]
        }
        None => DatasetId::all().to_vec(),
    };

    let mut cfg = ExperimentConfig {
        datasets: datasets.clone(),
        profile,
        // Runtime tables: the four timed algorithms (uniform is excluded
        // by the paper here; it appears in the cost tables).
        algorithms: vec![
            SeedingAlgorithm::FastKMeansPP,
            SeedingAlgorithm::Rejection,
            SeedingAlgorithm::KMeansPP,
            SeedingAlgorithm::Afkmc2,
        ],
        // Default 2 reps: runtime *ratios* are stable across reps, and the
        // Θ(ndk)/Θ(mk^2 d) baselines dominate the bench budget (pass
        // --reps 5 to match the paper's repetition count exactly).
        reps: args.get_usize("reps", 2)?,
        seed: args.get_u64("seed", 42)?,
        ..Default::default()
    };
    let min_n = datasets.iter().map(|d| d.n(profile)).min().unwrap();
    cfg.ks = match args.get("ks") {
        Some(ks) => ks.split(',').map(|s| s.parse().unwrap()).collect(),
        None => {
            let g = if args.get("full").is_some() {
                k_grid_for(min_n) // the paper's complete grid
            } else {
                bench_default_k_grid(min_n)
            };
            if g.is_empty() {
                vec![50, 150]
            } else {
                g
            }
        }
    };

    eprintln!(
        "table_runtime: profile={} ks={:?} reps={}",
        profile.name(),
        cfg.ks,
        cfg.reps
    );
    let t0 = std::time::Instant::now();
    let res = run_grid(&cfg, |line| eprintln!("  [{:7.1}s] {line}", t0.elapsed().as_secs_f64()))?;

    for &ds in &datasets {
        println!("{}", tables::runtime_table(&res, ds, &cfg.ks));
        println!("{}", tables::rejection_diagnostics(&res, ds, &cfg.ks));
        // Raw seconds appendix (not in the paper; useful for EXPERIMENTS.md).
        println!("raw seconds ({}):", ds.name());
        for &algo in &cfg.algorithms {
            print!("  {:<18}", algo.paper_name());
            for &k in &cfg.ks {
                match res.get(ds, algo, k) {
                    Some(c) => print!(" {:>9.3}", c.seconds.mean()),
                    None => print!(" {:>9}", "—"),
                }
            }
            println!();
        }
        println!();
    }
    Ok(())
}
