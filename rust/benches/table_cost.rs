//! Regenerates the paper's **Tables 4–6** (seeding costs, scaled per
//! dataset) and **Tables 7–8** (variance of the costs over repetitions).
//!
//! ```bash
//! cargo bench --bench table_cost                       # tables 4-8, scaled profile
//! cargo bench --bench table_cost -- --table 4          # KDD costs only
//! cargo bench --bench table_cost -- --profile smoke --reps 3
//! ```
//!
//! Expected shape (synthetic stand-ins; DESIGN.md §2): FASTK-MEANS++ and
//! REJECTIONSAMPLING within ~0-15% of K-MEANS++ (worst at small k);
//! UNIFORMSAMPLING far worse on the clustered/heavy-tailed kdd_sim; all
//! D^2-family variances well below uniform's (Tables 7-8).

use fastkmeanspp::cli::Args;
use fastkmeanspp::coordinator::config::{bench_default_k_grid, k_grid_for, ExperimentConfig};
use fastkmeanspp::coordinator::{run_grid, tables};
use fastkmeanspp::data::registry::{DatasetId, Profile};

fn main() -> fastkmeanspp::error::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&std::iter::once("bench".to_string()).chain(argv).collect::<Vec<_>>())?;

    let profile = Profile::parse(args.get("profile").unwrap_or("scaled"))?;
    let (datasets, which): (Vec<DatasetId>, Vec<u8>) = match args.get("table") {
        Some(t) => {
            let t: u8 = t.parse()?;
            let ds = match t {
                4 | 8 => DatasetId::KddSim,
                5 | 7 => DatasetId::SongSim,
                6 => DatasetId::CensusSim,
                _ => fastkmeanspp::bail!("cost/variance tables are 4..8"),
            };
            (vec![ds], vec![t])
        }
        None => (DatasetId::all().to_vec(), vec![4, 5, 6, 7, 8]),
    };

    let mut cfg = ExperimentConfig {
        datasets: datasets.clone(),
        profile,
        // Cost tables include UNIFORMSAMPLING (paper algorithm order).
        // Paper: 5 runs. Default 3 keeps the default `cargo bench` within
        // a CI-scale budget (the AFK-MC2 baseline is Θ(mk^2 d) per rep);
        // pass --reps 5 for the paper's exact protocol.
        reps: args.get_usize("reps", 3)?,
        seed: args.get_u64("seed", 42)?,
        ..Default::default()
    };
    let min_n = datasets.iter().map(|d| d.n(profile)).min().unwrap();
    cfg.ks = match args.get("ks") {
        Some(ks) => ks.split(',').map(|s| s.parse().unwrap()).collect(),
        None => {
            let g = if args.get("full").is_some() {
                k_grid_for(min_n) // the paper's complete grid
            } else {
                bench_default_k_grid(min_n)
            };
            if g.is_empty() {
                vec![50, 150]
            } else {
                g
            }
        }
    };

    eprintln!(
        "table_cost: profile={} ks={:?} reps={}",
        profile.name(),
        cfg.ks,
        cfg.reps
    );
    let t0 = std::time::Instant::now();
    let res = run_grid(&cfg, |line| eprintln!("  [{:7.1}s] {line}", t0.elapsed().as_secs_f64()))?;

    for &t in &which {
        match t {
            4 | 5 | 6 => {
                let ds = datasets.iter().find(|d| d.cost_table() == t).unwrap();
                println!("{}", tables::cost_table(&res, *ds, &cfg.ks));
            }
            7 => println!(
                "{}",
                tables::variance_table(&res, DatasetId::SongSim, &cfg.ks)
            ),
            8 => println!(
                "{}",
                tables::variance_table(&res, DatasetId::KddSim, &cfg.ks)
            ),
            _ => {}
        }
    }
    Ok(())
}
