//! PJRT-vs-native throughput for the dense entry points (`cost`,
//! `assign`, `lloyd_step`, `d2_update`) — the L1/L2 artifacts against
//! the tuned rust kernels on identical inputs — plus the kernel-engine
//! section (`--kernels-only`):
//!
//! * **kernels v1 vs v2**: the naive direct-distance loops against the
//!   blocked norm-trick loops, single thread, at the acceptance shape
//!   n = 100k, d = 128, k = 64 (plus d = 16 in full mode). The measured
//!   cells are written as `BENCH_kernels.json` (the `grid_json`-shaped
//!   perf-trajectory artifact, via `coordinator/tables.rs::kernels_json`);
//! * the **kernel thread-scaling table**: `d2_update_min` /
//!   `assign_argmin` / `cost` at 1/2/4/8 threads for d in {16, 128} on
//!   n = 100k (the shapes the paper's Tables 1–3 runtimes are built
//!   from), through the autotuned dispatch as shipped.
//!
//! ```bash
//! cargo bench --bench micro_runtime
//! cargo bench --bench micro_runtime -- --n 100000 --k 512
//! cargo bench --bench micro_runtime -- --kernels-only
//! cargo bench --bench micro_runtime -- --kernels-only --short --reps 2  # CI smoke
//! cargo bench --bench micro_runtime -- --shard-only                     # k-means‖ table
//! cargo bench --bench micro_runtime -- --rejection-only                 # oracle sweep
//! cargo bench --bench micro_runtime -- --dist-only                      # transport seam
//! ```
//!
//! `--kernels-only` flags: `--short` (headline shape only, skip the
//! scaling table), `--json <path>` (artifact path, default
//! `BENCH_kernels.json`), `--seed <u64>`.
//!
//! `--shard-only`: k-means‖ (shards ∈ {1,4,8}) vs exact k-means++ vs
//! fastkmeans++ seeding wall-clock at n=100k, d=128, k=64 (`--short`:
//! n=20k, d=64), written as `BENCH_shard.json` via
//! `coordinator/tables.rs::shard_json`. Same `--json`/`--seed`/`--reps`
//! flags.
//!
//! `--rejection-only`: Algorithm 4 with each ANN oracle (exact / lsh /
//! lsh-rigorous) at n=100k, d=128, k ∈ {64, 1000} (`--short`: n=20k,
//! d=64, k=150 — above PREFIX_CAP so the smoke rows exercise real bucket
//! probes), written as `BENCH_rejection.json` via
//! `coordinator/tables.rs::rejection_json`. Same flags.
//!
//! `--dist-only`: k-means‖ through the in-process `RoundExecutor`
//! (workers = 0) vs 2 real `fkmpp worker` subprocesses over localhost,
//! at n=100k, d=64, k=32 (`--short`: n=20k, d=32, k=16), written as
//! `BENCH_dist.json` via `coordinator/tables.rs::dist_json`. Every rep
//! asserts the two transports pick byte-identical centers, so the bench
//! doubles as a cross-process parity smoke. Same flags. Pins
//! `FKMPP_KERNEL=blocked` (inherited by the workers) — a precondition
//! for cross-process bit-parity.
//!
//! The PJRT section skips (with a note) when `artifacts/` is missing or
//! the `pjrt` feature is off. The useful output is points/second per
//! entry point; on this CPU-only image the native path typically wins
//! (PJRT pays per-call literal copies) — the PJRT numbers are the
//! integration-fidelity check, and the real accelerator story is the
//! DESIGN.md §Hardware-Adaptation estimate.

use std::time::Instant;

use fastkmeanspp::cli::Args;
use fastkmeanspp::coordinator::tables::{
    dist_json, kernels_json, rejection_json, shard_json, DistCell, KernelCell, RejectionCell,
    ShardCell,
};
use fastkmeanspp::data::matrix::PointSet;
use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::error::Context;
use fastkmeanspp::kernels;
use fastkmeanspp::metrics::Stats;
use fastkmeanspp::rng::Pcg64;
use fastkmeanspp::runtime::{native, pjrt::PjrtRuntime};
use fastkmeanspp::seeding::Seeding;
use fastkmeanspp::shard::kmeanspar::{kmeans_par, KMeansParConfig};

/// Wall-clock `Stats` over `reps` calls of `f` (one warmup call first).
fn time_reps(reps: usize, mut f: impl FnMut()) -> Stats {
    f();
    let mut s = Stats::new();
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        s.push(t0.elapsed().as_secs_f64());
    }
    s
}

/// Kernels v1 vs v2, single thread — the ISSUE 3 acceptance table
/// (>= 1.5x for `assign_argmin` at n=100k, d=128, k=64). Returns the
/// measured cells for the `BENCH_kernels.json` artifact.
fn kernels_v2_compare(reps: usize, short: bool, seed: u64) -> Vec<KernelCell> {
    std::env::set_var("FKMPP_THREADS", "1");
    let shapes: &[(usize, usize, usize)] = if short {
        &[(100_000, 128, 64)]
    } else {
        &[(100_000, 16, 64), (100_000, 128, 64)]
    };
    let mut cells = Vec::new();
    println!("\n== kernels v2 (blocked norm-trick) vs v1 (naive), 1 thread ==\n");
    println!("| kernel | n | d | k | v1 s | v2 s | speedup |");
    println!("|---|---|---|---|---|---|---|");
    for &(n, d, k) in shapes {
        let ps = gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: k,
                ..Default::default()
            },
            seed,
        );
        let centers = ps.gather(&(0..k).map(|j| j * (n / k)).collect::<Vec<_>>());
        let pn = kernels::norms::squared_norms(&ps);
        let cn = kernels::norms::squared_norms(&centers);
        let center = ps.row(0).to_vec();
        let mut buf = vec![f32::INFINITY; n];
        let dataset = format!("synth_n{n}_d{d}");

        let mut record = |name: &str, v1: Stats, v2: Stats| {
            let speedup = v1.mean() / v2.mean();
            println!(
                "| {name} | {n} | {d} | {k} | {:.4} | {:.4} | {speedup:.2}x |",
                v1.mean(),
                v2.mean()
            );
            cells.push(KernelCell {
                dataset: dataset.clone(),
                algorithm: format!("{name}_v1_naive"),
                k,
                seconds: v1,
                speedup_vs_naive: 1.0,
            });
            cells.push(KernelCell {
                dataset: dataset.clone(),
                algorithm: format!("{name}_v2_blocked"),
                k,
                seconds: v2,
                speedup_vs_naive: speedup,
            });
        };

        let v1 = time_reps(reps, || {
            kernels::d2::d2_update_min(&ps, &center, &mut buf);
        });
        let v2 = time_reps(reps, || {
            kernels::blocked::d2_update_min_blocked(&ps, &center, &pn, &mut buf);
        });
        record("d2_update_min", v1, v2);

        let v1 = time_reps(reps, || {
            std::hint::black_box(kernels::assign::assign_argmin_naive(&ps, &centers));
        });
        let v2 = time_reps(reps, || {
            let r = kernels::blocked::assign_argmin_blocked(&ps, &pn, &centers, &cn);
            std::hint::black_box(r);
        });
        record("assign_argmin", v1, v2);

        let v1 = time_reps(reps, || {
            std::hint::black_box(kernels::reduce::cost_naive(&ps, &centers));
        });
        std::env::set_var("FKMPP_KERNEL", "blocked");
        let v2 = time_reps(reps, || {
            let c = kernels::reduce::cost_cached(&ps, Some(&pn), &centers, Some(&cn));
            std::hint::black_box(c);
        });
        std::env::remove_var("FKMPP_KERNEL");
        record("cost", v1, v2);
    }
    std::env::remove_var("FKMPP_THREADS");
    cells
}

/// Sharded seeding wall-clock (`--shard-only`): k-means‖ at shards ∈
/// {1, 4, 8} against the exact k-means++ and fastkmeans++ baselines at
/// the acceptance shape n=100k, d=128, k=64 (`--short` shrinks to
/// n=20k, d=64 for CI smoke). Threads stay at the ambient
/// `FKMPP_THREADS` — the point of this table is the sharded engine's
/// behavior under real parallelism. Cells land in `BENCH_shard.json`
/// (the `grid_json`-shaped artifact, `tables::shard_json`).
fn shard_compare(reps: usize, short: bool, seed: u64) -> Vec<ShardCell> {
    let (n, d, k) = if short {
        (20_000, 64, 64)
    } else {
        (100_000, 128, 64)
    };
    let ps = gaussian_mixture(
        &SynthSpec {
            n,
            d,
            k_true: k,
            ..Default::default()
        },
        seed,
    );
    let dataset = format!("synth_n{n}_d{d}");
    let mut cells: Vec<ShardCell> = Vec::new();
    println!(
        "\n== sharded seeding: kmeans-par vs kmeans++ vs fastkmeans++ \
         (n={n}, d={d}, k={k}, threads={}) ==\n",
        fastkmeanspp::parallel::num_threads()
    );
    println!("| algorithm | shards | mean s | min s | mean cost |");
    println!("|---|---|---|---|---|");

    fn bench_seeder(
        ps: &PointSet,
        reps: usize,
        seed: u64,
        f: &dyn Fn(&PointSet, &mut Pcg64) -> Seeding,
    ) -> (Stats, Stats) {
        let mut secs = Stats::new();
        let mut cost = Stats::new();
        for rep in 0..reps.max(1) {
            let mut rng = Pcg64::seed_from(seed.wrapping_add(rep as u64));
            let t0 = Instant::now();
            let s = f(ps, &mut rng);
            secs.push(t0.elapsed().as_secs_f64());
            cost.push(kernels::reduce::cost(ps, &s.centers));
        }
        (secs, cost)
    }

    let mut record = |name: String, shards: usize, secs: Stats, cost: Stats| {
        println!(
            "| {name} | {shards} | {:.4} | {:.4} | {:.4e} |",
            secs.mean(),
            secs.min(),
            cost.mean()
        );
        cells.push(ShardCell {
            dataset: dataset.clone(),
            algorithm: name,
            k,
            shards,
            seconds: secs,
            cost,
        });
    };

    for &shards in &[1usize, 4, 8] {
        let cfg = KMeansParConfig {
            shards,
            ..Default::default()
        };
        let (secs, cost) = bench_seeder(&ps, reps, seed, &|ps, rng| kmeans_par(ps, k, &cfg, rng));
        record(format!("kmeans-par_s{shards}"), shards, secs, cost);
    }
    let (secs, cost) = bench_seeder(&ps, reps, seed, &|ps, rng| {
        fastkmeanspp::seeding::kmeanspp::kmeanspp(ps, k, rng)
    });
    record("kmeanspp".to_string(), 1, secs, cost);
    let (secs, cost) = bench_seeder(&ps, reps, seed, &|ps, rng| {
        fastkmeanspp::seeding::fastkmeanspp::fast_kmeanspp(ps, k, &Default::default(), rng)
    });
    record("fastkmeanspp".to_string(), 1, secs, cost);
    cells
}

/// Rejection-oracle sweep (`--rejection-only`): Algorithm 4 timed with
/// each ANN oracle — exact linear scan (the `Ω(k²)` ablation) vs
/// practical single-scale LSH vs rigorous multi-scale LSH — at
/// n=100k, d=128, k ∈ {64, 1000} (`--short`: n=20k, d=64, k=150 for CI
/// smoke — past PREFIX_CAP so bucket probes are actually on the path).
/// Cost and proposals-per-center ride along so the speed/quality
/// trade-off the oracle buys is visible in one table. Cells land in
/// `BENCH_rejection.json` (`grid_json`-shaped, `tables::rejection_json`;
/// cells add `oracle`).
fn rejection_compare(reps: usize, short: bool, seed: u64) -> Vec<RejectionCell> {
    use fastkmeanspp::seeding::rejection::{rejection_sampling, OracleKind, RejectionConfig};
    // Short mode keeps n/d CI-sized but pins k = 150 > PREFIX_CAP (128):
    // below the cap every oracle answers from the exact insertion prefix
    // and the three rows would measure one configuration.
    let (n, d, ks): (usize, usize, &[usize]) = if short {
        (20_000, 64, &[150])
    } else {
        (100_000, 128, &[64, 1000])
    };
    let ps = gaussian_mixture(
        &SynthSpec {
            n,
            d,
            k_true: 64,
            ..Default::default()
        },
        seed,
    );
    let dataset = format!("synth_n{n}_d{d}");
    let mut cells: Vec<RejectionCell> = Vec::new();
    println!(
        "\n== rejection sampling: exact vs lsh vs lsh-rigorous oracle \
         (n={n}, d={d}, threads={}) ==\n",
        fastkmeanspp::parallel::num_threads()
    );
    println!("| oracle | k | mean s | min s | mean cost | proposals/center |");
    println!("|---|---|---|---|---|---|");
    for &k in ks {
        for oracle in OracleKind::all() {
            let cfg = RejectionConfig {
                oracle,
                ..Default::default()
            };
            let mut secs = Stats::new();
            let mut cost = Stats::new();
            let mut ppc = Stats::new();
            for rep in 0..reps.max(1) {
                let mut rng = Pcg64::seed_from(seed.wrapping_add(rep as u64));
                let t0 = Instant::now();
                let s = rejection_sampling(&ps, k, &cfg, &mut rng);
                secs.push(t0.elapsed().as_secs_f64());
                cost.push(kernels::reduce::cost(&ps, &s.centers));
                ppc.push(s.stats.proposals as f64 / k.max(1) as f64);
            }
            println!(
                "| {} | {k} | {:.4} | {:.4} | {:.4e} | {:.2} |",
                oracle.name(),
                secs.mean(),
                secs.min(),
                cost.mean(),
                ppc.mean()
            );
            cells.push(RejectionCell {
                dataset: dataset.clone(),
                algorithm: "rejection".to_string(),
                oracle: oracle.name().to_string(),
                k,
                seconds: secs,
                cost,
                proposals_per_center: ppc,
            });
        }
    }
    cells
}

/// One `fkmpp worker --port 0` subprocess for `--dist-only`; killed on
/// drop so a panicking parity assert can't leak processes.
struct WorkerProc {
    child: std::process::Child,
    addr: String,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn a worker on an ephemeral port and parse its ready line
/// (`[worker] listening on http://ADDR`).
fn spawn_worker() -> fastkmeanspp::error::Result<WorkerProc> {
    use std::io::BufRead;
    let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_fkmpp"))
        .args(["worker", "--port", "0"])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .context("spawn fkmpp worker")?;
    let stdout = child.stdout.take().context("worker stdout")?;
    let mut reader = std::io::BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).context("worker ready line")?;
    let addr = line
        .rsplit("http://")
        .next()
        .context("worker ready line")?
        .trim()
        .to_string();
    // Keep draining stdout so the worker never blocks on a full pipe.
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(b) if b > 0) {
            sink.clear();
        }
    });
    Ok(WorkerProc { child, addr })
}

/// Distributed-fit transport seam (`--dist-only`): the identical
/// k-means‖ configuration timed through the in-process executor and
/// through 2 worker subprocesses. Beyond the timings, every rep asserts
/// byte-identical center indices across the seam — the cheap standing
/// guard that `BENCH_dist.json` numbers always compare like with like.
fn dist_compare(reps: usize, short: bool, seed: u64) -> fastkmeanspp::error::Result<Vec<DistCell>> {
    use fastkmeanspp::dist::{kmeans_par_dist, DistConfig};
    // Worker subprocesses inherit the environment; pinning the kernel on
    // both sides of the seam is a precondition for bit-parity (the
    // autotuner may otherwise probe to different kernels per process).
    std::env::set_var("FKMPP_KERNEL", "blocked");
    let (n, d, k) = if short {
        (20_000, 32, 16)
    } else {
        (100_000, 64, 32)
    };
    let rounds = 3;
    let oversample = 2.0;
    let ps = gaussian_mixture(
        &SynthSpec {
            n,
            d,
            k_true: k,
            ..Default::default()
        },
        seed,
    );
    let dataset = format!("synth_n{n}_d{d}");
    let mut cells: Vec<DistCell> = Vec::new();
    println!(
        "\n== distributed fit: in-process executor vs 2 worker processes \
         (n={n}, d={d}, k={k}, threads={}) ==\n",
        fastkmeanspp::parallel::num_threads()
    );
    println!("| algorithm | workers | mean s | min s | mean cost |");
    println!("|---|---|---|---|---|");

    // In-process row (workers = 0): LocalShardExecutor behind the same
    // RoundExecutor driver the coordinator uses.
    let lcfg = KMeansParConfig {
        shards: 2,
        rounds,
        oversample,
    };
    let mut local_secs = Stats::new();
    let mut local_cost = Stats::new();
    let mut local_indices: Vec<Vec<usize>> = Vec::new();
    for rep in 0..reps.max(1) {
        let mut rng = Pcg64::seed_from(seed.wrapping_add(rep as u64));
        let t0 = Instant::now();
        let s = kmeans_par(&ps, k, &lcfg, &mut rng);
        local_secs.push(t0.elapsed().as_secs_f64());
        local_cost.push(kernels::reduce::cost(&ps, &s.centers));
        local_indices.push(s.indices);
    }
    println!(
        "| kmeans-par | 0 | {:.4} | {:.4} | {:.4e} |",
        local_secs.mean(),
        local_secs.min(),
        local_cost.mean()
    );
    cells.push(DistCell {
        dataset: dataset.clone(),
        algorithm: "kmeans-par".to_string(),
        k,
        workers: 0,
        seconds: local_secs,
        cost: local_cost,
    });

    // 2-process row: real `fkmpp worker` subprocesses over localhost.
    let workers = [spawn_worker()?, spawn_worker()?];
    let dcfg = DistConfig {
        workers: workers.iter().map(|w| w.addr.clone()).collect(),
        rounds,
        oversample,
        ..DistConfig::default()
    };
    let mut secs = Stats::new();
    let mut cost = Stats::new();
    for rep in 0..reps.max(1) {
        let mut rng = Pcg64::seed_from(seed.wrapping_add(rep as u64));
        let t0 = Instant::now();
        let s = kmeans_par_dist(&ps, k, &dcfg, &mut rng)?;
        secs.push(t0.elapsed().as_secs_f64());
        cost.push(kernels::reduce::cost(&ps, &s.centers));
        assert_eq!(
            s.indices, local_indices[rep],
            "distributed rep {rep} diverged from the in-process run"
        );
    }
    println!(
        "| kmeans-par_w2 | 2 | {:.4} | {:.4} | {:.4e} |",
        secs.mean(),
        secs.min(),
        cost.mean()
    );
    cells.push(DistCell {
        dataset,
        algorithm: "kmeans-par_w2".to_string(),
        k,
        workers: 2,
        seconds: secs,
        cost,
    });
    drop(workers);
    std::env::remove_var("FKMPP_KERNEL");
    Ok(cells)
}

/// Kernel thread-scaling: the acceptance shape for the kernel engine is
/// >1.5x at 4 threads on n=100k, d=128; the table prints the measured
/// speedup per (kernel, d, threads) cell so regressions are visible in
/// the bench log.
fn kernel_scaling(reps: usize) {
    let n = 100_000;
    let k = 64;
    println!("\n== kernel engine: thread scaling (n={n}, k={k}) ==\n");
    println!("| kernel | d | threads | seconds | Mpoints/s | speedup vs 1T |");
    println!("|---|---|---|---|---|---|");
    for &d in &[16usize, 128] {
        let ps = gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: k,
                ..Default::default()
            },
            7,
        );
        let centers = ps.gather(&(0..k).map(|j| j * (n / k)).collect::<Vec<_>>());
        let center = ps.row(0).to_vec();
        let mut buf = vec![f32::INFINITY; n];
        let mut base = [0.0f64; 3];
        for &threads in &[1usize, 2, 4, 8] {
            std::env::set_var("FKMPP_THREADS", threads.to_string());
            for (slot, name) in ["d2_update_min", "assign_argmin", "cost"].iter().enumerate() {
                // No per-rep buf reset: d2_update_min computes every
                // distance regardless of the current min, so timing is
                // state-independent and the serial fill would only skew
                // the high-thread-count speedup numbers.
                let mut run = |slot: usize| match slot {
                    0 => {
                        kernels::d2::d2_update_min(&ps, &center, &mut buf);
                    }
                    1 => {
                        std::hint::black_box(kernels::assign::assign_argmin(&ps, &centers));
                    }
                    _ => {
                        std::hint::black_box(kernels::reduce::cost(&ps, &centers));
                    }
                };
                run(slot); // warmup
                let t0 = Instant::now();
                for _ in 0..reps {
                    run(slot);
                }
                let secs = t0.elapsed().as_secs_f64() / reps as f64;
                if threads == 1 {
                    base[slot] = secs;
                }
                println!(
                    "| {name} | {d} | {threads} | {secs:.4} | {:.2} | {:.2}x |",
                    n as f64 / secs / 1e6,
                    base[slot] / secs
                );
            }
        }
        std::env::remove_var("FKMPP_THREADS");
    }
}

fn main() -> fastkmeanspp::error::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let args = Args::parse(&std::iter::once("bench".to_string()).chain(argv).collect::<Vec<_>>())?;
    let n = args.get_usize("n", 65_536)?;
    let k = args.get_usize("k", 256)?;
    let d = args.get_usize("d", 74)?;
    let reps = args.get_usize("reps", 5)?;

    if args.get("shard-only").is_some() {
        let short = args.get("short").is_some();
        let seed = args.get_u64("seed", 7)?;
        let cells = shard_compare(reps, short, seed);
        let path = args.get("json").unwrap_or("BENCH_shard.json");
        let doc = shard_json(&cells, reps, seed, fastkmeanspp::parallel::num_threads());
        std::fs::write(path, doc.emit() + "\n").with_context(|| format!("write {path}"))?;
        println!("\nwrote {path}");
        return Ok(());
    }

    if args.get("dist-only").is_some() {
        let short = args.get("short").is_some();
        let seed = args.get_u64("seed", 7)?;
        let cells = dist_compare(reps, short, seed)?;
        let path = args.get("json").unwrap_or("BENCH_dist.json");
        let doc = dist_json(&cells, reps, seed, fastkmeanspp::parallel::num_threads());
        std::fs::write(path, doc.emit() + "\n").with_context(|| format!("write {path}"))?;
        println!("\nwrote {path}");
        return Ok(());
    }

    if args.get("rejection-only").is_some() {
        let short = args.get("short").is_some();
        let seed = args.get_u64("seed", 7)?;
        let cells = rejection_compare(reps, short, seed);
        let path = args.get("json").unwrap_or("BENCH_rejection.json");
        let doc = rejection_json(&cells, reps, seed, fastkmeanspp::parallel::num_threads());
        std::fs::write(path, doc.emit() + "\n").with_context(|| format!("write {path}"))?;
        println!("\nwrote {path}");
        return Ok(());
    }

    if args.get("kernels-only").is_some() {
        let short = args.get("short").is_some();
        let seed = args.get_u64("seed", 7)?;
        let cells = kernels_v2_compare(reps, short, seed);
        if !short {
            kernel_scaling(reps);
        }
        let path = args.get("json").unwrap_or("BENCH_kernels.json");
        let doc = kernels_json(&cells, reps, seed, 1);
        std::fs::write(path, doc.emit() + "\n").with_context(|| format!("write {path}"))?;
        println!("\nwrote {path}");
        return Ok(());
    }

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = match PjrtRuntime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("note: PJRT unavailable ({e:#}) — native only");
            None
        }
    };

    let ps = gaussian_mixture(
        &SynthSpec {
            n,
            d,
            k_true: 64,
            ..Default::default()
        },
        1,
    );
    let mut rng = Pcg64::seed_from(2);
    let centers = ps.gather(&(0..k).map(|_| rng.index(n)).collect::<Vec<_>>());
    println!("n={n} d={d} k={k} reps={reps}\n");
    println!("| entry point | backend | seconds | Mpoints/s |");
    println!("|---|---|---|---|");

    let mut report = |name: &str, backend: &str, secs: f64| {
        println!(
            "| {name} | {backend} | {:.4} | {:.2} |",
            secs,
            n as f64 / secs / 1e6
        );
    };

    // cost
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(native::cost(&ps, &centers));
    }
    report("cost", "native", t0.elapsed().as_secs_f64() / reps as f64);
    if let Some(rt) = &rt {
        rt.cost(&ps, &centers)?; // compile outside the timer
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(rt.cost(&ps, &centers)?);
        }
        report("cost", "pjrt", t0.elapsed().as_secs_f64() / reps as f64);
    }

    // assign
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(native::assign(&ps, &centers));
    }
    report("assign", "native", t0.elapsed().as_secs_f64() / reps as f64);
    if let Some(rt) = &rt {
        rt.assign(&ps, &centers)?;
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(rt.assign(&ps, &centers)?);
        }
        report("assign", "pjrt", t0.elapsed().as_secs_f64() / reps as f64);
    }

    // lloyd_step
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(native::lloyd_step(&ps, &centers));
    }
    report("lloyd_step", "native", t0.elapsed().as_secs_f64() / reps as f64);
    if let Some(rt) = &rt {
        rt.lloyd_step(&ps, &centers)?;
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(rt.lloyd_step(&ps, &centers)?);
        }
        report("lloyd_step", "pjrt", t0.elapsed().as_secs_f64() / reps as f64);
    }

    // d2_update
    let center = ps.row(0).to_vec();
    let mut buf = vec![f32::INFINITY; n];
    let t0 = Instant::now();
    for _ in 0..reps {
        fastkmeanspp::seeding::kmeanspp::update_d2_parallel(&ps, 0, &mut buf);
    }
    report("d2_update", "native", t0.elapsed().as_secs_f64() / reps as f64);
    if let Some(rt) = &rt {
        rt.d2_update(&ps, &center, &mut buf)?;
        let t0 = Instant::now();
        for _ in 0..reps {
            rt.d2_update(&ps, &center, &mut buf)?;
        }
        report("d2_update", "pjrt", t0.elapsed().as_secs_f64() / reps as f64);
    }

    kernel_scaling(reps);

    Ok(())
}
