//! The *sample-tree* (paper §4): a node-weighted balanced binary tree with
//! one leaf per point, supporting
//!
//! * `update(i, w)` — set leaf `i`'s weight, `O(log n)`;
//! * `sample(rng)` — draw a leaf with probability `w_i / Σ w`, `O(log n)`
//!   (Algorithm 2: walk from the root, choosing each child with
//!   probability proportional to its subtree weight);
//! * `total()` — Σ w, `O(1)`.
//!
//! Implemented as an implicit complete binary tree (segment tree) over
//! `n` leaves padded to a power of two; node `v`'s weight is stored in a
//! flat array with children `2v`/`2v+1`. Weights are `f64`: the inputs are
//! squared f32 distances whose sums overflow f32 precision long before n
//! reaches the paper's dataset sizes.

use crate::rng::Pcg64;

/// Weighted balanced binary tree over `n` leaves (invariant 2 of §4:
/// every internal node's weight equals the sum of the weights of the
/// leaves in its subtree).
#[derive(Clone, Debug)]
pub struct SampleTree {
    n: usize,
    /// Number of leaves padded to a power of two.
    base: usize,
    /// 1-indexed heap layout; `tree[1]` is the root, leaves start at `base`.
    tree: Vec<f64>,
}

impl SampleTree {
    /// Build with all weights zero.
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "empty sample tree");
        let base = n.next_power_of_two();
        SampleTree {
            n,
            base,
            tree: vec![0.0; 2 * base],
        }
    }

    /// Build with every leaf at `w` (the `M`-initialization of §4), `O(n)`.
    pub fn with_uniform_weight(n: usize, w: f64) -> Self {
        let mut t = SampleTree::new(n);
        for i in 0..n {
            t.tree[t.base + i] = w;
        }
        // Bottom-up sums in O(base).
        for v in (1..t.base).rev() {
            t.tree[v] = t.tree[2 * v] + t.tree[2 * v + 1];
        }
        t
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Current weight of leaf `i`.
    #[inline]
    pub fn weight(&self, i: usize) -> f64 {
        self.tree[self.base + i]
    }

    /// Total weight (root).
    #[inline]
    pub fn total(&self) -> f64 {
        self.tree[1]
    }

    /// Set leaf `i` to `w`, updating the `O(log n)` ancestors.
    #[inline]
    pub fn update(&mut self, i: usize, w: f64) {
        debug_assert!(i < self.n);
        debug_assert!(w >= 0.0 && w.is_finite(), "weight {w}");
        let mut v = self.base + i;
        let delta = w - self.tree[v];
        if delta == 0.0 {
            return;
        }
        self.tree[v] = w;
        v /= 2;
        while v >= 1 {
            self.tree[v] += delta;
            if v == 1 {
                break;
            }
            v /= 2;
        }
        // Guard against drift pushing a node slightly negative.
        if self.tree[1] < 0.0 {
            self.rebuild();
        }
    }

    /// Recompute all internal sums from the leaves (drift repair), `O(n)`.
    pub fn rebuild(&mut self) {
        for v in (1..self.base).rev() {
            self.tree[v] = self.tree[2 * v] + self.tree[2 * v + 1];
        }
    }

    /// Algorithm 2: sample a leaf proportional to its weight.
    /// Returns `None` when the total weight is zero.
    pub fn sample(&self, rng: &mut Pcg64) -> Option<usize> {
        let total = self.tree[1];
        if !(total > 0.0) {
            return None;
        }
        let mut v = 1usize;
        // Descend: pick left child w.p. w(L)/(w(L)+w(R)).
        let mut target = rng.next_f64() * total;
        while v < self.base {
            let left = self.tree[2 * v];
            if target < left {
                v = 2 * v;
            } else {
                target -= left;
                v = 2 * v + 1;
            }
        }
        let idx = v - self.base;
        if idx >= self.n || self.tree[v] <= 0.0 {
            // Floating-point edge (target landed in padding / a zero leaf
            // due to rounding): resample by scanning to the nearest
            // positive leaf — rare, O(n) worst case, keeps correctness.
            return (0..self.n).find(|&i| self.tree[self.base + i] > 0.0);
        }
        Some(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(t: &SampleTree, draws: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seed_from(seed);
        let mut counts = vec![0usize; t.len()];
        for _ in 0..draws {
            counts[t.sample(&mut rng).unwrap()] += 1;
        }
        counts.iter().map(|&c| c as f64 / draws as f64).collect()
    }

    #[test]
    fn uniform_init_sums() {
        let t = SampleTree::with_uniform_weight(10, 2.5);
        assert!((t.total() - 25.0).abs() < 1e-12);
        for i in 0..10 {
            assert_eq!(t.weight(i), 2.5);
        }
    }

    #[test]
    fn invariant_after_updates() {
        let mut t = SampleTree::with_uniform_weight(13, 1.0);
        let mut rng = Pcg64::seed_from(1);
        for _ in 0..500 {
            let i = rng.index(13);
            t.update(i, rng.next_f64() * 10.0);
        }
        // Invariant 2: every internal node = sum of children.
        for v in 1..t.base {
            let want = t.tree[2 * v] + t.tree[2 * v + 1];
            assert!((t.tree[v] - want).abs() < 1e-6 * want.max(1.0), "node {v}");
        }
        let leaf_sum: f64 = (0..13).map(|i| t.weight(i)).sum();
        assert!((t.total() - leaf_sum).abs() < 1e-9 * leaf_sum.max(1.0));
    }

    #[test]
    fn sampling_distribution_matches_weights() {
        let mut t = SampleTree::new(4);
        for (i, w) in [0.1, 0.0, 0.6, 0.3].iter().enumerate() {
            t.update(i, *w);
        }
        let freq = empirical(&t, 200_000, 2);
        assert!((freq[0] - 0.1).abs() < 0.01);
        assert_eq!(freq[1], 0.0);
        assert!((freq[2] - 0.6).abs() < 0.01);
        assert!((freq[3] - 0.3).abs() < 0.01);
    }

    #[test]
    fn sampling_non_power_of_two() {
        let mut t = SampleTree::new(7);
        for i in 0..7 {
            t.update(i, (i + 1) as f64);
        }
        let freq = empirical(&t, 280_000, 3);
        for i in 0..7 {
            let want = (i + 1) as f64 / 28.0;
            assert!((freq[i] - want).abs() < 0.01, "i={i} got={} want={want}", freq[i]);
        }
    }

    #[test]
    fn zero_total_returns_none() {
        let t = SampleTree::new(5);
        let mut rng = Pcg64::seed_from(4);
        assert_eq!(t.sample(&mut rng), None);
    }

    #[test]
    fn single_positive_leaf_always_sampled() {
        let mut t = SampleTree::new(9);
        t.update(6, 1e-30);
        let mut rng = Pcg64::seed_from(5);
        for _ in 0..100 {
            assert_eq!(t.sample(&mut rng), Some(6));
        }
    }

    #[test]
    fn update_to_zero_removes_mass() {
        let mut t = SampleTree::with_uniform_weight(3, 1.0);
        t.update(0, 0.0);
        t.update(2, 0.0);
        let mut rng = Pcg64::seed_from(6);
        for _ in 0..50 {
            assert_eq!(t.sample(&mut rng), Some(1));
        }
    }

    #[test]
    fn property_random_ops_vs_linear_oracle() {
        // Hand-rolled property test: the O(log n) tree must agree with the
        // weighted linear scan oracle in distribution across many random
        // (size, ops) instances.
        for seed in 0..8u64 {
            let mut rng = Pcg64::seed_from(100 + seed);
            let n = 2 + rng.index(60);
            let mut t = SampleTree::new(n);
            let mut w = vec![0.0f64; n];
            for _ in 0..200 {
                let i = rng.index(n);
                let x = if rng.next_bool(0.2) {
                    0.0
                } else {
                    rng.next_f64() * 5.0
                };
                w[i] = x;
                t.update(i, x);
            }
            let total: f64 = w.iter().sum();
            assert!((t.total() - total).abs() < 1e-9 * total.max(1.0));
            if total > 0.0 {
                // Chi-square-ish agreement on 20k draws.
                let freq = empirical(&t, 20_000, 200 + seed);
                for i in 0..n {
                    let want = w[i] / total;
                    assert!(
                        (freq[i] - want).abs() < 0.025 + want * 0.15,
                        "seed={seed} i={i} got={} want={want}",
                        freq[i]
                    );
                }
            }
        }
    }
}
