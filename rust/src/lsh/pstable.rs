//! p-stable LSH family (Datar, Immorlica, Indyk, Mirrokni 2004).
//!
//! A single hash is `h(p) = floor((a·p + b) / r)` with `a ~ N(0,1)^d`
//! and `b ~ U[0, r)`. For the 2-stable (Gaussian) case the collision
//! probability is a monotone function of `||p-q||_2 / r`, which is all
//! Definition D.1 needs. A *table hash* concatenates `m` such hashes
//! (`f_i(p) = [h_1(p), ..., h_m(p)]`, Appendix D.1); we fold the m-tuple
//! into a single `u64` bucket key with splitmix mixing — a collision of
//! keys is a collision of tuples up to 2^-64 false-positive noise.

use crate::rng::{splitmix64, Pcg64};

/// One m-fold concatenated table hash over d-dimensional points.
#[derive(Clone, Debug)]
pub struct TableHash {
    /// `m x d` Gaussian projection matrix, row-major.
    a: Vec<f32>,
    /// Per-row offset `b in [0, r)`.
    b: Vec<f32>,
    /// Bucket width `r` (the paper's experiments use 10 on quantized data).
    r: f32,
    m: usize,
    d: usize,
}

impl TableHash {
    pub fn new(d: usize, m: usize, r: f32, rng: &mut Pcg64) -> Self {
        assert!(r > 0.0 && m > 0 && d > 0);
        let a = (0..m * d).map(|_| rng.next_gaussian() as f32).collect();
        let b = (0..m).map(|_| rng.next_f32() * r).collect();
        TableHash { a, b, r, m, d }
    }

    #[inline]
    pub fn m(&self) -> usize {
        self.m
    }

    /// Raw m-dimensional integer hash (tests/diagnostics).
    pub fn hash_vec(&self, p: &[f32]) -> Vec<i64> {
        (0..self.m).map(|i| self.hash_row(i, p)).collect()
    }

    #[inline]
    fn hash_row(&self, i: usize, p: &[f32]) -> i64 {
        debug_assert_eq!(p.len(), self.d);
        let row = &self.a[i * self.d..(i + 1) * self.d];
        let mut acc = 0.0f32;
        for (x, y) in row.iter().zip(p) {
            acc += x * y;
        }
        ((acc + self.b[i]) / self.r).floor() as i64
    }

    /// Bucket key: the m-tuple folded into a u64.
    #[inline]
    pub fn bucket(&self, p: &[f32]) -> u64 {
        let mut key = 0x9E37_79B9_7F4A_7C15u64;
        for i in 0..self.m {
            key = splitmix64(key ^ (self.hash_row(i, p) as u64));
        }
        key
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn gauss_vec(d: usize, rng: &mut Pcg64) -> Vec<f32> {
        (0..d).map(|_| rng.next_gaussian() as f32).collect()
    }

    #[test]
    fn bucket_deterministic() {
        let mut rng = Pcg64::seed_from(1);
        let h = TableHash::new(8, 4, 2.0, &mut rng);
        let p = gauss_vec(8, &mut rng);
        assert_eq!(h.bucket(&p), h.bucket(&p));
    }

    #[test]
    fn identical_points_always_collide() {
        let mut rng = Pcg64::seed_from(2);
        let h = TableHash::new(16, 15, 10.0, &mut rng);
        let p = gauss_vec(16, &mut rng);
        let q = p.clone();
        assert_eq!(h.bucket(&p), h.bucket(&q));
    }

    #[test]
    fn near_points_collide_more_than_far_points() {
        // The defining LSH property (Definition D.1), checked empirically
        // over independent hash draws.
        let mut rng = Pcg64::seed_from(3);
        let d = 12;
        let p = gauss_vec(d, &mut rng);
        let mut near = p.clone();
        near[0] += 0.2;
        let mut far = p.clone();
        for v in far.iter_mut() {
            *v += 4.0;
        }
        let trials = 400;
        let mut near_coll = 0;
        let mut far_coll = 0;
        for t in 0..trials {
            let mut hr = Pcg64::seed_from(100 + t);
            let h = TableHash::new(d, 4, 2.0, &mut hr);
            if h.bucket(&p) == h.bucket(&near) {
                near_coll += 1;
            }
            if h.bucket(&p) == h.bucket(&far) {
                far_coll += 1;
            }
        }
        assert!(
            near_coll > far_coll + trials / 10,
            "near={near_coll} far={far_coll}"
        );
    }

    #[test]
    fn hash_vec_consistent_with_bucket() {
        let mut rng = Pcg64::seed_from(4);
        let h = TableHash::new(6, 3, 1.5, &mut rng);
        let p = gauss_vec(6, &mut rng);
        let q = gauss_vec(6, &mut rng);
        if h.hash_vec(&p) == h.hash_vec(&q) {
            assert_eq!(h.bucket(&p), h.bucket(&q));
        }
    }

    #[test]
    fn wider_r_collides_more() {
        let mut rng = Pcg64::seed_from(5);
        let d = 10;
        let p = gauss_vec(d, &mut rng);
        let mut q = p.clone();
        q[3] += 1.0;
        let mut narrow = 0;
        let mut wide = 0;
        for t in 0..300u64 {
            let mut r1 = Pcg64::seed_from(1000 + t);
            let mut r2 = Pcg64::seed_from(1000 + t);
            if TableHash::new(d, 2, 0.5, &mut r1).bucket(&p)
                == TableHash::new(d, 2, 0.5, &mut r2).bucket(&q)
            {
                narrow += 1;
            }
            let mut r3 = Pcg64::seed_from(1000 + t);
            let mut r4 = Pcg64::seed_from(1000 + t);
            if TableHash::new(d, 2, 8.0, &mut r3).bucket(&p)
                == TableHash::new(d, 2, 8.0, &mut r4).bucket(&q)
            {
                wide += 1;
            }
        }
        assert!(wide > narrow, "wide={wide} narrow={narrow}");
    }
}
