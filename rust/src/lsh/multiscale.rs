//! The user-facing monotone LSH oracle (Theorem 5.1).
//!
//! Two modes:
//!
//! * **Practical** (default; Appendix D.3, what the paper's experiments
//!   run): a single scale — one gap structure with the radius filter
//!   disabled, m = 15, bucket width 10 (quantized coordinates).
//! * **Rigorous** (Appendix D.2): `log2(2Δ)` copies of the `(c/2, R_i)`
//!   gap structure at geometric scales `R_i = 2^{i-1} · MAXDIST/(2Δ)`;
//!   a query asks every copy and keeps the closest.
//!
//! Both modes additionally keep the **first inserted point** in the
//! candidate set of every query. This guarantees `query` is total once
//! anything was inserted (the seeding loop needs *a* distance; `min{1,·}`
//! in Algorithm 4 absorbs overestimates) and cannot break monotonicity:
//! the candidate set still only grows.

use std::cell::Cell;

use crate::data::matrix::{d2, PointSet};
use crate::kernels::blocked::dot;
use crate::lsh::gap::{GapConfig, GapStructure};
use crate::lsh::{NnOracle, OracleProbes};
use crate::rng::Pcg64;

/// Which Appendix-D construction to use.
#[derive(Clone, Debug)]
pub enum LshMode {
    /// Single-scale (Appendix D.3).
    Practical,
    /// Multi-scale stack (Appendix D.2); needs `max_dist` and an aspect
    /// ratio (upper bound) to lay out the scales.
    Rigorous { max_dist: f32, delta: f32 },
}

/// Tunables shared by both modes.
#[derive(Clone, Debug)]
pub struct LshParams {
    /// Approximation factor `c > 1` (the rejection sampler's `c`).
    pub c: f32,
    /// Tables per gap structure.
    pub tables: usize,
    /// Concatenated hashes per table (paper: 15).
    pub m: usize,
    /// p-stable bucket width (paper: 10 on quantized data).
    pub bucket_width: f32,
    /// Bucket scan bound per query.
    pub probe_limit: usize,
}

impl Default for LshParams {
    fn default() -> Self {
        LshParams {
            c: 2.0,
            tables: 8,
            m: 15,
            bucket_width: 10.0,
            probe_limit: 16,
        }
    }
}

/// How many of the earliest insertions every query scans exactly.
///
/// The first `PREFIX_CAP` inserted points form a *fixed, append-only
/// prefix*, so scanning all of them keeps queries monotone while making
/// the oracle **exact** until that many centers exist — removing the
/// early-phase bias where sparse centers rarely collide with any bucket.
/// Past the cap the scan costs a constant `PREFIX_CAP * d` per query.
pub const PREFIX_CAP: usize = 128;

/// Once the total per-**insert** hashing work (structures × tables × m ×
/// d multiply-adds) crosses this floor, insertion bucket keys are
/// computed through [`crate::parallel::parallel_map`] (one task per gap
/// structure) instead of serially. The floor sits well above
/// `parallel_map`'s scoped-thread spawn cost (~tens of µs), and inserts
/// only happen k times per seeding run — queries never pay it: the
/// witness path hashes lazily per structure with early exit.
const PARALLEL_HASH_MIN_MACS: usize = 262_144;

/// Monotone approximate-NN oracle (implements [`NnOracle`]).
pub struct MonotoneLsh {
    structures: Vec<GapStructure>,
    /// First `PREFIX_CAP` inserted ids (append-only; scanned exactly).
    prefix: Vec<u32>,
    /// The prefix rows copied into one contiguous, L1-resident buffer —
    /// the scan is the per-query hot loop and sequential access beats
    /// `PREFIX_CAP` random row gathers (§Perf log).
    prefix_rows: Vec<f32>,
    /// `‖row‖²` per prefix slot — lets the cached witness scan use the
    /// kernels-v2 norm trick over the same contiguous buffer.
    prefix_norms: Vec<f32>,
    dim: usize,
    inserted: usize,
    /// Monitoring counters ([`OracleProbes`]). `Cell`: witness scans take
    /// `&self` and the oracle lives on the single-threaded acceptance
    /// loop; the cells are never touched from the parallel hash tasks.
    probes: Cell<u64>,
    prefix_hits: Cell<u64>,
    scale_hits: Vec<Cell<u64>>,
    /// Structure index that produced the most recent witness — probed
    /// first on the next query. Pure probe-order heuristic: `dist_below`
    /// is an existence test over a fixed candidate set, so the order can
    /// change probe counts but never the decision.
    last_hit: Cell<usize>,
}

impl MonotoneLsh {
    /// Single-scale practical construction (Appendix D.3).
    pub fn practical(dim: usize, params: &LshParams, rng: &mut Pcg64) -> Self {
        let cfg = GapConfig {
            c: params.c,
            r_scale: f32::INFINITY,
            tables: params.tables,
            m: params.m,
            bucket_width: params.bucket_width,
            probe_limit: params.probe_limit,
        };
        Self::from_structures(vec![GapStructure::new(dim, cfg, rng)], dim)
    }

    fn from_structures(structures: Vec<GapStructure>, dim: usize) -> Self {
        let scale_hits = (0..structures.len()).map(|_| Cell::new(0)).collect();
        MonotoneLsh {
            structures,
            prefix: Vec::new(),
            prefix_rows: Vec::new(),
            prefix_norms: Vec::new(),
            dim,
            inserted: 0,
            probes: Cell::new(0),
            prefix_hits: Cell::new(0),
            scale_hits,
            last_hit: Cell::new(0),
        }
    }

    /// Multi-scale rigorous construction (Appendix D.2): scales
    /// `R_i = 2^{i-1} MAXDIST / (2Δ)`, accuracy `c/2` each.
    pub fn rigorous(
        dim: usize,
        params: &LshParams,
        max_dist: f32,
        delta: f32,
        rng: &mut Pcg64,
    ) -> Self {
        let delta = delta.max(1.0);
        let levels = (2.0 * delta).log2().ceil().max(1.0) as usize;
        let r_min = max_dist / (2.0 * delta);
        let structures = (0..levels)
            .map(|i| {
                let cfg = GapConfig {
                    c: (params.c / 2.0).max(1.01),
                    r_scale: r_min * (1u64 << i) as f32,
                    tables: params.tables,
                    m: params.m,
                    // Scale-proportional bucket width: collisions at scale
                    // R_i should happen for points within ~R_i.
                    bucket_width: (r_min * (1u64 << i) as f32).max(f32::MIN_POSITIVE),
                    probe_limit: params.probe_limit,
                };
                let mut sr = rng.fork(i as u64);
                GapStructure::new(dim, cfg, &mut sr)
            })
            .collect();
        Self::from_structures(structures, dim)
    }

    /// Build from a mode descriptor.
    pub fn new(dim: usize, params: &LshParams, mode: &LshMode, rng: &mut Pcg64) -> Self {
        match mode {
            LshMode::Practical => Self::practical(dim, params, rng),
            LshMode::Rigorous { max_dist, delta } => {
                Self::rigorous(dim, params, *max_dist, *delta, rng)
            }
        }
    }

    /// Per-point bucket keys of every structure — the insert path.
    /// Hashing is the bulk of the per-insert cost on deep rigorous
    /// stacks, and it is pure, so it fans out over
    /// [`crate::parallel::parallel_map`] (order-preserving — results are
    /// bit-identical to the serial path) once the total work crosses
    /// [`PARALLEL_HASH_MIN_MACS`]. The practical single-scale mode stays
    /// inline.
    fn all_keys(&self, q: &[f32]) -> Vec<Vec<u64>> {
        let structures = &self.structures;
        let macs: usize = structures
            .iter()
            .map(|s| s.hashes_per_point() * self.dim)
            .sum();
        if structures.len() > 1 && macs >= PARALLEL_HASH_MIN_MACS {
            crate::parallel::parallel_map(structures.len(), |s| structures[s].bucket_keys(q))
        } else {
            structures.iter().map(|s| s.bucket_keys(q)).collect()
        }
    }
}

impl NnOracle for MonotoneLsh {
    fn insert(&mut self, ps: &PointSet, i: u32) {
        let row = ps.row(i as usize);
        let norm = dot(row, row);
        if self.prefix.len() < PREFIX_CAP {
            self.prefix.push(i);
            self.prefix_rows.extend_from_slice(row);
            self.prefix_norms.push(norm);
        }
        // Hash every (structure, table) key — in parallel on deep stacks
        // — then do the cheap bucket appends serially, preserving the
        // append-only insertion order the monotonicity argument needs.
        let keys = self.all_keys(row);
        for (s, k) in keys.iter().enumerate() {
            self.structures[s].insert_hashed(k, i, norm);
        }
        self.inserted += 1;
    }

    fn query(&self, ps: &PointSet, q: &[f32]) -> Option<(u32, f32)> {
        if self.inserted == 0 {
            return None;
        }
        // Exact scan over the fixed insertion prefix (monotone: it only
        // grows, and never changes once full). This makes the oracle
        // exact while |S| <= PREFIX_CAP and a guaranteed-candidate
        // fallback afterwards.
        let mut best: Option<(u32, f32)> = None;
        for (slot, &i) in self.prefix.iter().enumerate() {
            let row = &self.prefix_rows[slot * self.dim..(slot + 1) * self.dim];
            let d = d2(row, q).sqrt();
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((i, d));
            }
        }
        let mut best = best?;
        for s in &self.structures {
            if let Some((i, d)) = s.query(ps, q) {
                if d < best.1 {
                    best = (i, d);
                }
            }
        }
        Some(best)
    }

    fn dist_below(&self, ps: &PointSet, q: &[f32], threshold: f32) -> bool {
        let t2 = threshold * threshold;
        // Witness scan, cheapest first: the contiguous prefix buffer.
        if self
            .prefix_rows
            .chunks_exact(self.dim)
            .any(|row| d2(row, q) < t2)
        {
            return true;
        }
        self.structures
            .iter()
            .any(|s| s.dist_below(ps, q, threshold))
    }

    fn dist_below_cached(&self, ps: &PointSet, q: &[f32], q_norm2: f32, threshold: f32) -> bool {
        let t2 = threshold * threshold;
        let mut probes = 0u64;
        // (1) Exact prefix scan via the norm trick over the contiguous
        // buffer — rejects (the common case) usually find their witness
        // here without touching a single hash.
        for (slot, &cn) in self.prefix_norms.iter().enumerate() {
            probes += 1;
            let row = &self.prefix_rows[slot * self.dim..(slot + 1) * self.dim];
            let dd = (q_norm2 + cn - 2.0 * dot(row, q)).max(0.0);
            if dd < t2 {
                self.probes.set(self.probes.get() + probes);
                self.prefix_hits.set(self.prefix_hits.get() + 1);
                return true;
            }
        }
        // (2) Bucket probes over every scale, most-recent-witness
        // structure first (order affects probe counts, never the
        // decision — `dist_below` is an existence test). Keys are hashed
        // lazily per structure so an early witness skips the remaining
        // scales' hashing entirely (the dominant per-probe cost).
        let n = self.structures.len();
        let start = self.last_hit.get().min(n.saturating_sub(1));
        for step in 0..n {
            let s = (start + step) % n;
            let keys = self.structures[s].bucket_keys(q);
            let (hit, p) =
                self.structures[s].dist_below_hashed_cached(ps, &keys, q, q_norm2, threshold);
            probes += p;
            if hit {
                self.scale_hits[s].set(self.scale_hits[s].get() + 1);
                self.last_hit.set(s);
                self.probes.set(self.probes.get() + probes);
                return true;
            }
        }
        self.probes.set(self.probes.get() + probes);
        false
    }

    fn len(&self) -> usize {
        self.inserted
    }

    fn probe_stats(&self) -> OracleProbes {
        OracleProbes {
            probes: self.probes.get(),
            prefix_hits: self.prefix_hits.get(),
            scale_hits: self.scale_hits.iter().map(Cell::get).collect(),
        }
    }
}

/// Estimate a sensible p-stable bucket width.
///
/// The Datar et al. collision probability for a single hash at distance
/// `u` is ≈ `1 - 2Φ(-r/u) - ...`: with `m = 15` concatenated hashes and a
/// handful of tables, good recall needs `r ≈ 8-10x` the nearest-neighbor
/// distance scale. Random *pairs* measure the inter-cluster scale (orders
/// of magnitude larger), so instead we sample `probes` query points and
/// take the median of their true NN distance within a sampled subset —
/// an upper bound on the NN scale (subset ⊂ full set), which errs toward
/// wider buckets, i.e. better recall at slightly larger buckets.
///
/// (On Appendix-F quantized data the paper's fixed `10` corresponds to a
/// few grid steps; this helper generalizes that choice to raw inputs.)
pub fn auto_bucket_width(ps: &PointSet, probes: usize, rng: &mut Pcg64) -> f32 {
    let n = ps.len();
    if n < 2 {
        return 1.0;
    }
    let probes = probes.clamp(8, 64);
    let subset = 1024.min(n);
    let subset_idx: Vec<usize> = (0..subset).map(|_| rng.index(n)).collect();
    let mut nn: Vec<f32> = Vec::with_capacity(probes);
    for _ in 0..probes {
        let q = rng.index(n);
        let mut best = f32::INFINITY;
        for &j in &subset_idx {
            if j == q {
                continue;
            }
            let dd = ps.d2_rows(q, j);
            if dd > 0.0 && dd < best {
                best = dd;
            }
        }
        if best.is_finite() {
            nn.push(best.sqrt());
        }
    }
    if nn.is_empty() {
        return 1.0;
    }
    nn.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = nn[nn.len() / 2];
    (median * 8.0).max(f32::MIN_POSITIVE)
}

/// Bucket width tuned for querying against ~`k` inserted centers (the
/// rejection sampler's workload): the relevant collision scale is the
/// distance from a random point to its nearest center, i.e. the NN
/// distance `u_q` to a random `k`-subset — typically orders of magnitude
/// larger than the dataset NN scale that [`auto_bucket_width`] measures.
///
/// With `m` concatenated hashes the table collision probability at
/// distance `u` is ≈ `exp(-0.8 m u / w)`, so `w = m * u_q` gives ~0.45
/// per table at the query scale (near-certain over several tables) while
/// staying selective at a few multiples of `u_q`.
pub fn auto_bucket_width_for_k(ps: &PointSet, k: usize, m: usize, rng: &mut Pcg64) -> f32 {
    let n = ps.len();
    if n < 2 {
        return 1.0;
    }
    let k = k.clamp(1, n - 1);
    let subset: Vec<usize> = (0..k).map(|_| rng.index(n)).collect();
    let probes = 48.min(n);
    let mut nn: Vec<f32> = Vec::with_capacity(probes);
    for _ in 0..probes {
        let q = rng.index(n);
        let mut best = f32::INFINITY;
        for &j in &subset {
            if j == q {
                continue;
            }
            let dd = ps.d2_rows(q, j);
            if dd > 0.0 && dd < best {
                best = dd;
            }
        }
        if best.is_finite() {
            nn.push(best.sqrt());
        }
    }
    if nn.is_empty() {
        return 1.0;
    }
    nn.sort_by(|a, b| a.partial_cmp(b).unwrap());
    // Median distance-to-k-subset times m: widths that are too narrow
    // force fallback answers (clamped acceptance = distribution bias);
    // too wide only costs probe time.
    (nn[nn.len() / 2] * m.max(1) as f32).max(f32::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};
    use crate::lsh::ExactNn;

    fn dataset(n: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d: 12,
                k_true: 10,
                center_spread: 30.0,
                cluster_std: 1.0,
                ..Default::default()
            },
            seed,
        )
    }

    fn params(ps: &PointSet, rng: &mut Pcg64) -> LshParams {
        LshParams {
            bucket_width: auto_bucket_width(ps, 200, rng),
            m: 8,
            ..Default::default()
        }
    }

    #[test]
    fn query_total_once_inserted() {
        let ps = dataset(50, 1);
        let mut rng = Pcg64::seed_from(2);
        let p = params(&ps, &mut rng);
        let mut lsh = MonotoneLsh::practical(12, &p, &mut rng);
        assert!(lsh.query(&ps, ps.row(0)).is_none());
        lsh.insert(&ps, 3);
        // Even if hashing misses, the fallback candidate answers.
        let (i, d) = lsh.query(&ps, ps.row(0)).unwrap();
        assert_eq!(i, 3);
        assert!((d - ps.d2_rows(0, 3).sqrt()).abs() < 1e-5);
    }

    #[test]
    fn practical_monotone_under_insertions() {
        let ps = dataset(400, 3);
        let mut rng = Pcg64::seed_from(4);
        let p = params(&ps, &mut rng);
        let lsh = MonotoneLsh::practical(12, &p, &mut rng);
        for q in [399usize, 200, 57] {
            let mut lsh2 = MonotoneLsh::practical(12, &p, &mut rng);
            let mut last = f32::INFINITY;
            for i in 0..150u32 {
                lsh2.insert(&ps, i);
                let (_, d) = lsh2.query(&ps, ps.row(q)).unwrap();
                assert!(d <= last + 1e-5, "q={q} i={i}: {d} > {last}");
                last = d;
            }
        }
        let _ = lsh; // silence unused in release cfg
    }

    #[test]
    fn rigorous_monotone_and_total() {
        let ps = dataset(300, 5);
        let mut rng = Pcg64::seed_from(6);
        let p = LshParams {
            m: 4,
            ..params(&ps, &mut rng)
        };
        let max_dist = ps.max_dist_upper_bound();
        let mut lsh = MonotoneLsh::rigorous(12, &p, max_dist, 1024.0, &mut rng);
        let q = ps.row(299).to_vec();
        let mut last = f32::INFINITY;
        for i in 0..200u32 {
            lsh.insert(&ps, i);
            let (_, d) = lsh.query(&ps, &q).unwrap();
            assert!(d <= last + 1e-5);
            last = d;
        }
    }

    #[test]
    fn approximation_quality_vs_exact() {
        // The returned distance must (a) upper-bound the true NN distance
        // (it is a real inserted point) and (b) usually be within a small
        // factor of it.
        let ps = dataset(600, 7);
        let mut rng = Pcg64::seed_from(8);
        let p = params(&ps, &mut rng);
        let mut lsh = MonotoneLsh::practical(12, &p, &mut rng);
        let mut exact = ExactNn::default();
        for i in 0..300u32 {
            lsh.insert(&ps, i);
            exact.insert(&ps, i);
        }
        let mut within = 0;
        let total = 300;
        for q in 300..600 {
            let (_, d) = lsh.query(&ps, ps.row(q)).unwrap();
            let (_, t) = exact.query(&ps, ps.row(q)).unwrap();
            assert!(d + 1e-5 >= t, "LSH distance below true NN");
            if d <= 2.0 * t + 1e-3 {
                within += 1;
            }
        }
        assert!(
            within as f64 >= 0.6 * total as f64,
            "only {within}/{total} within 2x of exact"
        );
    }

    #[test]
    fn cached_witness_matches_uncached_both_modes() {
        // The norm-trick witness path (what the rejection seeder drives)
        // must agree with the reference scan away from the f32 knife
        // edge, and the probe counters must advance.
        let ps = dataset(500, 13);
        let norms = crate::kernels::norms::squared_norms(&ps);
        let mut rng = Pcg64::seed_from(14);
        let p = params(&ps, &mut rng);
        let max_dist = ps.max_dist_upper_bound();
        for rigorous in [false, true] {
            let mut lsh = if rigorous {
                MonotoneLsh::rigorous(12, &p, max_dist, 512.0, &mut rng)
            } else {
                MonotoneLsh::practical(12, &p, &mut rng)
            };
            for i in 0..250u32 {
                lsh.insert(&ps, i);
            }
            for q in (250..500).step_by(5) {
                let (_, dist) = lsh.query(&ps, ps.row(q)).unwrap();
                for mult in [0.5f32, 2.0] {
                    let t = dist * mult;
                    assert_eq!(
                        lsh.dist_below(&ps, ps.row(q), t),
                        lsh.dist_below_cached(&ps, ps.row(q), norms[q], t),
                        "rigorous={rigorous} q={q} mult={mult}"
                    );
                }
            }
            let stats = lsh.probe_stats();
            assert!(stats.probes > 0, "rigorous={rigorous}");
            assert_eq!(stats.scale_hits.len(), lsh.structures.len());
        }
    }

    #[test]
    fn auto_bucket_width_positive_and_scales() {
        let ps = dataset(200, 9);
        let mut rng = Pcg64::seed_from(10);
        let w = auto_bucket_width(&ps, 100, &mut rng);
        assert!(w > 0.0);
        // Scaling the data scales the width estimate.
        let mut scaled = ps.clone();
        for v in scaled.flat_mut() {
            *v *= 100.0;
        }
        let mut rng2 = Pcg64::seed_from(10);
        let w2 = auto_bucket_width(&scaled, 100, &mut rng2);
        assert!(w2 > 20.0 * w, "w={w} w2={w2}");
    }

    #[test]
    fn duplicate_points_distance_zero() {
        let mut rows = vec![vec![5.0f32; 12]; 2];
        rows.push(vec![9.0f32; 12]);
        let ps = PointSet::from_rows(&rows);
        let mut rng = Pcg64::seed_from(11);
        let p = LshParams::default();
        let mut lsh = MonotoneLsh::practical(12, &p, &mut rng);
        lsh.insert(&ps, 0);
        let (_, d) = lsh.query(&ps, ps.row(1)).unwrap();
        assert!(d <= 1e-6);
    }
}
