//! The `(c, R)`-gap data structure (Appendix D.1).
//!
//! `ℓ` hash tables with append-only linked lists (here: `Vec`s, which
//! preserve insertion order). `Insert(p)` appends `p` to the bucket
//! `T_i[f_i(p)]` of every table. `Query(p)` takes, per table, the *first*
//! element of the bucket within distance `cR`, then returns the closest of
//! the ≤ ℓ candidates.
//!
//! Monotonicity (the property the seeding proof needs) is by
//! construction: insertions append at the *end* of bucket lists while
//! queries scan from the *beginning*, so every candidate a query saw
//! before an insertion is still a candidate after it — the returned
//! distance can only decrease.
//!
//! One practical deviation, recorded in DESIGN.md §8: we bound the bucket
//! scan by `probe_limit` entries (the theory guarantees no false
//! positives whp, making the first in-range element sit at the bucket
//! head; real buckets are noisier). A fixed prefix of an append-only list
//! is still a monotone candidate set.

use std::collections::HashMap;

use crate::data::matrix::{d2, PointSet};
use crate::kernels::blocked::dot;
use crate::lsh::pstable::TableHash;
use crate::rng::Pcg64;

/// Configuration of a single gap structure.
#[derive(Clone, Debug)]
pub struct GapConfig {
    /// Approximation factor `c > 1`.
    pub c: f32,
    /// Scale `R` (`cR` is the acceptance radius). `f32::INFINITY`
    /// disables the radius filter (the practical single-scale mode).
    pub r_scale: f32,
    /// Number of hash tables `ℓ`.
    pub tables: usize,
    /// Concatenation width `m` per table hash.
    pub m: usize,
    /// Bucket width `r` of the p-stable hash.
    pub bucket_width: f32,
    /// Max bucket entries scanned per query.
    pub probe_limit: usize,
}

impl Default for GapConfig {
    fn default() -> Self {
        // Appendix D.3 parameters: one scale, m = 15 hash functions,
        // collision parameter r = 10 (quantized integer coordinates).
        GapConfig {
            c: 2.0,
            r_scale: f32::INFINITY,
            tables: 8,
            m: 15,
            bucket_width: 10.0,
            probe_limit: 16,
        }
    }
}

/// A single `(c, R)`-gap structure.
pub struct GapStructure {
    cfg: GapConfig,
    hashes: Vec<TableHash>,
    /// One bucket map per table; values are append-only `(point id, ‖p‖²)`
    /// lists — the squared norm rides along with the id so cached probes
    /// can evaluate candidates via the kernels-v2 norm trick without an
    /// extra row pass.
    buckets: Vec<HashMap<u64, Vec<(u32, f32)>>>,
    inserted: usize,
}

impl GapStructure {
    pub fn new(dim: usize, cfg: GapConfig, rng: &mut Pcg64) -> Self {
        let hashes = (0..cfg.tables)
            .map(|t| {
                let mut hr = rng.fork(t as u64);
                TableHash::new(dim, cfg.m, cfg.bucket_width, &mut hr)
            })
            .collect();
        GapStructure {
            buckets: vec![HashMap::new(); cfg.tables],
            hashes,
            cfg,
            inserted: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.inserted
    }

    pub fn is_empty(&self) -> bool {
        self.inserted == 0
    }

    /// Hash evaluations one `bucket_keys` call performs (tables × m) —
    /// the per-point hashing cost in d-dimensional dot products, used by
    /// the multiscale oracle to decide whether key hashing is worth
    /// parallelizing.
    pub fn hashes_per_point(&self) -> usize {
        self.cfg.tables * self.cfg.m
    }

    /// Per-table bucket keys for `p` — the `O(tables · m · d)` hashing
    /// work, split out so [`crate::lsh::multiscale::MonotoneLsh`] can
    /// compute keys for many structures in parallel (hashing is pure)
    /// while the cheap bucket appends stay serial and deterministic.
    pub fn bucket_keys(&self, p: &[f32]) -> Vec<u64> {
        self.hashes.iter().map(|h| h.bucket(p)).collect()
    }

    /// Append `i` (with its cached `‖p_i‖²`) under precomputed per-table
    /// `keys` (from [`GapStructure::bucket_keys`]).
    pub fn insert_hashed(&mut self, keys: &[u64], i: u32, norm: f32) {
        debug_assert_eq!(keys.len(), self.buckets.len());
        for (table, &key) in self.buckets.iter_mut().zip(keys) {
            table.entry(key).or_default().push((i, norm));
        }
        self.inserted += 1;
    }

    /// Append `i` to its bucket in every table.
    pub fn insert(&mut self, ps: &PointSet, i: u32) {
        let p = ps.row(i as usize);
        let norm = dot(p, p);
        let keys = self.bucket_keys(p);
        self.insert_hashed(&keys, i, norm);
    }

    /// Candidate per table, then the closest overall. Returns
    /// `(index, distance)`.
    ///
    /// With a finite scale this is Appendix D.1 verbatim: the *first*
    /// bucket element within `cR`. With the radius filter disabled
    /// (practical single-scale mode) the "first within ∞" rule would
    /// degenerate to "oldest colliding point", so we instead take the
    /// minimum over the scanned prefix — still a monotone candidate set
    /// (a fixed-length prefix of an append-only list only ever grows).
    pub fn query(&self, ps: &PointSet, q: &[f32]) -> Option<(u32, f32)> {
        let radius = self.cfg.c * self.cfg.r_scale;
        let first_in_range = radius.is_finite();
        let mut best: Option<(u32, f32)> = None;
        for (hash, table) in self.hashes.iter().zip(&self.buckets) {
            let Some(bucket) = table.get(&hash.bucket(q)) else {
                continue;
            };
            for &(i, _) in bucket.iter().take(self.cfg.probe_limit) {
                let dist = d2(ps.row(i as usize), q).sqrt();
                if dist <= radius {
                    if best.map_or(true, |(_, bd)| dist < bd) {
                        best = Some((i, dist));
                    }
                    if first_in_range {
                        break; // first in-range element of this list
                    }
                }
            }
        }
        best
    }

    /// Early-exit witness scan over the same candidate set as [`query`]:
    /// is any candidate closer than `threshold`?
    ///
    /// [`query`]: GapStructure::query
    pub fn dist_below(&self, ps: &PointSet, q: &[f32], threshold: f32) -> bool {
        let t2 = threshold * threshold;
        for (hash, table) in self.hashes.iter().zip(&self.buckets) {
            let Some(bucket) = table.get(&hash.bucket(q)) else {
                continue;
            };
            if self.scan_bucket_direct(ps, bucket, q, threshold, t2) {
                return true;
            }
        }
        false
    }

    /// [`GapStructure::dist_below`] over precomputed per-table `keys`,
    /// evaluating candidates via the norm trick
    /// (`‖q‖² + ‖c‖² − 2 q·c`, with `‖c‖²` cached in the bucket entry).
    /// Same candidate set and early-exit semantics as the direct scan;
    /// the arithmetic differs only at the f32-rounding level. Returns
    /// `(witness_found, candidates_evaluated)` so the caller can
    /// aggregate probe counters.
    pub fn dist_below_hashed_cached(
        &self,
        ps: &PointSet,
        keys: &[u64],
        q: &[f32],
        q_norm2: f32,
        threshold: f32,
    ) -> (bool, u64) {
        let radius = (self.cfg.c * self.cfg.r_scale).min(threshold);
        let t2 = threshold * threshold;
        let mut probes = 0u64;
        for (table, &key) in self.buckets.iter().zip(keys) {
            let Some(bucket) = table.get(&key) else {
                continue;
            };
            for &(i, cn) in bucket.iter().take(self.cfg.probe_limit) {
                probes += 1;
                let dd = (q_norm2 + cn - 2.0 * dot(ps.row(i as usize), q)).max(0.0);
                if dd < t2 && dd.sqrt() <= radius {
                    return (true, probes);
                }
            }
        }
        (false, probes)
    }

    #[inline]
    fn scan_bucket_direct(
        &self,
        ps: &PointSet,
        bucket: &[(u32, f32)],
        q: &[f32],
        threshold: f32,
        t2: f32,
    ) -> bool {
        let radius = (self.cfg.c * self.cfg.r_scale).min(threshold);
        bucket.iter().take(self.cfg.probe_limit).any(|&(i, _)| {
            let dd = d2(ps.row(i as usize), q);
            dd < t2 && dd.sqrt() <= radius
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn dataset(n: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d: 10,
                k_true: 8,
                center_spread: 20.0,
                cluster_std: 1.0,
                ..Default::default()
            },
            seed,
        )
    }

    fn cfg_unit() -> GapConfig {
        GapConfig {
            c: 2.0,
            r_scale: f32::INFINITY,
            tables: 8,
            m: 6,
            // ~8x the within-cluster NN scale of `dataset` (std 1, d=10).
            bucket_width: 32.0,
            probe_limit: 16,
        }
    }

    #[test]
    fn empty_returns_none() {
        let ps = dataset(10, 1);
        let mut rng = Pcg64::seed_from(2);
        let g = GapStructure::new(10, cfg_unit(), &mut rng);
        assert!(g.query(&ps, ps.row(0)).is_none());
    }

    #[test]
    fn query_self_after_insert_is_exact() {
        let ps = dataset(100, 3);
        let mut rng = Pcg64::seed_from(4);
        let mut g = GapStructure::new(10, cfg_unit(), &mut rng);
        for i in 0..100u32 {
            g.insert(&ps, i);
        }
        // Identical point always collides in every table -> distance 0.
        for i in (0..100).step_by(7) {
            let (_, d) = g.query(&ps, ps.row(i)).unwrap();
            assert!(d <= 1e-6, "self-query i={i} dist={d}");
        }
    }

    #[test]
    fn finds_near_neighbors_with_good_recall() {
        let ps = dataset(400, 5);
        let mut rng = Pcg64::seed_from(6);
        let mut g = GapStructure::new(10, cfg_unit(), &mut rng);
        for i in 0..200u32 {
            g.insert(&ps, i);
        }
        // For queries among the inserted cluster structure, the returned
        // distance should usually be within 2x of the true NN distance.
        let mut ok = 0;
        let mut total = 0;
        for q in 200..400 {
            let truth = (0..200)
                .map(|i| ps.d2_rows(q, i).sqrt())
                .fold(f32::INFINITY, f32::min);
            if let Some((_, d)) = g.query(&ps, ps.row(q)) {
                total += 1;
                if d <= 3.0 * truth + 1e-3 {
                    ok += 1;
                }
            }
        }
        assert!(total > 150, "too many empty queries: {total}");
        assert!(
            ok as f64 >= 0.7 * total as f64,
            "recall {ok}/{total} too low"
        );
    }

    #[test]
    fn monotone_under_insertions() {
        let ps = dataset(300, 7);
        let mut rng = Pcg64::seed_from(8);
        let mut g = GapStructure::new(10, cfg_unit(), &mut rng);
        let q = ps.row(299).to_vec();
        let mut last = f32::INFINITY;
        for i in 0..299u32 {
            g.insert(&ps, i);
            if let Some((_, d)) = g.query(&ps, &q) {
                assert!(
                    d <= last + 1e-5,
                    "monotonicity violated after inserting {i}: {d} > {last}"
                );
                last = d;
            } else {
                assert_eq!(last, f32::INFINITY, "candidate disappeared");
            }
        }
    }

    #[test]
    fn cached_witness_scan_matches_direct() {
        // The norm-trick probe (`dist_below_hashed_cached`) must agree
        // with the direct scan on the same candidate set (thresholds are
        // fixed and off the f32-rounding knife edge, so the decision is
        // arithmetic-independent).
        let ps = dataset(300, 11);
        let mut rng = Pcg64::seed_from(12);
        let mut g = GapStructure::new(10, cfg_unit(), &mut rng);
        for i in 0..150u32 {
            g.insert(&ps, i);
        }
        let norms = crate::kernels::norms::squared_norms(&ps);
        for q in (150..300).step_by(3) {
            let row = ps.row(q);
            let keys = g.bucket_keys(row);
            for t in [0.5f32, 2.0, 8.0, 64.0] {
                let direct = g.dist_below(&ps, row, t);
                let (cached, probes) = g.dist_below_hashed_cached(&ps, &keys, row, norms[q], t);
                assert_eq!(direct, cached, "q={q} t={t}");
                if cached {
                    assert!(probes >= 1);
                }
            }
        }
    }

    #[test]
    fn radius_filter_rejects_far_points() {
        let ps = PointSet::from_rows(&[vec![0.0f32, 0.0], vec![100.0, 100.0]]);
        let mut rng = Pcg64::seed_from(9);
        let cfg = GapConfig {
            c: 2.0,
            r_scale: 1.0, // cR = 2 -> the far point is out of range
            tables: 8,
            m: 2,
            bucket_width: 500.0, // force collisions
            probe_limit: 8,
        };
        let mut g = GapStructure::new(2, cfg, &mut rng);
        g.insert(&ps, 1);
        assert!(g.query(&ps, ps.row(0)).is_none());
        // A query point near the inserted point IS within cR.
        assert!(g.query(&ps, &[99.5f32, 100.0]).is_some());
    }
}
