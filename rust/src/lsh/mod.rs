//! Locality-sensitive hashing (paper §5 + Appendix D).
//!
//! Three pieces:
//!
//! * [`pstable`] — the Datar et al. p-stable hash family
//!   `h(p) = floor((a·p + b)/r)` and the m-fold concatenated table hash;
//! * [`gap`] — the `(c, R)`-gap data structure of Appendix D.1:
//!   append-only bucket lists, "first candidate within `cR`" queries,
//!   monotone under insertion by construction;
//! * [`multiscale`] — the user-facing [`multiscale::MonotoneLsh`]:
//!   either the rigorous `log(2Δ)`-scale stack of gap structures
//!   (Theorem 5.1 / Appendix D.2) or the practical single-scale variant
//!   the paper's own experiments use (Appendix D.3), plus the exact
//!   linear-scan oracle used as a baseline and test oracle.
//!
//! The only property the seeding analysis needs beyond approximation is
//! **monotonicity**: `DIST(p, Query(p))` never increases as more points
//! are inserted. All oracles here preserve it exactly: every query
//! inspects a candidate set that only grows over time and returns the
//! minimum distance over it.

pub mod gap;
pub mod multiscale;
pub mod pstable;

use std::cell::Cell;

use crate::data::matrix::PointSet;

/// Cumulative probe counters an oracle may expose — monitoring only
/// (the rejection seeder flushes them to [`crate::metrics::global`] as
/// `oracle.probes` / `oracle.prefix_hits` / `oracle.scale.*`). Counting
/// happens on the cached witness path only (the seeding hot path);
/// `query`/`dist_below` keep the untracked reference semantics.
#[derive(Clone, Debug, Default)]
pub struct OracleProbes {
    /// Candidate distance evaluations across all cached witness scans.
    pub probes: u64,
    /// Witnesses found in the exact insertion-prefix scan (LSH only).
    pub prefix_hits: u64,
    /// Witnesses per scale level of the multi-scale stack (index =
    /// structure index; single-scale practical mode has one entry;
    /// empty for oracles without scales).
    pub scale_hits: Vec<u64>,
}

/// Approximate nearest-neighbor oracle over a fixed point set, inserting
/// dataset indices. The contract mirrors Theorem 5.1:
///
/// * `insert(i)` adds point `i` to the structure;
/// * `query(q)` returns `(index, distance)` of some inserted point whose
///   distance upper-bounds within the structure's guarantee, with the
///   returned distance **non-increasing under insertions** (monotone);
/// * `query` returns `None` iff nothing was inserted.
pub trait NnOracle {
    fn insert(&mut self, ps: &PointSet, i: u32);
    fn query(&self, ps: &PointSet, q: &[f32]) -> Option<(u32, f32)>;

    /// Decide `DIST(q, Query(q)) < threshold` — i.e. whether ANY
    /// candidate in the *same* candidate set `query` would inspect lies
    /// below `threshold`. Implementations may early-exit on the first
    /// witness, which is what makes the rejection sampler's accept test
    /// cheap: the test only needs this indicator, never the distance
    /// itself (`P(accept) = P(dist^2 >= u c^2 w)` for `u ~ U[0,1)`), and
    /// rejects (the overwhelmingly common case) usually find a witness in
    /// a couple of probes.
    fn dist_below(&self, ps: &PointSet, q: &[f32], threshold: f32) -> bool {
        self.query(ps, q).map_or(false, |(_, d)| d < threshold)
    }

    /// [`NnOracle::dist_below`] for callers holding the query point's
    /// squared norm (`q_norm2 = ‖q‖²` from the seeder's per-run norm
    /// cache). Implementations that store per-candidate norms (the exact
    /// oracle) use it to evaluate candidates via the kernels-v2 norm
    /// trick — one fused multiply-add per coordinate instead of the
    /// subtract/square pair — which perturbs the decision only at the
    /// f32-rounding level (the candidate set, early-exit semantics and
    /// monotonicity are unchanged). The default ignores the cache.
    fn dist_below_cached(&self, ps: &PointSet, q: &[f32], q_norm2: f32, threshold: f32) -> bool {
        let _ = q_norm2;
        self.dist_below(ps, q, threshold)
    }

    /// Number of inserted points.
    fn len(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cumulative probe counters (default: none tracked).
    fn probe_stats(&self) -> OracleProbes {
        OracleProbes::default()
    }
}

/// Exact oracle: linear scan over inserted points. `O(|S| d)` per query —
/// this is exactly the `Ω(k^2)` bottleneck the paper's LSH removes, kept
/// as the correctness oracle and as the `rejection-exact` ablation.
///
/// Kernels v2: each inserted center's squared norm is cached once at
/// insertion and reused by every [`NnOracle::dist_below_cached`] scan
/// across all later rounds (`query`/`dist_below` keep the direct v1
/// arithmetic — they are the reference semantics the oracle tests pin).
#[derive(Default, Clone, Debug)]
pub struct ExactNn {
    inserted: Vec<u32>,
    /// `‖c‖²` per entry of `inserted`, via [`crate::kernels::blocked::dot`].
    norms: Vec<f32>,
    /// Candidate evaluations on the cached witness path (`Cell`: the
    /// scan takes `&self`; oracles run on the single-threaded
    /// acceptance loop).
    probes: Cell<u64>,
}

impl NnOracle for ExactNn {
    fn insert(&mut self, ps: &PointSet, i: u32) {
        let row = ps.row(i as usize);
        self.inserted.push(i);
        self.norms.push(crate::kernels::blocked::dot(row, row));
    }

    fn query(&self, ps: &PointSet, q: &[f32]) -> Option<(u32, f32)> {
        let mut best: Option<(u32, f32)> = None;
        for &i in &self.inserted {
            let d = crate::data::matrix::d2(ps.row(i as usize), q).sqrt();
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((i, d));
            }
        }
        best
    }

    fn dist_below(&self, ps: &PointSet, q: &[f32], threshold: f32) -> bool {
        let t2 = threshold * threshold;
        self.inserted
            .iter()
            .any(|&i| crate::data::matrix::d2(ps.row(i as usize), q) < t2)
    }

    fn dist_below_cached(&self, ps: &PointSet, q: &[f32], q_norm2: f32, threshold: f32) -> bool {
        let t2 = threshold * threshold;
        let mut probes = 0u64;
        let mut found = false;
        for (&i, &cn) in self.inserted.iter().zip(&self.norms) {
            probes += 1;
            let dd = q_norm2 + cn - 2.0 * crate::kernels::blocked::dot(ps.row(i as usize), q);
            if dd.max(0.0) < t2 {
                found = true;
                break;
            }
        }
        self.probes.set(self.probes.get() + probes);
        found
    }

    fn len(&self) -> usize {
        self.inserted.len()
    }

    fn probe_stats(&self) -> OracleProbes {
        OracleProbes {
            probes: self.probes.get(),
            ..OracleProbes::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};
    use crate::rng::Pcg64;

    #[test]
    fn exact_nn_finds_nearest() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 100,
                d: 8,
                k_true: 4,
                ..Default::default()
            },
            1,
        );
        let mut nn = ExactNn::default();
        assert!(nn.query(&ps, ps.row(0)).is_none());
        for i in 0..50u32 {
            nn.insert(&ps, i);
        }
        let mut rng = Pcg64::seed_from(2);
        for _ in 0..20 {
            let q = rng.index(100);
            let (idx, dist) = nn.query(&ps, ps.row(q)).unwrap();
            // brute-force check
            let (bi, bd) = (0..50)
                .map(|i| (i, ps.d2_rows(q, i).sqrt()))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            assert_eq!(idx as usize, bi);
            assert!((dist - bd).abs() < 1e-6);
        }
    }

    #[test]
    fn exact_nn_monotone() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 60,
                d: 5,
                k_true: 3,
                ..Default::default()
            },
            3,
        );
        let mut nn = ExactNn::default();
        let q = ps.row(59).to_vec();
        let mut last = f32::INFINITY;
        for i in 0..59u32 {
            nn.insert(&ps, i);
            let (_, d) = nn.query(&ps, &q).unwrap();
            assert!(d <= last + 1e-6, "monotonicity violated at {i}");
            last = d;
        }
    }
}
