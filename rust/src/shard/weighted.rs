//! Weighted instances: [`WeightedPointSet`] plus weighted `D²`-seeding
//! and weighted cost.
//!
//! The k-means‖ recluster reduces the full dataset to a small candidate
//! set whose **weights are assignment counts** — clustering the weighted
//! candidates approximates clustering the original points. The same
//! weighted-instance machinery serves coreset-style workloads (Shah et
//! al., PAPERS.md).
//!
//! **Weight semantics.** `weights[i]` multiplies point `i`'s mass
//! everywhere it appears: the first center is drawn `∝ w_i`, every later
//! `D²` draw `∝ w_i · D²(x_i)`, and the objective is
//! `Σ w_i · min_j ‖x_i − c_j‖²`
//! ([`crate::kernels::reduce::cost_weighted_cached`]). A zero-weight
//! point is never sampled and contributes nothing to the cost, but can
//! still be *covered* by centers chosen for other points. All weights
//! equal to 1 reduces every operation bitwise to its unweighted
//! counterpart (locked by `rust/tests/weighted_parity.rs`).

use crate::data::matrix::PointSet;
use crate::kernels::{norms, reduce};
use crate::rng::Pcg64;
use crate::seeding::kmeanspp::kmeanspp_core;
use crate::seeding::Seeding;

/// A point set with one non-negative finite f32 weight per row.
pub struct WeightedPointSet {
    pub points: PointSet,
    pub weights: Vec<f32>,
}

impl WeightedPointSet {
    /// Pair points with weights. Panics on length mismatch or a
    /// negative/non-finite weight (weights are masses, not scores).
    pub fn new(points: PointSet, weights: Vec<f32>) -> WeightedPointSet {
        assert_eq!(points.len(), weights.len(), "weight array length mismatch");
        assert!(
            weights.iter().all(|w| w.is_finite() && *w >= 0.0),
            "weights must be finite and non-negative"
        );
        WeightedPointSet { points, weights }
    }

    /// Unit weights — the embedding of a plain point set.
    pub fn unit(points: PointSet) -> WeightedPointSet {
        let weights = vec![1.0; points.len()];
        WeightedPointSet { points, weights }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.points.dim()
    }

    /// Total mass `Σ w_i` (f64, fixed-boundary tree sum).
    pub fn total_weight(&self) -> f64 {
        reduce::sum_f32(&self.weights)
    }
}

/// Weighted k-means++: exact `D²` seeding where every draw is weighted
/// by instance mass — the recluster step of k-means‖, and an honest
/// seeder for coresets. Delegates to the shared exact-`D²` engine
/// ([`kmeanspp_core`]), so unit weights reproduce
/// [`crate::seeding::kmeanspp::kmeanspp`] bitwise.
pub fn weighted_kmeanspp(wps: &WeightedPointSet, k: usize, rng: &mut Pcg64) -> Seeding {
    kmeanspp_core(&wps.points, Some(&wps.weights), k, rng)
}

/// Weighted k-means objective `Σ_i w_i · min_j ‖x_i − c_j‖²`.
pub fn weighted_cost(wps: &WeightedPointSet, centers: &PointSet) -> f64 {
    reduce::cost_weighted(&wps.points, &wps.weights, centers)
}

/// [`weighted_cost`] with caller-owned squared-norm caches (the
/// kernels-v2 reuse discipline: compute once, evaluate many candidate
/// center sets).
pub fn weighted_cost_cached(
    wps: &WeightedPointSet,
    point_norms: &[f32],
    centers: &PointSet,
) -> f64 {
    let cn = norms::squared_norms(centers);
    reduce::cost_weighted_cached(
        &wps.points,
        &wps.weights,
        Some(point_norms),
        centers,
        Some(&cn),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn ps(n: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d: 5,
                k_true: 4,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn returns_k_distinct_indices() {
        let points = ps(400, 8);
        let weights: Vec<f32> = (0..400).map(|i| 1.0 + (i % 5) as f32).collect();
        let wps = WeightedPointSet::new(points, weights);
        let mut rng = Pcg64::seed_from(3);
        let s = weighted_kmeanspp(&wps, 12, &mut rng);
        assert_eq!(s.k(), 12);
        let mut idx = s.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 12);
    }

    #[test]
    fn zero_weight_points_are_never_sampled() {
        // Half the points carry zero mass: no draw may land on them.
        let points = ps(300, 9);
        let weights: Vec<f32> = (0..300)
            .map(|i| if i % 2 == 0 { 1.0 } else { 0.0 })
            .collect();
        let wps = WeightedPointSet::new(points, weights);
        for seed in 0..5u64 {
            let mut rng = Pcg64::seed_from(seed);
            let s = weighted_kmeanspp(&wps, 10, &mut rng);
            for &i in &s.indices {
                assert_eq!(i % 2, 0, "zero-weight point {i} was sampled");
            }
        }
    }

    #[test]
    fn heavy_weight_attracts_the_first_center() {
        // One point with overwhelming mass: it must be the first center
        // essentially always.
        let points = ps(200, 10);
        let mut weights = vec![1e-6f32; 200];
        weights[77] = 1.0;
        let wps = WeightedPointSet::new(points, weights);
        let mut hits = 0;
        for seed in 0..20u64 {
            let mut rng = Pcg64::seed_from(100 + seed);
            let s = weighted_kmeanspp(&wps, 1, &mut rng);
            if s.indices[0] == 77 {
                hits += 1;
            }
        }
        assert!(hits >= 19, "only {hits}/20 first draws hit the heavy point");
    }

    #[test]
    fn weighted_cost_scales_with_mass() {
        let points = ps(500, 11);
        let centers = points.gather(&[0, 250]);
        let unit = WeightedPointSet::unit(points.clone());
        let doubled = WeightedPointSet::new(points, vec![2.0; 500]);
        let c1 = weighted_cost(&unit, &centers);
        let c2 = weighted_cost(&doubled, &centers);
        assert!((c2 - 2.0 * c1).abs() <= 1e-9 * c2.abs().max(1.0));
        assert_eq!(unit.total_weight(), 500.0);
    }

    #[test]
    fn cached_cost_matches_uncached() {
        let points = ps(2_000, 12);
        let weights: Vec<f32> = (0..2_000).map(|i| (i % 3) as f32).collect();
        let wps = WeightedPointSet::new(points, weights);
        let centers = wps.points.gather(&[5, 600, 1_500]);
        let pn = crate::kernels::norms::squared_norms(&wps.points);
        assert_eq!(
            weighted_cost(&wps, &centers),
            weighted_cost_cached(&wps, &pn, &centers)
        );
    }

    #[test]
    #[should_panic(expected = "finite and non-negative")]
    fn negative_weights_rejected() {
        WeightedPointSet::new(ps(4, 13), vec![1.0, -1.0, 1.0, 1.0]);
    }
}
