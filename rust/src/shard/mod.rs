//! The sharded seeding engine — the first subsystem with an explicit
//! **coordinator / shard split**, the stepping stone from "parallel on
//! one machine" to true multi-node sharding.
//!
//! The paper's rejection sampler makes a single machine near-linear;
//! this layer scales seeding *across data shards* with k-means‖
//! (Bahmani et al.; analysis tightened by Makarychev–Reddy–Shan, see
//! PAPERS.md): a few oversampling rounds in which every shard thins its
//! own slice against the current candidate set, then a **weighted
//! k-means++** recluster of the small candidate set down to `k`.
//!
//! * [`ShardedDataset`] ([`Shard`]) — deterministic contiguous
//!   partition of a [`PointSet`]; each shard owns its row slice plus a
//!   per-shard squared-norm cache with shard lifetime (the kernels-v2
//!   cache discipline of [`crate::kernels::norms`]).
//! * [`kmeanspar`] — the round driver: per-shard `D²` maintenance
//!   through the kernel engine, Poisson (independent Bernoulli)
//!   oversampling with per-point RNG streams split from the run seed,
//!   coordinator-side candidate merge and assignment-count weights.
//! * [`weighted`] — [`weighted::WeightedPointSet`] and weighted
//!   `D²`-seeding/cost on top of the shared exact-`D²` core
//!   ([`crate::seeding::kmeanspp::kmeanspp_core`]) and the weighted
//!   reductions ([`crate::kernels::reduce::cost_weighted_cached`]).
//! * [`aligned_ranges`] — the summation-block-aligned contiguous
//!   partition the multi-process fit ([`crate::dist`]) hands to its
//!   workers.
//!
//! **Invariance contract.** For a fixed seed, the selected centers are
//! bitwise invariant to the shard count *and* the thread count: shard
//! boundaries never change any per-point value (updates are per-point
//! exact), global sums run at fixed block boundaries
//! ([`crate::kernels::reduce::sum_f32`]), sampling streams split per
//! *point*, and the driver resolves the kernel implementation once on
//! the global shape so every shard computes identical bits (see
//! [`kmeanspar`] for the full argument).

pub mod kmeanspar;
pub mod weighted;

use crate::data::matrix::PointSet;
use crate::kernels::norms;
use crate::parallel::parallel_map;

/// Points-per-shard threshold that picks the engine's single parallel
/// layer: above it, shards are processed **serially** and each kernel
/// call parallelizes internally (the kernels spawn their own workers
/// past their inline cutoffs); at or below it, shards run **in
/// parallel** and the per-shard kernel calls stay inline. Either way
/// exactly one layer spawns threads — no nested scopes oversubscribing
/// the machine — and results are bitwise identical, because per-point
/// kernel work is layout-independent. Matches the largest kernel inline
/// cutoff (`MIN_POINTS_PER_THREAD` of the update/norm kernels).
pub(crate) const OUTER_PARALLEL_MAX_SHARD: usize = 4096;

/// Split `[0, n)` into at most `parts` contiguous non-empty ranges whose
/// interior boundaries all fall on multiples of `align` — the
/// distributed-fit partition ([`crate::dist`]).
///
/// Aligning to [`crate::kernels::reduce::SUM_BLOCK`] keeps every fixed
/// summation block of [`crate::kernels::reduce::sum_f32`] wholly inside
/// one range, so concatenating per-range block partials in range order
/// and summing left-to-right reproduces the global fixed-boundary tree
/// sum bit-for-bit. Whole blocks are spread as evenly as possible
/// (earlier ranges get the remainder); when `n` spans fewer than `parts`
/// blocks the extra trailing ranges are dropped rather than returned
/// empty. Pure function of `(n, parts, align)` — no RNG — so both sides
/// of a distributed run derive the same partition independently.
pub fn aligned_ranges(n: usize, parts: usize, align: usize) -> Vec<(usize, usize)> {
    let align = align.max(1);
    let parts = parts.max(1);
    if n == 0 {
        return Vec::new();
    }
    let nblocks = n.div_ceil(align);
    let parts = parts.min(nblocks);
    let base = nblocks / parts;
    let extra = nblocks % parts;
    let mut out = Vec::with_capacity(parts);
    let mut block = 0usize;
    for p in 0..parts {
        let take = base + usize::from(p < extra);
        let lo = block * align;
        block += take;
        let hi = (block * align).min(n);
        out.push((lo, hi));
    }
    out
}

/// One data shard: a contiguous row slice of the parent dataset, owned
/// (as a node would own its partition), plus the shard-lifetime
/// squared-norm cache the v2 kernels consume.
pub struct Shard {
    /// Global index of this shard's first row.
    pub offset: usize,
    /// The shard's rows (parent rows `offset .. offset + points.len()`).
    pub points: PointSet,
    /// `‖x‖²` per shard row ([`crate::kernels::norms::squared_norms`]),
    /// computed once at partition time and reused by every round.
    pub norms: Vec<f32>,
}

impl Shard {
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// A [`PointSet`] partitioned into `S` deterministic contiguous shards:
/// shard `s` owns rows `[s·⌈n/S⌉, (s+1)·⌈n/S⌉) ∩ [0, n)`. The partition
/// is a pure function of `(n, S)` — no RNG — so a run can be replayed
/// with any shard count and the engine's invariance contract is
/// testable bitwise.
pub struct ShardedDataset {
    shards: Vec<Shard>,
    n: usize,
    dim: usize,
    shard_size: usize,
}

impl ShardedDataset {
    /// Partition `ps` into (at most) `s` contiguous shards. `s` is
    /// clamped to `[1, n]`; trailing empty shards are dropped, so every
    /// shard is non-empty.
    pub fn partition(ps: &PointSet, s: usize) -> ShardedDataset {
        let n = ps.len();
        let s = s.max(1).min(n.max(1));
        let shard_size = n.div_ceil(s).max(1);
        let nshards = n.div_ceil(shard_size).max(1).min(s);
        // Shard slices are copied out — each shard *owns* its rows, as a
        // node owns its partition in the multi-node deployment this
        // subsystem rehearses. That is a deliberate trade-off: one
        // O(nd) copy and a transient 2x dataset memory per kmeans_par
        // run buys the explicit ownership boundary (and node-local norm
        // caches) the coordinator/shard split is about. Each norm cache
        // is built from the shard's own rows — the same per-row
        // arithmetic as a global cache (bitwise identical, see the
        // `shard_norms_match_global_cache_bitwise` test), so the
        // exact-zero self-distance identity of `kernels::norms` holds
        // shard-locally too.
        let build = |si: usize| {
            let lo = si * shard_size;
            let hi = (lo + shard_size).min(n);
            let points = PointSet::from_flat(
                hi - lo,
                ps.dim(),
                ps.flat()[lo * ps.dim()..hi * ps.dim()].to_vec(),
            );
            let norms = norms::squared_norms(&points);
            Shard {
                offset: lo,
                points,
                norms,
            }
        };
        // One parallel layer only (see OUTER_PARALLEL_MAX_SHARD): big
        // shards build serially with the norm kernel parallelizing
        // inside; small shards build in parallel with inline norms.
        let shards = if shard_size > OUTER_PARALLEL_MAX_SHARD {
            (0..nshards).map(build).collect()
        } else {
            parallel_map(nshards, build)
        };
        ShardedDataset {
            shards,
            n,
            dim: ps.dim(),
            shard_size,
        }
    }

    /// Total point count across shards.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Shard] {
        &self.shards
    }

    /// Rows per shard (the last shard may hold fewer).
    pub fn shard_size(&self) -> usize {
        self.shard_size
    }

    /// Global end offset of each shard, in shard order — the piece
    /// boundaries for splitting a global per-point array
    /// ([`crate::parallel::parallel_slices_mut`]).
    pub fn boundaries(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|sh| sh.offset + sh.len())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn ps(n: usize) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d: 7,
                k_true: 3,
                ..Default::default()
            },
            5,
        )
    }

    #[test]
    fn partition_covers_rows_in_order() {
        let ps = ps(1_003);
        for s in [1usize, 2, 4, 7, 1_003, 5_000] {
            let sd = ShardedDataset::partition(&ps, s);
            assert_eq!(sd.len(), 1_003);
            assert_eq!(sd.dim(), 7);
            assert!(sd.num_shards() <= s.min(1_003));
            let mut next = 0usize;
            for sh in sd.shards() {
                assert_eq!(sh.offset, next, "s={s}");
                assert!(!sh.is_empty(), "s={s}: empty shard");
                for r in 0..sh.len() {
                    assert_eq!(sh.points.row(r), ps.row(sh.offset + r), "s={s}");
                }
                assert_eq!(sh.norms.len(), sh.len());
                next += sh.len();
            }
            assert_eq!(next, 1_003, "s={s}: rows lost");
            assert_eq!(*sd.boundaries().last().unwrap(), 1_003);
        }
    }

    #[test]
    fn shard_norms_match_global_cache_bitwise() {
        let ps = ps(500);
        let global = crate::kernels::norms::squared_norms(&ps);
        let sd = ShardedDataset::partition(&ps, 3);
        for sh in sd.shards() {
            assert_eq!(sh.norms, &global[sh.offset..sh.offset + sh.len()]);
        }
    }

    #[test]
    fn single_point_and_oversharded() {
        let ps = ps(1);
        let sd = ShardedDataset::partition(&ps, 8);
        assert_eq!(sd.num_shards(), 1);
        assert_eq!(sd.shards()[0].len(), 1);
    }

    #[test]
    fn aligned_ranges_cover_align_and_balance() {
        let align = 4096;
        for &(n, parts) in &[
            (20_000usize, 4usize),
            (20_000, 2),
            (20_000, 1),
            (20_000, 64),
            (10_000, 4),
            (100, 4),
            (4096, 2),
            (8192, 2),
            (1, 3),
        ] {
            let ranges = aligned_ranges(n, parts, align);
            assert!(!ranges.is_empty(), "n={n} parts={parts}");
            assert!(ranges.len() <= parts, "n={n} parts={parts}");
            // Contiguous cover of [0, n), every range non-empty, every
            // interior boundary on an align multiple.
            let mut next = 0usize;
            for (i, &(lo, hi)) in ranges.iter().enumerate() {
                assert_eq!(lo, next, "n={n} parts={parts} range {i}");
                assert!(hi > lo, "n={n} parts={parts}: empty range {i}");
                if hi != n {
                    assert_eq!(hi % align, 0, "n={n} parts={parts}: boundary off-block");
                }
                next = hi;
            }
            assert_eq!(next, n, "n={n} parts={parts}: rows lost");
            // Balance: block counts differ by at most one.
            let blocks: Vec<usize> = ranges.iter().map(|&(lo, hi)| (hi - lo).div_ceil(align)).collect();
            let (mn, mx) = (blocks.iter().min().unwrap(), blocks.iter().max().unwrap());
            assert!(mx - mn <= 1, "n={n} parts={parts}: unbalanced blocks {blocks:?}");
        }
        // The dist_parity shape: 5 blocks over 4 workers -> all 4 engaged.
        assert_eq!(
            aligned_ranges(20_000, 4, align),
            vec![(0, 8192), (8192, 12_288), (12_288, 16_384), (16_384, 20_000)]
        );
        assert!(aligned_ranges(0, 3, align).is_empty());
    }
}
