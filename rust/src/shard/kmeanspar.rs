//! k-means‖ over data shards: oversampling rounds + weighted k-means++
//! recluster — the `kmeans-par` seeding algorithm.
//!
//! The round lifecycle itself lives in the transport-generic driver
//! [`crate::dist::run_rounds`]; this module provides the in-process
//! [`crate::dist::RoundExecutor`] ([`LocalShardExecutor`]) that runs it
//! over a [`ShardedDataset`], and [`kmeans_par`], the classic entry
//! point gluing the two. The multi-process transport
//! ([`crate::dist::coordinator`]) runs the *same* driver over remote
//! workers.
//!
//! ## Round lifecycle
//!
//! 1. **Partition** (coordinator): [`ShardedDataset::partition`] splits
//!    the dataset into contiguous shards, each with its own norm cache.
//! 2. **Seed** (coordinator): one uniform first center, as in k-means++.
//! 3. **Rounds** (`R = rounds`): every shard, in parallel
//!    ([`crate::parallel::parallel_slices_mut`] /
//!    [`crate::parallel::parallel_map`]):
//!    * maintains its slice of the global `D²` array against the newest
//!      candidates through the kernel engine (the same
//!      `d2_update_min` contract as exact k-means++);
//!    * Poisson-samples its rows — each point `x` joins the candidate
//!      set independently with probability `min(1, ℓ·D²(x)/cost)`,
//!      `ℓ = oversample · k` (Bahmani et al.'s oversampling; a handful
//!      of rounds suffices per Makarychev–Reddy–Shan).
//!    The coordinator merges per-shard candidates in shard order
//!    (= ascending global index) and broadcasts them to all shards.
//! 4. **Weights** (shards → coordinator): each shard assigns its rows to
//!    the nearest candidate; per-candidate assignment counts, summed in
//!    `u64` across shards, become the candidate weights.
//! 5. **Recluster** (coordinator): weighted k-means++
//!    ([`crate::shard::weighted::weighted_kmeanspp`]) reduces the small
//!    weighted candidate set to the final `k` centers.
//!
//! ## RNG stream-splitting contract
//!
//! The run RNG is touched exactly twice before the recluster — one
//! `stream_root` tag, then the uniform first center — so its consumption
//! is independent of `n`, the shard count and the round outcomes. Round
//! sampling draws come from counter-based streams split from
//! `stream_root` per **(round, global point index)** (finer than
//! per-shard): a point's membership coin is a pure function of
//! `(seed, round, i)`, so the candidate set is bitwise invariant to the
//! shard and thread layout. The recluster then resumes the run RNG.
//!
//! ## Invariance argument (shard count & thread count, bitwise)
//!
//! * `D²` maintenance is per-point exact; min-folds over candidates are
//!   order-free; the kernel *implementation* (v1/v2) is resolved once on
//!   the **global** shape ([`crate::kernels::tune::kernel_for`]) and
//!   executed per shard, so per-shard dispatch can never diverge between
//!   shard layouts.
//! * The round cost is a fixed-boundary tree sum over the global `D²`
//!   array ([`crate::kernels::reduce::sum_f32`]) — shard boundaries
//!   never move the summation blocks.
//! * Membership coins are per-point counter streams (above).
//! * Candidate weights are exact `u64` count sums.
//! * The recluster operates on shard-independent inputs with the run
//!   RNG.
//!
//! Cross-*process* bit-reproducibility additionally requires pinning
//! `FKMPP_KERNEL`, exactly as for the rest of the engine (PR 3).

use std::time::Instant;

use crate::data::matrix::PointSet;
use crate::dist::{run_rounds, RoundExecutor};
use crate::error::Result;
use crate::kernels::{assign, blocked, d2 as d2_kernel, norms, reduce, tune};
use crate::metrics;
use crate::parallel::{parallel_map, parallel_slices_mut};
use crate::rng::{splitmix64, Pcg64};
use crate::seeding::{Seeding, SeedingStats};
use crate::shard::ShardedDataset;

/// k-means‖ knobs (`fkmpp seed --algo kmeans-par --shards S --rounds R
/// --oversample L`).
#[derive(Clone, Debug)]
pub struct KMeansParConfig {
    /// Number of data shards `S` (clamped to `[1, n]`).
    pub shards: usize,
    /// Oversampling rounds `R`.
    pub rounds: usize,
    /// Oversampling factor: each round samples `ℓ = oversample · k`
    /// candidates in expectation.
    pub oversample: f64,
}

impl Default for KMeansParConfig {
    fn default() -> Self {
        KMeansParConfig {
            shards: 4,
            rounds: 5,
            oversample: 2.0,
        }
    }
}

/// One membership coin: uniform in `[0, 1)`, a pure function of
/// `(round_tag, global point index)` — the counter-based stream split
/// that makes sampling independent of the shard/thread layout. Public
/// because it is a *wire contract* of the distributed fit: remote
/// workers ([`crate::dist::worker`]) flip the identical coins for their
/// global row range, which is what makes the multi-process run bitwise
/// reproduce the in-process one.
#[inline]
pub fn point_uniform(round_tag: u64, i: u64) -> f64 {
    let x = splitmix64(round_tag.wrapping_add(splitmix64(i.wrapping_add(0x6A09_E667_F3BC_C909))));
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Update every shard's slice of the global `D²` array against the new
/// candidates, with the globally-resolved kernel implementation.
///
/// One parallel layer only ([`crate::shard::OUTER_PARALLEL_MAX_SHARD`]):
/// big shards run serially here and the kernel parallelizes internally;
/// small shards run in parallel with the kernel calls inline. Identical
/// bits either way — per-point kernel work is layout-independent.
fn update_shards(
    sd: &ShardedDataset,
    kernel: tune::Kernel,
    ends: &[usize],
    rows: &PointSet,
    cur_d2: &mut [f32],
) {
    let apply = |s: usize, slice: &mut [f32]| {
        let sh = &sd.shards()[s];
        for c in 0..rows.len() {
            let row = rows.row(c);
            match kernel {
                tune::Kernel::Naive => d2_kernel::d2_update_min(&sh.points, row, slice),
                tune::Kernel::Blocked => {
                    blocked::d2_update_min_blocked(&sh.points, row, &sh.norms, slice)
                }
            }
        }
    };
    if sd.shard_size() > crate::shard::OUTER_PARALLEL_MAX_SHARD {
        let mut lo = 0;
        for (s, &hi) in ends.iter().enumerate() {
            apply(s, &mut cur_d2[lo..hi]);
            lo = hi;
        }
    } else {
        parallel_slices_mut(cur_d2, ends, apply);
    }
}

/// The in-process [`RoundExecutor`]: k-means‖ rounds over a
/// [`ShardedDataset`], exactly the engine `kmeans_par` has always run —
/// now behind the same trait as the multi-process coordinator
/// ([`crate::dist::coordinator::DistCoordinator`]), so the two
/// transports share one round driver ([`crate::dist::run_rounds`]) and
/// cannot drift. Infallible in practice; the `Result`s exist for the
/// transport that can fail.
pub struct LocalShardExecutor {
    sharded: ShardedDataset,
    ends: Vec<usize>,
    /// Update kernel, resolved once on the global shape (the invariance
    /// contract — see the module docs).
    upd_kernel: tune::Kernel,
    n: usize,
    dim: usize,
    cur_d2: Vec<f32>,
    is_candidate: Vec<bool>,
}

impl LocalShardExecutor {
    /// Partition `ps` into (at most) `shards` contiguous shards and
    /// resolve the update kernel on the global shape.
    pub fn new(ps: &PointSet, shards: usize) -> LocalShardExecutor {
        let n = ps.len();
        let sharded = ShardedDataset::partition(ps, shards);
        let ends = sharded.boundaries();
        // Resolve both kernel implementations once, on the GLOBAL shape:
        // per-shard dispatch would couple the implementation (and its f32
        // rounding) to the shard size, breaking shard-count invariance.
        let upd_kernel = tune::kernel_for(tune::Op::Update, n, ps.dim(), 1);
        LocalShardExecutor {
            sharded,
            ends,
            upd_kernel,
            n,
            dim: ps.dim(),
            cur_d2: vec![f32::INFINITY; n],
            is_candidate: vec![false; n],
        }
    }
}

impl RoundExecutor for LocalShardExecutor {
    fn update(&mut self, indices: &[usize], rows: &PointSet) -> Result<Vec<f64>> {
        for &i in indices {
            self.is_candidate[i] = true;
        }
        update_shards(
            &self.sharded,
            self.upd_kernel,
            &self.ends,
            rows,
            &mut self.cur_d2,
        );
        // Global cost partials at fixed block boundaries — summing them
        // left-to-right is sum_f32 on the global D² array.
        Ok(reduce::block_sums(&self.cur_d2, reduce::SUM_BLOCK))
    }

    fn sample(&mut self, round_tag: u64, cost: f64, ell: f64) -> Result<Vec<usize>> {
        let sharded = &self.sharded;
        let cur_d2 = &self.cur_d2;
        let is_candidate = &self.is_candidate;
        // Every shard thins its own slice; merging per-shard candidate
        // lists in shard order IS ascending global-index order.
        let per_shard: Vec<Vec<usize>> = parallel_map(sharded.num_shards(), |s| {
            let sh = &sharded.shards()[s];
            let mut local = Vec::new();
            for r in 0..sh.len() {
                let i = sh.offset + r;
                if is_candidate[i] {
                    continue;
                }
                let di = cur_d2[i] as f64;
                if di <= 0.0 {
                    continue;
                }
                if point_uniform(round_tag, i as u64) * cost < ell * di {
                    local.push(i);
                }
            }
            local
        });
        Ok(per_shard.into_iter().flatten().collect())
    }

    fn weigh(&mut self, candidates: &PointSet) -> Result<Vec<u64>> {
        let sharded = &self.sharded;
        let asg_kernel = tune::kernel_for(tune::Op::Assign, self.n, self.dim, candidates.len());
        let cand_norms = norms::squared_norms(candidates);
        let shard_counts = |s: usize| {
            let sh = &sharded.shards()[s];
            let (labels, _) = match asg_kernel {
                tune::Kernel::Naive => assign::assign_argmin_naive(&sh.points, candidates),
                tune::Kernel::Blocked => {
                    blocked::assign_argmin_blocked(&sh.points, &sh.norms, candidates, &cand_norms)
                }
            };
            let mut counts = vec![0u64; candidates.len()];
            for &l in &labels {
                counts[l as usize] += 1;
            }
            counts
        };
        // Same single-parallel-layer policy as update_shards: the assign
        // kernel parallelizes internally on big shards.
        let per_shard_counts: Vec<Vec<u64>> =
            if sharded.shard_size() > crate::shard::OUTER_PARALLEL_MAX_SHARD {
                (0..sharded.num_shards()).map(shard_counts).collect()
            } else {
                parallel_map(sharded.num_shards(), shard_counts)
            };
        let mut weights = vec![0u64; candidates.len()];
        for counts in per_shard_counts {
            for (w, c) in weights.iter_mut().zip(counts) {
                *w += c;
            }
        }
        Ok(weights)
    }
}

/// k-means‖ seeding: `R` oversampling rounds over `S` data shards, then
/// a weighted k-means++ recluster of the candidates down to `k`. See the
/// module docs for the lifecycle and the invariance contract. Round
/// counters and timings land in the process-wide metrics sink
/// ([`crate::metrics::global`], `shard.*` — surfaced by `fkmpp serve`
/// `/metrics`).
pub fn kmeans_par(ps: &PointSet, k: usize, cfg: &KMeansParConfig, rng: &mut Pcg64) -> Seeding {
    if k.min(ps.len()) == 0 {
        metrics::global().incr("shard.runs", 1);
        return Seeding::from_indices(ps, Vec::new(), SeedingStats::default());
    }
    let t0 = Instant::now();
    let mut exec = {
        let _s = crate::trace::Span::enter_with(
            "shard.init",
            vec![("n", ps.len().into()), ("shards", cfg.shards.into())],
        );
        LocalShardExecutor::new(ps, cfg.shards)
    };
    let init_secs = t0.elapsed().as_secs_f64();
    run_rounds(ps, k, cfg.rounds, cfg.oversample, &mut exec, init_secs, rng)
        .expect("the in-process round executor is infallible")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, separated_grid, SynthSpec};
    use crate::lloyd::cost_native;

    fn mixture(n: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d: 6,
                k_true: 8,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn returns_k_distinct_valid_indices() {
        let ps = mixture(2_000, 1);
        for shards in [1usize, 3, 8] {
            let cfg = KMeansParConfig {
                shards,
                ..Default::default()
            };
            let mut rng = Pcg64::seed_from(7);
            let s = kmeans_par(&ps, 20, &cfg, &mut rng);
            assert_eq!(s.k(), 20, "shards={shards}");
            let mut idx = s.indices.clone();
            idx.sort_unstable();
            idx.dedup();
            assert_eq!(idx.len(), 20, "shards={shards}: duplicate centers");
            assert!(idx.iter().all(|&i| i < ps.len()));
        }
    }

    #[test]
    fn bitwise_invariant_to_shard_count() {
        let ps = mixture(3_000, 2);
        let base = {
            let mut rng = Pcg64::seed_from(11);
            kmeans_par(
                &ps,
                16,
                &KMeansParConfig {
                    shards: 1,
                    ..Default::default()
                },
                &mut rng,
            )
        };
        for shards in [2usize, 4, 7] {
            let mut rng = Pcg64::seed_from(11);
            let s = kmeans_par(
                &ps,
                16,
                &KMeansParConfig {
                    shards,
                    ..Default::default()
                },
                &mut rng,
            );
            assert_eq!(s.indices, base.indices, "shards={shards}");
            assert_eq!(s.centers, base.centers, "shards={shards}");
        }
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let ps = mixture(1_500, 3);
        let cfg = KMeansParConfig::default();
        let mut r1 = Pcg64::seed_from(5);
        let mut r2 = Pcg64::seed_from(5);
        let a = kmeans_par(&ps, 12, &cfg, &mut r1);
        let b = kmeans_par(&ps, 12, &cfg, &mut r2);
        assert_eq!(a.indices, b.indices);
    }

    #[test]
    fn covers_separated_clusters() {
        // Oversampling + weighted recluster must find every cluster of a
        // hugely separated instance essentially always.
        let ps = separated_grid(8, 60, 3, 21);
        let mut hits = 0;
        for seed in 0..10u64 {
            let mut rng = Pcg64::seed_from(seed);
            let s = kmeans_par(&ps, 8, &KMeansParConfig::default(), &mut rng);
            let mut clusters: Vec<usize> = s.indices.iter().map(|&i| i / 60).collect();
            clusters.sort_unstable();
            clusters.dedup();
            if clusters.len() == 8 {
                hits += 1;
            }
        }
        assert!(hits >= 9, "only {hits}/10 runs covered all clusters");
    }

    #[test]
    fn quality_close_to_exact_kmeanspp() {
        let ps = mixture(4_000, 4);
        let (mut par, mut exact) = (0.0, 0.0);
        for seed in 0..5u64 {
            let mut r1 = Pcg64::seed_from(300 + seed);
            par += cost_native(
                &ps,
                &kmeans_par(&ps, 24, &KMeansParConfig::default(), &mut r1).centers,
            );
            let mut r2 = Pcg64::seed_from(400 + seed);
            exact += cost_native(
                &ps,
                &crate::seeding::kmeanspp::kmeanspp(&ps, 24, &mut r2).centers,
            );
        }
        assert!(
            par <= 1.3 * exact,
            "kmeans_par {par:.4e} far worse than exact {exact:.4e}"
        );
    }

    #[test]
    fn k_larger_than_n_clamps_and_k_zero_is_empty() {
        let ps = mixture(15, 5);
        let mut rng = Pcg64::seed_from(6);
        let s = kmeans_par(&ps, 50, &KMeansParConfig::default(), &mut rng);
        assert_eq!(s.k(), 15);
        let mut idx = s.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 15);
        let empty = kmeans_par(&ps, 0, &KMeansParConfig::default(), &mut rng);
        assert_eq!(empty.k(), 0);
    }

    #[test]
    fn records_round_metrics() {
        // Counters accumulate process-wide; assert deltas via snapshot so
        // concurrent unit tests can't make this flaky.
        let before = crate::metrics::CounterSnapshot::of(metrics::global());
        let ps = mixture(800, 7);
        let mut rng = Pcg64::seed_from(9);
        let cfg = KMeansParConfig {
            rounds: 3,
            ..Default::default()
        };
        kmeans_par(&ps, 10, &cfg, &mut rng);
        let m = metrics::global();
        assert!(before.delta(m, "shard.rounds") >= 1, "no shard rounds recorded");
        assert!(before.delta(m, "shard.runs") >= 1);
    }
}
