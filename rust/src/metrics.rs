//! Lightweight metrics: scoped wall-clock timers, counters, and the
//! mean/variance accumulators the paper's Tables 7–8 report.
//!
//! Everything is plain `std` (no external deps in the offline build) and
//! cheap enough to leave enabled on the hot path — counters are single
//! adds; timers are two `Instant::now()` calls around coarse phases only.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Online mean/variance (Welford). Used for the repeated-run statistics in
/// Tables 4–8 and for bench reporting.
#[derive(Clone, Debug)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

/// Must match `new()`: a derived `Default` would seed `min`/`max` at
/// 0.0, and `record_duration`'s `.or_default()` entry would then clamp
/// every reported timing minimum to 0.0.
impl Default for Stats {
    fn default() -> Self {
        Stats::new()
    }
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (the paper reports variance over 5 runs).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// A scoped timer: measures from construction to `stop()`/drop and records
/// into a [`Metrics`] sink.
pub struct ScopedTimer<'a> {
    metrics: &'a Metrics,
    name: &'static str,
    start: Instant,
    stopped: bool,
}

impl<'a> ScopedTimer<'a> {
    pub fn stop(mut self) -> Duration {
        self.stopped = true;
        let elapsed = self.start.elapsed();
        self.metrics.record_duration(self.name, elapsed);
        elapsed
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        if !self.stopped {
            self.metrics.record_duration(self.name, self.start.elapsed());
        }
    }
}

/// Number of finite log₂ buckets in a [`Histogram`]; one overflow slot
/// follows them.
pub const HIST_BUCKETS: usize = 32;
/// Exponent of the first finite upper edge: bucket `i` covers
/// `(2^(HIST_MIN_EXP+i-1), 2^(HIST_MIN_EXP+i)]` seconds, so the edges
/// run `2^-20 s` (≈0.95 µs) through `2^11 s` (2048 s).
pub const HIST_MIN_EXP: i32 = -20;

/// Log₂-bucketed latency histogram with p50/p90/p99 estimation.
///
/// Bucket edges are **fixed powers of two**, identical for every
/// instance, so merging histograms from different threads, shards, or
/// processes is exact: counts add, no re-bucketing, no drift. This is
/// what lets `/metrics` expose Prometheus `_bucket` series whose sums
/// across scrapes stay consistent. Used where latency *distributions*
/// matter (server request handling, dist RPC round-trips, oracle probe
/// timing); [`Stats`] remains the tool for mean/variance over runs.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// `counts[i]` for the finite buckets, `counts[HIST_BUCKETS]` for
    /// the overflow (`+Inf`) bucket. Non-cumulative.
    counts: [u64; HIST_BUCKETS + 1],
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: [0; HIST_BUCKETS + 1],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Upper edge of finite bucket `i`, in seconds.
    pub fn edge(i: usize) -> f64 {
        (2.0f64).powi(HIST_MIN_EXP + i as i32)
    }

    fn bucket_index(x: f64) -> usize {
        if x <= 0.0 || !x.is_finite() {
            return if x.is_finite() { 0 } else { HIST_BUCKETS };
        }
        // Smallest i with x <= 2^(HIST_MIN_EXP + i). log2 of an exact
        // power of two is exact in f64, so edge values land in their
        // own (le-inclusive) bucket.
        let i = (x.log2().ceil() as i64) - HIST_MIN_EXP as i64;
        if i < 0 {
            0
        } else if i as usize >= HIST_BUCKETS {
            HIST_BUCKETS
        } else {
            i as usize
        }
    }

    pub fn observe(&mut self, x: f64) {
        self.counts[Self::bucket_index(x)] += 1;
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn observe_duration(&mut self, d: Duration) {
        self.observe(d.as_secs_f64());
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Per-bucket (non-cumulative) counts; the last slot is overflow.
    pub fn bucket_counts(&self) -> &[u64; HIST_BUCKETS + 1] {
        &self.counts
    }

    /// Quantile estimate (`q` in `[0, 1]`): locate the bucket holding
    /// the target rank, then interpolate geometrically inside it (the
    /// buckets are log-spaced). Clamped to the observed `[min, max]`,
    /// so p50/p99 can never fall outside real data. 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let before = cum;
            cum += c;
            if (cum as f64) < target {
                continue;
            }
            let lo = if i == 0 { 0.0 } else { Self::edge(i - 1) };
            let hi = if i >= HIST_BUCKETS {
                self.max.max(Self::edge(HIST_BUCKETS - 1))
            } else {
                Self::edge(i)
            };
            let frac = ((target - before as f64) / c as f64).clamp(0.0, 1.0);
            let est = if lo > 0.0 && hi > lo {
                lo * (hi / lo).powf(frac)
            } else {
                lo + frac * (hi - lo)
            };
            return est.clamp(self.min, self.max);
        }
        self.max
    }

    /// Exact merge — bucket edges are shared constants, so counts add
    /// with zero re-bucketing error.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// A scoped latency timer: like [`ScopedTimer`] but records into a
/// named [`Histogram`] on the sink instead of a [`Stats`] entry.
pub struct ScopedLatencyTimer<'a> {
    metrics: &'a Metrics,
    name: &'static str,
    start: Instant,
    stopped: bool,
}

impl<'a> ScopedLatencyTimer<'a> {
    pub fn stop(mut self) -> Duration {
        self.stopped = true;
        let elapsed = self.start.elapsed();
        self.metrics.record_latency(self.name, elapsed);
        elapsed
    }
}

impl Drop for ScopedLatencyTimer<'_> {
    fn drop(&mut self) {
        if !self.stopped {
            self.metrics.record_latency(self.name, self.start.elapsed());
        }
    }
}

/// Thread-safe metrics sink: named counters, duration statistics, and
/// latency histograms.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<&'static str, u64>>,
    timings: Mutex<BTreeMap<&'static str, Stats>>,
    histograms: Mutex<BTreeMap<&'static str, Histogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn incr(&self, name: &'static str, by: u64) {
        *self.counters.lock().unwrap().entry(name).or_insert(0) += by;
    }

    pub fn counter(&self, name: &'static str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn timer(&self, name: &'static str) -> ScopedTimer<'_> {
        ScopedTimer {
            metrics: self,
            name,
            start: Instant::now(),
            stopped: false,
        }
    }

    pub fn record_duration(&self, name: &'static str, d: Duration) {
        self.timings
            .lock()
            .unwrap()
            .entry(name)
            .or_default()
            .push(d.as_secs_f64());
    }

    pub fn duration_stats(&self, name: &'static str) -> Option<Stats> {
        self.timings.lock().unwrap().get(name).cloned()
    }

    /// Record one observation (seconds) into the named histogram.
    pub fn observe(&self, name: &'static str, x: f64) {
        self.histograms
            .lock()
            .unwrap()
            .entry(name)
            .or_default()
            .observe(x);
    }

    /// Record a latency sample into the named histogram.
    pub fn record_latency(&self, name: &'static str, d: Duration) {
        self.observe(name, d.as_secs_f64());
    }

    /// Scoped timer that records into the named histogram on drop.
    pub fn latency_timer(&self, name: &'static str) -> ScopedLatencyTimer<'_> {
        ScopedLatencyTimer {
            metrics: self,
            name,
            start: Instant::now(),
            stopped: false,
        }
    }

    pub fn histogram(&self, name: &'static str) -> Option<Histogram> {
        self.histograms.lock().unwrap().get(name).cloned()
    }

    /// Snapshot of every histogram, name-ordered.
    pub fn histograms_snapshot(&self) -> Vec<(&'static str, Histogram)> {
        self.histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(&k, h)| (k, h.clone()))
            .collect()
    }

    /// Snapshot of every counter, name-ordered (the `/metrics` endpoint
    /// and other machine-readable sinks).
    pub fn counters_snapshot(&self) -> Vec<(&'static str, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(&k, &v)| (k, v))
            .collect()
    }

    /// Snapshot of every duration statistic, name-ordered.
    pub fn timings_snapshot(&self) -> Vec<(&'static str, Stats)> {
        self.timings
            .lock()
            .unwrap()
            .iter()
            .map(|(&k, s)| (k, s.clone()))
            .collect()
    }

    /// Render all metrics as aligned text (CLI `--metrics` output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                out.push_str(&format!("  {k:<40} {v}\n"));
            }
        }
        let timings = self.timings.lock().unwrap();
        if !timings.is_empty() {
            out.push_str("timings (seconds):\n");
            for (k, s) in timings.iter() {
                out.push_str(&format!(
                    "  {k:<40} n={:<4} mean={:.6} min={:.6} max={:.6}\n",
                    s.count(),
                    s.mean(),
                    s.min(),
                    s.max()
                ));
            }
        }
        let histograms = self.histograms.lock().unwrap();
        if !histograms.is_empty() {
            out.push_str("latency histograms (seconds):\n");
            for (k, h) in histograms.iter() {
                out.push_str(&format!(
                    "  {k:<40} n={:<4} mean={:.6} p50={:.6} p90={:.6} p99={:.6} max={:.6}\n",
                    h.count(),
                    h.mean(),
                    h.quantile(0.50),
                    h.quantile(0.90),
                    h.quantile(0.99),
                    h.max()
                ));
            }
        }
        out
    }
}

/// Point-in-time counter snapshot for delta assertions against a shared
/// sink. [`global()`] counters only accumulate — other tests, spans, or
/// histogram traffic running in the same process can bump them at any
/// time — so tests must assert `snapshot.delta(...) >= expected`, never
/// absolute values.
#[derive(Clone, Debug, Default)]
pub struct CounterSnapshot {
    at: BTreeMap<&'static str, u64>,
}

impl CounterSnapshot {
    pub fn of(metrics: &Metrics) -> Self {
        CounterSnapshot {
            at: metrics.counters_snapshot().into_iter().collect(),
        }
    }

    /// How much `name` has grown on `metrics` since this snapshot.
    pub fn delta(&self, metrics: &Metrics, name: &'static str) -> u64 {
        metrics
            .counter(name)
            .saturating_sub(self.at.get(name).copied().unwrap_or(0))
    }
}

/// A metric name valid for Prometheus exposition: `[a-zA-Z_:]` first,
/// `[a-zA-Z0-9_:]` after. Dotted internal names (`shard.rounds`) map to
/// underscores, and everything gets the `fkmpp_` namespace prefix.
pub fn prometheus_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 6);
    out.push_str("fkmpp_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn prometheus_f64(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x == f64::INFINITY {
        "+Inf".to_string()
    } else if x == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else if x != 0.0 && x.abs() < 1e-3 {
        // Sub-millisecond bucket edges: exponent form keeps the labels
        // readable (9.5367431640625e-7, not 22 digits of decimals).
        format!("{x:e}")
    } else {
        format!("{x}")
    }
}

/// Render merged metric snapshots in the Prometheus text exposition
/// format (v0.0.4): gauges, `_total` counters, [`Stats`] as summaries
/// (`_sum`/`_count`), and [`Histogram`]s as cumulative `_bucket{le=…}`
/// series ending in `le="+Inf"` plus `_sum`/`_count`.
pub fn render_prometheus(
    gauges: &[(String, f64)],
    counters: &[(&'static str, u64)],
    timings: &[(&'static str, Stats)],
    histograms: &[(&'static str, Histogram)],
) -> String {
    let mut out = String::new();
    for (name, value) in gauges {
        let n = prometheus_name(name);
        out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", prometheus_f64(*value)));
    }
    for (name, value) in counters {
        let n = format!("{}_total", prometheus_name(name));
        out.push_str(&format!("# TYPE {n} counter\n{n} {value}\n"));
    }
    for (name, s) in timings {
        if s.count() == 0 {
            continue;
        }
        let n = prometheus_name(name);
        out.push_str(&format!(
            "# TYPE {n} summary\n{n}_sum {}\n{n}_count {}\n",
            prometheus_f64(s.mean() * s.count() as f64),
            s.count()
        ));
    }
    for (name, h) in histograms {
        let n = prometheus_name(name);
        out.push_str(&format!("# TYPE {n} histogram\n"));
        let mut cum = 0u64;
        for (i, &c) in h.bucket_counts().iter().enumerate() {
            cum += c;
            let le = if i >= HIST_BUCKETS {
                "+Inf".to_string()
            } else {
                prometheus_f64(Histogram::edge(i))
            };
            out.push_str(&format!("{n}_bucket{{le=\"{le}\"}} {cum}\n"));
        }
        out.push_str(&format!(
            "{n}_sum {}\n{n}_count {}\n",
            prometheus_f64(h.sum()),
            h.count()
        ));
    }
    out
}

/// Process-wide metrics sink for components that run without a context
/// handle — the sharded seeding engine ([`crate::shard`]) records its
/// round counters and timings here from wherever it is invoked (CLI,
/// benches, or a server fit worker). `fkmpp serve` merges this sink into
/// the `/metrics` payload, so shard-round counters are observable after
/// a `kmeans_par` fit. Counters only ever accumulate; readers must
/// assert deltas or lower bounds, not absolute values.
pub fn global() -> &'static Metrics {
    static GLOBAL: std::sync::OnceLock<Metrics> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(Metrics::new)
}

/// Format a duration as human-readable seconds/millis/micros.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_variance() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 4.0).abs() < 1e-12);
        assert!((s.stddev() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn sample_variance_bessel() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0] {
            s.push(x);
        }
        assert!((s.sample_variance() - 1.0).abs() < 1e-12);
        assert!((s.variance() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("lsh.collisions", 3);
        m.incr("lsh.collisions", 4);
        assert_eq!(m.counter("lsh.collisions"), 7);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timer_records() {
        let m = Metrics::new();
        {
            let _t = m.timer("phase");
        }
        let t = m.timer("phase");
        let d = t.stop();
        assert!(d.as_nanos() > 0 || d.as_nanos() == 0); // no panic path
        let s = m.duration_stats("phase").unwrap();
        assert_eq!(s.count(), 2);
    }

    #[test]
    fn snapshots_are_name_ordered() {
        let m = Metrics::new();
        m.incr("b", 2);
        m.incr("a", 1);
        m.record_duration("t", Duration::from_millis(1));
        assert_eq!(m.counters_snapshot(), vec![("a", 1), ("b", 2)]);
        let timings = m.timings_snapshot();
        assert_eq!(timings.len(), 1);
        assert_eq!(timings[0].0, "t");
        assert_eq!(timings[0].1.count(), 1);
    }

    #[test]
    fn render_contains_entries() {
        let m = Metrics::new();
        m.incr("x", 1);
        m.record_duration("y", Duration::from_millis(5));
        m.record_latency("z", Duration::from_millis(2));
        let out = m.render();
        assert!(out.contains('x') && out.contains('y'));
        assert!(out.contains("p99="), "histogram line missing: {out}");
    }

    /// Regression: the derived `Default` seeded min/max at 0.0, so the
    /// first `record_duration` (which goes through `.or_default()`)
    /// clamped every reported minimum to 0.0.
    #[test]
    fn default_stats_match_new_so_minima_are_real() {
        let d = Stats::default();
        assert_eq!(d.min(), f64::INFINITY);
        assert_eq!(d.max(), f64::NEG_INFINITY);
        let m = Metrics::new();
        m.record_duration("t", Duration::from_millis(8));
        let s = m.duration_stats("t").unwrap();
        assert!(s.min() > 0.007, "min clamped to {}", s.min());
        assert!(s.max() > 0.007, "max clamped to {}", s.max());
    }

    #[test]
    fn fmt_duration_tiers() {
        assert_eq!(fmt_duration(Duration::from_secs_f64(1.5)), "1.500s");
        assert_eq!(fmt_duration(Duration::from_secs_f64(1.0)), "1.000s");
        assert_eq!(fmt_duration(Duration::from_secs_f64(0.5)), "500.000ms");
        assert_eq!(fmt_duration(Duration::from_secs_f64(1e-3)), "1.000ms");
        assert_eq!(fmt_duration(Duration::from_secs_f64(12e-6)), "12.000us");
        assert_eq!(fmt_duration(Duration::from_secs_f64(999e-6)), "999.000us");
        assert_eq!(fmt_duration(Duration::ZERO), "0.000us");
    }

    #[test]
    fn histogram_buckets_are_le_inclusive_powers_of_two() {
        let mut h = Histogram::new();
        // An exact edge value must land in the bucket it bounds.
        h.observe(Histogram::edge(5));
        assert_eq!(h.bucket_counts()[5], 1);
        // Just above an edge spills into the next bucket.
        let mut h2 = Histogram::new();
        h2.observe(Histogram::edge(5) * 1.0001);
        assert_eq!(h2.bucket_counts()[6], 1);
        // Below the smallest edge, at/below zero, and past the largest
        // edge all land somewhere (no panics, no lost samples).
        let mut h3 = Histogram::new();
        h3.observe(0.0);
        h3.observe(1e-12);
        h3.observe(1e9);
        assert_eq!(h3.count(), 3);
        assert_eq!(h3.bucket_counts()[0], 2);
        assert_eq!(h3.bucket_counts()[HIST_BUCKETS], 1);
    }

    #[test]
    fn histogram_quantiles_bracket_the_data() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.observe(i as f64 * 1e-3); // 1ms .. 1s
        }
        let (p50, p90, p99) = (h.quantile(0.50), h.quantile(0.90), h.quantile(0.99));
        assert!(p50 >= h.min() && p50 <= h.max());
        assert!(p50 <= p90 && p90 <= p99, "p50={p50} p90={p90} p99={p99}");
        // Log buckets: estimates are within one bucket (2x) of truth.
        assert!(p50 > 0.25 && p50 < 1.0, "p50={p50}");
        assert!(p99 > 0.5 && p99 <= 1.0, "p99={p99}");
        assert_eq!(h.quantile(1.0), h.max());
        assert_eq!(Histogram::new().quantile(0.5), 0.0);
    }

    #[test]
    fn histogram_merge_is_exact() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for i in 0..200u64 {
            let x = (i as f64 + 1.0) * 3.7e-5;
            if i % 2 == 0 { &mut a } else { &mut b }.observe(x);
            whole.observe(x);
        }
        a.merge(&b);
        assert_eq!(a.bucket_counts(), whole.bucket_counts());
        assert_eq!(a.count(), whole.count());
        // Bucket merges are exact; the f64 sum is only order-sensitive.
        assert!((a.sum() - whole.sum()).abs() < 1e-12);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn latency_timer_records_into_histogram() {
        let m = Metrics::new();
        {
            let _t = m.latency_timer("rpc");
        }
        let t = m.latency_timer("rpc");
        t.stop();
        let h = m.histogram("rpc").unwrap();
        assert_eq!(h.count(), 2);
        assert!(m.histograms_snapshot().iter().any(|(k, _)| *k == "rpc"));
    }

    #[test]
    fn prometheus_rendering_is_well_formed() {
        let mut h = Histogram::new();
        for x in [1e-4, 2e-4, 5e-2, 1.5] {
            h.observe(x);
        }
        let mut s = Stats::new();
        s.push(0.25);
        s.push(0.75);
        let out = render_prometheus(
            &[("uptime_seconds".to_string(), 12.5)],
            &[("shard.rounds", 7)],
            &[("shard.round_secs", s)],
            &[("http.latency_secs", h)],
        );
        assert!(out.contains("# TYPE fkmpp_uptime_seconds gauge\n"));
        assert!(out.contains("fkmpp_uptime_seconds 12.5\n"));
        assert!(out.contains("# TYPE fkmpp_shard_rounds_total counter\n"));
        assert!(out.contains("fkmpp_shard_rounds_total 7\n"));
        assert!(out.contains("fkmpp_shard_round_secs_sum 1\n"));
        assert!(out.contains("fkmpp_shard_round_secs_count 2\n"));
        assert!(out.contains("# TYPE fkmpp_http_latency_secs histogram\n"));
        assert!(out.contains("fkmpp_http_latency_secs_bucket{le=\"+Inf\"} 4\n"));
        assert!(out.contains("fkmpp_http_latency_secs_count 4\n"));
        // Every emitted name matches the Prometheus grammar and every
        // _bucket series is cumulative-monotone.
        let name_ok = |n: &str| {
            let mut cs = n.chars();
            let first = cs.next().unwrap();
            (first.is_ascii_alphabetic() || first == '_' || first == ':')
                && cs.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        };
        let mut last_bucket = 0u64;
        for line in out.lines() {
            if line.starts_with("# ") {
                continue;
            }
            let name = line.split(['{', ' ']).next().unwrap();
            assert!(name_ok(name), "bad metric name in {line:?}");
            if line.contains("_bucket{") {
                let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= last_bucket, "non-monotone bucket: {line}");
                last_bucket = v;
            }
        }
        assert_eq!(prometheus_name("dist.worker.rpc_secs"), "fkmpp_dist_worker_rpc_secs");
    }

    #[test]
    fn counter_snapshot_deltas_ignore_prior_traffic() {
        let m = Metrics::new();
        m.incr("a", 5);
        let snap = CounterSnapshot::of(&m);
        assert_eq!(snap.delta(&m, "a"), 0);
        assert_eq!(snap.delta(&m, "never_seen"), 0);
        m.incr("a", 3);
        m.incr("b", 2);
        assert_eq!(snap.delta(&m, "a"), 3);
        assert_eq!(snap.delta(&m, "b"), 2);
    }
}
