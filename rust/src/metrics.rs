//! Lightweight metrics: scoped wall-clock timers, counters, and the
//! mean/variance accumulators the paper's Tables 7–8 report.
//!
//! Everything is plain `std` (no external deps in the offline build) and
//! cheap enough to leave enabled on the hot path — counters are single
//! adds; timers are two `Instant::now()` calls around coarse phases only.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Online mean/variance (Welford). Used for the repeated-run statistics in
/// Tables 4–8 and for bench reporting.
#[derive(Clone, Debug, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (the paper reports variance over 5 runs).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// A scoped timer: measures from construction to `stop()`/drop and records
/// into a [`Metrics`] sink.
pub struct ScopedTimer<'a> {
    metrics: &'a Metrics,
    name: &'static str,
    start: Instant,
    stopped: bool,
}

impl<'a> ScopedTimer<'a> {
    pub fn stop(mut self) -> Duration {
        self.stopped = true;
        let elapsed = self.start.elapsed();
        self.metrics.record_duration(self.name, elapsed);
        elapsed
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        if !self.stopped {
            self.metrics.record_duration(self.name, self.start.elapsed());
        }
    }
}

/// Thread-safe metrics sink: named counters and duration statistics.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<&'static str, u64>>,
    timings: Mutex<BTreeMap<&'static str, Stats>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn incr(&self, name: &'static str, by: u64) {
        *self.counters.lock().unwrap().entry(name).or_insert(0) += by;
    }

    pub fn counter(&self, name: &'static str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn timer(&self, name: &'static str) -> ScopedTimer<'_> {
        ScopedTimer {
            metrics: self,
            name,
            start: Instant::now(),
            stopped: false,
        }
    }

    pub fn record_duration(&self, name: &'static str, d: Duration) {
        self.timings
            .lock()
            .unwrap()
            .entry(name)
            .or_default()
            .push(d.as_secs_f64());
    }

    pub fn duration_stats(&self, name: &'static str) -> Option<Stats> {
        self.timings.lock().unwrap().get(name).cloned()
    }

    /// Snapshot of every counter, name-ordered (the `/metrics` endpoint
    /// and other machine-readable sinks).
    pub fn counters_snapshot(&self) -> Vec<(&'static str, u64)> {
        self.counters
            .lock()
            .unwrap()
            .iter()
            .map(|(&k, &v)| (k, v))
            .collect()
    }

    /// Snapshot of every duration statistic, name-ordered.
    pub fn timings_snapshot(&self) -> Vec<(&'static str, Stats)> {
        self.timings
            .lock()
            .unwrap()
            .iter()
            .map(|(&k, s)| (k, s.clone()))
            .collect()
    }

    /// Render all metrics as aligned text (CLI `--metrics` output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let counters = self.counters.lock().unwrap();
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                out.push_str(&format!("  {k:<40} {v}\n"));
            }
        }
        let timings = self.timings.lock().unwrap();
        if !timings.is_empty() {
            out.push_str("timings (seconds):\n");
            for (k, s) in timings.iter() {
                out.push_str(&format!(
                    "  {k:<40} n={:<4} mean={:.6} min={:.6} max={:.6}\n",
                    s.count(),
                    s.mean(),
                    s.min(),
                    s.max()
                ));
            }
        }
        out
    }
}

/// Process-wide metrics sink for components that run without a context
/// handle — the sharded seeding engine ([`crate::shard`]) records its
/// round counters and timings here from wherever it is invoked (CLI,
/// benches, or a server fit worker). `fkmpp serve` merges this sink into
/// the `/metrics` payload, so shard-round counters are observable after
/// a `kmeans_par` fit. Counters only ever accumulate; readers must
/// assert deltas or lower bounds, not absolute values.
pub fn global() -> &'static Metrics {
    static GLOBAL: std::sync::OnceLock<Metrics> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(Metrics::new)
}

/// Format a duration as human-readable seconds/millis.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else {
        format!("{:.3}ms", s * 1e3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_mean_variance() {
        let mut s = Stats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 4.0).abs() < 1e-12);
        assert!((s.stddev() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn sample_variance_bessel() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0] {
            s.push(x);
        }
        assert!((s.sample_variance() - 1.0).abs() < 1e-12);
        assert!((s.variance() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("lsh.collisions", 3);
        m.incr("lsh.collisions", 4);
        assert_eq!(m.counter("lsh.collisions"), 7);
        assert_eq!(m.counter("missing"), 0);
    }

    #[test]
    fn timer_records() {
        let m = Metrics::new();
        {
            let _t = m.timer("phase");
        }
        let t = m.timer("phase");
        let d = t.stop();
        assert!(d.as_nanos() > 0 || d.as_nanos() == 0); // no panic path
        let s = m.duration_stats("phase").unwrap();
        assert_eq!(s.count(), 2);
    }

    #[test]
    fn snapshots_are_name_ordered() {
        let m = Metrics::new();
        m.incr("b", 2);
        m.incr("a", 1);
        m.record_duration("t", Duration::from_millis(1));
        assert_eq!(m.counters_snapshot(), vec![("a", 1), ("b", 2)]);
        let timings = m.timings_snapshot();
        assert_eq!(timings.len(), 1);
        assert_eq!(timings[0].0, "t");
        assert_eq!(timings[0].1.count(), 1);
    }

    #[test]
    fn render_contains_entries() {
        let m = Metrics::new();
        m.incr("x", 1);
        m.record_duration("y", Duration::from_millis(5));
        let out = m.render();
        assert!(out.contains('x') && out.contains('y'));
    }
}
