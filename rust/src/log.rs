//! Structured logging + flight recorder.
//!
//! Every operational message in the crate goes through this module as a
//! **leveled JSON-lines event**: one strict-JSON object per line on
//! stderr, rendered through `server/json.rs` (the crate's single
//! serialization point), with a fixed envelope —
//!
//! ```text
//! {"ts_ms":<unix millis>,"level":"warn","event":"serve.accept_error",
//!  "trace_id":"1f2e…",<caller fields…>}
//! ```
//!
//! — so operational errors are machine-parseable instead of free-form
//! `eprintln!` text. The stderr threshold comes from `FKMPP_LOG`
//! (`error|warn|info|debug|off`) or the CLI `--log-level` flag and
//! defaults to `info`.
//!
//! Underneath the threshold sits the **flight recorder**: a fixed-size
//! ring buffer that records *every* event regardless of level, so the
//! recent debug-grade history is available post-mortem. It is dumped to
//! stderr on panic ([`install_panic_hook`]) and on fatal CLI errors
//! (`main.rs`), and served live at `GET /debug/log` by `fkmpp serve`.
//!
//! Determinism contract (same as `trace.rs`): logging reads only the
//! wall clock, never the RNG, and call sites live only at coarse
//! operational boundaries — a logged run is bitwise identical to a
//! silent one.

use std::collections::VecDeque;
use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::server::json::Json;

/// Severity levels, most severe first. `Off` silences stderr entirely
/// (the flight recorder still records).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Off = 4,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "off" | "none" => Some(Level::Off),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Off => "off",
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Off,
        }
    }
}

/// Stderr threshold. `u8::MAX` = "not yet initialized from the env".
static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

/// Events the flight recorder keeps. Small enough that a dump is
/// readable, large enough to cover the lead-up to a crash.
pub const RING_CAPACITY: usize = 256;

fn ring() -> &'static Mutex<VecDeque<String>> {
    static RING: OnceLock<Mutex<VecDeque<String>>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(VecDeque::with_capacity(RING_CAPACITY)))
}

/// Set the stderr threshold explicitly (`--log-level`). Wins over the
/// environment.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current stderr threshold, initializing from `FKMPP_LOG` on first
/// use. An unset or unparseable variable means `info`.
pub fn level() -> Level {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return Level::from_u8(v);
    }
    let from_env = std::env::var("FKMPP_LOG")
        .ok()
        .and_then(|s| Level::parse(s.trim()))
        .unwrap_or(Level::Info);
    // Racing first-callers agree on the env value, so last-write-wins
    // is fine here.
    LEVEL.store(from_env as u8, Ordering::Relaxed);
    from_env
}

fn unix_ms() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64() * 1e3)
        .unwrap_or(0.0)
}

/// Render one event as its JSON line. The envelope keys come first so
/// `grep '"event":"…"'` and jq pipelines see a stable prefix.
fn render(level: Level, event: &str, fields: &[(&str, Json)]) -> String {
    let mut obj: Vec<(String, Json)> = vec![
        ("ts_ms".to_string(), Json::num(unix_ms())),
        ("level".to_string(), Json::str(level.name())),
        ("event".to_string(), Json::str(event)),
    ];
    let tid = crate::trace::trace_id();
    if tid != 0 {
        obj.push(("trace_id".to_string(), Json::str(format!("{tid:016x}"))));
    }
    for (k, v) in fields {
        obj.push((k.to_string(), v.clone()));
    }
    Json::Obj(obj).emit()
}

/// Record an event: always into the flight recorder, and to stderr when
/// `level` clears the threshold.
pub fn log(level: Level, event: &str, fields: &[(&str, Json)]) {
    if level == Level::Off {
        return;
    }
    let line = render(level, event, fields);
    {
        let mut ring = ring().lock().unwrap();
        if ring.len() >= RING_CAPACITY {
            ring.pop_front();
        }
        ring.push_back(line.clone());
    }
    // `Off` sits above every severity numerically but means "print
    // nothing", so it needs the explicit carve-out.
    let threshold = self::level();
    if threshold != Level::Off && level <= threshold {
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "{line}");
    }
}

pub fn error(event: &str, fields: &[(&str, Json)]) {
    log(Level::Error, event, fields);
}

pub fn warn(event: &str, fields: &[(&str, Json)]) {
    log(Level::Warn, event, fields);
}

pub fn info(event: &str, fields: &[(&str, Json)]) {
    log(Level::Info, event, fields);
}

pub fn debug(event: &str, fields: &[(&str, Json)]) {
    log(Level::Debug, event, fields);
}

/// Snapshot of the flight recorder, oldest first. Each entry is one
/// rendered JSON line (`GET /debug/log` re-parses them into a JSON
/// array).
pub fn flight_recorder_snapshot() -> Vec<String> {
    ring().lock().unwrap().iter().cloned().collect()
}

/// Dump the flight recorder to stderr (panic / fatal-error path). The
/// dump bypasses the level threshold — it exists precisely for the
/// events that were below it.
pub fn dump_flight_recorder(reason: &str) {
    let entries = flight_recorder_snapshot();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(
        err,
        "--- flight recorder dump ({reason}; {} events, newest last) ---",
        entries.len()
    );
    for line in &entries {
        let _ = writeln!(err, "{line}");
    }
    let _ = writeln!(err, "--- end flight recorder dump ---");
}

/// Install a panic hook that records the panic as an `error` event and
/// dumps the flight recorder before the default hook runs. Idempotent.
pub fn install_panic_hook() {
    static INSTALLED: std::sync::Once = std::sync::Once::new();
    INSTALLED.call_once(|| {
        let default_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let msg = info
                .payload()
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| info.payload().downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "panic".to_string());
            let loc = info
                .location()
                .map(|l| format!("{}:{}", l.file(), l.line()))
                .unwrap_or_default();
            error(
                "panic",
                &[("message", Json::str(msg)), ("location", Json::str(loc))],
            );
            dump_flight_recorder("panic");
            default_hook(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json::parse;

    // The ring and level are process-global; like the trace tests, every
    // assertion filters on this test's own `logtest.` event names.
    #[test]
    fn events_are_strict_json_lines_and_ring_snapshots() {
        set_level(Level::Off); // keep test stderr clean; ring still records
        info(
            "logtest.hello",
            &[("path", Json::str("/tmp/x")), ("n", Json::num(3.0))],
        );
        debug("logtest.detail", &[]);
        let mine: Vec<String> = flight_recorder_snapshot()
            .into_iter()
            .filter(|l| l.contains("\"logtest."))
            .collect();
        assert!(mine.len() >= 2, "ring missing events: {mine:?}");
        for line in &mine {
            let doc = parse(line).expect("log line must be strict JSON");
            assert!(doc.get("ts_ms").and_then(Json::as_f64).is_some());
            assert!(doc.get("level").and_then(Json::as_str).is_some());
            assert!(doc.get("event").and_then(Json::as_str).is_some());
        }
        let hello = mine
            .iter()
            .map(|l| parse(l).unwrap())
            .find(|d| d.get("event").and_then(Json::as_str) == Some("logtest.hello"))
            .expect("hello event recorded");
        assert_eq!(hello.get("path").and_then(Json::as_str), Some("/tmp/x"));
        assert_eq!(hello.get("n").and_then(Json::as_u64), Some(3));
    }

    #[test]
    fn level_parse_round_trips_and_orders() {
        for lvl in [Level::Error, Level::Warn, Level::Info, Level::Debug, Level::Off] {
            assert_eq!(Level::parse(lvl.name()), Some(lvl));
        }
        assert_eq!(Level::parse("bogus"), None);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }
}
