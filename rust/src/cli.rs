//! Hand-rolled CLI (the offline build has no clap).
//!
//! ```text
//! fkmpp seed      --dataset kdd_sim --algo rejection -k 1000 [--lloyd 10]
//! fkmpp grid      --datasets kdd_sim,song_sim --ks 100,500 --reps 5 [--json out.json]
//! fkmpp table     --which 1..8|all [--profile scaled] [--reps 5]
//! fkmpp datasets  gen [--profile scaled]
//! fkmpp serve     --port 8080 [--data-dir data] [--fit-workers 1]
//! fkmpp loadgen   [--short] [--conns 1,2,8] [--json BENCH_serve.json]
//! fkmpp worker    --port 9090 [--fail-after N]
//! fkmpp report    --trace trace.json [--baseline other.json]
//! fkmpp info
//! ```
//!
//! Every command also accepts `--log-level error|warn|info|debug|off`
//! (structured-log stderr threshold, overrides `FKMPP_LOG`).

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::bail;
use crate::coordinator::config::{k_grid_for, ExperimentConfig};
use crate::coordinator::{run_grid, tables};
use crate::data::registry::{DatasetId, Profile};
use crate::error::{Context, Result};
use crate::lloyd::{lloyd, LloydConfig};
use crate::rng::Pcg64;
use crate::runtime::Backend;
use crate::seeding::SeedingAlgorithm;
use crate::server::json::Json;

/// Parsed command line: one subcommand, positional args, `--key value`
/// flags (also `--flag` booleans and `-k 5` shorthands).
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        args.command = it.next().cloned().unwrap_or_else(|| "help".into());
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--").or_else(|| tok.strip_prefix('-')) {
                let value = match it.peek() {
                    Some(v) if !v.starts_with('-') || v.parse::<f64>().is_ok() => {
                        it.next().unwrap().clone()
                    }
                    _ => "true".to_string(),
                };
                args.flags.insert(name.to_string(), value);
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}")),
            None => Ok(default),
        }
    }
}

/// Assemble an [`ExperimentConfig`] from common flags.
pub fn config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(ds) = args.get("datasets").or_else(|| args.get("dataset")) {
        cfg.datasets = ds
            .split(',')
            .map(DatasetId::parse)
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(p) = args.get("profile") {
        cfg.profile = Profile::parse(p)?;
    }
    if let Some(a) = args.get("algos").or_else(|| args.get("algo")) {
        cfg.algorithms = a
            .split(',')
            .map(SeedingAlgorithm::parse)
            .collect::<Result<Vec<_>>>()?;
    }
    if let Some(ks) = args.get("ks") {
        cfg.ks = ks
            .split(',')
            .map(|s| s.parse::<usize>().context("--ks"))
            .collect::<Result<Vec<_>>>()?;
    } else if let Some(k) = args.get("k") {
        cfg.ks = vec![k.parse().context("-k")?];
    }
    cfg.reps = args.get_usize("reps", cfg.reps)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.lloyd_iters = args.get_usize("lloyd", cfg.lloyd_iters)?;
    cfg.rejection.c = args.get_f32("c", cfg.rejection.c)?;
    // Rejection-oracle selection + LSH knobs. `--oracle` steers plain
    // `rejection`; the `rejection-exact` / `rejection-rigorous` variants
    // pin theirs regardless (SeedingAlgorithm::forced_oracle).
    if let Some(o) = args.get("oracle") {
        cfg.rejection.oracle = crate::seeding::rejection::OracleKind::parse(o)?;
    }
    cfg.rejection.lsh.tables = args.get_usize("lsh-tables", cfg.rejection.lsh.tables)?;
    cfg.rejection.lsh.m = args.get_usize("lsh-m", cfg.rejection.lsh.m)?;
    cfg.rejection.lsh.probe_limit =
        args.get_usize("lsh-probe-limit", cfg.rejection.lsh.probe_limit)?;
    if let Some(w) = args.get("lsh-bucket-width") {
        let w: f32 = w.parse().with_context(|| format!("--lsh-bucket-width {w:?}"))?;
        // An explicit width wins over the data-driven estimate.
        cfg.rejection.lsh.bucket_width = w;
        cfg.rejection.auto_bucket_width = false;
    }
    cfg.rejection.max_proposals = args.get_u64("max-proposals", cfg.rejection.max_proposals)?;
    cfg.rejection.validate()?;
    cfg.kmeanspar.shards = args.get_usize("shards", cfg.kmeanspar.shards)?;
    cfg.kmeanspar.rounds = args.get_usize("rounds", cfg.kmeanspar.rounds)?;
    cfg.kmeanspar.oversample = args.get_f64("oversample", cfg.kmeanspar.oversample)?;
    if cfg.kmeanspar.shards == 0 || cfg.kmeanspar.rounds == 0 {
        bail!("--shards and --rounds must be >= 1");
    }
    if !(cfg.kmeanspar.oversample > 0.0) {
        bail!("--oversample must be > 0");
    }
    cfg.quantize = args.get("no-quantize").is_none();
    if let Some(dir) = args.get("data-dir") {
        cfg.data_dir = PathBuf::from(dir);
    }
    if let Some(dir) = args.get("artifacts-dir") {
        cfg.artifacts_dir = PathBuf::from(dir);
    }
    Ok(cfg)
}

/// Entry point used by `main.rs` (and by CLI tests).
pub fn run(argv: &[String]) -> Result<String> {
    let args = Args::parse(argv)?;
    // `--log-level LEVEL` pins the structured-log stderr threshold for
    // any command, overriding `FKMPP_LOG` (see `crate::log`).
    if let Some(v) = args.get("log-level") {
        match crate::log::Level::parse(v) {
            Some(lvl) => crate::log::set_level(lvl),
            None => bail!("--log-level {v:?} (expected error|warn|info|debug|off)"),
        }
    }
    // `--trace PATH` (or `FKMPP_TRACE=PATH`) arms the run-trace recorder
    // for the workload commands; on success the Chrome-trace JSON lands
    // at PATH (load it in Perfetto / chrome://tracing, or summarize with
    // `fkmpp report --trace PATH`). Spans sit only at coarse phase
    // boundaries, so traced runs stay bitwise-identical to untraced ones
    // (`rust/tests/trace_parity.rs`).
    let trace_path = match args.command.as_str() {
        "seed" | "grid" | "serve" | "loadgen" => args
            .get("trace")
            .map(str::to_string)
            .or_else(|| std::env::var("FKMPP_TRACE").ok().filter(|s| !s.is_empty())),
        _ => None,
    };
    if trace_path.is_some() {
        crate::trace::set_enabled(true);
    }
    let result = match args.command.as_str() {
        "seed" => cmd_seed(&args),
        "grid" => cmd_grid(&args),
        "table" => cmd_table(&args),
        "datasets" => cmd_datasets(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        "worker" => cmd_worker(&args),
        "report" => cmd_report(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => Ok(USAGE.to_string()),
        other => bail!("unknown command {other:?}\n{USAGE}"),
    };
    if let Some(path) = trace_path {
        let mut out = result?;
        let spans = crate::trace::write_file(&path)?;
        out.push_str(&format!("wrote trace {path} ({spans} spans)\n"));
        return Ok(out);
    }
    result
}

/// `fkmpp report --trace PATH [--baseline PATH]`: per-phase wall-time
/// breakdown of a recorded trace, in the style of the paper's runtime
/// tables; with `--baseline`, the per-phase diff between the two runs.
fn cmd_report(args: &Args) -> Result<String> {
    let path = args.get("trace").context("report needs --trace <path>")?;
    let doc = read_trace(path)?;
    if let Some(base_path) = args.get("baseline") {
        let base = read_trace(base_path)?;
        return crate::trace::render_report_diff(&doc, &base);
    }
    crate::trace::render_report(&doc)
}

/// Read + parse one trace file for `cmd_report`. An empty (or
/// whitespace-only) file — e.g. a run killed before the exporter
/// flushed — reports as a trace with no spans rather than a parse
/// error; anything non-empty must be valid trace JSON.
fn read_trace(path: &str) -> Result<Json> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace file {path}"))?;
    if text.trim().is_empty() {
        return Ok(Json::obj(vec![("traceEvents", Json::Arr(Vec::new()))]));
    }
    crate::server::json::parse(&text).with_context(|| format!("parsing {path}"))
}

const USAGE: &str = "fastkmeanspp (NeurIPS 2020 reproduction)

USAGE:
  fkmpp seed     --dataset <kdd_sim|song_sim|census_sim> --algo <name> -k <K>
                 [--profile paper|scaled|smoke] [--seed N] [--lloyd ITERS]
                 [--c FLOAT] [--no-quantize]
                 [--oracle exact|lsh|lsh-rigorous]            (rejection)
                 [--lsh-tables L] [--lsh-m M] [--lsh-probe-limit P]
                 [--lsh-bucket-width W] [--max-proposals N]
                 [--shards S] [--rounds R] [--oversample L]   (kmeans-par)
                 [--workers host:port,...]                    (distributed kmeans-par)
                 [--trace trace.json]
  fkmpp grid     --datasets a,b --algos x,y --ks 100,500 --reps 5
                 [--json results.json] [--trace trace.json]
  fkmpp table    --which 1|2|...|8|all [--profile scaled] [--reps 5]
  fkmpp datasets gen [--profile scaled] [--data-dir data]
  fkmpp serve    [--port 8080] [--host 127.0.0.1] [--data-dir data]
                 [--http-workers 4] [--fit-workers 1] [--no-persist]
                 [--queue-depth 128] [--fit-queue-depth 64]
                 [--idle-timeout-secs 15] [--max-requests-per-conn 1000]
                 [--observe-refresh-every 256] [--trace trace.json]
  fkmpp loadgen  [--short] [--conns 1,2,8] [--points 256] [--dim 16]
                 [-k 64] [--requests 100] [--reps 2] [--seed 42]
                 [--observe 0] [--json BENCH_serve.json] [--trace trace.json]
  fkmpp worker   [--port 0] [--host 127.0.0.1] [--fail-after N]
  fkmpp report   --trace trace.json [--baseline other.json]
  fkmpp info

`--trace PATH` (or env FKMPP_TRACE=PATH) records a Chrome-trace-event
JSON of the run's phase spans (Perfetto / chrome://tracing loadable);
`fkmpp report --trace PATH` prints its per-phase breakdown table, and
`--baseline OTHER` the per-phase diff (Δtotal / Δmean / Δshare%)
against a second trace. `--log-level error|warn|info|debug|off` (or
env FKMPP_LOG; default info) sets the structured-log stderr threshold
for any command.

Algorithms: kmeanspp fastkmeanspp rejection rejection-exact rejection-rigorous
            afkmc2 uniform greedy
            kmeans-par (sharded k-means|| + weighted k-means++ recluster)";

fn cmd_seed(args: &Args) -> Result<String> {
    let cfg = config_from_args(args)?;
    let dataset = cfg.datasets[0];
    let algo = cfg.algorithms[0];
    let k = *cfg.ks.first().context("need -k")?;
    let ps = dataset.load_cached(&cfg.data_dir, cfg.profile, cfg.seed)?;
    let seed_space = if cfg.quantize {
        let mut qrng = Pcg64::seed_from(cfg.seed ^ 0x5EED_0F00D);
        crate::data::quantize::quantize(&ps, &mut qrng).points
    } else {
        ps.clone()
    };
    let mut rng = Pcg64::seed_from(cfg.seed);
    let t0 = std::time::Instant::now();
    // `--workers host:port,...` swaps the in-process k-means|| round
    // executor for remote worker processes; everything else (quantize,
    // RNG seeding, cost evaluation) is identical, so a distributed run
    // is bitwise comparable to the local one.
    let seeding = if let Some(w) = args.get("workers") {
        if algo != SeedingAlgorithm::KMeansPar {
            bail!(
                "--workers only applies to --algo kmeans-par (got {})",
                algo.name()
            );
        }
        let dcfg = crate::dist::DistConfig {
            workers: w
                .split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect(),
            rounds: cfg.kmeanspar.rounds,
            oversample: cfg.kmeanspar.oversample,
            ..crate::dist::DistConfig::default()
        };
        crate::dist::kmeans_par_dist(&seed_space, k, &dcfg, &mut rng)?
    } else {
        crate::coordinator::runner::run_seeding(&cfg, algo, &seed_space, k, &mut rng)
    };
    let secs = t0.elapsed().as_secs_f64();
    let backend = Backend::auto(&cfg.artifacts_dir);
    let centers = ps.gather(&seeding.indices);
    let cost = backend.cost(&ps, &centers)?;
    let mut out = format!(
        "dataset={} n={} d={} algo={} k={}\nseeding: {:.3}s (init {:.3}s select {:.3}s), \
         proposals={} rejections={}\nseeding cost = {cost:.6e} (backend: {})\n",
        dataset.name(),
        ps.len(),
        ps.dim(),
        algo.name(),
        k,
        secs,
        seeding.stats.init_secs,
        seeding.stats.select_secs,
        seeding.stats.proposals,
        seeding.stats.rejections,
        backend.name(),
    );
    if cfg.lloyd_iters > 0 {
        let res = lloyd(
            &ps,
            &centers,
            &LloydConfig {
                max_iters: cfg.lloyd_iters,
                tol: 1e-6,
            },
            &backend,
        )?;
        out.push_str(&format!(
            "lloyd: {} iters, cost {:.6e} -> {:.6e}\n",
            res.iterations,
            res.history.first().unwrap(),
            res.history.last().unwrap()
        ));
    }
    Ok(out)
}

fn cmd_grid(args: &Args) -> Result<String> {
    let cfg = config_from_args(args)?;
    let res = run_grid(&cfg, |line| {
        crate::log::info("grid.progress", &[("line", Json::str(line))])
    })?;
    let mut out = String::new();
    // `--json path`: machine-readable artifact alongside the tables (the
    // BENCH_*.json perf trajectory), via the serving layer's emitter.
    if let Some(path) = args.get("json") {
        let doc = tables::grid_json(&res, &cfg);
        std::fs::write(path, doc.emit()).with_context(|| format!("write {path:?}"))?;
        out.push_str(&format!("wrote {path}\n"));
    }
    for &ds in &cfg.datasets {
        out.push_str(&tables::runtime_table(&res, ds, &cfg.ks));
        out.push('\n');
        out.push_str(&tables::cost_table(&res, ds, &cfg.ks));
        out.push('\n');
    }
    Ok(out)
}

fn cmd_table(args: &Args) -> Result<String> {
    let which = args.get("which").unwrap_or("all");
    let mut cfg = config_from_args(args)?;
    let (datasets, want): (Vec<DatasetId>, Vec<u8>) = match which {
        "all" => (DatasetId::all().to_vec(), vec![1, 2, 3, 4, 5, 6, 7, 8]),
        w => {
            let t: u8 = w.parse().context("--which")?;
            let ds = match t {
                1 | 4 | 8 => DatasetId::KddSim,
                2 | 5 | 7 => DatasetId::SongSim,
                3 | 6 => DatasetId::CensusSim,
                _ => bail!("tables are numbered 1..8"),
            };
            (vec![ds], vec![t])
        }
    };
    cfg.datasets = datasets;
    // Cap the k grid by dataset size at this profile.
    let min_n = cfg
        .datasets
        .iter()
        .map(|d| d.n(cfg.profile))
        .min()
        .unwrap();
    if args.get("ks").is_none() {
        cfg.ks = k_grid_for(min_n);
        if cfg.ks.is_empty() {
            // `(min_n / 20).max(1)`, NOT `min_n / (20.max(1))`: the
            // former keeps k >= 1 on tiny datasets; the latter (the old
            // operator-precedence bug) yielded k = 0 for min_n < 20.
            cfg.ks = vec![(min_n / 20).max(1)];
        }
    }
    let res = run_grid(&cfg, |line| {
        crate::log::info("table.progress", &[("line", Json::str(line))])
    })?;
    let mut out = format!(
        "profile={} reps={} backend={}\n\n",
        cfg.profile.name(),
        cfg.reps,
        res.backend_name
    );
    for &t in &want {
        let s = match t {
            1 | 2 | 3 => {
                let ds = cfg.datasets.iter().find(|d| d.runtime_table() == t);
                ds.map(|&d| tables::runtime_table(&res, d, &cfg.ks))
            }
            4 | 5 | 6 => {
                let ds = cfg.datasets.iter().find(|d| d.cost_table() == t);
                ds.map(|&d| tables::cost_table(&res, d, &cfg.ks))
            }
            7 => Some(tables::variance_table(&res, DatasetId::SongSim, &cfg.ks)),
            8 => Some(tables::variance_table(&res, DatasetId::KddSim, &cfg.ks)),
            _ => None,
        };
        if let Some(s) = s {
            out.push_str(&s);
            out.push('\n');
        }
    }
    Ok(out)
}

fn cmd_datasets(args: &Args) -> Result<String> {
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("gen");
    if action != "gen" {
        bail!("datasets: only `gen` is supported");
    }
    let cfg = config_from_args(args)?;
    let mut out = String::new();
    for ds in DatasetId::all() {
        let t0 = std::time::Instant::now();
        let ps = ds.load_cached(&cfg.data_dir, cfg.profile, cfg.seed)?;
        out.push_str(&format!(
            "{}: n={} d={} ({:.2}s)\n",
            ds.name(),
            ps.len(),
            ps.dim(),
            t0.elapsed().as_secs_f64()
        ));
    }
    Ok(out)
}

/// `fkmpp serve`: boot the clustering service ([`crate::server`]) and
/// block until `POST /shutdown` (or the process is killed).
fn cmd_serve(args: &Args) -> Result<String> {
    let defaults = crate::server::ServeConfig::default();
    let port = args.get_usize("port", defaults.port as usize)?;
    if port > u16::MAX as usize {
        bail!("--port {port} out of range (max 65535)");
    }
    let scfg = crate::server::ServeConfig {
        host: args
            .get("host")
            .map(str::to_string)
            .unwrap_or(defaults.host),
        port: port as u16,
        data_dir: args
            .get("data-dir")
            .map(PathBuf::from)
            .unwrap_or(defaults.data_dir),
        artifacts_dir: args
            .get("artifacts-dir")
            .map(PathBuf::from)
            .unwrap_or(defaults.artifacts_dir),
        http_workers: args.get_usize("http-workers", defaults.http_workers)?,
        fit_workers: args.get_usize("fit-workers", defaults.fit_workers)?,
        persist: args.get("no-persist").is_none(),
        queue_depth: args.get_usize("queue-depth", defaults.queue_depth)?,
        fit_queue_depth: args.get_usize("fit-queue-depth", defaults.fit_queue_depth)?,
        keepalive_idle: {
            let secs =
                args.get_f64("idle-timeout-secs", defaults.keepalive_idle.as_secs_f64())?;
            if !(secs > 0.0 && secs.is_finite()) {
                bail!("--idle-timeout-secs must be a positive number");
            }
            std::time::Duration::from_secs_f64(secs)
        },
        keepalive_max_requests: args
            .get_usize("max-requests-per-conn", defaults.keepalive_max_requests)?,
        observe_refresh_every: {
            let every =
                args.get_usize("observe-refresh-every", defaults.observe_refresh_every)?;
            if every == 0 {
                bail!("--observe-refresh-every must be >= 1");
            }
            every
        },
    };
    let server = crate::server::Server::bind(&scfg)?;
    crate::log::info(
        "serve.listening",
        &[("addr", Json::str(format!("http://{}", server.local_addr()?)))],
    );
    server.run()?;
    Ok("server stopped\n".to_string())
}

/// `fkmpp loadgen`: drive a self-booted server through the
/// route × connection-mode × connections sweep and (optionally) write
/// the `BENCH_serve.json` artifact.
fn cmd_loadgen(args: &Args) -> Result<String> {
    let mut cfg = if args.get("short").is_some() {
        crate::server::loadgen::LoadgenConfig::short()
    } else {
        crate::server::loadgen::LoadgenConfig::default()
    };
    if let Some(list) = args.get("conns") {
        cfg.conns = list
            .split(',')
            .map(|s| s.trim().parse::<usize>().context("--conns"))
            .collect::<Result<Vec<_>>>()?;
    }
    cfg.points = args.get_usize("points", cfg.points)?;
    cfg.dim = args.get_usize("dim", cfg.dim)?;
    cfg.k = args.get_usize("k", cfg.k)?;
    cfg.requests = args.get_usize("requests", cfg.requests)?;
    cfg.reps = args.get_usize("reps", cfg.reps)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.observe = args.get_usize("observe", cfg.observe)?;
    cfg.json_path = args.get("json").map(str::to_string);
    crate::server::loadgen::run(&cfg)
}

/// `fkmpp worker`: boot a distributed-fit worker ([`crate::dist::worker`])
/// and serve `/rpc` until `POST /shutdown` (or the process is killed).
fn cmd_worker(args: &Args) -> Result<String> {
    let defaults = crate::dist::worker::WorkerConfig::default();
    let port = args.get_usize("port", defaults.port as usize)?;
    if port > u16::MAX as usize {
        bail!("--port {port} out of range (max 65535)");
    }
    let fail_after = match args.get("fail-after") {
        Some(v) => Some(v.parse().with_context(|| format!("--fail-after {v:?}"))?),
        None => None,
    };
    let wcfg = crate::dist::worker::WorkerConfig {
        host: args
            .get("host")
            .map(str::to_string)
            .unwrap_or(defaults.host),
        port: port as u16,
        fail_after,
    };
    crate::dist::worker::run_worker(&wcfg)?;
    Ok("worker stopped\n".to_string())
}

fn cmd_info(args: &Args) -> Result<String> {
    let cfg = config_from_args(args)?;
    let backend = Backend::auto(&cfg.artifacts_dir);
    let mut out = format!(
        "fastkmeanspp — Fast and Accurate k-means++ via Rejection Sampling (NeurIPS 2020)\n\
         backend: {}\nthreads: {}\n",
        backend.name(),
        crate::parallel::num_threads()
    );
    if let Backend::Pjrt(rt) = &backend {
        out.push_str(&format!(
            "artifacts: {} variants\n",
            rt.manifest().variants.len()
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parse_flags_and_positional() {
        let a = Args::parse(&argv("seed --dataset kdd_sim -k 100 --lloyd 5 pos")).unwrap();
        assert_eq!(a.command, "seed");
        assert_eq!(a.get("dataset"), Some("kdd_sim"));
        assert_eq!(a.get("k"), Some("100"));
        assert_eq!(a.get_usize("lloyd", 0).unwrap(), 5);
        assert_eq!(a.positional, vec!["pos"]);
    }

    #[test]
    fn boolean_flags() {
        let a = Args::parse(&argv("seed --no-quantize --dataset song_sim")).unwrap();
        assert_eq!(a.get("no-quantize"), Some("true"));
        let cfg = config_from_args(&a).unwrap();
        assert!(!cfg.quantize);
    }

    #[test]
    fn config_defaults() {
        let a = Args::parse(&argv("grid")).unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.reps, 5);
        assert_eq!(cfg.ks.len(), 6);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&argv("frobnicate")).is_err());
    }

    #[test]
    fn serve_rejects_out_of_range_port() {
        // Fails validation before any socket is bound.
        assert!(run(&argv("serve --port 99999")).is_err());
    }

    #[test]
    fn serve_rejects_zero_refresh_cadence() {
        // Fails validation before any socket is bound.
        let err = format!("{:#}", run(&argv("serve --observe-refresh-every 0")).unwrap_err());
        assert!(err.contains("observe-refresh-every"), "{err}");
    }

    #[test]
    fn worker_rejects_out_of_range_port() {
        // Fails validation before any socket is bound.
        assert!(run(&argv("worker --port 99999")).is_err());
        let err = format!("{:#}", run(&argv("worker --fail-after nope")).unwrap_err());
        assert!(err.contains("fail-after"), "{err}");
    }

    #[test]
    fn workers_flag_requires_kmeans_par() {
        let err = format!(
            "{:#}",
            run(&argv(
                "seed --dataset kdd_sim --algo uniform -k 10 --profile smoke \
                 --data-dir /tmp/fkmpp_cli_test --artifacts-dir /nonexistent \
                 --workers 127.0.0.1:1",
            ))
            .unwrap_err()
        );
        assert!(err.contains("kmeans-par"), "{err}");
    }

    #[test]
    fn help_prints_usage() {
        let out = run(&argv("help")).unwrap();
        assert!(out.contains("USAGE"));
    }

    #[test]
    fn grid_json_artifact() {
        let path = std::env::temp_dir().join("fkmpp_grid_cli_test.json");
        let _ = std::fs::remove_file(&path);
        let out = run(&argv(&format!(
            "grid --datasets kdd_sim --algos uniform --ks 10 --reps 1 --profile smoke \
             --data-dir /tmp/fkmpp_cli_test --artifacts-dir /nonexistent --seed 3 \
             --json {}",
            path.display()
        )))
        .unwrap();
        assert!(out.contains("wrote"), "{out}");
        let text = std::fs::read_to_string(&path).unwrap();
        let v = crate::server::json::parse(&text).unwrap();
        assert_eq!(v.get("backend").and_then(|b| b.as_str()), Some("native"));
        let cells = v.get("cells").and_then(|c| c.as_array()).unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(
            cells[0].get("algorithm").and_then(|a| a.as_str()),
            Some("uniform")
        );
    }

    #[test]
    fn oracle_flag_reaches_rejection_config() {
        use crate::seeding::rejection::OracleKind;
        let a = Args::parse(&argv(
            "seed --dataset kdd_sim --algo rejection --oracle lsh-rigorous",
        ))
        .unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.rejection.oracle, OracleKind::LshRigorous);
        // Unknown oracle: the error enumerates the valid names.
        let a = Args::parse(&argv("seed --oracle bogus")).unwrap();
        let err = format!("{:#}", config_from_args(&a).unwrap_err());
        for o in OracleKind::all() {
            assert!(err.contains(o.name()), "{:?} missing from {err:?}", o.name());
        }
    }

    #[test]
    fn lsh_knobs_validated_and_explicit_width_disables_autotune() {
        for bad in [
            "seed --lsh-tables 0",
            "seed --lsh-m 0",
            "seed --lsh-probe-limit 0",
            "seed --lsh-bucket-width 0",
            "seed --c 0.5",
        ] {
            let a = Args::parse(&argv(bad)).unwrap();
            assert!(config_from_args(&a).is_err(), "{bad} should fail validation");
        }
        let a = Args::parse(&argv("seed --lsh-bucket-width 12.5 --lsh-tables 4")).unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(cfg.rejection.lsh.bucket_width, 12.5);
        assert_eq!(cfg.rejection.lsh.tables, 4);
        assert!(!cfg.rejection.auto_bucket_width);
    }

    #[test]
    fn seed_smoke_run_with_lsh_oracle() {
        let out = run(&argv(
            "seed --dataset kdd_sim --algo rejection --oracle lsh -k 10 --profile smoke \
             --data-dir /tmp/fkmpp_cli_test --artifacts-dir /nonexistent --seed 3",
        ))
        .unwrap();
        assert!(out.contains("seeding cost"), "{out}");
    }

    #[test]
    fn seed_trace_writes_chrome_trace_and_report_reads_it() {
        // `--trace` (not FKMPP_TRACE: lib tests share the process env)
        // arms the recorder; the run appends the "wrote trace" line and
        // the file is strict-parseable Chrome trace JSON. Other unit
        // tests may be emitting spans concurrently (the sink is
        // process-global), so assert only on this run's own span names.
        let path = std::env::temp_dir().join("fkmpp_cli_trace_test.json");
        let _ = std::fs::remove_file(&path);
        let out = run(&argv(&format!(
            "seed --dataset kdd_sim --algo kmeanspp -k 10 --profile smoke \
             --data-dir /tmp/fkmpp_cli_test --artifacts-dir /nonexistent --seed 3 \
             --trace {}",
            path.display()
        )))
        .unwrap();
        assert!(out.contains("wrote trace"), "{out}");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = crate::server::json::parse(&text).unwrap();
        let events = doc.get("traceEvents").and_then(|e| e.as_array()).unwrap();
        assert!(
            events.iter().any(|e| {
                e.get("name").and_then(|n| n.as_str()) == Some("seed.kmeanspp.select")
            }),
            "missing seed.kmeanspp.select span"
        );
        let report = run(&argv(&format!("report --trace {}", path.display()))).unwrap();
        assert!(report.contains("seed.kmeanspp.select"), "{report}");
        assert!(report.contains("share%"), "{report}");
        // Missing --trace and an unparseable file both fail with typed
        // errors, not panics.
        assert!(run(&argv("report")).is_err());
        let bogus = std::env::temp_dir().join("fkmpp_cli_trace_bogus.json");
        std::fs::write(&bogus, "{\"not\": \"a trace\"}").unwrap();
        assert!(run(&argv(&format!("report --trace {}", bogus.display()))).is_err());
    }

    #[test]
    fn report_baseline_diff_and_empty_trace_file() {
        // An empty (or whitespace-only) trace file — what a run killed
        // before the exporter flushed leaves behind — reports as a
        // span-free trace, not a parse error.
        let empty = std::env::temp_dir().join("fkmpp_cli_report_empty.json");
        std::fs::write(&empty, "  \n").unwrap();
        let out = run(&argv(&format!("report --trace {}", empty.display()))).unwrap();
        assert!(out.contains("(trace contains no spans)"), "{out}");
        // `--baseline`: per-phase diff table, including phases present
        // in only one of the two traces.
        let cur = std::env::temp_dir().join("fkmpp_cli_report_cur.json");
        let base = std::env::temp_dir().join("fkmpp_cli_report_base.json");
        std::fs::write(
            &cur,
            r#"{"traceEvents":[{"name":"phase.x","ph":"X","pid":1,"tid":1,"ts":0,"dur":2000000}]}"#,
        )
        .unwrap();
        std::fs::write(
            &base,
            r#"{"traceEvents":[
                {"name":"phase.x","ph":"X","pid":1,"tid":1,"ts":0,"dur":500000},
                {"name":"phase.y","ph":"X","pid":1,"tid":1,"ts":0,"dur":250000}]}"#,
        )
        .unwrap();
        let out = run(&argv(&format!(
            "report --trace {} --baseline {}",
            cur.display(),
            base.display()
        )))
        .unwrap();
        assert!(out.contains("Δtotal"), "{out}");
        assert!(out.contains("phase.x"), "{out}");
        assert!(out.contains("phase.y"), "{out}");
    }

    #[test]
    fn log_level_flag_validates() {
        let err = format!("{:#}", run(&argv("info --log-level bogus")).unwrap_err());
        assert!(err.contains("log-level"), "{err}");
    }

    #[test]
    fn seed_smoke_run() {
        let out = run(&argv(
            "seed --dataset kdd_sim --algo uniform -k 10 --profile smoke \
             --data-dir /tmp/fkmpp_cli_test --artifacts-dir /nonexistent --seed 3",
        ))
        .unwrap();
        assert!(out.contains("seeding cost"), "{out}");
    }
}
