//! Run-trace layer: hierarchical wall-clock spans with Chrome
//! trace-event export (Perfetto-loadable) and a per-phase report.
//!
//! A [`Span`] is an RAII guard opened at a **coarse phase boundary**
//! (seeding init/select, a k-means‖ round, one dist RPC, one HTTP
//! request) and closed on drop. Spans nest naturally: Perfetto renders
//! overlapping complete events on the same thread track as a stack, so
//! no explicit parent pointers are recorded.
//!
//! Contract with the determinism suite: tracing reads **only clocks**
//! (`Instant`), never the RNG, and is recorded **only at coarse
//! boundaries** — never inside the `n·k` kernel loops — so every
//! fixed-seed bitwise contract (kernel/shard/thread/worker invariance)
//! holds with tracing on. `rust/tests/trace_parity.rs` gates this.
//!
//! Recording is off by default and costs one relaxed atomic load per
//! `Span::enter` when disabled. When enabled (CLI `--trace <path>` or
//! env `FKMPP_TRACE`), closed spans go to a per-thread buffer that is
//! flushed into the process-wide sink in batches (and on thread exit),
//! so hot-ish sites like per-RPC spans never serialize on a global lock
//! per event.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::error::{Context, Result};
use crate::server::json::Json;

/// Logical Chrome-trace process id of the recording process itself.
/// Merged foreign (worker) spans get distinct pids ≥ 2.
pub const LOCAL_PID: u32 = 1;

/// A span argument value (rendered into the Chrome event's `args`).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceArg {
    U64(u64),
    F64(f64),
    Str(String),
}

impl From<u64> for TraceArg {
    fn from(v: u64) -> Self {
        TraceArg::U64(v)
    }
}

impl From<usize> for TraceArg {
    fn from(v: usize) -> Self {
        TraceArg::U64(v as u64)
    }
}

impl From<f64> for TraceArg {
    fn from(v: f64) -> Self {
        TraceArg::F64(v)
    }
}

impl From<&str> for TraceArg {
    fn from(v: &str) -> Self {
        TraceArg::Str(v.to_string())
    }
}

impl From<String> for TraceArg {
    fn from(v: String) -> Self {
        TraceArg::Str(v)
    }
}

/// One closed span, ready for export.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Stable per-thread id (allocation order, starting at 1).
    pub tid: u64,
    /// Start offset from the process trace epoch, microseconds.
    pub ts_us: f64,
    pub dur_us: f64,
    pub args: Vec<(&'static str, TraceArg)>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static TRACE_ID: AtomicU64 = AtomicU64::new(0);

/// `(monotonic epoch, the same instant as unix micros)` — the wall
/// anchor lets merged foreign timelines be shifted onto this process's
/// `ts` axis without any cross-process clock protocol.
fn epoch_pair() -> (Instant, f64) {
    static EPOCH: OnceLock<(Instant, f64)> = OnceLock::new();
    *EPOCH.get_or_init(|| {
        let unix_us = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64() * 1e6)
            .unwrap_or(0.0);
        (Instant::now(), unix_us)
    })
}

fn epoch() -> Instant {
    epoch_pair().0
}

/// The trace epoch as unix microseconds (wall clock captured at the
/// same moment the monotonic epoch was pinned).
pub fn epoch_unix_us() -> f64 {
    epoch_pair().1
}

fn sink() -> &'static Mutex<Vec<SpanEvent>> {
    static SINK: OnceLock<Mutex<Vec<SpanEvent>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

fn foreign_sink() -> &'static Mutex<Vec<ForeignSpan>> {
    static SINK: OnceLock<Mutex<Vec<ForeignSpan>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

/// Turn recording on/off. The epoch is pinned at the first enable so
/// timestamps are offsets into the traced run, not process lifetime.
/// Enabling also assigns the run a trace id if none was adopted yet.
pub fn set_enabled(on: bool) {
    if on {
        epoch();
        if TRACE_ID.load(Ordering::Relaxed) == 0 {
            // Not an RNG draw — the id only labels the trace, and the
            // wall clock + pid keep concurrent runs distinct.
            let nanos = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0);
            let id = (nanos ^ ((std::process::id() as u64) << 48)) | 1;
            let _ = TRACE_ID.compare_exchange(0, id, Ordering::Relaxed, Ordering::Relaxed);
        }
    }
    ENABLED.store(on, Ordering::Relaxed);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process trace id (0 = none assigned yet). Workers adopt the
/// coordinator's id from the wire instead of generating their own.
pub fn trace_id() -> u64 {
    TRACE_ID.load(Ordering::Relaxed)
}

/// Adopt a propagated trace id (worker side of the wire contract).
pub fn set_trace_id(id: u64) {
    TRACE_ID.store(id, Ordering::Relaxed);
}

/// Allocate a span id for cross-process parent tagging. Ids are only
/// labels in the exported `args` — span nesting itself stays implicit
/// (Chrome complete events stack by overlap on one pid/tid track).
pub fn next_span_id() -> u64 {
    NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed)
}

/// Flush batch size for the per-thread buffer.
const FLUSH_AT: usize = 64;

struct LocalBuf {
    tid: u64,
    events: Vec<SpanEvent>,
}

impl LocalBuf {
    fn flush(&mut self) {
        if !self.events.is_empty() {
            sink().lock().unwrap().append(&mut self.events);
        }
    }
}

impl Drop for LocalBuf {
    // Thread exit: whatever the batch threshold left behind goes to the
    // sink, so export-after-join sees every span.
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        events: Vec::new(),
    });
}

/// Flush the calling thread's buffer into the sink. Exporters call this
/// so the exporting thread's own spans are never missing from the file.
pub fn flush_current_thread() {
    LOCAL.with(|l| l.borrow_mut().flush());
}

/// Drop every recorded event, local and foreign (test isolation).
pub fn clear() {
    flush_current_thread();
    sink().lock().unwrap().clear();
    foreign_sink().lock().unwrap().clear();
}

/// A span merged in from another process (a worker's `TraceDump`
/// answer): owned name/arg keys (they crossed the wire), an explicit
/// pid row, and `ts_us` already shifted onto this process's epoch.
#[derive(Clone, Debug)]
pub struct ForeignSpan {
    /// Chrome-trace process row (≥ 2; `LOCAL_PID` is this process).
    pub pid: u32,
    /// Human label for the pid row (Perfetto `process_name` metadata).
    pub process: String,
    /// Trace id the remote process recorded under.
    pub trace_id: u64,
    pub name: String,
    pub tid: u64,
    pub ts_us: f64,
    pub dur_us: f64,
    pub args: Vec<(String, TraceArg)>,
}

/// Merge foreign spans into the export sinks. The caller (the dist
/// coordinator) owns pid assignment and timestamp shifting.
pub fn add_foreign(spans: Vec<ForeignSpan>) {
    foreign_sink().lock().unwrap().extend(spans);
}

/// Snapshot of merged foreign spans, time-ordered.
pub fn snapshot_foreign() -> Vec<ForeignSpan> {
    let mut evs = foreign_sink().lock().unwrap().clone();
    evs.sort_by(|a, b| {
        a.ts_us
            .partial_cmp(&b.ts_us)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then((a.pid, a.tid).cmp(&(b.pid, b.tid)))
    });
    evs
}

/// Snapshot of all events recorded so far, time-ordered.
pub fn snapshot_events() -> Vec<SpanEvent> {
    flush_current_thread();
    let mut evs = sink().lock().unwrap().clone();
    evs.sort_by(|a, b| {
        a.ts_us
            .partial_cmp(&b.ts_us)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.tid.cmp(&b.tid))
    });
    evs
}

/// An open span: records `[enter, drop)` into the trace when enabled.
/// A disabled-recorder span is a no-op shell (one atomic load).
pub struct Span {
    inner: Option<SpanInner>,
}

struct SpanInner {
    name: &'static str,
    start: Instant,
    args: Vec<(&'static str, TraceArg)>,
}

impl Span {
    pub fn enter(name: &'static str) -> Span {
        Span::enter_with(name, Vec::new())
    }

    pub fn enter_with(name: &'static str, args: Vec<(&'static str, TraceArg)>) -> Span {
        if !enabled() {
            return Span { inner: None };
        }
        Span {
            inner: Some(SpanInner {
                name,
                start: Instant::now(),
                args,
            }),
        }
    }

    /// Attach an argument known only mid-span (status, byte counts,
    /// retry totals).
    pub fn arg(&mut self, key: &'static str, value: impl Into<TraceArg>) {
        if let Some(inner) = &mut self.inner {
            inner.args.push((key, value.into()));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else {
            return;
        };
        let dur_us = inner.start.elapsed().as_secs_f64() * 1e6;
        // Saturates to zero for spans entered before the epoch was
        // pinned (enable raced a long-lived span) — harmless.
        let ts_us = inner.start.duration_since(epoch()).as_secs_f64() * 1e6;
        LOCAL.with(|l| {
            let mut buf = l.borrow_mut();
            let tid = buf.tid;
            buf.events.push(SpanEvent {
                name: inner.name,
                tid,
                ts_us,
                dur_us,
                args: inner.args,
            });
            if buf.events.len() >= FLUSH_AT {
                buf.flush();
            }
        });
    }
}

fn arg_json(a: &TraceArg) -> Json {
    match a {
        TraceArg::U64(v) => Json::num(*v as f64),
        TraceArg::F64(v) => Json::num(*v),
        TraceArg::Str(s) => Json::str(s.clone()),
    }
}

/// One Chrome `"ph":"X"` complete event. `trace_id` rides in `args` so
/// per-process provenance survives the merge into one file.
fn complete_event(
    name: &str,
    pid: u32,
    tid: u64,
    ts_us: f64,
    dur_us: f64,
    trace_id: u64,
    args: Vec<(String, Json)>,
) -> Json {
    let mut arg_obj: Vec<(String, Json)> = Vec::with_capacity(args.len() + 1);
    if trace_id != 0 {
        arg_obj.push(("trace_id".to_string(), Json::str(format!("{trace_id:016x}"))));
    }
    arg_obj.extend(args);
    let mut fields = vec![
        ("name", Json::str(name)),
        ("cat", Json::str("fkmpp")),
        ("ph", Json::str("X")),
        ("pid", Json::num(pid as f64)),
        ("tid", Json::num(tid as f64)),
        ("ts", Json::num(ts_us)),
        ("dur", Json::num(dur_us)),
    ];
    if !arg_obj.is_empty() {
        fields.push(("args", Json::Obj(arg_obj)));
    }
    Json::obj(fields)
}

/// Perfetto `process_name` metadata event labelling a pid row.
fn process_name_event(pid: u32, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::str("process_name")),
        ("ph", Json::str("M")),
        ("pid", Json::num(pid as f64)),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

/// Render events as a Chrome trace-event JSON document (the format
/// Perfetto and `chrome://tracing` load): complete (`"ph":"X"`) events
/// with microsecond `ts`/`dur`, pid `LOCAL_PID`, per-thread `tid`
/// tracks.
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    chrome_trace_json_merged(events, &[])
}

/// Render local plus merged foreign (worker) events as one document:
/// each remote process gets its own pid row (with a `process_name`
/// metadata label) and every complete event carries the trace id it was
/// recorded under, so one file shows coordinator wire-time and worker
/// compute-time side by side.
pub fn chrome_trace_json_merged(events: &[SpanEvent], foreign: &[ForeignSpan]) -> Json {
    let local_trace_id = trace_id();
    let mut evs: Vec<Json> = Vec::with_capacity(events.len() + foreign.len() + 4);
    evs.push(process_name_event(LOCAL_PID, "fkmpp-coordinator"));
    let mut named: Vec<u32> = Vec::new();
    for f in foreign {
        if !named.contains(&f.pid) {
            named.push(f.pid);
            evs.push(process_name_event(f.pid, &f.process));
        }
    }
    for e in events {
        evs.push(complete_event(
            e.name,
            LOCAL_PID,
            e.tid,
            e.ts_us,
            e.dur_us,
            local_trace_id,
            e.args
                .iter()
                .map(|(k, v)| (k.to_string(), arg_json(v)))
                .collect(),
        ));
    }
    for f in foreign {
        evs.push(complete_event(
            &f.name,
            f.pid,
            f.tid,
            f.ts_us,
            f.dur_us,
            f.trace_id,
            f.args
                .iter()
                .map(|(k, v)| (k.clone(), arg_json(v)))
                .collect(),
        ));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Export everything recorded so far (local + merged foreign spans) as
/// Chrome trace JSON.
pub fn export_json() -> Json {
    chrome_trace_json_merged(&snapshot_events(), &snapshot_foreign())
}

/// Write the recorded trace to `path`; returns the span count (local +
/// foreign).
pub fn write_file(path: &str) -> Result<usize> {
    let events = snapshot_events();
    let foreign = snapshot_foreign();
    let doc = chrome_trace_json_merged(&events, &foreign);
    std::fs::write(path, doc.emit())
        .with_context(|| format!("writing trace file {path}"))?;
    Ok(events.len() + foreign.len())
}

/// Per-phase aggregate over a recorded trace (one table row).
#[derive(Clone, Debug)]
pub struct PhaseRow {
    pub name: String,
    pub count: u64,
    pub total_secs: f64,
    pub mean_secs: f64,
    pub max_secs: f64,
}

/// Aggregate a Chrome trace document by span name. Fails with a typed
/// error when the document is not a trace (missing `traceEvents`).
///
/// Spans merged from another process (pid ≠ `LOCAL_PID`) aggregate
/// under `"{process}/{name}"` — the process label from the pid row's
/// `process_name` metadata (`"pid{N}"` when unlabelled) — so the table
/// separates coordinator wire-time from worker compute-time.
pub fn phase_rows(doc: &Json) -> Result<Vec<PhaseRow>> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_array)
        .context("not a Chrome trace: no \"traceEvents\" array")?;
    let mut process_names: std::collections::BTreeMap<u64, String> =
        std::collections::BTreeMap::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) == Some("M")
            && e.get("name").and_then(Json::as_str) == Some("process_name")
        {
            if let (Some(pid), Some(name)) = (
                e.get("pid").and_then(Json::as_u64),
                e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str),
            ) {
                process_names.insert(pid, name.to_string());
            }
        }
    }
    let mut by_name: std::collections::BTreeMap<String, PhaseRow> =
        std::collections::BTreeMap::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .context("trace event without a name")?;
        let pid = e.get("pid").and_then(Json::as_u64).unwrap_or(LOCAL_PID as u64);
        let label = if pid == LOCAL_PID as u64 {
            name.to_string()
        } else {
            let process = process_names
                .get(&pid)
                .cloned()
                .unwrap_or_else(|| format!("pid{pid}"));
            format!("{process}/{name}")
        };
        let dur_s = e.get("dur").and_then(Json::as_f64).unwrap_or(0.0) / 1e6;
        let row = by_name.entry(label.clone()).or_insert_with(|| PhaseRow {
            name: label.clone(),
            count: 0,
            total_secs: 0.0,
            mean_secs: 0.0,
            max_secs: 0.0,
        });
        row.count += 1;
        row.total_secs += dur_s;
        row.max_secs = row.max_secs.max(dur_s);
    }
    let mut rows: Vec<PhaseRow> = by_name
        .into_values()
        .map(|mut r| {
            r.mean_secs = r.total_secs / r.count.max(1) as f64;
            r
        })
        .collect();
    rows.sort_by(|a, b| {
        b.total_secs
            .partial_cmp(&a.total_secs)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.name.cmp(&b.name))
    });
    Ok(rows)
}

/// Render the paper-style per-phase breakdown table from a recorded
/// trace document (`fkmpp report --trace <path>`). `share%` is each
/// phase's fraction of the *sum of recorded span time* — spans nest, so
/// shares can double-count and need not total 100.
pub fn render_report(doc: &Json) -> Result<String> {
    let rows = phase_rows(doc)?;
    let total: f64 = rows.iter().map(|r| r.total_secs).sum();
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:>8} {:>12} {:>12} {:>12} {:>7}\n",
        "phase", "count", "total", "mean", "max", "share%"
    ));
    for r in &rows {
        let share = if total > 0.0 {
            100.0 * r.total_secs / total
        } else {
            0.0
        };
        out.push_str(&format!(
            "{:<28} {:>8} {:>12} {:>12} {:>12} {:>7.2}\n",
            r.name,
            r.count,
            crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(r.total_secs)),
            crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(r.mean_secs)),
            crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(r.max_secs)),
            share
        ));
    }
    if rows.is_empty() {
        out.push_str("(trace contains no spans)\n");
    }
    Ok(out)
}

/// Per-phase diff between two trace documents
/// (`fkmpp report --trace <a> --baseline <b>`): Δtotal and Δmean are
/// `a − b` wall time, Δshare% is the change in each phase's fraction of
/// its own trace's recorded span time. Phases present in only one trace
/// diff against zero. Rows sort by |Δtotal| descending.
pub fn render_report_diff(doc: &Json, baseline: &Json) -> Result<String> {
    let cur = phase_rows(doc)?;
    let base = phase_rows(baseline)?;
    let cur_total: f64 = cur.iter().map(|r| r.total_secs).sum();
    let base_total: f64 = base.iter().map(|r| r.total_secs).sum();
    let share = |total: f64, of: f64| if of > 0.0 { 100.0 * total / of } else { 0.0 };
    let mut names: Vec<String> = cur.iter().map(|r| r.name.clone()).collect();
    for r in &base {
        if !names.contains(&r.name) {
            names.push(r.name.clone());
        }
    }
    struct DiffRow {
        name: String,
        cur_total: f64,
        base_total: f64,
        d_total: f64,
        d_mean: f64,
        d_share: f64,
    }
    let mut rows: Vec<DiffRow> = names
        .into_iter()
        .map(|name| {
            let a = cur.iter().find(|r| r.name == name);
            let b = base.iter().find(|r| r.name == name);
            let (at, am) = a.map(|r| (r.total_secs, r.mean_secs)).unwrap_or((0.0, 0.0));
            let (bt, bm) = b.map(|r| (r.total_secs, r.mean_secs)).unwrap_or((0.0, 0.0));
            DiffRow {
                name,
                cur_total: at,
                base_total: bt,
                d_total: at - bt,
                d_mean: am - bm,
                d_share: share(at, cur_total) - share(bt, base_total),
            }
        })
        .collect();
    rows.sort_by(|a, b| {
        b.d_total
            .abs()
            .partial_cmp(&a.d_total.abs())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.name.cmp(&b.name))
    });
    let signed = |secs: f64| -> String {
        let mag = crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(secs.abs()));
        if secs < 0.0 {
            format!("-{mag}")
        } else {
            format!("+{mag}")
        }
    };
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:>12} {:>12} {:>12} {:>12} {:>8}\n",
        "phase", "total", "baseline", "Δtotal", "Δmean", "Δshare%"
    ));
    for r in &rows {
        out.push_str(&format!(
            "{:<28} {:>12} {:>12} {:>12} {:>12} {:>+8.2}\n",
            r.name,
            crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(r.cur_total)),
            crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(r.base_total)),
            signed(r.d_total),
            signed(r.d_mean),
            r.d_share,
        ));
    }
    if rows.is_empty() {
        out.push_str("(neither trace contains spans)\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json::parse;

    // The recorder is process-global and sibling unit tests run in
    // parallel in this process, so every assertion filters on this
    // test's own `ttest.` span names — never on global totals.
    #[test]
    fn record_export_report_round_trip() {
        let mine = |evs: Vec<SpanEvent>| -> Vec<SpanEvent> {
            evs.into_iter()
                .filter(|e| e.name.starts_with("ttest.") && e.name != "ttest.noop")
                .collect()
        };

        // Disabled spans are inert. A sibling test can flip the recorder
        // on concurrently (it is never flipped off), so `enabled()` is
        // monotone: if it is still off *after* the drop, it was off at
        // enter time and nothing can have been recorded.
        if !enabled() {
            let mut s = Span::enter("ttest.noop");
            s.arg("x", 1u64);
            drop(s);
            if !enabled() {
                assert!(snapshot_events().iter().all(|e| e.name != "ttest.noop"));
            }
        }

        set_enabled(true);
        {
            let mut s = Span::enter_with("ttest.outer", vec![("round", TraceArg::U64(3))]);
            {
                let _inner = Span::enter("ttest.inner");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            s.arg("status", 200u64);
        }
        // A span closed on another thread must land in the sink once the
        // thread exits (the LocalBuf drop flush).
        std::thread::spawn(|| {
            let _s = Span::enter("ttest.worker");
        })
        .join()
        .unwrap();
        // Deliberately NOT disabled again: sibling tests (the CLI
        // `--trace` test) may have enabled recording concurrently, and
        // flipping it off under them would lose their spans. Leaving it
        // on is safe — every assertion here filters on `ttest.` names.

        let events = mine(snapshot_events());
        assert_eq!(events.len(), 3, "events: {events:?}");
        let outer = events.iter().find(|e| e.name == "ttest.outer").unwrap();
        let inner = events.iter().find(|e| e.name == "ttest.inner").unwrap();
        assert!(outer.dur_us >= inner.dur_us);
        assert!(outer.ts_us <= inner.ts_us);
        assert_eq!(
            outer.args,
            vec![("round", TraceArg::U64(3)), ("status", TraceArg::U64(200))]
        );
        let worker = events.iter().find(|e| e.name == "ttest.worker").unwrap();
        assert_ne!(worker.tid, outer.tid, "worker thread shares a tid");

        // Export must round-trip through the crate's strict parser and
        // carry the Chrome trace-event shape: one `process_name`
        // metadata row plus the complete events, each tagged with the
        // process trace id.
        let text = chrome_trace_json(&events).emit();
        let doc = parse(&text).expect("exported trace must be strict-valid JSON");
        let all = doc.get("traceEvents").and_then(Json::as_array).unwrap();
        let metas: Vec<&Json> = all
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .collect();
        assert_eq!(metas.len(), 1);
        assert_eq!(
            metas[0].get("name").and_then(Json::as_str),
            Some("process_name")
        );
        let evs: Vec<&Json> = all
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        assert_eq!(evs.len(), 3);
        let tid_hex = format!("{:016x}", trace_id());
        for e in &evs {
            assert_eq!(e.get("cat").and_then(Json::as_str), Some("fkmpp"));
            assert!(e.get("ts").and_then(Json::as_f64).is_some());
            assert!(e.get("dur").and_then(Json::as_f64).is_some());
            assert_eq!(e.get("pid").and_then(Json::as_u64), Some(LOCAL_PID as u64));
            // set_enabled(true) above assigned a trace id, so every
            // exported event must carry it.
            assert_eq!(
                e.get("args")
                    .and_then(|a| a.get("trace_id"))
                    .and_then(Json::as_str),
                Some(tid_hex.as_str())
            );
        }
        let outer_json = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("ttest.outer"))
            .unwrap();
        let args = outer_json.get("args").expect("outer args serialized");
        assert_eq!(args.get("round").and_then(Json::as_u64), Some(3));
        assert_eq!(args.get("status").and_then(Json::as_u64), Some(200));

        // Report: aggregated by name, one row per distinct span.
        let report = render_report(&doc).unwrap();
        assert!(report.contains("ttest.outer"), "{report}");
        assert!(report.contains("ttest.inner"), "{report}");
        assert!(report.contains("share%"), "{report}");
        assert!(phase_rows(&doc).unwrap().iter().all(|r| r.count == 1));

        // Non-trace documents are a typed error, not a panic.
        assert!(render_report(&parse("{\"x\":1}").unwrap()).is_err());
    }

    // Pure-function coverage of the merge + diff paths: synthetic
    // foreign spans, no recorder state beyond the process trace id.
    #[test]
    fn merged_export_separates_processes_and_diff_reports() {
        let local = vec![SpanEvent {
            name: "mtest.rpc",
            tid: 1,
            ts_us: 0.0,
            dur_us: 4_000_000.0,
            args: vec![("round", TraceArg::U64(1))],
        }];
        let foreign = vec![ForeignSpan {
            pid: 2,
            process: "worker-1".to_string(),
            trace_id: 0xabcd,
            name: "worker.update".to_string(),
            tid: 1,
            ts_us: 500.0,
            dur_us: 1_000_000.0,
            args: vec![("n".to_string(), TraceArg::U64(7))],
        }];
        let doc =
            parse(&chrome_trace_json_merged(&local, &foreign).emit()).expect("strict JSON");
        let all = doc.get("traceEvents").and_then(Json::as_array).unwrap();
        // Both pid rows are labelled.
        let labels: Vec<&str> = all
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .filter_map(|e| e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str))
            .collect();
        assert!(labels.contains(&"fkmpp-coordinator"), "{labels:?}");
        assert!(labels.contains(&"worker-1"), "{labels:?}");
        // The worker event sits on its own pid row with its own trace id.
        let worker_ev = all
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("worker.update"))
            .unwrap();
        assert_eq!(worker_ev.get("pid").and_then(Json::as_u64), Some(2));
        assert_eq!(
            worker_ev
                .get("args")
                .and_then(|a| a.get("trace_id"))
                .and_then(Json::as_str),
            Some("000000000000abcd")
        );
        assert_eq!(
            worker_ev.get("args").and_then(|a| a.get("n")).and_then(Json::as_u64),
            Some(7)
        );
        // The report keys foreign rows by process label.
        let rows = phase_rows(&doc).unwrap();
        assert!(rows.iter().any(|r| r.name == "mtest.rpc"), "{rows:?}");
        let wrow = rows
            .iter()
            .find(|r| r.name == "worker-1/worker.update")
            .expect("worker-process phase row");
        assert!((wrow.total_secs - 1.0).abs() < 1e-9);
        let report = render_report(&doc).unwrap();
        assert!(report.contains("worker-1/worker.update"), "{report}");

        // Diff against a baseline missing the worker row: Δtotal signed,
        // missing side diffs against zero.
        let base =
            parse(&chrome_trace_json_merged(&local, &[]).emit()).expect("strict JSON");
        let diff = render_report_diff(&doc, &base).unwrap();
        assert!(diff.contains("Δtotal"), "{diff}");
        assert!(diff.contains("worker-1/worker.update"), "{diff}");
        assert!(diff.contains("+1.0"), "worker row gained 1s: {diff}");
        // Span-free traces diff cleanly.
        let empty = parse("{\"traceEvents\":[]}").unwrap();
        let empty_diff = render_report_diff(&empty, &empty).unwrap();
        assert!(empty_diff.contains("(neither trace contains spans)"), "{empty_diff}");
        assert!(render_report_diff(&doc, &parse("{\"x\":1}").unwrap()).is_err());
    }
}
