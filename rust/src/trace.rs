//! Run-trace layer: hierarchical wall-clock spans with Chrome
//! trace-event export (Perfetto-loadable) and a per-phase report.
//!
//! A [`Span`] is an RAII guard opened at a **coarse phase boundary**
//! (seeding init/select, a k-means‖ round, one dist RPC, one HTTP
//! request) and closed on drop. Spans nest naturally: Perfetto renders
//! overlapping complete events on the same thread track as a stack, so
//! no explicit parent pointers are recorded.
//!
//! Contract with the determinism suite: tracing reads **only clocks**
//! (`Instant`), never the RNG, and is recorded **only at coarse
//! boundaries** — never inside the `n·k` kernel loops — so every
//! fixed-seed bitwise contract (kernel/shard/thread/worker invariance)
//! holds with tracing on. `rust/tests/trace_parity.rs` gates this.
//!
//! Recording is off by default and costs one relaxed atomic load per
//! `Span::enter` when disabled. When enabled (CLI `--trace <path>` or
//! env `FKMPP_TRACE`), closed spans go to a per-thread buffer that is
//! flushed into the process-wide sink in batches (and on thread exit),
//! so hot-ish sites like per-RPC spans never serialize on a global lock
//! per event.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::error::{Context, Result};
use crate::server::json::Json;

/// A span argument value (rendered into the Chrome event's `args`).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceArg {
    U64(u64),
    F64(f64),
    Str(String),
}

impl From<u64> for TraceArg {
    fn from(v: u64) -> Self {
        TraceArg::U64(v)
    }
}

impl From<usize> for TraceArg {
    fn from(v: usize) -> Self {
        TraceArg::U64(v as u64)
    }
}

impl From<f64> for TraceArg {
    fn from(v: f64) -> Self {
        TraceArg::F64(v)
    }
}

impl From<&str> for TraceArg {
    fn from(v: &str) -> Self {
        TraceArg::Str(v.to_string())
    }
}

impl From<String> for TraceArg {
    fn from(v: String) -> Self {
        TraceArg::Str(v)
    }
}

/// One closed span, ready for export.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Stable per-thread id (allocation order, starting at 1).
    pub tid: u64,
    /// Start offset from the process trace epoch, microseconds.
    pub ts_us: f64,
    pub dur_us: f64,
    pub args: Vec<(&'static str, TraceArg)>,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn sink() -> &'static Mutex<Vec<SpanEvent>> {
    static SINK: OnceLock<Mutex<Vec<SpanEvent>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

/// Turn recording on/off. The epoch is pinned at the first enable so
/// timestamps are offsets into the traced run, not process lifetime.
pub fn set_enabled(on: bool) {
    if on {
        epoch();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flush batch size for the per-thread buffer.
const FLUSH_AT: usize = 64;

struct LocalBuf {
    tid: u64,
    events: Vec<SpanEvent>,
}

impl LocalBuf {
    fn flush(&mut self) {
        if !self.events.is_empty() {
            sink().lock().unwrap().append(&mut self.events);
        }
    }
}

impl Drop for LocalBuf {
    // Thread exit: whatever the batch threshold left behind goes to the
    // sink, so export-after-join sees every span.
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static LOCAL: RefCell<LocalBuf> = RefCell::new(LocalBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        events: Vec::new(),
    });
}

/// Flush the calling thread's buffer into the sink. Exporters call this
/// so the exporting thread's own spans are never missing from the file.
pub fn flush_current_thread() {
    LOCAL.with(|l| l.borrow_mut().flush());
}

/// Drop every recorded event (test isolation).
pub fn clear() {
    flush_current_thread();
    sink().lock().unwrap().clear();
}

/// Snapshot of all events recorded so far, time-ordered.
pub fn snapshot_events() -> Vec<SpanEvent> {
    flush_current_thread();
    let mut evs = sink().lock().unwrap().clone();
    evs.sort_by(|a, b| {
        a.ts_us
            .partial_cmp(&b.ts_us)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.tid.cmp(&b.tid))
    });
    evs
}

/// An open span: records `[enter, drop)` into the trace when enabled.
/// A disabled-recorder span is a no-op shell (one atomic load).
pub struct Span {
    inner: Option<SpanInner>,
}

struct SpanInner {
    name: &'static str,
    start: Instant,
    args: Vec<(&'static str, TraceArg)>,
}

impl Span {
    pub fn enter(name: &'static str) -> Span {
        Span::enter_with(name, Vec::new())
    }

    pub fn enter_with(name: &'static str, args: Vec<(&'static str, TraceArg)>) -> Span {
        if !enabled() {
            return Span { inner: None };
        }
        Span {
            inner: Some(SpanInner {
                name,
                start: Instant::now(),
                args,
            }),
        }
    }

    /// Attach an argument known only mid-span (status, byte counts,
    /// retry totals).
    pub fn arg(&mut self, key: &'static str, value: impl Into<TraceArg>) {
        if let Some(inner) = &mut self.inner {
            inner.args.push((key, value.into()));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else {
            return;
        };
        let dur_us = inner.start.elapsed().as_secs_f64() * 1e6;
        // Saturates to zero for spans entered before the epoch was
        // pinned (enable raced a long-lived span) — harmless.
        let ts_us = inner.start.duration_since(epoch()).as_secs_f64() * 1e6;
        LOCAL.with(|l| {
            let mut buf = l.borrow_mut();
            let tid = buf.tid;
            buf.events.push(SpanEvent {
                name: inner.name,
                tid,
                ts_us,
                dur_us,
                args: inner.args,
            });
            if buf.events.len() >= FLUSH_AT {
                buf.flush();
            }
        });
    }
}

fn arg_json(a: &TraceArg) -> Json {
    match a {
        TraceArg::U64(v) => Json::num(*v as f64),
        TraceArg::F64(v) => Json::num(*v),
        TraceArg::Str(s) => Json::str(s.clone()),
    }
}

/// Render events as a Chrome trace-event JSON document (the format
/// Perfetto and `chrome://tracing` load): complete (`"ph":"X"`) events
/// with microsecond `ts`/`dur`, one `pid`, per-thread `tid` tracks.
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    let evs = events
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("name", Json::str(e.name)),
                ("cat", Json::str("fkmpp")),
                ("ph", Json::str("X")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(e.tid as f64)),
                ("ts", Json::num(e.ts_us)),
                ("dur", Json::num(e.dur_us)),
            ];
            if !e.args.is_empty() {
                fields.push((
                    "args",
                    Json::Obj(
                        e.args
                            .iter()
                            .map(|(k, v)| (k.to_string(), arg_json(v)))
                            .collect(),
                    ),
                ));
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Export everything recorded so far as Chrome trace JSON.
pub fn export_json() -> Json {
    chrome_trace_json(&snapshot_events())
}

/// Write the recorded trace to `path`; returns the span count.
pub fn write_file(path: &str) -> Result<usize> {
    let events = snapshot_events();
    let doc = chrome_trace_json(&events);
    std::fs::write(path, doc.emit())
        .with_context(|| format!("writing trace file {path}"))?;
    Ok(events.len())
}

/// Per-phase aggregate over a recorded trace (one table row).
#[derive(Clone, Debug)]
pub struct PhaseRow {
    pub name: String,
    pub count: u64,
    pub total_secs: f64,
    pub mean_secs: f64,
    pub max_secs: f64,
}

/// Aggregate a Chrome trace document by span name. Fails with a typed
/// error when the document is not a trace (missing `traceEvents`).
pub fn phase_rows(doc: &Json) -> Result<Vec<PhaseRow>> {
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_array)
        .context("not a Chrome trace: no \"traceEvents\" array")?;
    let mut by_name: std::collections::BTreeMap<String, PhaseRow> =
        std::collections::BTreeMap::new();
    for e in events {
        if e.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .context("trace event without a name")?;
        let dur_s = e.get("dur").and_then(Json::as_f64).unwrap_or(0.0) / 1e6;
        let row = by_name.entry(name.to_string()).or_insert_with(|| PhaseRow {
            name: name.to_string(),
            count: 0,
            total_secs: 0.0,
            mean_secs: 0.0,
            max_secs: 0.0,
        });
        row.count += 1;
        row.total_secs += dur_s;
        row.max_secs = row.max_secs.max(dur_s);
    }
    let mut rows: Vec<PhaseRow> = by_name
        .into_values()
        .map(|mut r| {
            r.mean_secs = r.total_secs / r.count.max(1) as f64;
            r
        })
        .collect();
    rows.sort_by(|a, b| {
        b.total_secs
            .partial_cmp(&a.total_secs)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.name.cmp(&b.name))
    });
    Ok(rows)
}

/// Render the paper-style per-phase breakdown table from a recorded
/// trace document (`fkmpp report --trace <path>`). `share%` is each
/// phase's fraction of the *sum of recorded span time* — spans nest, so
/// shares can double-count and need not total 100.
pub fn render_report(doc: &Json) -> Result<String> {
    let rows = phase_rows(doc)?;
    let total: f64 = rows.iter().map(|r| r.total_secs).sum();
    let mut out = String::new();
    out.push_str(&format!(
        "{:<28} {:>8} {:>12} {:>12} {:>12} {:>7}\n",
        "phase", "count", "total", "mean", "max", "share%"
    ));
    for r in &rows {
        let share = if total > 0.0 {
            100.0 * r.total_secs / total
        } else {
            0.0
        };
        out.push_str(&format!(
            "{:<28} {:>8} {:>12} {:>12} {:>12} {:>7.2}\n",
            r.name,
            r.count,
            crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(r.total_secs)),
            crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(r.mean_secs)),
            crate::metrics::fmt_duration(std::time::Duration::from_secs_f64(r.max_secs)),
            share
        ));
    }
    if rows.is_empty() {
        out.push_str("(trace contains no spans)\n");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json::parse;

    // The recorder is process-global and sibling unit tests run in
    // parallel in this process, so every assertion filters on this
    // test's own `ttest.` span names — never on global totals.
    #[test]
    fn record_export_report_round_trip() {
        let mine = |evs: Vec<SpanEvent>| -> Vec<SpanEvent> {
            evs.into_iter()
                .filter(|e| e.name.starts_with("ttest.") && e.name != "ttest.noop")
                .collect()
        };

        // Disabled spans are inert. A sibling test can flip the recorder
        // on concurrently (it is never flipped off), so `enabled()` is
        // monotone: if it is still off *after* the drop, it was off at
        // enter time and nothing can have been recorded.
        if !enabled() {
            let mut s = Span::enter("ttest.noop");
            s.arg("x", 1u64);
            drop(s);
            if !enabled() {
                assert!(snapshot_events().iter().all(|e| e.name != "ttest.noop"));
            }
        }

        set_enabled(true);
        {
            let mut s = Span::enter_with("ttest.outer", vec![("round", TraceArg::U64(3))]);
            {
                let _inner = Span::enter("ttest.inner");
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            s.arg("status", 200u64);
        }
        // A span closed on another thread must land in the sink once the
        // thread exits (the LocalBuf drop flush).
        std::thread::spawn(|| {
            let _s = Span::enter("ttest.worker");
        })
        .join()
        .unwrap();
        // Deliberately NOT disabled again: sibling tests (the CLI
        // `--trace` test) may have enabled recording concurrently, and
        // flipping it off under them would lose their spans. Leaving it
        // on is safe — every assertion here filters on `ttest.` names.

        let events = mine(snapshot_events());
        assert_eq!(events.len(), 3, "events: {events:?}");
        let outer = events.iter().find(|e| e.name == "ttest.outer").unwrap();
        let inner = events.iter().find(|e| e.name == "ttest.inner").unwrap();
        assert!(outer.dur_us >= inner.dur_us);
        assert!(outer.ts_us <= inner.ts_us);
        assert_eq!(
            outer.args,
            vec![("round", TraceArg::U64(3)), ("status", TraceArg::U64(200))]
        );
        let worker = events.iter().find(|e| e.name == "ttest.worker").unwrap();
        assert_ne!(worker.tid, outer.tid, "worker thread shares a tid");

        // Export must round-trip through the crate's strict parser and
        // carry the Chrome trace-event shape.
        let text = chrome_trace_json(&events).emit();
        let doc = parse(&text).expect("exported trace must be strict-valid JSON");
        let evs = doc.get("traceEvents").and_then(Json::as_array).unwrap();
        assert_eq!(evs.len(), 3);
        for e in evs {
            assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
            assert_eq!(e.get("cat").and_then(Json::as_str), Some("fkmpp"));
            assert!(e.get("ts").and_then(Json::as_f64).is_some());
            assert!(e.get("dur").and_then(Json::as_f64).is_some());
        }
        let outer_json = evs
            .iter()
            .find(|e| e.get("name").and_then(Json::as_str) == Some("ttest.outer"))
            .unwrap();
        let args = outer_json.get("args").expect("outer args serialized");
        assert_eq!(args.get("round").and_then(Json::as_u64), Some(3));
        assert_eq!(args.get("status").and_then(Json::as_u64), Some(200));

        // Report: aggregated by name, one row per distinct span.
        let report = render_report(&doc).unwrap();
        assert!(report.contains("ttest.outer"), "{report}");
        assert!(report.contains("ttest.inner"), "{report}");
        assert!(report.contains("share%"), "{report}");
        assert!(phase_rows(&doc).unwrap().iter().all(|r| r.count == 1));

        // Non-trace documents are a typed error, not a panic.
        assert!(render_report(&parse("{\"x\":1}").unwrap()).is_err());
    }
}
