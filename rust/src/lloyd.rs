//! Lloyd's local-improvement algorithm (Lloyd 1982) — the refinement the
//! paper runs after seeding ("K-MEANS++ … combination of a randomized
//! seeding with the classic local improvement algorithm").
//!
//! Iterations run on either backend ([`crate::runtime::Backend`]): the
//! tuned native path (whose assignment/cost loops route through
//! [`crate::kernels`]) or the AOT JAX/Pallas `lloyd_step` artifact via
//! PJRT. Empty clusters are re-seeded with the point farthest from its
//! assigned center (the standard repair).

use crate::data::matrix::PointSet;
use crate::runtime::{native, Backend};

/// Lloyd configuration.
#[derive(Clone, Debug)]
pub struct LloydConfig {
    /// Max iterations.
    pub max_iters: usize,
    /// Stop when the relative cost improvement falls below this.
    pub tol: f64,
}

impl Default for LloydConfig {
    fn default() -> Self {
        LloydConfig {
            max_iters: 20,
            tol: 1e-4,
        }
    }
}

/// Result of a Lloyd run.
#[derive(Clone, Debug)]
pub struct LloydResult {
    pub centers: PointSet,
    /// Cost under the centers *before* each iteration, plus the final
    /// cost: `history.len() == iterations + 1`.
    pub history: Vec<f64>,
    pub iterations: usize,
}

/// Convenience: k-means cost on the native backend.
pub fn cost_native(ps: &PointSet, centers: &PointSet) -> f64 {
    native::cost(ps, centers)
}

/// Run Lloyd iterations from `seed_centers` on `backend`.
pub fn lloyd(
    ps: &PointSet,
    seed_centers: &PointSet,
    cfg: &LloydConfig,
    backend: &Backend,
) -> crate::error::Result<LloydResult> {
    let k = seed_centers.len();
    let d = ps.dim();
    let mut centers = seed_centers.clone();
    let mut history = Vec::with_capacity(cfg.max_iters + 1);
    let mut iterations = 0;
    // Kernels-v2 norm cache: the points never change across iterations,
    // so one O(nd) pass here serves every step, repair assignment and
    // the final cost evaluation (centers change per iteration — their
    // norms are recomputed inside the kernels, an O(kd) triviality).
    // PJRT has no norm-cache contract and its backend arms ignore the
    // slice, so skip the pass there (empty slice = "no cache").
    let point_norms = match backend {
        Backend::Native => crate::kernels::norms::squared_norms(ps),
        Backend::Pjrt(_) => Vec::new(),
    };
    for _ in 0..cfg.max_iters {
        let (sums, counts, cost) = backend.lloyd_step_cached(ps, &point_norms, &centers)?;
        history.push(cost);
        // New centers = cluster means; empty clusters re-seeded below.
        let mut next = PointSet::zeros(k, d);
        let mut empties = Vec::new();
        for j in 0..k {
            if counts[j] == 0 {
                empties.push(j);
                next.row_mut(j).copy_from_slice(centers.row(j));
            } else {
                let row = next.row_mut(j);
                for t in 0..d {
                    row[t] = (sums[j * d + t] / counts[j] as f64) as f32;
                }
            }
        }
        if !empties.is_empty() {
            // Re-seed each empty cluster with the point currently farthest
            // from its center (one extra assignment pass).
            let (_, mind2) = backend.assign_cached(ps, &point_norms, &centers)?;
            let mut order: Vec<usize> = (0..ps.len()).collect();
            order.sort_by(|&a, &b| mind2[b].partial_cmp(&mind2[a]).unwrap());
            for (slot, j) in empties.into_iter().enumerate() {
                if slot < order.len() {
                    next.row_mut(j).copy_from_slice(ps.row(order[slot]));
                }
            }
        }
        centers = next;
        iterations += 1;
        // Convergence on relative improvement.
        if history.len() >= 2 {
            let prev = history[history.len() - 2];
            let cur = history[history.len() - 1];
            if prev.is_finite() && prev > 0.0 && (prev - cur) / prev < cfg.tol {
                break;
            }
        }
    }
    history.push(backend.cost_cached(ps, &point_norms, &centers)?);
    Ok(LloydResult {
        centers,
        history,
        iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, separated_grid, SynthSpec};
    use crate::rng::Pcg64;
    use crate::seeding::kmeanspp::kmeanspp;

    #[test]
    fn cost_decreases_monotonically() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 2000,
                d: 6,
                k_true: 8,
                ..Default::default()
            },
            1,
        );
        let mut rng = Pcg64::seed_from(2);
        let seed = kmeanspp(&ps, 8, &mut rng);
        let res = lloyd(&ps, &seed.centers, &LloydConfig::default(), &Backend::Native).unwrap();
        for w in res.history.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + 1e-9),
                "cost increased: {} -> {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn recovers_separated_clusters_exactly() {
        let ps = separated_grid(6, 100, 3, 3);
        let mut rng = Pcg64::seed_from(4);
        let seed = kmeanspp(&ps, 6, &mut rng);
        let res = lloyd(
            &ps,
            &seed.centers,
            &LloydConfig {
                max_iters: 30,
                tol: 1e-9,
            },
            &Backend::Native,
        )
        .unwrap();
        // Final cost ~ within-cluster variance only: per point ~ d*0.25.
        let final_cost = *res.history.last().unwrap();
        let per_point = final_cost / ps.len() as f64;
        assert!(per_point < 3.0 * 0.25 * 3.0, "per-point cost {per_point}");
    }

    #[test]
    fn single_iteration_limit_respected() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 300,
                d: 4,
                k_true: 3,
                ..Default::default()
            },
            5,
        );
        let mut rng = Pcg64::seed_from(6);
        let seed = kmeanspp(&ps, 3, &mut rng);
        let res = lloyd(
            &ps,
            &seed.centers,
            &LloydConfig {
                max_iters: 1,
                tol: 0.0,
            },
            &Backend::Native,
        )
        .unwrap();
        assert_eq!(res.iterations, 1);
        assert_eq!(res.history.len(), 2);
    }

    #[test]
    fn empty_cluster_repair() {
        // Duplicate seed centers force an empty cluster on step one.
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 500,
                d: 4,
                k_true: 5,
                ..Default::default()
            },
            7,
        );
        let dup = ps.gather(&[0, 0, 0, 100]);
        let res = lloyd(&ps, &dup, &LloydConfig::default(), &Backend::Native).unwrap();
        // After repair the final centers should be distinct.
        for i in 0..4 {
            for j in (i + 1)..4 {
                let dd = crate::data::matrix::d2(res.centers.row(i), res.centers.row(j));
                assert!(dd > 0.0, "centers {i} and {j} identical after repair");
            }
        }
    }
}
