//! `fkmpp` — the CLI entry point. All logic lives in the library
//! (`fastkmeanspp::cli`); this binary is a thin shim so the coordinator
//! stays testable.

fn main() {
    fastkmeanspp::log::install_panic_hook();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match fastkmeanspp::cli::run(&argv) {
        Ok(out) => print!("{out}"),
        Err(e) => {
            fastkmeanspp::log::error(
                "fatal",
                &[(
                    "error",
                    fastkmeanspp::server::json::Json::str(format!("{e:#}")),
                )],
            );
            fastkmeanspp::log::dump_flight_recorder("fatal error");
            std::process::exit(1);
        }
    }
}
