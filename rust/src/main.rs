//! `fkmpp` — the CLI entry point. All logic lives in the library
//! (`fastkmeanspp::cli`); this binary is a thin shim so the coordinator
//! stays testable.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match fastkmeanspp::cli::run(&argv) {
        Ok(out) => print!("{out}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
