//! Incremental `D^2` update: the `Θ(nd)`-per-round kernel of exact
//! k-means++ (and the `d2_update` PJRT artifact's native twin).

use crate::data::matrix::{d2, PointSet};
use crate::kernels::{blocked, tune};
use crate::parallel::parallel_chunks_mut;

/// Points per worker below which the update runs inline (spawning
/// threads costs more than the arithmetic saves).
const MIN_POINTS_PER_THREAD: usize = 4096;

/// [`d2_update_min`] for callers holding a point-norm cache
/// ([`crate::kernels::norms::squared_norms`] of `ps`, reusable across
/// rounds): dispatches between the v1 direct loop and the v2 norm-trick
/// loop ([`crate::kernels::blocked::d2_update_min_blocked`]) via the
/// runtime autotuner. Without a cache the norm trick cannot win (its
/// one-off `O(nd)` norm pass costs what it saves), so the uncached
/// [`d2_update_min`] is always the v1 loop.
pub fn d2_update_min_cached(
    ps: &PointSet,
    center: &[f32],
    point_norms: &[f32],
    cur_d2: &mut [f32],
) {
    match tune::kernel_for(tune::Op::Update, ps.len(), ps.dim(), 1) {
        tune::Kernel::Naive => d2_update_min(ps, center, cur_d2),
        tune::Kernel::Blocked => blocked::d2_update_min_blocked(ps, center, point_norms, cur_d2),
    }
}

/// `cur_d2[i] = min(cur_d2[i], ||x_i - center||^2)` for every point, in
/// parallel chunks. `center` is an arbitrary point of dimension
/// `ps.dim()`; pass `ps.row(j)` to open dataset point `j`. This is the
/// v1 direct-distance loop (the reference semantics).
pub fn d2_update_min(ps: &PointSet, center: &[f32], cur_d2: &mut [f32]) {
    assert_eq!(center.len(), ps.dim(), "center dimension mismatch");
    assert_eq!(cur_d2.len(), ps.len(), "distance array length mismatch");
    parallel_chunks_mut(cur_d2, 1, MIN_POINTS_PER_THREAD, |start, chunk| {
        for (slot, i) in chunk.iter_mut().zip(start..) {
            let dd = d2(ps.row(i), center);
            if dd < *slot {
                *slot = dd;
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    #[test]
    fn matches_serial_reference() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 20_000,
                d: 12,
                k_true: 5,
                ..Default::default()
            },
            1,
        );
        let center = ps.row(17).to_vec();
        let mut par = vec![f32::INFINITY; ps.len()];
        d2_update_min(&ps, &center, &mut par);
        for i in 0..ps.len() {
            assert_eq!(par[i], d2(ps.row(i), &center), "i={i}");
        }
    }

    #[test]
    fn only_decreases() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 5_000,
                d: 8,
                k_true: 4,
                ..Default::default()
            },
            2,
        );
        let mut cur = vec![f32::INFINITY; ps.len()];
        d2_update_min(&ps, ps.row(0), &mut cur);
        let before = cur.clone();
        d2_update_min(&ps, ps.row(4_999), &mut cur);
        for i in 0..ps.len() {
            assert!(cur[i] <= before[i], "i={i}");
        }
        assert_eq!(cur[0], 0.0);
        assert_eq!(cur[4_999], 0.0);
    }

    #[test]
    fn tiny_input_runs_inline() {
        let ps = PointSet::from_rows(&[vec![0.0f32, 0.0], vec![3.0, 4.0]]);
        let mut cur = vec![f32::INFINITY; 2];
        d2_update_min(&ps, &[0.0, 0.0], &mut cur);
        assert_eq!(cur, vec![0.0, 25.0]);
    }
}
