//! The parallel distance-kernel engine — every exact-`D^2` hot path in
//! one place.
//!
//! The paper's runtime claims (Tables 1–3) compare the near-linear-time
//! seeders against exact baselines whose cost is dominated by three dense
//! primitives. They used to be re-implemented privately by each caller
//! (`seeding/kmeanspp.rs`, `seeding/afkmc2.rs`, `lloyd.rs`, ...); they now
//! live here, chunked and cache-blocked, driven by the
//! [`crate::parallel`] helpers:
//!
//! * [`d2::d2_update_min`] — incremental `D^2` array update against one
//!   new center: `cur[i] = min(cur[i], ||x_i - c||^2)`. `O(nd)` per call;
//!   the inner loop of exact k-means++ and AFK-MC² initialization.
//! * [`assign::assign_argmin`] — point → nearest-center assignment with
//!   center tiling, `O(nkd)`; the inner loop of Lloyd and cost evaluation.
//! * [`reduce`] — blocked tree-sum reductions: total cost, `f32 → f64`
//!   weight sums, per-block partial sums (the prefix structure `D^2`
//!   sampling scans), and the max-distance bound the tree embedding needs.
//!
//! **Kernels v2.** Each of the three primitives has two implementations
//! behind one entry point: the v1 *naive* direct-distance loops (the
//! scalar reference semantics) and the v2 *blocked* norm-trick loops
//! ([`blocked`]: `||x-c||^2 = ||x||^2 + ||c||^2 - 2·x·c` with 8-lane
//! accumulators and per-tile interleaved center panels). The v2 kernels
//! consume squared-norm caches ([`norms`]) owned by the call sites that
//! can reuse them across rounds — seeders, Lloyd, the server's model
//! registry. A small runtime autotuner ([`tune`]) picks the
//! implementation per `(op, n, d, k)` shape at first use; pin it with
//! `FKMPP_KERNEL=naive|blocked`.
//!
//! Threading policy is inherited from [`crate::parallel::num_threads`]
//! (override with `FKMPP_THREADS`); every kernel degrades to a single
//! inline call for small inputs, so tiny test instances pay no spawn
//! cost. The PJRT artifacts implement the same contracts
//! ([`crate::runtime`]); `rust/tests/kernel_parity.rs` property-tests the
//! v1 kernels against naive serial references across thread counts, and
//! `rust/tests/kernel_parity_v2.rs` pits the v2 kernels against the v1
//! references (remainder lanes, degenerate inputs, tie-breaking, and
//! thread-count-invariant sums).

pub mod assign;
pub mod blocked;
pub mod d2;
pub mod norms;
pub mod reduce;
pub mod tune;
