//! The parallel distance-kernel engine — every exact-`D^2` hot path in
//! one place.
//!
//! The paper's runtime claims (Tables 1–3) compare the near-linear-time
//! seeders against exact baselines whose cost is dominated by three dense
//! primitives. They used to be re-implemented privately by each caller
//! (`seeding/kmeanspp.rs`, `seeding/afkmc2.rs`, `lloyd.rs`, ...); they now
//! live here, chunked and cache-blocked, driven by the
//! [`crate::parallel`] helpers:
//!
//! * [`d2::d2_update_min`] — incremental `D^2` array update against one
//!   new center: `cur[i] = min(cur[i], ||x_i - c||^2)`. `O(nd)` per call;
//!   the inner loop of exact k-means++ and AFK-MC² initialization.
//! * [`assign::assign_argmin`] — point → nearest-center assignment with
//!   center tiling, `O(nkd)`; the inner loop of Lloyd and cost evaluation.
//! * [`reduce`] — blocked tree-sum reductions: total cost, `f32 → f64`
//!   weight sums, per-block partial sums (the prefix structure `D^2`
//!   sampling scans), and the max-distance bound the tree embedding needs.
//!
//! Threading policy is inherited from [`crate::parallel::num_threads`]
//! (override with `FKMPP_THREADS`); every kernel degrades to a single
//! inline call for small inputs, so tiny test instances pay no spawn
//! cost. The PJRT artifacts implement the same contracts
//! ([`crate::runtime`]); `rust/tests/kernel_parity.rs` property-tests the
//! kernels against naive serial references across thread counts.

pub mod assign;
pub mod d2;
pub mod reduce;
