//! Blocked tree-sum reductions over points and weights.
//!
//! Sums of squared f32 distances overflow f32 precision long before the
//! paper's dataset sizes, so the summing reductions here widen to f64
//! and accumulate in **fixed-size blocks at fixed global boundaries**
//! (a two-level tree sum). That buys two properties at once:
//!
//! * worst-case rounding error `O(blocks)` ulps instead of `O(n)`;
//! * results **independent of the thread count** — block boundaries
//!   never move with `FKMPP_THREADS`, so callers that compare sums
//!   (e.g. greedy k-means++ candidate selection) order candidates
//!   identically at any parallelism. `max_d2_to` needs neither trick:
//!   `max` is order-free.

use crate::data::matrix::{d2, PointSet};
use crate::kernels::assign::min_d2_block;
use crate::kernels::{blocked, norms, tune};
use crate::parallel::{parallel_chunks_mut, parallel_reduce};

/// Leaf block size of the two-level tree sum. Public because it is a
/// *wire contract* of the distributed fit ([`crate::dist`]): workers
/// return per-`SUM_BLOCK` f64 partial cost sums over ranges aligned to
/// this boundary, and the coordinator reproduces [`sum_f32`] bitwise by
/// concatenating them in range order and summing left-to-right.
pub const SUM_BLOCK: usize = 4096;

/// Points per worker below which reductions run inline.
const MIN_POINTS_PER_THREAD: usize = 2048;

/// Serial blocked sum of f32 values in f64 (the reduction leaf).
fn block_sum_serial(xs: &[f32]) -> f64 {
    xs.chunks(SUM_BLOCK)
        .map(|c| c.iter().map(|&v| v as f64).sum::<f64>())
        .sum()
}

/// Σ w\[i\] as f64: fixed-boundary parallel tree sum (thread-invariant).
pub fn sum_f32(w: &[f32]) -> f64 {
    block_sums(w, SUM_BLOCK).iter().sum()
}

/// Per-block partial sums: `out[b] = Σ w[b*block .. (b+1)*block]` in f64.
/// This is the coarse level of the prefix structure exact `D^2` sampling
/// scans (sum all blocks, pick a block, scan inside it).
pub fn block_sums(w: &[f32], block: usize) -> Vec<f64> {
    let block = block.max(1);
    let nblocks = w.len().div_ceil(block);
    let mut out = vec![0.0f64; nblocks];
    parallel_chunks_mut(&mut out, 1, 4, |start, chunk| {
        for (slot, b) in chunk.iter_mut().zip(start..) {
            let lo = b * block;
            let hi = (lo + block).min(w.len());
            *slot = block_sum_serial(&w[lo..hi]);
        }
    });
    out
}

/// k-means cost: Σ_i min_j `||x_i - c_j||^2` — `O(nkd)` work, fused
/// min-distance + sum. Each fixed `SUM_BLOCK`-point block is evaluated
/// with the center-tiled distance core (v1, [`crate::kernels::assign`])
/// or the blocked norm-trick core (v2, [`crate::kernels::blocked`],
/// winners rescored with the direct kernel) into a per-worker scratch,
/// then summed; blocks combine in order — cache-hot on the center
/// matrix, bounded rounding error, thread-count-invariant either way
/// (the block boundaries never move).
pub fn cost(ps: &PointSet, centers: &PointSet) -> f64 {
    cost_cached(ps, None, centers, None)
}

/// [`cost`] with optional precomputed squared-norm caches (consulted
/// only when the autotuner picks the v2 kernel; missing ones are
/// computed on the fly).
pub fn cost_cached(
    ps: &PointSet,
    point_norms: Option<&[f32]>,
    centers: &PointSet,
    center_norms: Option<&[f32]>,
) -> f64 {
    assert_eq!(ps.dim(), centers.dim(), "dimension mismatch");
    assert!(!centers.is_empty(), "no centers");
    match tune::kernel_for(tune::Op::Assign, ps.len(), ps.dim(), centers.len()) {
        tune::Kernel::Naive => cost_naive(ps, centers),
        tune::Kernel::Blocked => {
            let (mut pn_owned, mut cn_owned) = (None, None);
            let pn = norms::resolve(point_norms, ps, &mut pn_owned);
            let cn = norms::resolve(center_norms, centers, &mut cn_owned);
            cost_blocked(ps, pn, centers, cn)
        }
    }
}

/// The v1 cost reduction (direct distances, center-tiled).
pub fn cost_naive(ps: &PointSet, centers: &PointSet) -> f64 {
    assert_eq!(ps.dim(), centers.dim(), "dimension mismatch");
    assert!(!centers.is_empty(), "no centers");
    let n = ps.len();
    let nblocks = n.div_ceil(SUM_BLOCK);
    let mut partials = vec![0.0f64; nblocks];
    parallel_chunks_mut(&mut partials, 1, 1, |start, chunk| {
        let mut scratch = vec![0.0f32; SUM_BLOCK];
        for (slot, b) in chunk.iter_mut().zip(start..) {
            let lo = b * SUM_BLOCK;
            let hi = (lo + SUM_BLOCK).min(n);
            let ds = &mut scratch[..hi - lo];
            min_d2_block(ps, centers, lo, ds);
            *slot = ds.iter().map(|&v| v as f64).sum();
        }
    });
    partials.iter().sum()
}

/// The v2 cost reduction: blocked norm-trick argmin per fixed block,
/// winners rescored with the direct scalar kernel before summing, so the
/// sum carries v1-grade rounding (no norm-scale cancellation error).
fn cost_blocked(ps: &PointSet, pn: &[f32], centers: &PointSet, cn: &[f32]) -> f64 {
    let n = ps.len();
    let nblocks = n.div_ceil(SUM_BLOCK);
    let mut partials = vec![0.0f64; nblocks];
    parallel_chunks_mut(&mut partials, 1, 1, |start, chunk| {
        let mut ds_scratch = vec![0.0f32; SUM_BLOCK];
        let mut ids_scratch = vec![0u32; SUM_BLOCK];
        for (slot, b) in chunk.iter_mut().zip(start..) {
            let lo = b * SUM_BLOCK;
            let hi = (lo + SUM_BLOCK).min(n);
            let ds = &mut ds_scratch[..hi - lo];
            let ids = &mut ids_scratch[..hi - lo];
            ds.fill(f32::INFINITY);
            ids.fill(0);
            blocked::argmin_core(ps, pn, centers, cn, lo, ids, ds);
            blocked::rescore_block(ps, centers, lo, ids, ds);
            *slot = ds.iter().map(|&v| v as f64).sum();
        }
    });
    partials.iter().sum()
}

/// Weighted k-means cost: `Σ_i w_i · min_j ||x_i - c_j||²` — the
/// objective of a [`crate::shard::weighted::WeightedPointSet`] (candidate
/// sets whose weights are assignment counts, coresets). Same fused
/// min-distance + fixed-`SUM_BLOCK` f64 reduction as [`cost`]; the
/// weight multiply happens in f64 *after* the f32 min-distance, so
/// `w ≡ 1` reproduces [`cost`] bit-for-bit and results stay
/// thread-count-invariant (block boundaries never move).
pub fn cost_weighted(ps: &PointSet, weights: &[f32], centers: &PointSet) -> f64 {
    cost_weighted_cached(ps, weights, None, centers, None)
}

/// [`cost_weighted`] with optional precomputed squared-norm caches
/// (consulted only when the autotuner picks the v2 kernel).
pub fn cost_weighted_cached(
    ps: &PointSet,
    weights: &[f32],
    point_norms: Option<&[f32]>,
    centers: &PointSet,
    center_norms: Option<&[f32]>,
) -> f64 {
    assert_eq!(ps.dim(), centers.dim(), "dimension mismatch");
    assert_eq!(weights.len(), ps.len(), "weight array length mismatch");
    assert!(!centers.is_empty(), "no centers");
    match tune::kernel_for(tune::Op::Assign, ps.len(), ps.dim(), centers.len()) {
        tune::Kernel::Naive => cost_weighted_naive(ps, weights, centers),
        tune::Kernel::Blocked => {
            let (mut pn_owned, mut cn_owned) = (None, None);
            let pn = norms::resolve(point_norms, ps, &mut pn_owned);
            let cn = norms::resolve(center_norms, centers, &mut cn_owned);
            cost_weighted_blocked(ps, weights, pn, centers, cn)
        }
    }
}

/// The v1 weighted cost reduction (direct distances, center-tiled) —
/// the reference the weighted-parity suite measures against.
pub fn cost_weighted_naive(ps: &PointSet, weights: &[f32], centers: &PointSet) -> f64 {
    assert_eq!(ps.dim(), centers.dim(), "dimension mismatch");
    assert_eq!(weights.len(), ps.len(), "weight array length mismatch");
    assert!(!centers.is_empty(), "no centers");
    let n = ps.len();
    let nblocks = n.div_ceil(SUM_BLOCK);
    let mut partials = vec![0.0f64; nblocks];
    parallel_chunks_mut(&mut partials, 1, 1, |start, chunk| {
        let mut scratch = vec![0.0f32; SUM_BLOCK];
        for (slot, b) in chunk.iter_mut().zip(start..) {
            let lo = b * SUM_BLOCK;
            let hi = (lo + SUM_BLOCK).min(n);
            let ds = &mut scratch[..hi - lo];
            min_d2_block(ps, centers, lo, ds);
            *slot = ds
                .iter()
                .zip(&weights[lo..hi])
                .map(|(&d, &w)| d as f64 * w as f64)
                .sum();
        }
    });
    partials.iter().sum()
}

/// The v2 weighted cost reduction: blocked norm-trick argmin per fixed
/// block, winners rescored with the direct scalar kernel, weights folded
/// in f64 (same rounding discipline as [`cost`]'s v2 path).
fn cost_weighted_blocked(
    ps: &PointSet,
    weights: &[f32],
    pn: &[f32],
    centers: &PointSet,
    cn: &[f32],
) -> f64 {
    let n = ps.len();
    let nblocks = n.div_ceil(SUM_BLOCK);
    let mut partials = vec![0.0f64; nblocks];
    parallel_chunks_mut(&mut partials, 1, 1, |start, chunk| {
        let mut ds_scratch = vec![0.0f32; SUM_BLOCK];
        let mut ids_scratch = vec![0u32; SUM_BLOCK];
        for (slot, b) in chunk.iter_mut().zip(start..) {
            let lo = b * SUM_BLOCK;
            let hi = (lo + SUM_BLOCK).min(n);
            let ds = &mut ds_scratch[..hi - lo];
            let ids = &mut ids_scratch[..hi - lo];
            ds.fill(f32::INFINITY);
            ids.fill(0);
            blocked::argmin_core(ps, pn, centers, cn, lo, ids, ds);
            blocked::rescore_block(ps, centers, lo, ids, ds);
            *slot = ds
                .iter()
                .zip(&weights[lo..hi])
                .map(|(&d, &w)| d as f64 * w as f64)
                .sum();
        }
    });
    partials.iter().sum()
}

/// `max_i ||x_i - pivot||^2` — the parallel max-reduction behind the
/// `MAXDIST` upper bound every tree embedding build starts with.
pub fn max_d2_to(ps: &PointSet, pivot: &[f32]) -> f32 {
    assert_eq!(pivot.len(), ps.dim(), "pivot dimension mismatch");
    parallel_reduce(
        ps.len(),
        MIN_POINTS_PER_THREAD,
        0.0f32,
        |range| {
            let mut best = 0.0f32;
            for i in range {
                best = best.max(d2(ps.row(i), pivot));
            }
            best
        },
        f32::max,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn ps(n: usize, d: usize) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 5,
                ..Default::default()
            },
            7,
        )
    }

    #[test]
    fn sum_matches_naive() {
        let w: Vec<f32> = (0..50_000).map(|i| (i % 97) as f32 * 0.25).collect();
        let naive: f64 = w.iter().map(|&v| v as f64).sum();
        let got = sum_f32(&w);
        assert!((got - naive).abs() <= 1e-9 * naive.max(1.0), "{got} vs {naive}");
        assert_eq!(sum_f32(&[]), 0.0);
    }

    #[test]
    fn block_sums_cover_everything() {
        let w: Vec<f32> = (0..10_123).map(|i| (i % 13) as f32).collect();
        for block in [1usize, 7, 100, 8192, 20_000] {
            let bs = block_sums(&w, block);
            assert_eq!(bs.len(), w.len().div_ceil(block));
            let total: f64 = bs.iter().sum();
            let naive: f64 = w.iter().map(|&v| v as f64).sum();
            assert!((total - naive).abs() <= 1e-9 * naive, "block={block}");
            // Spot-check one interior block.
            if bs.len() > 1 {
                let lo = block;
                let hi = (2 * block).min(w.len());
                let want: f64 = w[lo..hi].iter().map(|&v| v as f64).sum();
                assert!((bs[1] - want).abs() <= 1e-9 * want.max(1.0));
            }
        }
        assert!(block_sums(&[], 64).is_empty());
    }

    #[test]
    fn cost_matches_assignment_sum() {
        let ps = ps(4_000, 10);
        let centers = ps.gather(&[0, 71, 999, 3_500]);
        let (_, mind2) = crate::kernels::assign::assign_argmin(&ps, &centers);
        let want: f64 = mind2.iter().map(|&v| v as f64).sum();
        let got = cost(&ps, &centers);
        assert!((got - want).abs() <= 1e-9 * want.max(1.0));
    }

    #[test]
    fn cost_zero_when_centers_cover() {
        let ps = ps(50, 4);
        assert_eq!(cost(&ps, &ps), 0.0);
    }

    #[test]
    fn cost_weighted_unit_weights_matches_cost_bitwise() {
        let ps = ps(6_000, 8);
        let centers = ps.gather(&[3, 500, 4_000]);
        let unit = vec![1.0f32; ps.len()];
        assert_eq!(cost_weighted(&ps, &unit, &centers), cost(&ps, &centers));
    }

    #[test]
    fn cost_weighted_matches_serial_reference() {
        let ps = ps(5_000, 6);
        let centers = ps.gather(&[0, 999, 2_500, 4_999]);
        let weights: Vec<f32> = (0..ps.len()).map(|i| (i % 7) as f32 * 0.5).collect();
        let (_, mind2) = crate::kernels::assign::assign_argmin(&ps, &centers);
        let want: f64 = mind2
            .iter()
            .zip(&weights)
            .map(|(&d, &w)| d as f64 * w as f64)
            .sum();
        let got = cost_weighted(&ps, &weights, &centers);
        assert!((got - want).abs() <= 1e-9 * want.max(1.0), "{got} vs {want}");
        // Zero weights kill the whole sum regardless of distances.
        assert_eq!(cost_weighted(&ps, &vec![0.0; ps.len()], &centers), 0.0);
    }

    #[test]
    fn max_d2_matches_naive() {
        let ps = ps(9_000, 6);
        let pivot = ps.row(0).to_vec();
        let naive = (0..ps.len())
            .map(|i| d2(ps.row(i), &pivot))
            .fold(0.0f32, f32::max);
        assert_eq!(max_d2_to(&ps, &pivot), naive);
    }
}
