//! Kernels v2: 8-lane-blocked, norm-trick distance loops.
//!
//! The v1 kernels compute `‖x − c‖²` directly (subtract, square, add —
//! two instructions per coordinate once vectorized). The v2 formulation
//! precomputes `‖x‖²` and `‖c‖²` ([`crate::kernels::norms`]) and reduces
//! every distance to a **dot product** plus `O(1)` scalar work:
//!
//! ```text
//!   ‖x − c‖² = ‖x‖² + ‖c‖² − 2·x·c
//! ```
//!
//! One fused multiply-add per coordinate, and — for the `O(nkd)`
//! assignment shape — the inner loop becomes a tiny GEMM micro-kernel:
//! each tile of [`LANES`] centers is transposed into an interleaved
//! panel, so the per-coordinate step is `acc[0..8] += x * panel[t][0..8]`,
//! exactly the shape LLVM turns into one 8-wide vector FMA. Remainder
//! coordinates and remainder centers (`d % 8`, `k % 8`) take scalar
//! lanes.
//!
//! Two contracts shared with v1, checked by `rust/tests/kernel_parity_v2.rs`:
//!
//! * **Tie-breaking**: argmin scans run in ascending center order with a
//!   strict `<`, so among centers with bitwise-equal computed distances
//!   the lowest index wins — identical to v1. (Near-ties that round
//!   differently under the two formulations may legitimately pick
//!   different, equally-near centers.)
//! * **Rescored outputs**: the norm trick cancels catastrophically when
//!   `‖x − c‖² ≪ ‖x‖²`, so argmin/cost kernels use the trick only to
//!   *choose* the nearest center, then recompute the winner's distance
//!   with the direct scalar kernel ([`crate::data::matrix::d2`]) — one
//!   extra `O(d)` per point (`1/k` of the work). Returned distances and
//!   cost sums therefore carry v1-grade rounding, and summed results stay
//!   thread-count-invariant (fixed block boundaries, see
//!   [`crate::kernels::reduce`]).
//!
//! `d2_update_min` (one center, `O(nd)`) keeps its norm-trick value
//! un-rescored — a rescore would cost as much as the update itself —
//! clamped at `0.0`; the `D²` sampling weights it feeds are tolerant of
//! norm-scale rounding, and self-distances are still exactly `0.0` (see
//! [`crate::kernels::norms`]).

use crate::data::matrix::{d2, PointSet};
use crate::parallel::{parallel_chunks_mut, parallel_chunks_mut2};

/// Accumulator lanes of the blocked loops (8 f32 = one AVX/NEON-pair
/// vector register).
pub const LANES: usize = 8;

/// Center rows per tile — same 32-row / 16 KiB L1 budget as the v1
/// assignment kernel, processed as four 8-lane groups.
const CENTER_TILE: usize = 4 * LANES;

/// Points per worker below which the update runs inline (matches v1).
const MIN_POINTS_PER_THREAD_UPDATE: usize = 4096;

/// Points per worker below which assignment runs inline (matches v1).
const MIN_POINTS_PER_THREAD_ASSIGN: usize = 1024;

/// 8-lane blocked dot product, remainder coordinates scalar. The lane
/// accumulators combine in a fixed tree order, so the result is a pure
/// function of the inputs (no dependence on threads or call site) — the
/// property the norm caches need for exact self-distance cancellation.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let blocks = a.len() / LANES;
    let (a8, a_rest) = a.split_at(blocks * LANES);
    let (b8, b_rest) = b.split_at(blocks * LANES);
    let mut acc = [0.0f32; LANES];
    for (ca, cb) in a8.chunks_exact(LANES).zip(b8.chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in a_rest.iter().zip(b_rest) {
        tail += x * y;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7])) + tail
}

/// v2 incremental `D²` update:
/// `cur_d2[i] = min(cur_d2[i], ‖x_i‖² + ‖c‖² − 2·x_i·c)` (clamped at 0),
/// in parallel chunks. `point_norms` must be
/// [`crate::kernels::norms::squared_norms`] of `ps`.
pub fn d2_update_min_blocked(
    ps: &PointSet,
    center: &[f32],
    point_norms: &[f32],
    cur_d2: &mut [f32],
) {
    assert_eq!(center.len(), ps.dim(), "center dimension mismatch");
    assert_eq!(cur_d2.len(), ps.len(), "distance array length mismatch");
    assert_eq!(point_norms.len(), ps.len(), "norm cache length mismatch");
    let cn = dot(center, center);
    parallel_chunks_mut(cur_d2, 1, MIN_POINTS_PER_THREAD_UPDATE, |start, chunk| {
        for (slot, i) in chunk.iter_mut().zip(start..) {
            let dd = (point_norms[i] + cn - 2.0 * dot(ps.row(i), center)).max(0.0);
            if dd < *slot {
                *slot = dd;
            }
        }
    });
}

/// v2 nearest-center assignment over the whole set. Same signature
/// contract as the v1 [`crate::kernels::assign::assign_argmin`]:
/// `(argmin indices, min squared distances)`, ties to the lowest center
/// index, distances rescored with the direct scalar kernel.
pub fn assign_argmin_blocked(
    ps: &PointSet,
    point_norms: &[f32],
    centers: &PointSet,
    center_norms: &[f32],
) -> (Vec<u32>, Vec<f32>) {
    assert_eq!(ps.dim(), centers.dim(), "dimension mismatch");
    assert!(!centers.is_empty(), "no centers");
    assert_eq!(point_norms.len(), ps.len(), "point norm cache length mismatch");
    assert_eq!(center_norms.len(), centers.len(), "center norm cache mismatch");
    let n = ps.len();
    let mut idx = vec![0u32; n];
    let mut mind2 = vec![f32::INFINITY; n];
    parallel_chunks_mut2(
        &mut idx,
        &mut mind2,
        MIN_POINTS_PER_THREAD_ASSIGN,
        |start, ids, ds| {
            argmin_core(ps, point_norms, centers, center_norms, start, ids, ds);
            rescore_block(ps, centers, start, ids, ds);
        },
    );
    (idx, mind2)
}

/// Norm-trick argmin over one contiguous point block: fills `ids` with
/// the nearest-center index per point and `ds` with the *norm-trick*
/// minimum value (callers rescore via [`rescore_block`]). `ds` must
/// arrive filled with `f32::INFINITY`-or-larger sentinels (freshly
/// allocated or `fill`ed).
pub(crate) fn argmin_core(
    ps: &PointSet,
    point_norms: &[f32],
    centers: &PointSet,
    center_norms: &[f32],
    start: usize,
    ids: &mut [u32],
    ds: &mut [f32],
) {
    let k = centers.len();
    let d = centers.dim();
    // Interleaved panel for the lane-complete part of the current tile:
    // panel[g*LANES*d + t*LANES + l] = centers.row(tile_base + g*LANES + l)[t].
    let mut panel = vec![0.0f32; CENTER_TILE * d];
    let mut c0 = 0usize;
    while c0 < k {
        let c1 = (c0 + CENTER_TILE).min(k);
        let groups = (c1 - c0) / LANES;
        let full = groups * LANES;
        for g in 0..groups {
            for l in 0..LANES {
                let row = centers.row(c0 + g * LANES + l);
                let pane = &mut panel[g * LANES * d..(g + 1) * LANES * d];
                for (t, &v) in row.iter().enumerate() {
                    pane[t * LANES + l] = v;
                }
            }
        }
        for (t, (id, dmin)) in ids.iter_mut().zip(ds.iter_mut()).enumerate() {
            let row = ps.row(start + t);
            let p = point_norms[start + t];
            for g in 0..groups {
                let pane = &panel[g * LANES * d..(g + 1) * LANES * d];
                let mut acc = [0.0f32; LANES];
                for (c8, &x) in pane.chunks_exact(LANES).zip(row) {
                    for l in 0..LANES {
                        acc[l] += x * c8[l];
                    }
                }
                let base = c0 + g * LANES;
                for (l, &a) in acc.iter().enumerate() {
                    let dd = (p + center_norms[base + l] - 2.0 * a).max(0.0);
                    if dd < *dmin {
                        *dmin = dd;
                        *id = (base + l) as u32;
                    }
                }
            }
            // Remainder centers of this tile (k % 8): scalar lane. The
            // cross term MUST accumulate in the same sequential
            // per-coordinate order as the panel lanes above — a
            // different summation order (e.g. the tree-order [`dot`])
            // would round differently, and a center bitwise-equal to a
            // panel center could then beat it by an ulp, breaking the
            // lowest-index tie contract across the k % 8 boundary.
            for j in (c0 + full)..c1 {
                let mut acc = 0.0f32;
                for (&x, &c) in row.iter().zip(centers.row(j)) {
                    acc += x * c;
                }
                let dd = (p + center_norms[j] - 2.0 * acc).max(0.0);
                if dd < *dmin {
                    *dmin = dd;
                    *id = j as u32;
                }
            }
        }
        c0 = c1;
    }
}

/// Replace each point's norm-trick minimum with the direct
/// `‖x_i − c_{ids[i]}‖²` of its chosen center — v1-grade rounding for
/// everything downstream (returned distances, cost sums).
pub(crate) fn rescore_block(
    ps: &PointSet,
    centers: &PointSet,
    start: usize,
    ids: &[u32],
    ds: &mut [f32],
) {
    for (t, (&id, dmin)) in ids.iter().zip(ds.iter_mut()).enumerate() {
        *dmin = d2(ps.row(start + t), centers.row(id as usize));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};
    use crate::kernels::norms::squared_norms;
    use crate::rng::Pcg64;

    fn case(n: usize, d: usize, k: usize, seed: u64) -> (PointSet, PointSet) {
        let ps = gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 6,
                ..Default::default()
            },
            seed,
        );
        let step = (n / k).max(1);
        let centers = ps.gather(&(0..k).map(|j| (j * step) % n).collect::<Vec<_>>());
        (ps, centers)
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        let mut rng = Pcg64::seed_from(1);
        for len in [0usize, 1, 2, 7, 8, 9, 15, 16, 17, 64, 127, 128] {
            let a: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32).collect();
            let naive: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = dot(&a, &b) as f64;
            assert!(
                (got - naive).abs() <= 1e-4 * naive.abs().max(1.0),
                "len={len} got={got} naive={naive}"
            );
        }
    }

    #[test]
    fn assign_agrees_with_v1_on_random_data() {
        let (ps, centers) = case(3_000, 17, 41, 2);
        let pn = squared_norms(&ps);
        let cn = squared_norms(&centers);
        let (gi, gd) = assign_argmin_blocked(&ps, &pn, &centers, &cn);
        let (wi, wd) = crate::kernels::assign::assign_argmin_naive(&ps, &centers);
        for i in 0..ps.len() {
            let scale = pn[i] + cn[wi[i] as usize] + 1.0;
            if gi[i] == wi[i] {
                // Same winner => rescored distance is bitwise v1.
                assert_eq!(gd[i], wd[i], "i={i}");
            } else {
                // Near-tie: the blocked choice must be as near as v1's.
                assert!(
                    (gd[i] - wd[i]).abs() <= 1e-4 * scale,
                    "i={i}: v2 picked {} (d2={}), v1 picked {} (d2={})",
                    gi[i],
                    gd[i],
                    wi[i],
                    wd[i]
                );
            }
        }
    }

    #[test]
    fn duplicate_centers_tie_break_to_lowest_index() {
        let ps = PointSet::from_rows(&[vec![1.0f32, 1.0], vec![5.0, 5.0]]);
        let dup = PointSet::from_rows(&vec![vec![1.0f32, 1.0]; CENTER_TILE + LANES + 3]);
        let pn = squared_norms(&ps);
        let cn = squared_norms(&dup);
        let (idx, mind2) = assign_argmin_blocked(&ps, &pn, &dup, &cn);
        assert_eq!(idx, vec![0, 0]);
        assert_eq!(mind2[0], 0.0);
    }

    #[test]
    fn self_distance_is_exactly_zero() {
        let (ps, _) = case(500, 11, 4, 3);
        let pn = squared_norms(&ps);
        let mut cur = vec![f32::INFINITY; ps.len()];
        d2_update_min_blocked(&ps, ps.row(123), &pn, &mut cur);
        assert_eq!(cur[123], 0.0);
        for (i, &v) in cur.iter().enumerate() {
            assert!(v >= 0.0, "negative clamped distance at {i}");
        }
    }

    #[test]
    fn update_matches_v1_within_norm_scale() {
        let (ps, _) = case(2_000, 13, 4, 5);
        let pn = squared_norms(&ps);
        let center = ps.row(7).to_vec();
        let cnorm = dot(&center, &center);
        let mut got = vec![f32::INFINITY; ps.len()];
        let mut want = vec![f32::INFINITY; ps.len()];
        d2_update_min_blocked(&ps, &center, &pn, &mut got);
        crate::kernels::d2::d2_update_min(&ps, &center, &mut want);
        for i in 0..ps.len() {
            let scale = pn[i] + cnorm + 1.0;
            let diff = (got[i] - want[i]).abs();
            assert!(diff <= 1e-4 * scale, "i={i}: {} vs {}", got[i], want[i]);
        }
    }
}
