//! Runtime kernel autotuner: picks the v1 (naive direct-distance) or v2
//! (blocked norm-trick, [`crate::kernels::blocked`]) implementation per
//! `(op, n, d, k)` shape at first use.
//!
//! Policy, in order:
//!
//! 1. **`FKMPP_KERNEL=naive|blocked`** pins the choice globally
//!    (checked on every call — tests and benches own this env var the
//!    same way they own `FKMPP_THREADS`). Pinning also makes seeding
//!    bit-reproducible across *processes*: the two formulations round
//!    differently at the f32 level, so an unpinned timing-based decision
//!    may legitimately flip knife-edge `D²` samples between runs.
//! 2. **Small shapes run naive without probing**: below a ~4M
//!    multiply-accumulate work floor (`SMALL_WORK`) the kernels finish
//!    in microseconds either way, a probe would cost more than it saves,
//!    and unit tests on tiny instances stay on the bitwise-v1 reference
//!    path.
//! 3. Otherwise the first call for a shape class probes both
//!    implementations on a small synthetic instance of the same `d`/`k`
//!    and caches the winner for the process lifetime (shape classes
//!    bucket `k` by power of two; `d` is kept exact — it drives the
//!    vectorizer). Probes run under whatever `FKMPP_THREADS` is current,
//!    but the probe shapes sit below the kernels' parallel cutoffs, so
//!    the measured single-thread ratio is what the decision encodes.
//!
//! The cached decision is process-wide, so within one process every
//! caller — seeders, Lloyd, the server, tests comparing against a direct
//! kernel call — agrees on the implementation and the exact bits it
//! produces.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::data::matrix::PointSet;
use crate::kernels::{blocked, norms};
use crate::rng::Pcg64;

/// Which kernel implementation to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// v1 direct-distance loops (the scalar reference semantics).
    Naive,
    /// v2 8-lane-blocked norm-trick loops.
    Blocked,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Naive => "naive",
            Kernel::Blocked => "blocked",
        }
    }
}

/// Kernel shape family being dispatched.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// `d2_update_min`: one center, `O(nd)`.
    Update,
    /// `assign_argmin` / `cost`: `k` centers, `O(nkd)`.
    Assign,
}

/// Below this many multiply-accumulates (`n·d·k`) dispatch returns
/// [`Kernel::Naive`] without probing.
const SMALL_WORK: usize = 1 << 22;

/// Points in the probe instance — below every parallel cutoff, so probes
/// measure the single-thread inner loops.
const PROBE_N: usize = 1024;

fn decisions() -> &'static Mutex<HashMap<(Op, usize, u32), Kernel>> {
    static DECISIONS: OnceLock<Mutex<HashMap<(Op, usize, u32), Kernel>>> = OnceLock::new();
    DECISIONS.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Resolve the kernel implementation for one call of shape `(n, d, k)`
/// (`k = 1` for the update family).
pub fn kernel_for(op: Op, n: usize, d: usize, k: usize) -> Kernel {
    if let Ok(v) = std::env::var("FKMPP_KERNEL") {
        match v.as_str() {
            "naive" => return Kernel::Naive,
            "blocked" => return Kernel::Blocked,
            other => {
                // A typo'd pin must not silently hand control back to
                // the (timing-dependent) autotuner: say so, once.
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    crate::log::warn(
                        "tune.unknown_kernel",
                        &[
                            ("value", crate::server::json::Json::str(other)),
                            ("expected", crate::server::json::Json::str("naive|blocked")),
                        ],
                    );
                });
            }
        }
    }
    let work = n.saturating_mul(d).saturating_mul(k.max(1));
    if work < SMALL_WORK {
        return Kernel::Naive;
    }
    let key = (op, d, k.max(1).ilog2());
    if let Some(&choice) = decisions().lock().unwrap().get(&key) {
        return choice;
    }
    // Probe OUTSIDE the lock so a first-touch probe (tens of ms) never
    // stalls concurrent dispatches of other shapes. Two racers on the
    // same shape both probe; the first insert wins and both return the
    // stored value, so the process-wide-agreement property holds.
    let probed = probe(op, d, k);
    *decisions().lock().unwrap().entry(key).or_insert(probed)
}

/// Best-of-2 wall-clock of `f` (after one warmup call), in seconds.
fn best_time(mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

/// Measure both implementations on a deterministic synthetic instance of
/// the same `d` (and `k` for the assign family) and return the faster.
fn probe(op: Op, d: usize, k: usize) -> Kernel {
    let mut rng = Pcg64::seed_from(0xA070_BEE5);
    let data: Vec<f32> = (0..PROBE_N * d).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let ps = PointSet::from_flat(PROBE_N, d, data);
    let pn = norms::squared_norms(&ps);
    match op {
        Op::Assign => {
            let kk = k.clamp(1, 128).min(PROBE_N);
            let centers = ps.gather(&(0..kk).collect::<Vec<_>>());
            let cn = norms::squared_norms(&centers);
            let t_naive = best_time(|| {
                std::hint::black_box(crate::kernels::assign::assign_argmin_naive(&ps, &centers));
            });
            let t_blocked = best_time(|| {
                std::hint::black_box(blocked::assign_argmin_blocked(&ps, &pn, &centers, &cn));
            });
            if t_blocked < t_naive {
                Kernel::Blocked
            } else {
                Kernel::Naive
            }
        }
        Op::Update => {
            let center = ps.row(0).to_vec();
            let mut buf = vec![f32::INFINITY; PROBE_N];
            let t_naive = best_time(|| {
                crate::kernels::d2::d2_update_min(&ps, &center, &mut buf);
                std::hint::black_box(&buf);
            });
            let t_blocked = best_time(|| {
                blocked::d2_update_min_blocked(&ps, &center, &pn, &mut buf);
                std::hint::black_box(&buf);
            });
            if t_blocked < t_naive {
                Kernel::Blocked
            } else {
                Kernel::Naive
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: no test here mutates FKMPP_KERNEL — env vars are process
    // globals and unit tests share one process. Env-override behavior is
    // covered by `rust/tests/kernel_parity_v2.rs`, which owns the var in
    // a single test function (the same discipline as FKMPP_THREADS).

    #[test]
    fn small_shapes_stay_naive() {
        // Regardless of cache state, tiny work units never probe.
        assert_eq!(kernel_for(Op::Assign, 100, 8, 4), Kernel::Naive);
        assert_eq!(kernel_for(Op::Update, 1_000, 16, 1), Kernel::Naive);
    }

    #[test]
    fn probe_decision_is_cached() {
        let n = 200_000; // over SMALL_WORK for d=32, k=16
        let a = kernel_for(Op::Assign, n, 32, 16);
        let b = kernel_for(Op::Assign, n, 32, 16);
        assert_eq!(a, b, "second lookup must hit the cache");
        // Same bucket (k in [16, 31]) resolves identically.
        let c = kernel_for(Op::Assign, n, 32, 17);
        assert_eq!(a, c);
    }

    #[test]
    fn kernel_names() {
        assert_eq!(Kernel::Naive.name(), "naive");
        assert_eq!(Kernel::Blocked.name(), "blocked");
    }
}
