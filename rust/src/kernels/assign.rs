//! Nearest-center assignment: the `O(nkd)` kernel behind Lloyd steps and
//! cost evaluation (the `assign` PJRT artifact's native twin).
//!
//! [`assign_argmin`] dispatches between the v1 tiled scalar loop
//! ([`assign_argmin_naive`]) and the v2 blocked norm-trick loop
//! ([`crate::kernels::blocked::assign_argmin_blocked`]) via the runtime
//! autotuner ([`crate::kernels::tune`]). Callers holding norm caches use
//! [`assign_argmin_cached`] so the v2 path skips its `O(nd)`/`O(kd)`
//! norm passes.

use crate::data::matrix::{d2, PointSet};
use crate::kernels::{blocked, norms, tune};
use crate::parallel::parallel_chunks_mut2;

/// Center rows per tile. A tile of `32 x 128` f32 coordinates is 16 KiB —
/// L1-resident on everything we target — so while a worker streams its
/// point chunk, the inner center loop hits cache instead of re-reading
/// the whole `k x d` center matrix from L2/DRAM per point.
const CENTER_TILE: usize = 32;

/// Points per worker below which assignment runs inline.
const MIN_POINTS_PER_THREAD: usize = 1024;

/// Nearest center of a single row: `(argmin index, min squared distance)`.
/// The shared scalar core of [`assign_argmin`] and the Lloyd-step fold.
#[inline]
pub fn nearest_center(row: &[f32], centers: &PointSet) -> (u32, f32) {
    let mut best = f32::INFINITY;
    let mut best_j = 0u32;
    for j in 0..centers.len() {
        let dd = d2(row, centers.row(j));
        if dd < best {
            best = dd;
            best_j = j as u32;
        }
    }
    (best_j, best)
}

/// Nearest center per point over the whole set:
/// `(argmin indices, min squared distances)`. Implementation (v1 tiled
/// scalar vs v2 blocked norm-trick) chosen by the runtime autotuner;
/// ties always resolve to the lowest center index.
pub fn assign_argmin(ps: &PointSet, centers: &PointSet) -> (Vec<u32>, Vec<f32>) {
    assign_argmin_cached(ps, None, centers, None)
}

/// [`assign_argmin`] with optional precomputed squared-norm caches
/// ([`crate::kernels::norms::squared_norms`] of `ps` / `centers`). The
/// caches are consulted only when the autotuner picks the v2 kernel;
/// missing ones are computed on the fly.
pub fn assign_argmin_cached(
    ps: &PointSet,
    point_norms: Option<&[f32]>,
    centers: &PointSet,
    center_norms: Option<&[f32]>,
) -> (Vec<u32>, Vec<f32>) {
    assert_eq!(ps.dim(), centers.dim(), "dimension mismatch");
    assert!(!centers.is_empty(), "no centers");
    match tune::kernel_for(tune::Op::Assign, ps.len(), ps.dim(), centers.len()) {
        tune::Kernel::Naive => assign_argmin_naive(ps, centers),
        tune::Kernel::Blocked => {
            let (mut pn_owned, mut cn_owned) = (None, None);
            let pn = norms::resolve(point_norms, ps, &mut pn_owned);
            let cn = norms::resolve(center_norms, centers, &mut cn_owned);
            blocked::assign_argmin_blocked(ps, pn, centers, cn)
        }
    }
}

/// The v1 implementation: parallel point chunks with center tiling,
/// direct scalar distances. Kept public as the reference the parity
/// suites and the autotuner probe measure against.
pub fn assign_argmin_naive(ps: &PointSet, centers: &PointSet) -> (Vec<u32>, Vec<f32>) {
    assert_eq!(ps.dim(), centers.dim(), "dimension mismatch");
    assert!(!centers.is_empty(), "no centers");
    let n = ps.len();
    let mut idx = vec![0u32; n];
    let mut mind2 = vec![f32::INFINITY; n];
    parallel_chunks_mut2(
        &mut idx,
        &mut mind2,
        MIN_POINTS_PER_THREAD,
        |start, ids, ds| assign_block(ps, centers, start, ids, ds),
    );
    (idx, mind2)
}

/// Assignment over one contiguous point block, tiling the center matrix
/// so each tile is reused across the whole block while cache-hot.
fn assign_block(ps: &PointSet, centers: &PointSet, start: usize, ids: &mut [u32], ds: &mut [f32]) {
    let k = centers.len();
    let mut c0 = 0usize;
    while c0 < k {
        let c1 = (c0 + CENTER_TILE).min(k);
        for (t, (id, dmin)) in ids.iter_mut().zip(ds.iter_mut()).enumerate() {
            let row = ps.row(start + t);
            for j in c0..c1 {
                let dd = d2(row, centers.row(j));
                if dd < *dmin {
                    *dmin = dd;
                    *id = j as u32;
                }
            }
        }
        c0 = c1;
    }
}

/// Min squared distance per point over one contiguous block, with the
/// same center tiling as [`assign_argmin`] but no argmin bookkeeping —
/// the distance core the cost reduction streams block by block.
pub(crate) fn min_d2_block(ps: &PointSet, centers: &PointSet, start: usize, ds: &mut [f32]) {
    ds.fill(f32::INFINITY);
    let k = centers.len();
    let mut c0 = 0usize;
    while c0 < k {
        let c1 = (c0 + CENTER_TILE).min(k);
        for (t, dmin) in ds.iter_mut().enumerate() {
            let row = ps.row(start + t);
            for j in c0..c1 {
                let dd = d2(row, centers.row(j));
                if dd < *dmin {
                    *dmin = dd;
                }
            }
        }
        c0 = c1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn case(n: usize, d: usize, k: usize) -> (PointSet, PointSet) {
        let ps = gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 6,
                ..Default::default()
            },
            3,
        );
        let step = (n / k).max(1);
        let centers = ps.gather(&(0..k).map(|j| (j * step) % n).collect::<Vec<_>>());
        (ps, centers)
    }

    #[test]
    fn matches_untiled_reference() {
        // k > CENTER_TILE exercises multiple tiles.
        let (ps, cs) = case(6_000, 9, 75);
        let (idx, mind2) = assign_argmin(&ps, &cs);
        for i in 0..ps.len() {
            let (bj, bd) = nearest_center(ps.row(i), &cs);
            assert_eq!(idx[i], bj, "i={i}");
            assert_eq!(mind2[i], bd, "i={i}");
        }
    }

    #[test]
    fn ties_resolve_to_lowest_index() {
        // Duplicate centers: the argmin must be the first occurrence, in
        // every tile configuration.
        let ps = PointSet::from_rows(&[vec![1.0f32, 1.0], vec![5.0, 5.0]]);
        let dup = PointSet::from_rows(&vec![vec![1.0f32, 1.0]; CENTER_TILE + 3]);
        let (idx, mind2) = assign_argmin(&ps, &dup);
        assert_eq!(idx[0], 0);
        assert_eq!(mind2[0], 0.0);
        assert_eq!(idx[1], 0);
    }

    #[test]
    fn single_center() {
        let (ps, _) = case(500, 4, 10);
        let one = ps.gather(&[42]);
        let (idx, mind2) = assign_argmin(&ps, &one);
        assert!(idx.iter().all(|&j| j == 0));
        assert_eq!(mind2[42], 0.0);
    }
}
