//! Squared-norm caches for the kernels-v2 norm-trick formulation
//! (`‖x − c‖² = ‖x‖² + ‖c‖² − 2·x·c`).
//!
//! Every v2 kernel ([`crate::kernels::blocked`]) consumes precomputed
//! per-row squared norms. Computing them costs one `O(nd)` pass — the
//! trick only pays when the cache is **reused**, so the norm arrays are
//! owned by the call sites with cross-round lifetime:
//!
//! * point norms: once per seeding run (`seeding/kmeanspp.rs`,
//!   `seeding/afkmc2.rs`, `seeding/rejection.rs`) and once per Lloyd run
//!   (`lloyd.rs`) — the points never change between rounds/iterations;
//! * center norms: once per registered model
//!   (`server/registry.rs::Model`), reused across every assign request.
//!
//! Norms are computed with the same 8-lane blocked dot product
//! ([`crate::kernels::blocked::dot`]) the v2 kernels use for the cross
//! term. That shared arithmetic gives an exact identity the seeders rely
//! on: for a point whose bits equal the center's,
//! `‖x‖² + ‖c‖² − 2·x·c` evaluates to exactly `0.0` (all three dots
//! return the same f32, and doubling/halving is exact), so opened centers
//! keep exact-zero `D²` weight and can never be re-sampled.

use crate::data::matrix::PointSet;
use crate::kernels::blocked;
use crate::parallel::parallel_chunks_mut;

/// Points per worker below which the norm pass runs inline.
const MIN_POINTS_PER_THREAD: usize = 4096;

/// Resolve an optional caller-provided norm cache for a v2 kernel: use
/// the cache when given, otherwise compute into `owned` and borrow it.
/// Shared by the dispatching entry points so the compute-on-miss
/// fallback cannot diverge between assign and cost.
pub(crate) fn resolve<'a>(
    cached: Option<&'a [f32]>,
    ps: &PointSet,
    owned: &'a mut Option<Vec<f32>>,
) -> &'a [f32] {
    match cached {
        Some(c) => c,
        None => &*owned.insert(squared_norms(ps)),
    }
}

/// Per-row squared Euclidean norms `‖x_i‖²`, computed in parallel chunks
/// with the v2 dot product (see the module docs for why that matters).
pub fn squared_norms(ps: &PointSet) -> Vec<f32> {
    let mut out = vec![0.0f32; ps.len()];
    parallel_chunks_mut(&mut out, 1, MIN_POINTS_PER_THREAD, |start, chunk| {
        for (slot, i) in chunk.iter_mut().zip(start..) {
            let row = ps.row(i);
            *slot = blocked::dot(row, row);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    #[test]
    fn matches_serial_reference() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 10_000,
                d: 13,
                k_true: 4,
                ..Default::default()
            },
            3,
        );
        let norms = squared_norms(&ps);
        for i in (0..ps.len()).step_by(503) {
            let want: f64 = ps.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum();
            let got = norms[i] as f64;
            let tol = 1e-4 * want.max(1.0);
            assert!((got - want).abs() <= tol, "i={i} got={got} want={want}");
        }
    }

    #[test]
    fn zero_rows_have_zero_norm() {
        let ps = PointSet::zeros(5, 7);
        assert_eq!(squared_norms(&ps), vec![0.0; 5]);
    }

    #[test]
    fn matches_blocked_dot_bitwise() {
        // The cache MUST be the same arithmetic as the v2 cross term —
        // this is what makes self-distances exactly zero.
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 100,
                d: 9,
                k_true: 3,
                ..Default::default()
            },
            4,
        );
        let norms = squared_norms(&ps);
        for i in 0..ps.len() {
            assert_eq!(norms[i], blocked::dot(ps.row(i), ps.row(i)), "i={i}");
        }
    }
}
