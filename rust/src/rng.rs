//! Deterministic PRNG + sampling helpers.
//!
//! The offline build has no `rand` crate, so the library ships its own
//! PCG64 (XSL-RR 128/64, O'Neill 2014): a small, fast, statistically solid
//! generator with a 128-bit state and jumpable streams. Every randomized
//! component in the crate (dataset synthesis, tree shifts, LSH projections,
//! seeding) takes a `&mut Pcg64` or a seed, making every experiment
//! reproducible from the CLI `--seed` flag.

/// PCG64 XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with an explicit state/stream pair.
    pub fn new(seed: u128, stream: u128) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed);
        rng.step();
        rng
    }

    /// Seed from a single 64-bit value (the common CLI path). The seed is
    /// diffused through splitmix64 so nearby seeds give unrelated streams.
    pub fn seed_from(seed: u64) -> Self {
        let a = splitmix64(seed);
        let b = splitmix64(a);
        let c = splitmix64(b);
        let d = splitmix64(c);
        Self::new(((a as u128) << 64) | b as u128, ((c as u128) << 64) | d as u128)
    }

    /// Derive an independent child generator (for per-thread / per-tree use).
    pub fn fork(&mut self, tag: u64) -> Self {
        let s = self.next_u64() ^ splitmix64(tag);
        let t = self.next_u64() ^ splitmix64(tag.wrapping_add(0x9E37_79B9_7F4A_7C15));
        Pcg64::new(((s as u128) << 64) | t as u128, (t as u128) << 1 | 1)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next 64 uniform random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in `[0, bound)` (Lemire's method).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.next_below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (pairs cached).
    #[inline]
    pub fn next_gaussian(&mut self) -> f64 {
        // Marsaglia polar method: no trig, rejection ~21%.
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index proportional to `weights` by linear scan.
    /// Returns `None` if the total weight is not positive/finite.
    /// (The sample-tree replaces this with an `O(log n)` version; the
    /// linear scan is the oracle it is property-tested against.)
    pub fn weighted_index(&mut self, weights: &[f64]) -> Option<usize> {
        let total: f64 = weights.iter().sum();
        if !(total > 0.0) || !total.is_finite() {
            return None;
        }
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target < 0.0 {
                return Some(i);
            }
        }
        // Floating-point slack: fall back to the last positive weight.
        weights.iter().rposition(|&w| w > 0.0)
    }
}

/// splitmix64 — seed diffusion.
#[inline]
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::seed_from(7);
        let mut b = Pcg64::seed_from(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg64::seed_from(1);
        let mut b = Pcg64::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seed_from(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut rng = Pcg64::seed_from(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut rng = Pcg64::seed_from(5);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[rng.next_below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 10_000).abs() < 600, "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seed_from(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn weighted_index_follows_weights() {
        let mut rng = Pcg64::seed_from(8);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_index(&w).unwrap()] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn weighted_index_rejects_degenerate() {
        let mut rng = Pcg64::seed_from(9);
        assert_eq!(rng.weighted_index(&[]), None);
        assert_eq!(rng.weighted_index(&[0.0, 0.0]), None);
        assert_eq!(rng.weighted_index(&[f64::NAN]), None);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed_from(10);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg64::seed_from(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
