//! Seeding algorithms: the paper's two contributions and its three
//! baselines, behind one [`Seeding`] result type and a string-dispatched
//! [`SeedingAlgorithm`] registry used by the CLI, coordinator and benches.
//!
//! | algorithm        | paper role | time (paper)                  |
//! |------------------|-----------|--------------------------------|
//! | `kmeanspp`       | baseline  | `Θ(ndk)`                       |
//! | `afkmc2`         | baseline  | `O(nd + mk^2 d)` (MCMC)        |
//! | `uniform`        | baseline  | `O(kd)`                        |
//! | `fastkmeanspp`   | Alg. 3    | `O(nd log(dΔ) + n log(dΔ) log n)` |
//! | `rejection`      | Alg. 4    | near-linear + LSH terms (practical single-scale oracle) |
//! | `rejection-rigorous` | Alg. 4 + App. D.2 | the Theorem-5.1 multi-scale oracle stack |
//! | `rejection-exact`| ablation  | the `Ω(k^2)` no-LSH variant §5 |
//! | `kmeans-par`     | extension | k-means‖ over data shards ([`crate::shard`]) |
//!
//! The rejection family carries its ANN-oracle choice: `rejection`
//! honors the configured [`rejection::RejectionConfig::oracle`] (default
//! practical LSH, overridable via `--oracle`), while `rejection-exact` /
//! `rejection-rigorous` pin theirs ([`SeedingAlgorithm::forced_oracle`]).

pub mod afkmc2;
pub mod fastkmeanspp;
pub mod kmeanspp;
pub mod rejection;
pub mod uniform;

use crate::bail;
use crate::data::matrix::PointSet;
use crate::error::Result;
use crate::rng::Pcg64;

/// Counters every seeder reports (the rejection-loop statistics back the
/// Lemma 5.3 empirical check in the benches).
#[derive(Clone, Debug, Default)]
pub struct SeedingStats {
    /// Draws from the proposal distribution (multi-tree samples, MCMC
    /// proposals, or exact D^2 samples depending on the algorithm).
    pub proposals: u64,
    /// Proposals rejected (rejection sampler / MCMC only).
    pub rejections: u64,
    /// Seconds spent in one-time initialization (tree builds, q-distr).
    pub init_secs: f64,
    /// Seconds spent selecting the k centers.
    pub select_secs: f64,
}

/// A seeding: `k` chosen centers (as dataset indices + materialized rows).
#[derive(Clone, Debug)]
pub struct Seeding {
    pub indices: Vec<usize>,
    pub centers: PointSet,
    pub stats: SeedingStats,
}

impl Seeding {
    pub(crate) fn from_indices(ps: &PointSet, indices: Vec<usize>, stats: SeedingStats) -> Self {
        let centers = ps.gather(&indices);
        Seeding {
            indices,
            centers,
            stats,
        }
    }

    pub fn k(&self) -> usize {
        self.indices.len()
    }
}

/// The algorithm registry (CLI names match the paper's).
///
/// New variants are APPENDED, never inserted: the discriminant feeds
/// fixed-seed derivations (`algo as u64` in the sweep runner's cell
/// seeds and the statistical suite's `seed_costs`), so inserting a
/// variant mid-enum would silently re-roll every later algorithm's
/// "fixed" seeds. Listing order for humans lives in
/// [`SeedingAlgorithm::all`], which is free to group related variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeedingAlgorithm {
    KMeansPP,
    FastKMeansPP,
    Rejection,
    RejectionExact,
    Afkmc2,
    Uniform,
    /// Greedy k-means++ (best of several D^2 draws per round) — the
    /// quality upper-bound reference; not in the paper's tables.
    KMeansPPGreedy,
    /// k-means‖ over data shards with a weighted k-means++ recluster
    /// ([`crate::shard::kmeanspar`]) — the scale-out seeder; not in the
    /// paper's tables.
    KMeansPar,
    /// Algorithm 4 with the rigorous multi-scale LSH oracle pinned
    /// (Appendix D.2 / Theorem 5.1) — the guarantee-grade variant.
    RejectionLshRigorous,
}

impl SeedingAlgorithm {
    /// Every registered algorithm (paper five + extensions), in registry
    /// order. The single source of truth for round-trip tests and the
    /// parse error message.
    pub fn all() -> [SeedingAlgorithm; 9] {
        [
            SeedingAlgorithm::KMeansPP,
            SeedingAlgorithm::FastKMeansPP,
            SeedingAlgorithm::Rejection,
            SeedingAlgorithm::RejectionExact,
            SeedingAlgorithm::RejectionLshRigorous,
            SeedingAlgorithm::Afkmc2,
            SeedingAlgorithm::Uniform,
            SeedingAlgorithm::KMeansPPGreedy,
            SeedingAlgorithm::KMeansPar,
        ]
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "kmeanspp" | "kmeans++" => SeedingAlgorithm::KMeansPP,
            "greedy" | "kmeanspp-greedy" => SeedingAlgorithm::KMeansPPGreedy,
            "fastkmeanspp" | "fast" => SeedingAlgorithm::FastKMeansPP,
            "rejection" | "rejectionsampling" | "rejection-lsh" => SeedingAlgorithm::Rejection,
            "rejection-exact" => SeedingAlgorithm::RejectionExact,
            "rejection-rigorous" | "rejection-lsh-rigorous" => {
                SeedingAlgorithm::RejectionLshRigorous
            }
            "afkmc2" => SeedingAlgorithm::Afkmc2,
            "uniform" => SeedingAlgorithm::Uniform,
            "kmeans-par" | "kmeanspar" | "kmeans_par" | "kmeans||" => SeedingAlgorithm::KMeansPar,
            _ => {
                // Enumerate the canonical names from the registry so the
                // message can never drift from the actual algorithm set.
                let names: Vec<&str> = Self::all().iter().map(|a| a.name()).collect();
                bail!("unknown algorithm {s:?} (valid: {})", names.join("|"))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SeedingAlgorithm::KMeansPP => "kmeanspp",
            SeedingAlgorithm::FastKMeansPP => "fastkmeanspp",
            SeedingAlgorithm::Rejection => "rejection",
            SeedingAlgorithm::RejectionExact => "rejection-exact",
            SeedingAlgorithm::RejectionLshRigorous => "rejection-rigorous",
            SeedingAlgorithm::Afkmc2 => "afkmc2",
            SeedingAlgorithm::Uniform => "uniform",
            SeedingAlgorithm::KMeansPPGreedy => "greedy",
            SeedingAlgorithm::KMeansPar => "kmeans-par",
        }
    }

    /// Paper display name (table rows).
    pub fn paper_name(self) -> &'static str {
        match self {
            SeedingAlgorithm::KMeansPP => "K-MEANS++",
            SeedingAlgorithm::FastKMeansPP => "FASTK-MEANS++",
            SeedingAlgorithm::Rejection => "REJECTIONSAMPLING",
            SeedingAlgorithm::RejectionExact => "REJECTION-EXACT",
            SeedingAlgorithm::RejectionLshRigorous => "REJECTION-RIGOROUS",
            SeedingAlgorithm::Afkmc2 => "AFKMC2",
            SeedingAlgorithm::Uniform => "UNIFORMSAMPLING",
            SeedingAlgorithm::KMeansPPGreedy => "GREEDY-K-MEANS++",
            SeedingAlgorithm::KMeansPar => "KMEANSPAR",
        }
    }

    /// All algorithms in the paper's table order. Pinned to the paper's
    /// five — extensions (`greedy`, `kmeans-par`) are appended to tables
    /// only when their cells exist ([`crate::coordinator::tables`]).
    pub fn paper_order() -> [SeedingAlgorithm; 5] {
        [
            SeedingAlgorithm::FastKMeansPP,
            SeedingAlgorithm::Rejection,
            SeedingAlgorithm::KMeansPP,
            SeedingAlgorithm::Afkmc2,
            SeedingAlgorithm::Uniform,
        ]
    }

    /// The ANN oracle a rejection-family variant pins, if any. `None`
    /// means "honor the configured [`rejection::RejectionConfig::oracle`]"
    /// (which is how `--oracle` reaches plain `rejection`); the ablation
    /// variants always force theirs, so `rejection-exact` stays the
    /// paper's `Ω(k²)` baseline no matter what the config says.
    pub fn forced_oracle(self) -> Option<rejection::OracleKind> {
        match self {
            SeedingAlgorithm::RejectionExact => Some(rejection::OracleKind::Exact),
            SeedingAlgorithm::RejectionLshRigorous => Some(rejection::OracleKind::LshRigorous),
            _ => None,
        }
    }

    /// The rejection config this variant should actually run with:
    /// `base` with the variant's pinned oracle (if any) applied. The one
    /// place the pinning rule lives — `run()`, the sweep runner and the
    /// server fit worker all resolve through here.
    pub fn resolved_rejection_config(
        self,
        base: &rejection::RejectionConfig,
    ) -> rejection::RejectionConfig {
        let mut rc = base.clone();
        if let Some(oracle) = self.forced_oracle() {
            rc.oracle = oracle;
        }
        rc
    }

    /// Whether this algorithm runs through
    /// [`rejection::rejection_sampling`] (and therefore honors a
    /// [`rejection::RejectionConfig`]).
    pub fn is_rejection(self) -> bool {
        matches!(
            self,
            SeedingAlgorithm::Rejection
                | SeedingAlgorithm::RejectionExact
                | SeedingAlgorithm::RejectionLshRigorous
        )
    }

    /// Run with default per-algorithm configs.
    pub fn run(self, ps: &PointSet, k: usize, rng: &mut Pcg64) -> Seeding {
        match self {
            SeedingAlgorithm::KMeansPP => kmeanspp::kmeanspp(ps, k, rng),
            SeedingAlgorithm::FastKMeansPP => {
                fastkmeanspp::fast_kmeanspp(ps, k, &Default::default(), rng)
            }
            SeedingAlgorithm::Rejection
            | SeedingAlgorithm::RejectionExact
            | SeedingAlgorithm::RejectionLshRigorous => {
                let cfg = self.resolved_rejection_config(&Default::default());
                rejection::rejection_sampling(ps, k, &cfg, rng)
            }
            SeedingAlgorithm::Afkmc2 => {
                afkmc2::afkmc2(ps, k, &Default::default(), rng)
            }
            SeedingAlgorithm::Uniform => uniform::uniform_sampling(ps, k, rng),
            SeedingAlgorithm::KMeansPPGreedy => kmeanspp::kmeanspp_greedy(ps, k, 5, rng),
            SeedingAlgorithm::KMeansPar => {
                crate::shard::kmeanspar::kmeans_par(ps, k, &Default::default(), rng)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::separated_grid;

    #[test]
    fn parse_all_names() {
        for a in SeedingAlgorithm::all() {
            assert_eq!(SeedingAlgorithm::parse(a.name()).unwrap(), a);
        }
        // The serve-layer spelling of the sharded seeder.
        assert_eq!(
            SeedingAlgorithm::parse("kmeans_par").unwrap(),
            SeedingAlgorithm::KMeansPar
        );
        // Oracle-explicit spellings of the rejection family.
        assert_eq!(
            SeedingAlgorithm::parse("rejection-lsh").unwrap(),
            SeedingAlgorithm::Rejection
        );
        assert_eq!(
            SeedingAlgorithm::parse("rejection-lsh-rigorous").unwrap(),
            SeedingAlgorithm::RejectionLshRigorous
        );
        assert!(SeedingAlgorithm::parse("bogus").is_err());
    }

    #[test]
    fn rejection_family_carries_its_oracle() {
        use crate::seeding::rejection::OracleKind;
        assert_eq!(
            SeedingAlgorithm::RejectionExact.forced_oracle(),
            Some(OracleKind::Exact)
        );
        assert_eq!(
            SeedingAlgorithm::RejectionLshRigorous.forced_oracle(),
            Some(OracleKind::LshRigorous)
        );
        // Plain `rejection` honors the config (default = practical LSH).
        assert_eq!(SeedingAlgorithm::Rejection.forced_oracle(), None);
        // resolved_rejection_config applies the pin, keeps the rest.
        let base = rejection::RejectionConfig {
            c: 2.5,
            oracle: OracleKind::LshPractical,
            ..Default::default()
        };
        let rc = SeedingAlgorithm::RejectionExact.resolved_rejection_config(&base);
        assert_eq!(rc.oracle, OracleKind::Exact);
        assert_eq!(rc.c, 2.5);
        let rc = SeedingAlgorithm::Rejection.resolved_rejection_config(&base);
        assert_eq!(rc.oracle, OracleKind::LshPractical);
        for a in SeedingAlgorithm::all() {
            assert_eq!(
                a.is_rejection(),
                matches!(
                    a,
                    SeedingAlgorithm::Rejection
                        | SeedingAlgorithm::RejectionExact
                        | SeedingAlgorithm::RejectionLshRigorous
                ),
                "{}",
                a.name()
            );
            if a.forced_oracle().is_some() {
                assert!(a.is_rejection(), "{}", a.name());
            }
        }
    }

    #[test]
    fn parse_error_enumerates_every_algorithm_name() {
        // Satellite lock: the error message must name every valid
        // algorithm (it is the CLI's discovery surface), and the paper
        // table order must stay pinned to the paper's five.
        let err = format!("{:#}", SeedingAlgorithm::parse("bogus").unwrap_err());
        for a in SeedingAlgorithm::all() {
            assert!(err.contains(a.name()), "{:?} missing from {err:?}", a.name());
        }
        assert!(err.contains("kmeans-par"), "{err:?}");
        assert_eq!(
            SeedingAlgorithm::paper_order(),
            [
                SeedingAlgorithm::FastKMeansPP,
                SeedingAlgorithm::Rejection,
                SeedingAlgorithm::KMeansPP,
                SeedingAlgorithm::Afkmc2,
                SeedingAlgorithm::Uniform,
            ],
            "paper_order must stay the paper's five"
        );
    }

    #[test]
    fn every_algorithm_returns_k_distinct_valid_indices() {
        let ps = separated_grid(5, 40, 4, 1);
        for a in SeedingAlgorithm::all() {
            let mut rng = Pcg64::seed_from(2);
            let s = a.run(&ps, 8, &mut rng);
            assert_eq!(s.k(), 8, "{}", a.name());
            assert_eq!(s.centers.len(), 8);
            assert_eq!(s.centers.dim(), 4);
            let mut idx = s.indices.clone();
            idx.sort_unstable();
            idx.dedup();
            assert_eq!(idx.len(), 8, "{} returned duplicates", a.name());
            assert!(idx.iter().all(|&i| i < ps.len()));
        }
    }
}
