//! AFK-MC² (Bachem, Lucic, Hassani, Krause — NeurIPS 2016), the paper's
//! "fast seeding" baseline.
//!
//! Metropolis–Hastings over the assumption-free proposal
//!
//! ```text
//!   q(x) = 1/2 · d(x, c1)^2 / Σ_y d(y, c1)^2  +  1/(2n)
//! ```
//!
//! built once in `O(nd)` (parallel, via
//! [`crate::kernels::d2::d2_update_min`]). Each of the `k-1` rounds runs
//! an `m`-step chain whose stationary distribution is the true `D^2`
//! distribution; each step evaluates `DIST(y, S)^2` against all current
//! centers — the `O(m k^2 d)` term that the rejection-sampling paper
//! removes. The paper's experiments use the authors' suggested `m = 200`;
//! so do we.

use std::time::Instant;

use crate::data::matrix::PointSet;
use crate::kernels::d2::d2_update_min_cached;
use crate::kernels::{blocked, norms};
use crate::rng::Pcg64;
use crate::seeding::{Seeding, SeedingStats};

/// AFK-MC² configuration.
#[derive(Clone, Debug)]
pub struct Afkmc2Config {
    /// Markov chain length per center (paper setup: 200).
    pub chain_length: usize,
}

impl Default for Afkmc2Config {
    fn default() -> Self {
        Afkmc2Config { chain_length: 200 }
    }
}

/// AFK-MC² seeding.
pub fn afkmc2(ps: &PointSet, k: usize, cfg: &Afkmc2Config, rng: &mut Pcg64) -> Seeding {
    let k = k.min(ps.len());
    let n = ps.len();
    let mut stats = SeedingStats::default();

    // Trace spans at the coarse init/select boundaries only (clock
    // reads, no RNG) — traced runs stay bitwise-identical to untraced.
    let init_span = crate::trace::Span::enter_with(
        "seed.afkmc2.init",
        vec![("n", n.into()), ("k", k.into())],
    );
    let t0 = Instant::now();
    // First center uniform; build the proposal q and its prefix sums.
    // The O(nd) distance pass runs on the parallel kernel engine.
    let c1 = rng.index(n);
    let c1_row = ps.row(c1).to_vec();
    let mut d2_c1 = vec![f32::INFINITY; n];
    // Kernels-v2 norm cache: one O(nd) pass reused across every chain
    // step of every round — both endpoints of a chain-step distance are
    // dataset points, so `DIST(y, S)^2` evaluations (the O(m k^2 d)
    // dominant term) run on the norm trick with zero per-step norm work.
    // The dense proposal build below shares the same cache.
    let point_norms = norms::squared_norms(ps);
    d2_update_min_cached(ps, &c1_row, &point_norms, &mut d2_c1);
    let mut q = vec![0.0f64; n];
    let mut total = 0.0f64;
    for (qi, &dd) in q.iter_mut().zip(&d2_c1) {
        *qi = dd as f64;
        total += dd as f64;
    }
    // q(x) = 0.5 d^2/Σ + 0.5/n ; degenerate Σ=0 -> uniform.
    let mut prefix = vec![0.0f64; n + 1];
    for i in 0..n {
        let val = if total > 0.0 {
            0.5 * q[i] / total + 0.5 / n as f64
        } else {
            1.0 / n as f64
        };
        q[i] = val;
        prefix[i + 1] = prefix[i] + val;
    }
    let norm = prefix[n];
    stats.init_secs = t0.elapsed().as_secs_f64();
    drop(init_span);

    let select_span = crate::trace::Span::enter_with(
        "seed.afkmc2.select",
        vec![("k", k.into()), ("chain", cfg.chain_length.into())],
    );
    let t1 = Instant::now();
    let mut indices = vec![c1];

    // dist^2 to the current center set, evaluated by scanning S on the
    // norm trick (clamped at 0; both norms come from the per-run cache).
    let dist_to_set = |x: usize, set: &[usize]| -> f64 {
        let row = ps.row(x);
        let xn = point_norms[x];
        set.iter()
            .map(|&s| {
                let dd = xn + point_norms[s] - 2.0 * blocked::dot(row, ps.row(s));
                dd.max(0.0) as f64
            })
            .fold(f64::INFINITY, f64::min)
    };
    // O(log n) inverse-CDF sampling from q.
    let sample_q = |rng: &mut Pcg64| -> usize {
        let target = rng.next_f64() * norm;
        // binary search for the first prefix > target
        let mut lo = 0usize;
        let mut hi = n;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if prefix[mid + 1] > target {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo.min(n - 1)
    };

    while indices.len() < k {
        // Initialize the chain.
        let mut x = sample_q(rng);
        let mut dx = dist_to_set(x, &indices);
        stats.proposals += 1;
        for _ in 1..cfg.chain_length.max(1) {
            let y = sample_q(rng);
            stats.proposals += 1;
            let dy = dist_to_set(y, &indices);
            // Acceptance: (dy/q(y)) / (dx/q(x)).
            let accept = if dx <= 0.0 {
                true // current state is a center; any proposal improves
            } else {
                let ratio = (dy * q[x]) / (dx * q[y]);
                rng.next_f64() < ratio
            };
            if accept {
                x = y;
                dx = dy;
            } else {
                stats.rejections += 1;
            }
        }
        if indices.contains(&x) {
            // The chain ended on an existing center (possible on tiny or
            // degenerate data): take any unchosen point to keep indices
            // distinct — matches the reference implementation's dedup.
            if let Some(fresh) = (0..n).find(|i| !indices.contains(i)) {
                indices.push(fresh);
            } else {
                break;
            }
        } else {
            indices.push(x);
        }
    }
    stats.select_secs = t1.elapsed().as_secs_f64();
    drop(select_span);
    Seeding::from_indices(ps, indices, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, separated_grid, SynthSpec};
    use crate::lloyd::cost_native;
    use crate::seeding::uniform::uniform_sampling;

    #[test]
    fn returns_k_distinct() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 400,
                d: 5,
                k_true: 8,
                ..Default::default()
            },
            1,
        );
        let mut rng = Pcg64::seed_from(2);
        let s = afkmc2(&ps, 25, &Afkmc2Config { chain_length: 20 }, &mut rng);
        assert_eq!(s.k(), 25);
        let mut idx = s.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 25);
    }

    #[test]
    fn proposal_counts_match_chain_length() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 200,
                d: 4,
                k_true: 4,
                ..Default::default()
            },
            3,
        );
        let mut rng = Pcg64::seed_from(4);
        let cfg = Afkmc2Config { chain_length: 50 };
        let s = afkmc2(&ps, 5, &cfg, &mut rng);
        // (k-1) chains x 50 proposals each.
        assert_eq!(s.stats.proposals, 4 * 50);
    }

    #[test]
    fn quality_between_uniform_and_kmeanspp() {
        // On separated clusters AFK-MC2 approaches exact D^2 quality and
        // beats uniform (this is Figure 1 of the Bachem et al. paper).
        let ps = separated_grid(10, 80, 4, 5);
        let mut afk_cost = 0.0;
        let mut uni_cost = 0.0;
        for seed in 0..5 {
            let mut rng = Pcg64::seed_from(100 + seed);
            let s = afkmc2(&ps, 10, &Afkmc2Config { chain_length: 100 }, &mut rng);
            afk_cost += cost_native(&ps, &s.centers);
            let mut rng2 = Pcg64::seed_from(200 + seed);
            let u = uniform_sampling(&ps, 10, &mut rng2);
            uni_cost += cost_native(&ps, &u.centers);
        }
        assert!(
            afk_cost < uni_cost,
            "afkmc2 ({afk_cost}) should beat uniform ({uni_cost})"
        );
    }

    #[test]
    fn single_center_is_uniform_draw() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 50,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            6,
        );
        let mut rng = Pcg64::seed_from(7);
        let s = afkmc2(&ps, 1, &Default::default(), &mut rng);
        assert_eq!(s.k(), 1);
        assert_eq!(s.stats.proposals, 0);
    }
}
