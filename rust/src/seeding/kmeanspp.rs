//! Exact `D^2` seeding — the original K-MEANS++ of Arthur & Vassilvitskii
//! (2007), the paper's primary baseline.
//!
//! `Θ(ndk)`: every one of the `k` rounds updates all `n` cached squared
//! distances against the newly opened center
//! ([`crate::kernels::d2::d2_update_min`], the same contract as the L1
//! Pallas kernel) and draws one sample from the exact `D^2` distribution
//! by a blocked prefix scan over
//! [`crate::kernels::reduce::block_sums`].

use std::time::Instant;

use crate::data::matrix::PointSet;
use crate::kernels::{d2 as d2_kernel, norms, reduce};
use crate::rng::Pcg64;
use crate::seeding::{Seeding, SeedingStats};

/// Exact k-means++ seeding.
///
/// The first center is drawn uniformly through the same blocked prefix
/// scan as every later `D²` draw ([`sample_d2`] over unit weights), so
/// the weighted generalization ([`kmeanspp_core`] with `Some(weights)`,
/// the engine behind [`crate::shard::weighted::weighted_kmeanspp`]) is
/// bitwise-identical to this function when all weights are 1.
pub fn kmeanspp(ps: &PointSet, k: usize, rng: &mut Pcg64) -> Seeding {
    kmeanspp_core(ps, None, k, rng)
}

/// The exact `D²`-seeding engine, optionally **weighted**: with
/// `weights = Some(w)` the first center is drawn `∝ w_i` and every later
/// round samples `∝ w_i · D²(x_i)` — honest weighted k-means++ over
/// weighted instances (candidate sets with assignment-count weights,
/// coresets). With `None` it is the plain paper baseline.
///
/// **Unit-weight parity contract** (locked by
/// `rust/tests/weighted_parity.rs`): `Some(&[1.0; n])` runs bitwise
/// identically to `None` under the same RNG state. Both paths make the
/// same [`sample_d2`] calls on bitwise-equal arrays — the first draw
/// scans the weight array itself (all ones ≡ the unweighted unit array)
/// and the round draws scan `w_i · D²_i`, which is `D²_i` exactly when
/// `w_i = 1.0` (IEEE multiplication by one is exact).
pub fn kmeanspp_core(
    ps: &PointSet,
    weights: Option<&[f32]>,
    k: usize,
    rng: &mut Pcg64,
) -> Seeding {
    let k = k.min(ps.len());
    let t0 = Instant::now();
    let n = ps.len();
    if let Some(w) = weights {
        assert_eq!(w.len(), n, "weight array length mismatch");
    }
    let mut cur_d2 = vec![f32::INFINITY; n];
    let mut indices = Vec::with_capacity(k);
    let mut stats = SeedingStats::default();
    if k == 0 {
        return Seeding::from_indices(ps, indices, stats);
    }
    // Kernels-v2 norm cache: one O(nd) pass here, reused by all k update
    // rounds (the points never change).
    let point_norms = {
        let _s = crate::trace::Span::enter_with(
            "seed.kmeanspp.init",
            vec![("n", n.into()), ("k", k.into())],
        );
        norms::squared_norms(ps)
    };
    stats.init_secs = t0.elapsed().as_secs_f64();

    // Trace spans sit only at these coarse phase boundaries (init /
    // select), mirroring the timers: they read the clock, never the RNG,
    // so traced and untraced runs draw identical streams.
    let select_span = crate::trace::Span::enter_with("seed.kmeanspp.select", vec![("k", k.into())]);
    let t1 = Instant::now();
    // First center ∝ weight (uniform when unweighted), via the same
    // blocked prefix scan as the round draws. A degenerate all-zero
    // weight array falls back to a uniform index.
    let first = {
        let unit;
        let w: &[f32] = match weights {
            Some(w) => w,
            None => {
                unit = vec![1.0f32; n];
                &unit
            }
        };
        sample_d2(w, rng).unwrap_or_else(|| rng.index(n))
    };
    indices.push(first);
    update_round(ps, first, &point_norms, &mut cur_d2);
    stats.proposals += 1;

    // Weighted sampling scratch: sw[i] = w[i] · D²[i], recomputed per
    // round. The unweighted path samples `cur_d2` directly — bitwise the
    // same draws, since 1.0 · x == x.
    let mut sw = weights.map(|_| vec![0.0f32; n]);
    while indices.len() < k {
        stats.proposals += 1;
        let sampled = match (weights, sw.as_mut()) {
            (Some(w), Some(sw)) => {
                for ((s, &wi), &di) in sw.iter_mut().zip(w).zip(&cur_d2) {
                    *s = wi * di;
                }
                sample_d2(sw, rng)
            }
            _ => sample_d2(&cur_d2, rng),
        };
        let next = match sampled {
            Some(i) => i,
            None => {
                // All remaining mass sits on chosen centers; fill with
                // arbitrary distinct indices to honor the k contract.
                match (0..n).find(|i| !indices.contains(i)) {
                    Some(i) => i,
                    None => break,
                }
            }
        };
        indices.push(next);
        update_round(ps, next, &point_norms, &mut cur_d2);
    }
    stats.select_secs = t1.elapsed().as_secs_f64();
    drop(select_span);
    Seeding::from_indices(ps, indices, stats)
}

/// One seeding round's `D^2` update against dataset point `center`,
/// through the autotuned kernel with the per-run norm cache.
fn update_round(ps: &PointSet, center: usize, point_norms: &[f32], cur_d2: &mut [f32]) {
    let c = ps.row(center).to_vec();
    d2_kernel::d2_update_min_cached(ps, &c, point_norms, cur_d2);
}

/// `cur[i] = min(cur[i], ||x_i - center||^2)` against dataset point
/// `center` (thin wrapper over [`crate::kernels::d2::d2_update_min`],
/// kept for the benches and the PJRT parity tests).
pub fn update_d2_parallel(ps: &PointSet, center: usize, cur_d2: &mut [f32]) {
    let c = ps.row(center).to_vec();
    update_d2_parallel_to(ps, &c, cur_d2)
}

/// Same, against an arbitrary center point.
pub fn update_d2_parallel_to(ps: &PointSet, c: &[f32], cur_d2: &mut [f32]) {
    d2_kernel::d2_update_min(ps, c, cur_d2)
}

/// Draw an index proportional to `w[i]` (exact `D^2`). Blocked prefix:
/// parallel block sums first, then a scan inside the selected block.
pub fn sample_d2(w: &[f32], rng: &mut Pcg64) -> Option<usize> {
    const BLOCK: usize = 8192;
    let block_sums = reduce::block_sums(w, BLOCK);
    let total: f64 = block_sums.iter().sum();
    if !(total > 0.0) || !total.is_finite() {
        return None;
    }
    let mut target = rng.next_f64() * total;
    for (b, &bs) in block_sums.iter().enumerate() {
        if target < bs {
            let start = b * BLOCK;
            let end = (start + BLOCK).min(w.len());
            for i in start..end {
                target -= w[i] as f64;
                if target < 0.0 {
                    return Some(i);
                }
            }
            // rounding slack: last positive weight in block
            return w[start..end]
                .iter()
                .rposition(|&x| x > 0.0)
                .map(|i| start + i)
                .or_else(|| w.iter().rposition(|&x| x > 0.0));
        }
        target -= bs;
    }
    w.iter().rposition(|&x| x > 0.0)
}

/// Greedy k-means++ (Arthur & Vassilvitskii's practical variant,
/// analyzed by Bhattacharya et al. — the paper's ref \[11\]; also
/// scikit-learn's default): each round draws `trials` candidates from
/// the `D^2` distribution and opens the one that reduces the total cost
/// the most. `Θ(ndk·trials)` — slower than plain k-means++, usually a
/// few percent better; included as the quality upper-bound reference for
/// the cost tables and the `greedy` CLI algorithm.
pub fn kmeanspp_greedy(ps: &PointSet, k: usize, trials: usize, rng: &mut Pcg64) -> Seeding {
    let k = k.min(ps.len());
    let trials = trials.max(1);
    let n = ps.len();
    let mut stats = SeedingStats::default();
    let _select_span = crate::trace::Span::enter_with(
        "seed.greedy.select",
        vec![("k", k.into()), ("trials", trials.into())],
    );
    let t1 = Instant::now();

    let mut cur_d2 = vec![f32::INFINITY; n];
    let mut indices = Vec::with_capacity(k);
    // One norm pass shared by every trial of every round.
    let point_norms = norms::squared_norms(ps);
    let first = rng.index(n);
    indices.push(first);
    update_round(ps, first, &point_norms, &mut cur_d2);
    stats.proposals += 1;

    let mut scratch = vec![0.0f32; n];
    while indices.len() < k {
        // Draw `trials` candidates, keep the cost-minimizing one.
        let mut best: Option<(usize, f64, Vec<f32>)> = None;
        for _ in 0..trials {
            stats.proposals += 1;
            let Some(cand) = sample_d2(&cur_d2, rng) else { break };
            scratch.copy_from_slice(&cur_d2);
            d2_kernel::d2_update_min_cached(ps, ps.row(cand), &point_norms, &mut scratch);
            let cost = reduce::sum_f32(&scratch);
            if best.as_ref().map_or(true, |(_, bc, _)| cost < *bc) {
                best = Some((cand, cost, scratch.clone()));
            } else {
                stats.rejections += 1;
            }
        }
        match best {
            Some((cand, _, new_d2)) => {
                indices.push(cand);
                cur_d2 = new_d2;
            }
            None => {
                // Degenerate: remaining points coincide with centers.
                match (0..n).find(|i| !indices.contains(i)) {
                    Some(i) => {
                        indices.push(i);
                        update_round(ps, i, &point_norms, &mut cur_d2);
                    }
                    None => break,
                }
            }
        }
    }
    stats.select_secs = t1.elapsed().as_secs_f64();
    Seeding::from_indices(ps, indices, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, separated_grid, SynthSpec};
    use crate::lloyd::cost_native;

    #[test]
    fn returns_k_distinct() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 500,
                d: 6,
                k_true: 10,
                ..Default::default()
            },
            1,
        );
        let mut rng = Pcg64::seed_from(2);
        let s = kmeanspp(&ps, 20, &mut rng);
        assert_eq!(s.k(), 20);
        let mut idx = s.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 20);
    }

    #[test]
    fn covers_separated_clusters() {
        // With k == true cluster count and huge separation, exact D^2
        // seeding finds every cluster essentially always.
        let ps = separated_grid(8, 50, 3, 3);
        let mut hits = 0;
        for seed in 0..10 {
            let mut rng = Pcg64::seed_from(seed);
            let s = kmeanspp(&ps, 8, &mut rng);
            let mut clusters: Vec<usize> = s.indices.iter().map(|&i| i / 50).collect();
            clusters.sort_unstable();
            clusters.dedup();
            if clusters.len() == 8 {
                hits += 1;
            }
        }
        assert!(hits >= 9, "only {hits}/10 runs covered all clusters");
    }

    #[test]
    fn k_larger_than_n_clamps() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 10,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            4,
        );
        let mut rng = Pcg64::seed_from(5);
        let s = kmeanspp(&ps, 50, &mut rng);
        assert_eq!(s.k(), 10);
    }

    #[test]
    fn sample_d2_respects_weights() {
        let mut rng = Pcg64::seed_from(6);
        let mut w = vec![0.0f32; 20_000];
        w[7] = 1.0;
        w[19_999] = 3.0;
        let mut counts = [0u32; 2];
        for _ in 0..20_000 {
            match sample_d2(&w, &mut rng) {
                Some(7) => counts[0] += 1,
                Some(19_999) => counts[1] += 1,
                other => panic!("sampled {other:?}"),
            }
        }
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn sample_d2_degenerate() {
        let mut rng = Pcg64::seed_from(7);
        assert_eq!(sample_d2(&[], &mut rng), None);
        assert_eq!(sample_d2(&[0.0, 0.0], &mut rng), None);
    }

    #[test]
    fn update_d2_parallel_matches_serial() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 20_000,
                d: 12,
                k_true: 5,
                ..Default::default()
            },
            8,
        );
        let mut par = vec![f32::INFINITY; ps.len()];
        update_d2_parallel(&ps, 17, &mut par);
        for i in (0..ps.len()).step_by(997) {
            let want = ps.d2_rows(i, 17);
            assert!((par[i] - want).abs() <= 1e-5 * want.max(1.0));
        }
    }

    #[test]
    fn greedy_returns_k_distinct() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 400,
                d: 5,
                k_true: 8,
                ..Default::default()
            },
            11,
        );
        let mut rng = Pcg64::seed_from(12);
        let s = kmeanspp_greedy(&ps, 15, 4, &mut rng);
        assert_eq!(s.k(), 15);
        let mut idx = s.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 15);
        // (k-1) rounds x 4 trials + the uniform first draw.
        assert_eq!(s.stats.proposals, 1 + 14 * 4);
    }

    #[test]
    fn greedy_no_worse_than_plain_on_average() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 2000,
                d: 8,
                k_true: 12,
                center_spread: 12.0,
                ..Default::default()
            },
            13,
        );
        let (mut greedy, mut plain) = (0.0, 0.0);
        for seed in 0..5u64 {
            let mut r1 = Pcg64::seed_from(500 + seed);
            greedy += cost_native(&ps, &kmeanspp_greedy(&ps, 12, 5, &mut r1).centers);
            let mut r2 = Pcg64::seed_from(600 + seed);
            plain += cost_native(&ps, &kmeanspp(&ps, 12, &mut r2).centers);
        }
        assert!(
            greedy <= plain * 1.05,
            "greedy {greedy} should not lose to plain {plain}"
        );
    }

    #[test]
    fn greedy_trials_one_behaves_like_plain() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 300,
                d: 4,
                k_true: 5,
                ..Default::default()
            },
            14,
        );
        let mut rng = Pcg64::seed_from(15);
        let s = kmeanspp_greedy(&ps, 10, 1, &mut rng);
        assert_eq!(s.k(), 10);
        assert_eq!(s.stats.rejections, 0);
    }

    #[test]
    fn seeding_cost_beats_uniform_on_clustered_data() {
        let ps = separated_grid(10, 100, 4, 9);
        let mut rng = Pcg64::seed_from(10);
        let pp = kmeanspp(&ps, 10, &mut rng);
        let uni = crate::seeding::uniform::uniform_sampling(&ps, 10, &mut rng);
        let c_pp = cost_native(&ps, &pp.centers);
        let c_uni = cost_native(&ps, &uni.centers);
        assert!(
            c_pp < c_uni,
            "kmeans++ ({c_pp}) should beat uniform ({c_uni}) on separated clusters"
        );
    }
}
