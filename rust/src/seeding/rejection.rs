//! `REJECTIONSAMPLING` (Algorithm 4): the paper's headline algorithm.
//!
//! Propose from the multi-tree `D^2` distribution (`MULTITREESAMPLE`),
//! accept with probability
//!
//! ```text
//!   min{ 1, DIST(x, Query(x))^2 / (c^2 · MULTITREEDIST(x, S)^2) }
//! ```
//!
//! where `Query` is the monotone (LSH) approximate-NN oracle over the
//! opened centers. Lemma 5.2: the resulting distribution over accepted
//! points is exactly `DIST(x, Query(x))^2 / Σ_y DIST(y, Query(y))^2` —
//! independent of the tree embedding — which is within `c^2` of the true
//! `D^2` distribution, giving the `O(c^6 log k)` guarantee (Theorem 5.4).
//! Lemma 5.3: the expected number of loop iterations is `O(c^2 d^2 k)`.

use std::time::Instant;

use crate::data::matrix::PointSet;
use crate::embed::multitree::{MultiTree, MultiTreeConfig};
use crate::lsh::multiscale::{LshMode, LshParams, MonotoneLsh};
use crate::lsh::{ExactNn, NnOracle};
use crate::rng::Pcg64;
use crate::seeding::{Seeding, SeedingStats};

/// Which NN oracle backs `Query`.
#[derive(Clone, Debug, Default)]
pub enum OracleKind {
    /// Practical single-scale LSH (Appendix D.3) — the paper's setup.
    #[default]
    LshPractical,
    /// Rigorous multi-scale LSH (Appendix D.2 / Theorem 5.1).
    LshRigorous,
    /// Exact linear scan — the `Ω(k^2)` no-LSH variant (§5), used as the
    /// ablation and correctness oracle.
    Exact,
}

/// Rejection-sampling configuration.
#[derive(Clone, Debug)]
pub struct RejectionConfig {
    /// LSH approximation factor `c > 1`. The acceptance test divides by
    /// `c^2`; quality degrades as `O(c^6 log k)` while speed improves.
    pub c: f32,
    pub oracle: OracleKind,
    pub lsh: LshParams,
    pub multitree: MultiTreeConfig,
    /// Auto-tune the LSH bucket width from the data (recommended for
    /// un-quantized inputs; the paper's fixed width 10 presumes
    /// Appendix-F integer coordinates).
    pub auto_bucket_width: bool,
    /// Safety valve on total proposals (`0` = derive from `c^2 d^2 k`).
    pub max_proposals: u64,
    /// JL projection target (§5 remark / Corollary 5.5): run the tree
    /// embedding, LSH and the acceptance test in a random projection to
    /// `O(log n)` dimensions, preserving every clustering cost up to a
    /// constant. `0` = auto (project when `d > 24`); `usize::MAX` = never.
    /// Without this, Lemma 5.3's `O(c^2 d^2)` proposals-per-center is the
    /// *typical* behavior on isotropic high-d data, not a worst case.
    pub project_dim: usize,
}

impl Default for RejectionConfig {
    fn default() -> Self {
        RejectionConfig {
            // The acceptance test pays 1/c^2 in loop iterations, so c
            // should be as small as the oracle's overestimates allow.
            // With the exact insertion-prefix (PREFIX_CAP) and the
            // k-density-tuned bucket width, measured LSH overestimates
            // stay well under 1.5x, and c = 1.5 matches exact-oracle
            // seeding quality while nearly halving proposals vs c = 2.
            c: 1.5,
            oracle: OracleKind::default(),
            lsh: LshParams::default(),
            multitree: MultiTreeConfig::default(),
            auto_bucket_width: true,
            max_proposals: 0,
            project_dim: 0,
        }
    }
}

/// Resolve the projection target: auto = `max(16, ~4 log2 n)` capped at d.
fn projection_target(cfg: &RejectionConfig, n: usize, d: usize) -> Option<usize> {
    let target = match cfg.project_dim {
        0 => {
            let t = (4.0 * (n.max(2) as f64).log2()).ceil() as usize;
            t.clamp(16, 24)
        }
        usize::MAX => return None,
        t => t,
    };
    if target < d {
        Some(target)
    } else {
        None
    }
}

/// Algorithm 4.
pub fn rejection_sampling(
    ps: &PointSet,
    k: usize,
    cfg: &RejectionConfig,
    rng: &mut Pcg64,
) -> Seeding {
    let k = k.min(ps.len());
    let mut stats = SeedingStats::default();

    let t0 = Instant::now();
    // §5 remark: build the proxy machinery (trees + LSH + acceptance test)
    // in a JL projection to O(log n) dims; the projected metric preserves
    // every clustering cost up to a constant, so the O(log k) guarantee
    // survives while the tree distortion drops from O(d^2) to
    // O(log^2 n). The O(ndt) projection and the O(nd) MAXDIST bound both
    // run on the parallel kernel engine (`crate::kernels`), so seeding
    // init scales with FKMPP_THREADS like the exact baselines do.
    let projected = projection_target(cfg, ps.len(), ps.dim()).map(|t| {
        let proj = crate::data::project::JlProjection::new(ps.dim(), t, rng);
        proj.apply_all(ps)
    });
    let work: &PointSet = projected.as_ref().unwrap_or(ps);

    // Kernels-v2 norm cache over the working set, computed once and
    // reused by every acceptance test across all rounds: the exact
    // oracle scans candidates via the norm trick (`dist_below_cached`),
    // with the proposal's ‖x‖² looked up here and the opened centers'
    // norms cached inside the oracle at insertion. The LSH oracles
    // ignore the cache (their bucket probes are hash-bound, not
    // distance-bound), so the O(nd) pass is only paid for the oracle
    // that consumes it.
    let work_norms = match cfg.oracle {
        OracleKind::Exact => crate::kernels::norms::squared_norms(work),
        OracleKind::LshPractical | OracleKind::LshRigorous => Vec::new(),
    };

    let mut mt = MultiTree::init(work, &cfg.multitree, rng);
    let mut oracle: Box<dyn NnOracle> = match cfg.oracle {
        OracleKind::Exact => Box::new(ExactNn::default()),
        OracleKind::LshPractical | OracleKind::LshRigorous => {
            let mut params = cfg.lsh.clone();
            params.c = cfg.c;
            if cfg.auto_bucket_width {
                // Tune for the query workload: distances to ~k centers.
                params.bucket_width = crate::lsh::multiscale::auto_bucket_width_for_k(
                    work, k, params.m, rng,
                );
            }
            let mode = match cfg.oracle {
                OracleKind::LshRigorous => LshMode::Rigorous {
                    max_dist: work.max_dist_upper_bound(),
                    // Post-quantization Δ is poly(nd) (Appendix F).
                    delta: (work.len() * work.dim()) as f32,
                },
                _ => LshMode::Practical,
            };
            Box::new(MonotoneLsh::new(work.dim(), &params, &mode, rng))
        }
    };
    stats.init_secs = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let c2 = (cfg.c as f64) * (cfg.c as f64);
    let budget = if cfg.max_proposals > 0 {
        cfg.max_proposals
    } else {
        // Lemma 5.3 bound with generous constants + floor for tiny runs.
        let d = work.dim() as u64;
        (200 * (c2 as u64 + 1) * d * d * k as u64).max(100_000)
    };

    let mut indices: Vec<usize> = Vec::with_capacity(k);
    while indices.len() < k && stats.proposals < budget {
        stats.proposals += 1;
        let x = match mt.sample(rng) {
            Some(x) => x,
            None => match (0..ps.len()).find(|i| !indices.contains(i)) {
                Some(i) => i,
                None => break,
            },
        };
        // Line 5: accept with probability min{1, dist^2 / (c^2 w_x)}
        // (1 on the first iteration). Evaluated in indicator form: for
        // u ~ U[0,1), accept iff dist(x, Query(x))^2 >= u * c^2 * w_x,
        // i.e. iff NO oracle candidate lies below the threshold — which
        // lets the oracle early-exit on the first witness instead of
        // computing the exact minimum (identical distribution, ~10x
        // cheaper on the reject-heavy loop; §Perf log).
        let accept = if indices.is_empty() {
            true
        } else {
            let w_x = mt.weight(x);
            debug_assert!(w_x > 0.0, "sampled an opened center");
            let u = rng.next_f64();
            let threshold = (u * c2 * w_x).sqrt() as f32;
            // `q_norm2` is only read by oracles that cache norms; the
            // 0.0 placeholder feeds the default (ignoring) impl.
            let q_norm2 = work_norms.get(x).copied().unwrap_or(0.0);
            !oracle.dist_below_cached(work, work.row(x), q_norm2, threshold)
        };
        if accept {
            indices.push(x);
            mt.open(x);
            oracle.insert(work, x as u32);
        } else {
            stats.rejections += 1;
        }
    }
    // Budget exhausted (pathological c / oracle): top up deterministically
    // so callers always get k centers; counted in `rejections`.
    while indices.len() < k {
        if let Some(i) = (0..ps.len()).find(|i| !indices.contains(i)) {
            indices.push(i);
            mt.open(i);
            oracle.insert(work, i as u32);
        } else {
            break;
        }
    }
    stats.select_secs = t1.elapsed().as_secs_f64();
    Seeding::from_indices(ps, indices, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, separated_grid, SynthSpec};
    use crate::lloyd::cost_native;
    use crate::seeding::kmeanspp::kmeanspp;
    use crate::seeding::uniform::uniform_sampling;

    fn data(n: usize, d: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 10,
                center_spread: 15.0,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn returns_k_distinct_all_oracles() {
        let ps = data(500, 8, 1);
        for oracle in [
            OracleKind::LshPractical,
            OracleKind::LshRigorous,
            OracleKind::Exact,
        ] {
            let cfg = RejectionConfig {
                oracle: oracle.clone(),
                ..Default::default()
            };
            let mut rng = Pcg64::seed_from(2);
            let s = rejection_sampling(&ps, 25, &cfg, &mut rng);
            assert_eq!(s.k(), 25, "{oracle:?}");
            let mut idx = s.indices.clone();
            idx.sort_unstable();
            idx.dedup();
            assert_eq!(idx.len(), 25, "{oracle:?}");
        }
    }

    #[test]
    fn acceptance_rate_within_lemma_5_3() {
        // Lemma 5.3: E[loop iterations] = O(c^2 d^2 k). Check the bound
        // with a modest constant on isotropic data (the worst case for
        // the tree distortion).
        let ps = data(2000, 8, 3);
        let cfg = RejectionConfig::default();
        let mut rng = Pcg64::seed_from(4);
        let k = 50u64;
        let s = rejection_sampling(&ps, k as usize, &cfg, &mut rng);
        assert_eq!(s.k(), 50);
        let c2d2 = (cfg.c as f64 * cfg.c as f64) * 64.0; // d = 8
        let bound = 5.0 * c2d2 * k as f64;
        assert!(
            (s.stats.proposals as f64) < bound,
            "proposals={} exceeds 5*c^2*d^2*k={bound}",
            s.stats.proposals
        );
    }

    #[test]
    fn matches_exact_d2_distribution_on_tiny_instance() {
        // With the exact oracle and c=1, acceptance p = d2(x,S)/w_x and
        // Lemma 5.2 says the accepted distribution IS the exact D^2
        // distribution. Check the second-center marginal on 6 points by
        // comparing against the analytic distribution, conditioned on the
        // same first center.
        let rows = vec![
            vec![0.0f32, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.5],
            vec![10.0, 10.0],
            vec![10.0, 11.0],
            vec![-5.0, 4.0],
        ];
        let ps = PointSet::from_rows(&rows);
        let cfg = RejectionConfig {
            c: 1.0,
            oracle: OracleKind::Exact,
            ..Default::default()
        };
        let trials = 30_000;
        let mut counts = vec![0.0f64; 6];
        let mut first_counts = vec![0.0f64; 6];
        for seed in 0..trials {
            let mut rng = Pcg64::seed_from(seed);
            let s = rejection_sampling(&ps, 2, &cfg, &mut rng);
            first_counts[s.indices[0]] += 1.0;
            counts[s.indices[1]] += 1.0;
        }
        // Analytic marginal: P(second = j) = E_first[ d2(j, first)/Σ ].
        let mut want = vec![0.0f64; 6];
        for f in 0..6 {
            let d2s: Vec<f64> = (0..6).map(|j| ps.d2_rows(j, f) as f64).collect();
            let sum: f64 = d2s.iter().sum();
            for j in 0..6 {
                want[j] += (first_counts[f] / trials as f64) * d2s[j] / sum;
            }
        }
        for j in 0..6 {
            let got = counts[j] / trials as f64;
            assert!(
                (got - want[j]).abs() < 0.015,
                "j={j} got={got} want={}",
                want[j]
            );
        }
    }

    #[test]
    fn quality_comparable_to_exact_kmeanspp() {
        // Table 4-6 shape: rejection sampling within ~20% of exact
        // k-means++ cost on clustered data (averaged over seeds).
        let ps = data(3000, 10, 5);
        let k = 30;
        let mut rej = 0.0;
        let mut exact = 0.0;
        for seed in 0..5 {
            let mut r1 = Pcg64::seed_from(1000 + seed);
            rej += cost_native(
                &ps,
                &rejection_sampling(&ps, k, &Default::default(), &mut r1).centers,
            );
            let mut r2 = Pcg64::seed_from(2000 + seed);
            exact += cost_native(&ps, &kmeanspp(&ps, k, &mut r2).centers);
        }
        assert!(
            rej < 1.5 * exact,
            "rejection cost {rej} too far above exact {exact}"
        );
    }

    #[test]
    fn beats_uniform_on_separated_clusters() {
        let ps = separated_grid(10, 80, 4, 7);
        let mut rej = 0.0;
        let mut uni = 0.0;
        for seed in 0..5 {
            let mut r1 = Pcg64::seed_from(3000 + seed);
            rej += cost_native(
                &ps,
                &rejection_sampling(&ps, 10, &Default::default(), &mut r1).centers,
            );
            let mut r2 = Pcg64::seed_from(4000 + seed);
            uni += cost_native(&ps, &uniform_sampling(&ps, 10, &mut r2).centers);
        }
        assert!(rej < uni, "rejection={rej} uniform={uni}");
    }

    #[test]
    fn budget_exhaustion_still_returns_k() {
        let ps = data(100, 6, 9);
        let cfg = RejectionConfig {
            max_proposals: 3, // absurdly small
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(10);
        let s = rejection_sampling(&ps, 10, &cfg, &mut rng);
        assert_eq!(s.k(), 10);
    }

    #[test]
    fn larger_c_accepts_less_selectively() {
        // As c grows the acceptance probability shrinks (1/c^2 factor),
        // so the proposal count grows.
        let ps = data(1500, 8, 11);
        let mut props = Vec::new();
        for &c in &[1.5f32, 4.0] {
            let cfg = RejectionConfig {
                c,
                oracle: OracleKind::Exact,
                ..Default::default()
            };
            let mut rng = Pcg64::seed_from(12);
            let s = rejection_sampling(&ps, 20, &cfg, &mut rng);
            props.push(s.stats.proposals);
        }
        assert!(
            props[1] > props[0],
            "c=4 proposals {} should exceed c=1.5 proposals {}",
            props[1],
            props[0]
        );
    }
}
