//! `REJECTIONSAMPLING` (Algorithm 4): the paper's headline algorithm.
//!
//! Propose from the multi-tree `D^2` distribution (`MULTITREESAMPLE`),
//! accept with probability
//!
//! ```text
//!   min{ 1, DIST(x, Query(x))^2 / (c^2 · MULTITREEDIST(x, S)^2) }
//! ```
//!
//! where `Query` is the monotone (LSH) approximate-NN oracle over the
//! opened centers. Lemma 5.2: the resulting distribution over accepted
//! points is exactly `DIST(x, Query(x))^2 / Σ_y DIST(y, Query(y))^2` —
//! independent of the tree embedding — which is within `c^2` of the true
//! `D^2` distribution, giving the `O(c^6 log k)` guarantee (Theorem 5.4).
//! Lemma 5.3: the expected number of loop iterations is `O(c^2 d^2 k)`.

use std::time::{Duration, Instant};

use crate::bail;
use crate::data::matrix::PointSet;
use crate::embed::multitree::{MultiTree, MultiTreeConfig};
use crate::error::Result;
use crate::lsh::multiscale::{LshMode, LshParams, MonotoneLsh};
use crate::lsh::{ExactNn, NnOracle};
use crate::rng::Pcg64;
use crate::seeding::{Seeding, SeedingStats};

/// Which NN oracle backs `Query`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OracleKind {
    /// Practical single-scale LSH (Appendix D.3) — the paper's setup.
    #[default]
    LshPractical,
    /// Rigorous multi-scale LSH (Appendix D.2 / Theorem 5.1).
    LshRigorous,
    /// Exact linear scan — the `Ω(k^2)` no-LSH variant (§5), used as the
    /// ablation and correctness oracle.
    Exact,
}

impl OracleKind {
    /// Every oracle, in registry order — the single source of truth for
    /// the parse error, CLI/server validation, and the oracle sweeps.
    pub fn all() -> [OracleKind; 3] {
        [
            OracleKind::LshPractical,
            OracleKind::LshRigorous,
            OracleKind::Exact,
        ]
    }

    /// Canonical flag/JSON spelling (`fkmpp seed --oracle <name>`,
    /// `POST /fit {"oracle": <name>}`).
    pub fn name(self) -> &'static str {
        match self {
            OracleKind::LshPractical => "lsh",
            OracleKind::LshRigorous => "lsh-rigorous",
            OracleKind::Exact => "exact",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "lsh" | "lsh-practical" | "practical" => OracleKind::LshPractical,
            "lsh-rigorous" | "rigorous" => OracleKind::LshRigorous,
            "exact" | "linear" => OracleKind::Exact,
            _ => {
                // Enumerate the canonical names from the registry so the
                // message can never drift from the actual oracle set.
                let names: Vec<&str> = Self::all().iter().map(|o| o.name()).collect();
                bail!("unknown oracle {s:?} (valid: {})", names.join("|"))
            }
        })
    }
}

/// Rejection-sampling configuration.
#[derive(Clone, Debug)]
pub struct RejectionConfig {
    /// LSH approximation factor `c > 1`. The acceptance test divides by
    /// `c^2`; quality degrades as `O(c^6 log k)` while speed improves.
    pub c: f32,
    pub oracle: OracleKind,
    pub lsh: LshParams,
    pub multitree: MultiTreeConfig,
    /// Auto-tune the LSH bucket width from the data (recommended for
    /// un-quantized inputs; the paper's fixed width 10 presumes
    /// Appendix-F integer coordinates).
    pub auto_bucket_width: bool,
    /// Safety valve on total proposals (`0` = derive from `c^2 d^2 k`).
    pub max_proposals: u64,
    /// JL projection target (§5 remark / Corollary 5.5): run the tree
    /// embedding, LSH and the acceptance test in a random projection to
    /// `O(log n)` dimensions, preserving every clustering cost up to a
    /// constant. `0` = auto (project when `d > 24`); `usize::MAX` = never.
    /// Without this, Lemma 5.3's `O(c^2 d^2)` proposals-per-center is the
    /// *typical* behavior on isotropic high-d data, not a worst case.
    pub project_dim: usize,
}

impl Default for RejectionConfig {
    fn default() -> Self {
        RejectionConfig {
            // The acceptance test pays 1/c^2 in loop iterations, so c
            // should be as small as the oracle's overestimates allow.
            // With the exact insertion-prefix (PREFIX_CAP) and the
            // k-density-tuned bucket width, measured LSH overestimates
            // stay well under 1.5x, and c = 1.5 matches exact-oracle
            // seeding quality while nearly halving proposals vs c = 2.
            c: 1.5,
            oracle: OracleKind::default(),
            lsh: LshParams::default(),
            multitree: MultiTreeConfig::default(),
            auto_bucket_width: true,
            max_proposals: 0,
            project_dim: 0,
        }
    }
}

impl RejectionConfig {
    /// Validate user-supplied knobs. The single check both untrusted
    /// entry points route through (`fkmpp seed` flags in `cli.rs`,
    /// `POST /fit` keys in `server/mod.rs`) so the bounds cannot drift
    /// between them.
    pub fn validate(&self) -> Result<()> {
        if !(self.c >= 1.0) {
            bail!("rejection `c` must be >= 1 (the LSH approximation factor)");
        }
        if self.lsh.tables == 0 || self.lsh.m == 0 || self.lsh.probe_limit == 0 {
            bail!("LSH tables/m/probe-limit must all be >= 1");
        }
        if !(self.lsh.bucket_width > 0.0) {
            bail!("LSH bucket width must be > 0");
        }
        Ok(())
    }
}

/// Resolve the projection target: auto = `max(16, ~4 log2 n)` capped at d.
fn projection_target(cfg: &RejectionConfig, n: usize, d: usize) -> Option<usize> {
    let target = match cfg.project_dim {
        0 => {
            let t = (4.0 * (n.max(2) as f64).log2()).ceil() as usize;
            t.clamp(16, 24)
        }
        usize::MAX => return None,
        t => t,
    };
    if target < d {
        Some(target)
    } else {
        None
    }
}

/// Algorithm 4.
pub fn rejection_sampling(
    ps: &PointSet,
    k: usize,
    cfg: &RejectionConfig,
    rng: &mut Pcg64,
) -> Seeding {
    let k = k.min(ps.len());
    let mut stats = SeedingStats::default();

    // Trace spans cover only the two coarse phases (init / select), the
    // same boundaries as `init_secs`/`select_secs` — never the per-
    // proposal loop. They read the clock only, so traced and untraced
    // runs draw identical RNG streams.
    let init_span = crate::trace::Span::enter_with(
        "seed.rejection.init",
        vec![("n", ps.len().into()), ("k", k.into())],
    );
    let t0 = Instant::now();
    // §5 remark: build the proxy machinery (trees + LSH + acceptance test)
    // in a JL projection to O(log n) dims; the projected metric preserves
    // every clustering cost up to a constant, so the O(log k) guarantee
    // survives while the tree distortion drops from O(d^2) to
    // O(log^2 n). The O(ndt) projection and the O(nd) MAXDIST bound both
    // run on the parallel kernel engine (`crate::kernels`), so seeding
    // init scales with FKMPP_THREADS like the exact baselines do.
    let projected = projection_target(cfg, ps.len(), ps.dim()).map(|t| {
        let proj = crate::data::project::JlProjection::new(ps.dim(), t, rng);
        proj.apply_all(ps)
    });
    let work: &PointSet = projected.as_ref().unwrap_or(ps);

    // Kernels-v2 norm cache over the working set, computed once and
    // reused by every acceptance test across all rounds: every oracle's
    // cached witness scan (`dist_below_cached`) evaluates candidates via
    // the norm trick, with the proposal's ‖x‖² looked up here and the
    // opened centers' norms cached inside the oracle at insertion (the
    // exact oracle's candidate list, the LSH prefix buffer, and the LSH
    // bucket entries all carry their norms).
    let work_norms = crate::kernels::norms::squared_norms(work);

    let mut mt = MultiTree::init(work, &cfg.multitree, rng);
    let mut oracle: Box<dyn NnOracle> = match cfg.oracle {
        OracleKind::Exact => Box::new(ExactNn::default()),
        OracleKind::LshPractical | OracleKind::LshRigorous => {
            let mut params = cfg.lsh.clone();
            params.c = cfg.c;
            if cfg.auto_bucket_width {
                // Tune for the query workload: distances to ~k centers.
                params.bucket_width = crate::lsh::multiscale::auto_bucket_width_for_k(
                    work, k, params.m, rng,
                );
            }
            let mode = match cfg.oracle {
                OracleKind::LshRigorous => LshMode::Rigorous {
                    max_dist: work.max_dist_upper_bound(),
                    // Post-quantization Δ is poly(nd) (Appendix F).
                    delta: (work.len() * work.dim()) as f32,
                },
                _ => LshMode::Practical,
            };
            Box::new(MonotoneLsh::new(work.dim(), &params, &mode, rng))
        }
    };
    stats.init_secs = t0.elapsed().as_secs_f64();
    drop(init_span);

    let select_span =
        crate::trace::Span::enter_with("seed.rejection.select", vec![("k", k.into())]);
    let t1 = Instant::now();
    let c2 = (cfg.c as f64) * (cfg.c as f64);
    let budget = if cfg.max_proposals > 0 {
        cfg.max_proposals
    } else {
        // Lemma 5.3 bound with generous constants + floor for tiny runs.
        let d = work.dim() as u64;
        (200 * (c2 as u64 + 1) * d * d * k as u64).max(100_000)
    };

    // RNG stream-split contract: proposal draws and acceptance coins come
    // from separate streams forked from one root, re-derived per accepted
    // -center round. Consequences: (a) the root fork count is fixed (2
    // per round), so round r+1's draws are independent of how many
    // proposals round r consumed; (b) for a fixed seed the whole loop is
    // bitwise deterministic and thread-count-invariant — nothing below
    // this line is parallel over RNG state (oracle hashing parallelism
    // is pure), asserted in `rust/tests/oracle_determinism.rs`.
    let mut stream_root = rng.fork(0x0AC1_E5);
    // Sampled probe-latency durations (see PROBE_TIMER_SAMPLE).
    let mut probe_samples: Vec<Duration> = Vec::new();
    let mut indices: Vec<usize> = Vec::with_capacity(k);
    'rounds: while indices.len() < k {
        let round = indices.len() as u64;
        let mut proposal_rng = stream_root.fork(2 * round);
        let mut accept_rng = stream_root.fork(2 * round + 1);
        loop {
            if stats.proposals >= budget {
                break 'rounds;
            }
            stats.proposals += 1;
            let x = match mt.sample(&mut proposal_rng) {
                Some(x) => x,
                None => {
                    // Residual D² mass is zero: every unopened point
                    // coincides with an opened center, so any choice has
                    // equal (zero) mass — open the first unopened point
                    // deterministically instead of running an accept test
                    // against a zero weight.
                    match (0..ps.len()).find(|i| !indices.contains(i)) {
                        Some(i) => {
                            indices.push(i);
                            mt.open(i);
                            oracle.insert(work, i as u32);
                            continue 'rounds;
                        }
                        None => break 'rounds,
                    }
                }
            };
            // Line 5: accept with probability min{1, dist^2 / (c^2 w_x)}
            // (1 on the first iteration). Evaluated in indicator form: for
            // u ~ U[0,1), accept iff dist(x, Query(x))^2 >= u * c^2 * w_x,
            // i.e. iff NO oracle candidate lies below the threshold — which
            // lets the oracle early-exit on the first witness instead of
            // computing the exact minimum (identical distribution, ~10x
            // cheaper on the reject-heavy loop; §Perf log).
            let accept = if indices.is_empty() {
                true
            } else {
                let w_x = mt.weight(x);
                debug_assert!(w_x > 0.0, "sampled an opened center");
                let u = accept_rng.next_f64();
                let threshold = (u * c2 * w_x).sqrt() as f32;
                // Per-probe Instant pairs would tax the reject-heavy
                // loop (the metrics.rs contract is coarse-phase timers
                // only), so the latency is SAMPLED: the first real probe
                // (proposals == 2) plus every PROBE_TIMER_SAMPLE-th one.
                let below = if stats.proposals % PROBE_TIMER_SAMPLE == 2 {
                    let tp = Instant::now();
                    let b = oracle.dist_below_cached(work, work.row(x), work_norms[x], threshold);
                    probe_samples.push(tp.elapsed());
                    b
                } else {
                    oracle.dist_below_cached(work, work.row(x), work_norms[x], threshold)
                };
                !below
            };
            if accept {
                indices.push(x);
                mt.open(x);
                oracle.insert(work, x as u32);
                continue 'rounds;
            }
            stats.rejections += 1;
        }
    }
    // Budget exhausted (pathological c / oracle): top up deterministically
    // so callers always get k centers. Fills are not proposals — they
    // advance no loop counter and surface only in `oracle.accepts`, so
    // accepts + rejects can exceed proposals on a budget-exhausted run.
    while indices.len() < k {
        if let Some(i) = (0..ps.len()).find(|i| !indices.contains(i)) {
            indices.push(i);
            mt.open(i);
            oracle.insert(work, i as u32);
        } else {
            break;
        }
    }
    stats.select_secs = t1.elapsed().as_secs_f64();
    drop(select_span);

    // Oracle observability: flush loop + probe counters to the
    // process-wide sink (same pattern as `shard.*` — fits run deep in
    // workers with no ctx handle; `/metrics` merges this sink). Counters
    // only accumulate, so readers assert deltas, not absolutes.
    let m = crate::metrics::global();
    m.incr("oracle.proposals", stats.proposals);
    m.incr("oracle.accepts", indices.len() as u64);
    m.incr("oracle.rejects", stats.rejections);
    let probe = oracle.probe_stats();
    m.incr("oracle.probes", probe.probes);
    for d in probe_samples {
        // Log-bucketed histogram, not plain Stats: probe latencies are
        // heavy-tailed and `/metrics` reports their p50/p99.
        m.record_latency("oracle.probe_secs", d);
    }
    if probe.prefix_hits > 0 {
        m.incr("oracle.prefix_hits", probe.prefix_hits);
    }
    for (level, &hits) in probe.scale_hits.iter().enumerate() {
        if hits > 0 {
            m.incr(scale_level_name(level), hits);
        }
    }
    Seeding::from_indices(ps, indices, stats)
}

/// Acceptance-probe latency sampling period: `oracle.probe_secs` records
/// the duration of the first real probe (the loop's second proposal —
/// always sampled so even tiny fits surface the metric) and of every
/// `PROBE_TIMER_SAMPLE`-th proposal thereafter. Per-probe `Instant`
/// pairs would be a double-digit-percent tax on the reject-heavy loop;
/// a 1/64 sample keeps the metric a faithful latency distribution at
/// ~1.5% of that cost.
const PROBE_TIMER_SAMPLE: u64 = 64;

/// Static counter names for the per-scale witness histogram
/// ([`crate::metrics::Metrics::incr`] takes `&'static str`); levels past
/// the table are clamped into the last bucket. Scale 0 is the finest
/// gap structure (the practical mode's only one).
const SCALE_NAMES: [&str; 12] = [
    "oracle.scale.0",
    "oracle.scale.1",
    "oracle.scale.2",
    "oracle.scale.3",
    "oracle.scale.4",
    "oracle.scale.5",
    "oracle.scale.6",
    "oracle.scale.7",
    "oracle.scale.8",
    "oracle.scale.9",
    "oracle.scale.10",
    "oracle.scale.11plus",
];

fn scale_level_name(level: usize) -> &'static str {
    SCALE_NAMES[level.min(SCALE_NAMES.len() - 1)]
}

/// Streaming variant of the rejection seeder for the online scenario:
/// points arrive in batches and acceptance state is maintained
/// incrementally — the monotone oracle **ingests each accepted center
/// via `insert`** instead of being rebuilt over a frozen dataset, which
/// is the whole point (refitting per batch would be `O(n)` per arrival).
///
/// The arriving stream plays the role of Algorithm 4's proposal
/// distribution, and the accept test keeps the indicator form: draw
/// `u ~ U[0,1)` and open `x` as a center iff no existing center lies
/// below `sqrt(u · c² · W)`, decided by the oracle's early-exit witness
/// scan ([`NnOracle::dist_below_cached`]). `W` is the running
/// **potential** `Σ d²(y, S)` over the observed stream — the streaming
/// stand-in for Lemma 5.2's normalizer `Σ_y DIST(y, Query(y))²`, so the
/// accept probability `min(1, d²(x,S) / (c²·W))` mirrors the batch
/// sampler's accepted distribution. Because `W` only grows, the accept
/// rate for in-distribution points decays harmonically (the online
/// facility-location shape), while an outlier whose `d²` rivals the
/// whole accumulated potential opens immediately — the accept count
/// doubles as a drift signal (`observe.novel` in the serving layer).
///
/// ## Determinism contract
///
/// Replays are bitwise: the accept draw for the `t`-th observed point
/// comes from `stream_root.fork(t)` (exactly one fork and one `f64`
/// draw per point), `W` accumulates in stream order, and the oracle
/// only ever sees accepted centers in stream order. Consequently the
/// final centers are a pure function of `(seed, cfg, point stream)` —
/// **independent of how the stream is chunked into `observe` calls**,
/// which is what lets the serving layer batch ingests freely.
pub struct StreamingRejection {
    cfg: RejectionConfig,
    /// Max centers (seeded + accepted).
    k: usize,
    dim: usize,
    oracle: Box<dyn NnOracle>,
    /// Accepted centers; row index = oracle insertion id (append-only,
    /// so earlier ids stay valid as the matrix grows).
    centers: PointSet,
    /// Running potential `Σ d²(x, S)` over the stream (the scale `W`).
    d2_sum: f64,
    observed: u64,
    accepted: u64,
    stream_root: Pcg64,
}

impl StreamingRejection {
    /// Build an empty streaming seeder. The rigorous multi-scale oracle
    /// needs the data's diameter up front, which a stream cannot
    /// provide, so only `lsh` and `exact` are accepted; likewise the
    /// bucket width is taken from the config as-is (auto-tuning needs
    /// data).
    pub fn new(dim: usize, k: usize, cfg: RejectionConfig, seed: u64) -> Result<StreamingRejection> {
        cfg.validate()?;
        if k == 0 {
            bail!("streaming rejection needs k >= 1");
        }
        if dim == 0 {
            bail!("streaming rejection needs dim >= 1");
        }
        let mut rng = Pcg64::seed_from(seed);
        let oracle: Box<dyn NnOracle> = match cfg.oracle {
            OracleKind::Exact => Box::new(ExactNn::default()),
            OracleKind::LshPractical => {
                let mut params = cfg.lsh.clone();
                params.c = cfg.c;
                Box::new(MonotoneLsh::new(dim, &params, &LshMode::Practical, &mut rng))
            }
            OracleKind::LshRigorous => {
                bail!("streaming rejection supports oracles lsh|exact (rigorous needs the diameter up front)")
            }
        };
        let stream_root = rng.fork(0x0AC1_E5);
        Ok(StreamingRejection {
            cfg,
            k,
            dim,
            oracle,
            centers: PointSet::from_flat(0, dim, Vec::new()),
            d2_sum: 0.0,
            observed: 0,
            accepted: 0,
            stream_root,
        })
    }

    /// Pre-open existing centers (e.g. a fitted model's) without
    /// consuming stream positions or accept draws. Each one is ingested
    /// by the oracle incrementally, exactly like a streamed accept.
    pub fn seed_centers(&mut self, centers: &PointSet) -> Result<()> {
        if centers.dim() != self.dim {
            bail!(
                "seed centers have d={}, streaming seeder built for d={}",
                centers.dim(),
                self.dim
            );
        }
        if self.centers.len() + centers.len() > self.k {
            bail!(
                "seeding {} centers would exceed the streaming cap k={}",
                centers.len(),
                self.k
            );
        }
        for i in 0..centers.len() {
            self.open(centers.row(i).to_vec());
        }
        Ok(())
    }

    /// Ingest a batch of arriving points; returns how many opened as new
    /// centers. Bitwise identical to ingesting the same points across
    /// any other chunking (see the determinism contract above).
    pub fn observe(&mut self, batch: &PointSet) -> Result<u64> {
        if batch.dim() != self.dim {
            bail!(
                "observed points have d={}, streaming seeder built for d={}",
                batch.dim(),
                self.dim
            );
        }
        let c2 = (self.cfg.c as f64) * (self.cfg.c as f64);
        let mut opened = 0u64;
        for r in 0..batch.len() {
            let t = self.observed;
            self.observed += 1;
            let x = batch.row(r);
            if self.centers.is_empty() {
                self.open(x.to_vec());
                opened += 1;
                continue;
            }
            let (_, d2) = crate::kernels::assign::nearest_center(x, &self.centers);
            self.d2_sum += d2 as f64;
            // The accept draw is consumed even when saturated or on a
            // duplicate point, keeping one fork + one draw per stream
            // position (chunk invariance is a counting argument).
            let u = self.stream_root.fork(t).next_f64();
            if self.centers.len() >= self.k || d2 <= 0.0 {
                continue;
            }
            let threshold = (u * c2 * self.d2_sum).sqrt() as f32;
            let x_norm = crate::kernels::blocked::dot(x, x);
            if !self
                .oracle
                .dist_below_cached(&self.centers, x, x_norm, threshold)
            {
                self.open(x.to_vec());
                opened += 1;
            }
        }
        self.accepted += opened;
        Ok(opened)
    }

    /// Append a center row and hand it to the oracle — the incremental
    /// ingest path (no rebuild).
    fn open(&mut self, row: Vec<f32>) {
        let mut flat = self.centers.flat().to_vec();
        flat.extend_from_slice(&row);
        let n = self.centers.len() + 1;
        self.centers = PointSet::from_flat(n, self.dim, flat);
        self.oracle.insert(&self.centers, (n - 1) as u32);
    }

    /// Centers opened so far (seeded + accepted), in arrival order.
    pub fn centers(&self) -> &PointSet {
        &self.centers
    }

    /// Total points streamed through `observe`.
    pub fn observed(&self) -> u64 {
        self.observed
    }

    /// Centers opened by the accept test (excludes [`Self::seed_centers`]).
    pub fn accepted(&self) -> u64 {
        self.accepted
    }

    /// True once the center budget `k` is exhausted.
    pub fn is_saturated(&self) -> bool {
        self.centers.len() >= self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, separated_grid, SynthSpec};
    use crate::lloyd::cost_native;
    use crate::seeding::kmeanspp::kmeanspp;
    use crate::seeding::uniform::uniform_sampling;

    fn data(n: usize, d: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 10,
                center_spread: 15.0,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn returns_k_distinct_all_oracles() {
        let ps = data(500, 8, 1);
        for oracle in [
            OracleKind::LshPractical,
            OracleKind::LshRigorous,
            OracleKind::Exact,
        ] {
            let cfg = RejectionConfig {
                oracle,
                ..Default::default()
            };
            let mut rng = Pcg64::seed_from(2);
            let s = rejection_sampling(&ps, 25, &cfg, &mut rng);
            assert_eq!(s.k(), 25, "{oracle:?}");
            let mut idx = s.indices.clone();
            idx.sort_unstable();
            idx.dedup();
            assert_eq!(idx.len(), 25, "{oracle:?}");
        }
    }

    #[test]
    fn acceptance_rate_within_lemma_5_3() {
        // Lemma 5.3: E[loop iterations] = O(c^2 d^2 k). Check the bound
        // with a modest constant on isotropic data (the worst case for
        // the tree distortion).
        let ps = data(2000, 8, 3);
        let cfg = RejectionConfig::default();
        let mut rng = Pcg64::seed_from(4);
        let k = 50u64;
        let s = rejection_sampling(&ps, k as usize, &cfg, &mut rng);
        assert_eq!(s.k(), 50);
        let c2d2 = (cfg.c as f64 * cfg.c as f64) * 64.0; // d = 8
        let bound = 5.0 * c2d2 * k as f64;
        assert!(
            (s.stats.proposals as f64) < bound,
            "proposals={} exceeds 5*c^2*d^2*k={bound}",
            s.stats.proposals
        );
    }

    #[test]
    fn matches_exact_d2_distribution_on_tiny_instance() {
        // With the exact oracle and c=1, acceptance p = d2(x,S)/w_x and
        // Lemma 5.2 says the accepted distribution IS the exact D^2
        // distribution. Check the second-center marginal on 6 points by
        // comparing against the analytic distribution, conditioned on the
        // same first center.
        let rows = vec![
            vec![0.0f32, 0.0],
            vec![1.0, 0.0],
            vec![0.0, 1.5],
            vec![10.0, 10.0],
            vec![10.0, 11.0],
            vec![-5.0, 4.0],
        ];
        let ps = PointSet::from_rows(&rows);
        let cfg = RejectionConfig {
            c: 1.0,
            oracle: OracleKind::Exact,
            ..Default::default()
        };
        let trials = 30_000;
        let mut counts = vec![0.0f64; 6];
        let mut first_counts = vec![0.0f64; 6];
        for seed in 0..trials {
            let mut rng = Pcg64::seed_from(seed);
            let s = rejection_sampling(&ps, 2, &cfg, &mut rng);
            first_counts[s.indices[0]] += 1.0;
            counts[s.indices[1]] += 1.0;
        }
        // Analytic marginal: P(second = j) = E_first[ d2(j, first)/Σ ].
        let mut want = vec![0.0f64; 6];
        for f in 0..6 {
            let d2s: Vec<f64> = (0..6).map(|j| ps.d2_rows(j, f) as f64).collect();
            let sum: f64 = d2s.iter().sum();
            for j in 0..6 {
                want[j] += (first_counts[f] / trials as f64) * d2s[j] / sum;
            }
        }
        for j in 0..6 {
            let got = counts[j] / trials as f64;
            assert!(
                (got - want[j]).abs() < 0.015,
                "j={j} got={got} want={}",
                want[j]
            );
        }
    }

    #[test]
    fn quality_comparable_to_exact_kmeanspp() {
        // Table 4-6 shape: rejection sampling within ~20% of exact
        // k-means++ cost on clustered data (averaged over seeds).
        let ps = data(3000, 10, 5);
        let k = 30;
        let mut rej = 0.0;
        let mut exact = 0.0;
        for seed in 0..5 {
            let mut r1 = Pcg64::seed_from(1000 + seed);
            rej += cost_native(
                &ps,
                &rejection_sampling(&ps, k, &Default::default(), &mut r1).centers,
            );
            let mut r2 = Pcg64::seed_from(2000 + seed);
            exact += cost_native(&ps, &kmeanspp(&ps, k, &mut r2).centers);
        }
        assert!(
            rej < 1.5 * exact,
            "rejection cost {rej} too far above exact {exact}"
        );
    }

    #[test]
    fn beats_uniform_on_separated_clusters() {
        let ps = separated_grid(10, 80, 4, 7);
        let mut rej = 0.0;
        let mut uni = 0.0;
        for seed in 0..5 {
            let mut r1 = Pcg64::seed_from(3000 + seed);
            rej += cost_native(
                &ps,
                &rejection_sampling(&ps, 10, &Default::default(), &mut r1).centers,
            );
            let mut r2 = Pcg64::seed_from(4000 + seed);
            uni += cost_native(&ps, &uniform_sampling(&ps, 10, &mut r2).centers);
        }
        assert!(rej < uni, "rejection={rej} uniform={uni}");
    }

    #[test]
    fn oracle_kind_parse_round_trips_and_enumerates() {
        for o in OracleKind::all() {
            assert_eq!(OracleKind::parse(o.name()).unwrap(), o);
        }
        assert_eq!(OracleKind::parse("practical").unwrap(), OracleKind::LshPractical);
        assert_eq!(OracleKind::parse("rigorous").unwrap(), OracleKind::LshRigorous);
        let err = format!("{:#}", OracleKind::parse("bogus").unwrap_err());
        for o in OracleKind::all() {
            assert!(err.contains(o.name()), "{:?} missing from {err:?}", o.name());
        }
    }

    #[test]
    fn config_validate_bounds() {
        assert!(RejectionConfig::default().validate().is_ok());
        let bad = [
            RejectionConfig {
                c: 0.5,
                ..Default::default()
            },
            RejectionConfig {
                lsh: LshParams {
                    tables: 0,
                    ..Default::default()
                },
                ..Default::default()
            },
            RejectionConfig {
                lsh: LshParams {
                    bucket_width: 0.0,
                    ..Default::default()
                },
                ..Default::default()
            },
        ];
        for cfg in bad {
            assert!(cfg.validate().is_err(), "{cfg:?} should fail validation");
        }
    }

    #[test]
    fn scale_level_names_are_static_and_clamped() {
        assert_eq!(scale_level_name(0), "oracle.scale.0");
        assert_eq!(scale_level_name(10), "oracle.scale.10");
        assert_eq!(scale_level_name(11), "oracle.scale.11plus");
        assert_eq!(scale_level_name(40), "oracle.scale.11plus");
    }

    #[test]
    fn oracle_metrics_flush_to_global_sink() {
        // Every run flushes loop + probe counters to `metrics::global()`
        // (counters accumulate process-wide: assert deltas only).
        let ps = data(400, 6, 21);
        let m = crate::metrics::global();
        let before = crate::metrics::CounterSnapshot::of(m);
        let mut rng = Pcg64::seed_from(22);
        let s = rejection_sampling(&ps, 20, &RejectionConfig::default(), &mut rng);
        assert_eq!(s.k(), 20);
        assert!(before.delta(m, "oracle.proposals") >= s.stats.proposals);
        assert!(before.delta(m, "oracle.accepts") >= 20);
        assert!(before.delta(m, "oracle.probes") > 0);
        // Probe latencies land in the log-bucketed histogram sink.
        let hist = m.histogram("oracle.probe_secs").expect("probe histogram");
        assert!(hist.count() > 0);
        assert!(hist.quantile(0.99) >= hist.quantile(0.50));
    }

    #[test]
    fn per_round_streams_make_fixed_seeds_bitwise_stable() {
        // The per-round proposal/acceptance stream split must be
        // deterministic for every oracle kind.
        let ps = data(800, 8, 23);
        for oracle in OracleKind::all() {
            let cfg = RejectionConfig {
                oracle,
                ..Default::default()
            };
            let run = || {
                let mut rng = Pcg64::seed_from(24);
                rejection_sampling(&ps, 30, &cfg, &mut rng)
            };
            let (a, b) = (run(), run());
            assert_eq!(a.indices, b.indices, "{oracle:?}");
            assert_eq!(a.stats.proposals, b.stats.proposals, "{oracle:?}");
            assert_eq!(a.stats.rejections, b.stats.rejections, "{oracle:?}");
        }
    }

    #[test]
    fn budget_exhaustion_still_returns_k() {
        let ps = data(100, 6, 9);
        let cfg = RejectionConfig {
            max_proposals: 3, // absurdly small
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from(10);
        let s = rejection_sampling(&ps, 10, &cfg, &mut rng);
        assert_eq!(s.k(), 10);
    }

    #[test]
    fn larger_c_accepts_less_selectively() {
        // As c grows the acceptance probability shrinks (1/c^2 factor),
        // so the proposal count grows.
        let ps = data(1500, 8, 11);
        let mut props = Vec::new();
        for &c in &[1.5f32, 4.0] {
            let cfg = RejectionConfig {
                c,
                oracle: OracleKind::Exact,
                ..Default::default()
            };
            let mut rng = Pcg64::seed_from(12);
            let s = rejection_sampling(&ps, 20, &cfg, &mut rng);
            props.push(s.stats.proposals);
        }
        assert!(
            props[1] > props[0],
            "c=4 proposals {} should exceed c=1.5 proposals {}",
            props[1],
            props[0]
        );
    }

    #[test]
    fn streaming_is_chunk_invariant_and_replayable() {
        // The contract the serving layer leans on: the final centers are
        // a pure function of (seed, cfg, stream) — identical bits no
        // matter how the stream is chunked into observe calls, and
        // identical again on replay.
        let ps = data(400, 8, 31);
        for oracle in [OracleKind::Exact, OracleKind::LshPractical] {
            let cfg = RejectionConfig {
                oracle,
                ..Default::default()
            };
            let mut whole = StreamingRejection::new(8, 12, cfg.clone(), 77).unwrap();
            whole.observe(&ps).unwrap();
            let mut chunked = StreamingRejection::new(8, 12, cfg.clone(), 77).unwrap();
            let mut at = 0;
            for size in [1usize, 7, 64, 13, 400] {
                let end = (at + size).min(ps.len());
                if at >= end {
                    break;
                }
                let rows: Vec<usize> = (at..end).collect();
                chunked.observe(&ps.gather(&rows)).unwrap();
                at = end;
            }
            assert_eq!(at, ps.len());
            assert_eq!(whole.observed(), chunked.observed(), "{oracle:?}");
            assert_eq!(whole.accepted(), chunked.accepted(), "{oracle:?}");
            assert_eq!(whole.centers(), chunked.centers(), "{oracle:?} chunking changed bits");
            assert!(whole.centers().len() >= 1 && whole.centers().len() <= 12);
        }
    }

    #[test]
    fn streaming_oracle_ingests_incrementally() {
        // Accepted centers reach the oracle one insert at a time; probe
        // stats move without any rebuild, and the accept test consults
        // the oracle (inserted == opened centers at every step).
        let ps = data(600, 6, 33);
        let cfg = RejectionConfig {
            oracle: OracleKind::LshPractical,
            ..Default::default()
        };
        let mut s = StreamingRejection::new(6, 16, cfg, 5).unwrap();
        s.observe(&ps).unwrap();
        assert!(s.centers().len() >= 2, "stream opened at least two centers");
        assert!(s.oracle.len() == s.centers().len(), "oracle saw every accept");
        assert!(s.oracle.probe_stats().probes > 0, "accept tests probed the oracle");
    }

    #[test]
    fn streaming_seeded_centers_gate_novelty() {
        // Seed with one tight cluster's centers: points from that
        // cluster nearly all reject; a far-away cluster opens centers.
        let near = gaussian_mixture(
            &SynthSpec {
                n: 200,
                d: 4,
                k_true: 1,
                ..Default::default()
            },
            61,
        );
        let mut s = StreamingRejection::new(
            4,
            16,
            RejectionConfig {
                oracle: OracleKind::Exact,
                ..Default::default()
            },
            9,
        )
        .unwrap();
        let seed_rows: Vec<usize> = (0..4).collect();
        s.seed_centers(&near.gather(&seed_rows)).unwrap();
        assert_eq!(s.centers().len(), 4);
        s.observe(&near).unwrap();
        let near_accepts = s.accepted();
        // Shift a copy far away: drift must open new centers.
        let mut far = near.clone();
        for v in far.flat_mut() {
            *v += 1000.0;
        }
        s.observe(&far).unwrap();
        assert!(
            s.accepted() > near_accepts,
            "far cluster opened no centers (accepted stuck at {near_accepts})"
        );
        // Dimension mismatch is an error, not a panic.
        assert!(s.observe(&data(5, 7, 1)).is_err());
        // Rigorous oracle is rejected up front.
        assert!(StreamingRejection::new(
            4,
            8,
            RejectionConfig {
                oracle: OracleKind::LshRigorous,
                ..Default::default()
            },
            9,
        )
        .is_err());
    }
}
