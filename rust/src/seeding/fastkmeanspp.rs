//! `FASTK-MEANS++` (Algorithm 3): `D^2` seeding over the multi-tree
//! metric.
//!
//! `MultiTreeInit()` then `k` rounds of `MULTITREESAMPLE` +
//! `MULTITREEOPEN`. Total `O(nd log(dΔ) + n log(dΔ) log n)`
//! (Corollary 4.3) — crucially *independent of k* beyond the `k` samples
//! themselves, which is where the order-of-magnitude speedups of
//! Tables 1–3 at k = 5000 come from.

use std::time::Instant;

use crate::data::matrix::PointSet;
use crate::embed::multitree::{MultiTree, MultiTreeConfig};
use crate::rng::Pcg64;
use crate::seeding::{Seeding, SeedingStats};

/// Configuration for FastKMeans++ (tree count ablation lives here).
#[derive(Clone, Debug, Default)]
pub struct FastConfig {
    pub multitree: MultiTreeConfig,
}

/// Algorithm 3.
pub fn fast_kmeanspp(ps: &PointSet, k: usize, cfg: &FastConfig, rng: &mut Pcg64) -> Seeding {
    let k = k.min(ps.len());
    let mut stats = SeedingStats::default();

    let t0 = Instant::now();
    let mut mt = MultiTree::init(ps, &cfg.multitree, rng);
    stats.init_secs = t0.elapsed().as_secs_f64();

    let t1 = Instant::now();
    let mut indices = Vec::with_capacity(k);
    while indices.len() < k {
        stats.proposals += 1;
        let x = match mt.sample(rng) {
            Some(x) => x,
            // Total multi-tree weight hit zero: every remaining point is
            // coincident with an opened center. Top up with arbitrary
            // distinct indices to honor the k contract.
            None => match (0..ps.len()).find(|i| !indices.contains(i)) {
                Some(i) => i,
                None => break,
            },
        };
        indices.push(x);
        mt.open(x);
    }
    stats.select_secs = t1.elapsed().as_secs_f64();
    Seeding::from_indices(ps, indices, stats)
}

/// Variant that also returns the multi-tree (the rejection sampler and
/// tests reuse it).
pub fn fast_kmeanspp_with_tree(
    ps: &PointSet,
    k: usize,
    cfg: &FastConfig,
    rng: &mut Pcg64,
) -> (Seeding, MultiTree) {
    let k = k.min(ps.len());
    let mut stats = SeedingStats::default();
    let t0 = Instant::now();
    let mut mt = MultiTree::init(ps, &cfg.multitree, rng);
    stats.init_secs = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let mut indices = Vec::with_capacity(k);
    while indices.len() < k {
        stats.proposals += 1;
        let x = match mt.sample(rng) {
            Some(x) => x,
            None => match (0..ps.len()).find(|i| !indices.contains(i)) {
                Some(i) => i,
                None => break,
            },
        };
        indices.push(x);
        mt.open(x);
    }
    stats.select_secs = t1.elapsed().as_secs_f64();
    (Seeding::from_indices(ps, indices, stats), mt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, separated_grid, SynthSpec};
    use crate::lloyd::cost_native;
    use crate::seeding::uniform::uniform_sampling;

    #[test]
    fn returns_k_distinct() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 600,
                d: 8,
                k_true: 12,
                ..Default::default()
            },
            1,
        );
        let mut rng = Pcg64::seed_from(2);
        let s = fast_kmeanspp(&ps, 40, &FastConfig::default(), &mut rng);
        assert_eq!(s.k(), 40);
        let mut idx = s.indices.clone();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 40);
    }

    #[test]
    fn first_sample_is_uniform() {
        // With S empty all weights are M, so the first draw is uniform.
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 20,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            3,
        );
        let mut counts = vec![0u32; 20];
        for seed in 0..8000u64 {
            let mut rng = Pcg64::seed_from(seed);
            let s = fast_kmeanspp(&ps, 1, &FastConfig::default(), &mut rng);
            counts[s.indices[0]] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 400).abs() < 150, "{counts:?}");
        }
    }

    #[test]
    fn covers_separated_clusters() {
        // The tree D^2 proxy must still find well-separated clusters: the
        // distortion is bounded, separation is huge.
        let ps = separated_grid(8, 60, 3, 4);
        let mut hits = 0;
        for seed in 0..10 {
            let mut rng = Pcg64::seed_from(50 + seed);
            let s = fast_kmeanspp(&ps, 8, &FastConfig::default(), &mut rng);
            let mut clusters: Vec<usize> = s.indices.iter().map(|&i| i / 60).collect();
            clusters.sort_unstable();
            clusters.dedup();
            if clusters.len() == 8 {
                hits += 1;
            }
        }
        assert!(hits >= 8, "only {hits}/10 runs covered all clusters");
    }

    #[test]
    fn beats_uniform_on_clustered_data() {
        let ps = separated_grid(10, 100, 4, 6);
        let mut fast_cost = 0.0;
        let mut uni_cost = 0.0;
        for seed in 0..5 {
            let mut rng = Pcg64::seed_from(300 + seed);
            let s = fast_kmeanspp(&ps, 10, &FastConfig::default(), &mut rng);
            fast_cost += cost_native(&ps, &s.centers);
            let mut rng2 = Pcg64::seed_from(400 + seed);
            uni_cost += cost_native(&ps, &uniform_sampling(&ps, 10, &mut rng2).centers);
        }
        assert!(fast_cost < uni_cost, "fast={fast_cost} uniform={uni_cost}");
    }

    #[test]
    fn k_equals_n() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 30,
                d: 4,
                k_true: 3,
                ..Default::default()
            },
            7,
        );
        let mut rng = Pcg64::seed_from(8);
        let s = fast_kmeanspp(&ps, 30, &FastConfig::default(), &mut rng);
        assert_eq!(s.k(), 30);
    }

    #[test]
    fn with_tree_variant_consistent() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 100,
                d: 5,
                k_true: 4,
                ..Default::default()
            },
            9,
        );
        let mut rng = Pcg64::seed_from(10);
        let (s, mt) = fast_kmeanspp_with_tree(&ps, 12, &FastConfig::default(), &mut rng);
        assert_eq!(s.k(), 12);
        assert_eq!(mt.opened().len(), 12);
        for &i in &s.indices {
            assert_eq!(mt.weight(i), 0.0, "opened center weight must be 0");
        }
    }
}
