//! `UNIFORMSAMPLING` — the trivial baseline (§6): `k` distinct uniform
//! indices. Blazing fast, no quality guarantee; the paper's tables show it
//! collapsing on clustered/heavy-tailed data (Table 4).

use std::collections::HashSet;
use std::time::Instant;

use crate::data::matrix::PointSet;
use crate::rng::Pcg64;
use crate::seeding::{Seeding, SeedingStats};

/// Sample `k` distinct points uniformly at random.
pub fn uniform_sampling(ps: &PointSet, k: usize, rng: &mut Pcg64) -> Seeding {
    let k = k.min(ps.len());
    let t0 = Instant::now();
    let n = ps.len();
    let mut chosen = Vec::with_capacity(k);
    if k * 3 >= n {
        // Dense regime: partial Fisher–Yates on the full index range.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + rng.index(n - i);
            idx.swap(i, j);
            chosen.push(idx[i]);
        }
    } else {
        // Sparse regime: rejection on a hash set.
        let mut seen = HashSet::with_capacity(k * 2);
        while chosen.len() < k {
            let i = rng.index(n);
            if seen.insert(i) {
                chosen.push(i);
            }
        }
    }
    let stats = SeedingStats {
        proposals: k as u64,
        select_secs: t0.elapsed().as_secs_f64(),
        ..Default::default()
    };
    Seeding::from_indices(ps, chosen, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn data(n: usize) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d: 4,
                k_true: 3,
                ..Default::default()
            },
            1,
        )
    }

    #[test]
    fn distinct_indices_both_regimes() {
        for (n, k) in [(100, 90), (10_000, 20)] {
            let ps = data(n);
            let mut rng = Pcg64::seed_from(2);
            let s = uniform_sampling(&ps, k, &mut rng);
            let mut idx = s.indices.clone();
            idx.sort_unstable();
            idx.dedup();
            assert_eq!(idx.len(), k, "n={n} k={k}");
        }
    }

    #[test]
    fn k_equals_n_returns_everything() {
        let ps = data(25);
        let mut rng = Pcg64::seed_from(3);
        let s = uniform_sampling(&ps, 25, &mut rng);
        let mut idx = s.indices.clone();
        idx.sort_unstable();
        assert_eq!(idx, (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn roughly_uniform_marginals() {
        let ps = data(10);
        let mut counts = [0u32; 10];
        for seed in 0..20_000u64 {
            let mut rng = Pcg64::seed_from(seed);
            let s = uniform_sampling(&ps, 1, &mut rng);
            counts[s.indices[0]] += 1;
        }
        for &c in &counts {
            assert!((c as i64 - 2000).abs() < 300, "{counts:?}");
        }
    }
}
