//! Multi-tree embedding + the `MultiTreeOpen` / `MultiTreeSample` data
//! structure (paper §3–§4).
//!
//! Three (configurable) independently-shifted grid trees; the multi-tree
//! distance is the *minimum* of the three tree distances, which bounds the
//! expected squared-distance distortion by `O(d^2)` (Lemma 3.1) — a single
//! tree has `Omega(n)` squared distortion.
//!
//! The shared data structure maintains the §4 invariants for the set `S`
//! of opened centers:
//!
//! 1. `w_x = MULTITREEDIST(x, S)^2` for every point `x`;
//! 2. every sample-tree node's weight is the sum of its leaf weights;
//! 3. a tree node is marked iff its subtree contains an opened point.
//!
//! `open(x)` walks each tree from `x`'s leaf up to the first marked
//! ancestor, marks the path, and min-updates the weights of exactly the
//! points whose tree distance to `S` shrank — each tree node is marked
//! once over the whole run, giving the `O(n log(dΔ) log n)` total of
//! Lemma 4.1. `sample()` is Algorithm 2 on the sample-tree, `O(log n)`.

use crate::data::matrix::PointSet;
use crate::embed::tree::{ShiftTree, NIL};
use crate::parallel::parallel_map;
use crate::rng::Pcg64;
use crate::sampletree::SampleTree;

/// Multi-tree configuration.
#[derive(Clone, Debug)]
pub struct MultiTreeConfig {
    /// Number of independently shifted trees (the paper fixes 3; the
    /// trees ablation sweeps this).
    pub num_trees: usize,
}

impl Default for MultiTreeConfig {
    fn default() -> Self {
        MultiTreeConfig { num_trees: 3 }
    }
}

/// The multi-tree embedding plus the open/sample data structure.
pub struct MultiTree {
    trees: Vec<ShiftTree>,
    /// Invariant 1: `w[x] = MULTITREEDIST(x, S)^2`.
    weights: Vec<f64>,
    /// Invariant 2 lives inside the sample-tree.
    sample_tree: SampleTree,
    /// Upper bound `M = 16 d MAXDIST^2` on any squared multi-tree distance.
    m_bound: f64,
    /// Opened centers, in open order.
    opened: Vec<u32>,
    /// Scratch path buffer (allocation-free `open`).
    path: Vec<u32>,
}

impl MultiTree {
    /// `MultiTreeInit()`: build the trees and initialize all weights to
    /// `M` (so the first sample is uniform). `O(n d H)` per tree.
    pub fn init(ps: &PointSet, cfg: &MultiTreeConfig, rng: &mut Pcg64) -> Self {
        assert!(cfg.num_trees >= 1);
        // Fork the per-tree rngs sequentially (deterministic in `rng`),
        // then build the independent trees in parallel.
        let tree_rngs: Vec<Pcg64> = (0..cfg.num_trees).map(|t| rng.fork(t as u64)).collect();
        let trees: Vec<ShiftTree> = parallel_map(cfg.num_trees, |t| {
            let mut tree_rng = tree_rngs[t].clone();
            ShiftTree::build(ps, &mut tree_rng)
        });
        let d = ps.dim() as f64;
        let m_bound = trees
            .iter()
            .map(|t| 16.0 * d * t.max_dist as f64 * t.max_dist as f64)
            .fold(0.0f64, f64::max)
            .max(f64::MIN_POSITIVE);
        MultiTree {
            weights: vec![m_bound; ps.len()],
            sample_tree: SampleTree::with_uniform_weight(ps.len(), m_bound),
            trees,
            m_bound,
            opened: Vec::new(),
            path: Vec::new(),
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.weights.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.weights.is_empty()
    }

    /// `w_x = MULTITREEDIST(x, S)^2` (= `M` while `S` is empty).
    #[inline]
    pub fn weight(&self, x: usize) -> f64 {
        self.weights[x]
    }

    /// Σ_y MULTITREEDIST(y, S)^2 — the D^2 normalizer.
    #[inline]
    pub fn total_weight(&self) -> f64 {
        self.sample_tree.total()
    }

    /// The `M` upper bound (`MULTITREEDIST(x, ∅)^2`).
    #[inline]
    pub fn m_bound(&self) -> f64 {
        self.m_bound
    }

    pub fn opened(&self) -> &[u32] {
        &self.opened
    }

    pub fn num_trees(&self) -> usize {
        self.trees.len()
    }

    /// `MULTITREESAMPLE()` (Algorithm 2): a point with probability
    /// `w_x / Σ w_y`, `O(log n)`. `None` once every point has weight 0.
    #[inline]
    pub fn sample(&self, rng: &mut Pcg64) -> Option<usize> {
        self.sample_tree.sample(rng)
    }

    /// `MULTITREEOPEN(x)` (Algorithm 1): add `x` to `S`, restore all
    /// three invariants.
    pub fn open(&mut self, x: usize) {
        self.opened.push(x as u32);
        for ti in 0..self.trees.len() {
            // Step 2-3: leaf -> up, stop at root or below a marked parent.
            let mut path = std::mem::take(&mut self.path);
            path.clear();
            {
                let tree = &self.trees[ti];
                let mut v = tree.leaf_of[x];
                loop {
                    path.push(v);
                    let parent = tree.nodes[v as usize].parent;
                    if parent == NIL || tree.nodes[parent as usize].marked {
                        break;
                    }
                    v = parent;
                }
            }
            // Step 4: mark the path.
            for &v in &path {
                self.trees[ti].nodes[v as usize].marked = true;
            }
            // Step 5-9: min-update exactly the points whose tree distance
            // to S dropped: P_T(v_0), then P_T(v_i) \ P_T(v_{i-1}).
            let weights = &mut self.weights;
            let sample_tree = &mut self.sample_tree;
            let tree = &self.trees[ti];
            let mut prev = NIL;
            for &v in &path {
                let h = tree.nodes[v as usize].height as usize;
                let dist = if prev == NIL {
                    0.0 // the leaf: coincident points, distance 0
                } else {
                    tree.dist_at_height(h)
                };
                let d2 = dist * dist;
                tree.for_each_point_in_subtree(v, prev, &mut |y| {
                    let yy = y as usize;
                    if d2 < weights[yy] {
                        weights[yy] = d2;
                        sample_tree.update(yy, d2);
                    }
                });
                prev = v;
            }
            self.path = path;
        }
    }

    /// `MULTITREEDIST(p, q)` — min over the trees. `O(H)`; used by the
    /// brute-force invariant checks and the distortion ablation, not on
    /// the hot path.
    pub fn multi_tree_dist(&self, p: usize, q: usize) -> f64 {
        self.trees
            .iter()
            .map(|t| t.tree_dist(p, q))
            .fold(f64::INFINITY, f64::min)
    }

    /// Brute-force `MULTITREEDIST(p, S)^2` for invariant tests.
    pub fn multi_tree_dist_to_opened_sq(&self, p: usize) -> f64 {
        if self.opened.is_empty() {
            return self.m_bound;
        }
        let d = self
            .opened
            .iter()
            .map(|&s| self.multi_tree_dist(p, s as usize))
            .fold(f64::INFINITY, f64::min);
        d * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::d2 as euclid_d2;
    use crate::data::synth::{gaussian_mixture, uniform_box, SynthSpec};

    fn build(n: usize, d: usize, seed: u64) -> (PointSet, MultiTree) {
        let ps = gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 6,
                ..Default::default()
            },
            seed,
        );
        let mut rng = Pcg64::seed_from(seed ^ 0xABCD);
        let mt = MultiTree::init(&ps, &MultiTreeConfig::default(), &mut rng);
        (ps, mt)
    }

    #[test]
    fn init_uniform_weights() {
        let (ps, mt) = build(64, 5, 1);
        assert_eq!(mt.len(), 64);
        for x in 0..ps.len() {
            assert_eq!(mt.weight(x), mt.m_bound());
        }
        assert!((mt.total_weight() - 64.0 * mt.m_bound()).abs() < 1e-6 * mt.total_weight());
    }

    #[test]
    fn open_zeroes_center_weight() {
        let (_, mut mt) = build(100, 4, 2);
        mt.open(17);
        assert_eq!(mt.weight(17), 0.0);
        assert_eq!(mt.opened(), &[17]);
    }

    #[test]
    fn invariants_after_each_open() {
        // Invariant 1 checked against brute force after every open.
        let (ps, mut mt) = build(120, 5, 3);
        let mut rng = Pcg64::seed_from(4);
        for step in 0..12 {
            let x = rng.index(ps.len());
            mt.open(x);
            for y in 0..ps.len() {
                let want = mt.multi_tree_dist_to_opened_sq(y);
                let got = mt.weight(y);
                assert!(
                    (got - want).abs() <= 1e-6 * want.max(1.0),
                    "step={step} y={y} got={got} want={want}"
                );
            }
        }
    }

    #[test]
    fn marks_follow_invariant_3() {
        let (ps, mut mt) = build(80, 4, 5);
        let mut rng = Pcg64::seed_from(6);
        for _ in 0..6 {
            mt.open(rng.index(ps.len()));
        }
        // A node is marked iff its subtree contains an opened point.
        for tree in &mt.trees {
            for (id, node) in tree.nodes.iter().enumerate() {
                let mut contains_open = false;
                tree.for_each_point_in_subtree(id as u32, NIL, &mut |p| {
                    if mt.opened.contains(&p) {
                        contains_open = true;
                    }
                });
                assert_eq!(
                    node.marked, contains_open,
                    "tree node {id} marked={} contains={}",
                    node.marked, contains_open
                );
            }
        }
    }

    #[test]
    fn weights_dominate_euclidean_d2() {
        // MULTITREEDIST >= DIST (Lemma 3.1), so w_y >= DIST(y,S)^2.
        let (ps, mut mt) = build(150, 6, 7);
        let mut rng = Pcg64::seed_from(8);
        let mut opened = Vec::new();
        for _ in 0..10 {
            let x = rng.index(ps.len());
            mt.open(x);
            opened.push(x);
        }
        for y in 0..ps.len() {
            let true_d2 = opened
                .iter()
                .map(|&s| euclid_d2(ps.row(y), ps.row(s)) as f64)
                .fold(f64::INFINITY, f64::min);
            assert!(
                mt.weight(y) + 1e-6 >= true_d2,
                "y={y} w={} true={true_d2}",
                mt.weight(y)
            );
        }
    }

    #[test]
    fn expected_multitree_distortion_is_moderate() {
        // Lemma 3.1: E[MULTITREEDIST^2] <= 48 d^2 DIST^2. Empirically the
        // mean over pairs should respect a comfortable multiple of that.
        let ps = uniform_box(200, 4, 100.0, 9);
        let mut rng = Pcg64::seed_from(10);
        let mt = MultiTree::init(&ps, &MultiTreeConfig::default(), &mut rng);
        let d = ps.dim() as f64;
        let mut ratio_sum = 0.0;
        let mut count = 0;
        let mut rng2 = Pcg64::seed_from(11);
        for _ in 0..500 {
            let (i, j) = (rng2.index(200), rng2.index(200));
            let dd = euclid_d2(ps.row(i), ps.row(j)) as f64;
            if dd == 0.0 {
                continue;
            }
            let md = mt.multi_tree_dist(i, j);
            ratio_sum += md * md / dd;
            count += 1;
        }
        let mean_ratio = ratio_sum / count as f64;
        assert!(
            mean_ratio <= 96.0 * d * d,
            "mean sq distortion {mean_ratio} vs bound {}",
            48.0 * d * d
        );
        assert!(mean_ratio >= 1.0, "embedding must not contract");
    }

    #[test]
    fn sample_respects_weights_after_opens() {
        let (ps, mut mt) = build(50, 3, 12);
        mt.open(0);
        mt.open(25);
        let total = mt.total_weight();
        if total == 0.0 {
            return; // degenerate: all coincide
        }
        let mut rng = Pcg64::seed_from(13);
        let mut counts = vec![0usize; ps.len()];
        let draws = 100_000;
        for _ in 0..draws {
            counts[mt.sample(&mut rng).unwrap()] += 1;
        }
        assert_eq!(counts[0], 0, "opened center must never be sampled");
        assert_eq!(counts[25], 0);
        for y in 0..ps.len() {
            let want = mt.weight(y) / total;
            let got = counts[y] as f64 / draws as f64;
            assert!(
                (got - want).abs() < 0.01 + want * 0.2,
                "y={y} got={got} want={want}"
            );
        }
    }

    #[test]
    fn all_opened_total_weight_zero() {
        let (ps, mut mt) = build(20, 3, 14);
        for x in 0..ps.len() {
            mt.open(x);
        }
        assert!(mt.total_weight() <= 1e-9);
        let mut rng = Pcg64::seed_from(15);
        assert_eq!(mt.sample(&mut rng), None);
    }

    #[test]
    fn single_tree_config() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 40,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            16,
        );
        let mut rng = Pcg64::seed_from(17);
        let mut mt = MultiTree::init(&ps, &MultiTreeConfig { num_trees: 1 }, &mut rng);
        mt.open(5);
        for y in 0..ps.len() {
            let want = mt.multi_tree_dist_to_opened_sq(y);
            assert!((mt.weight(y) - want).abs() <= 1e-6 * want.max(1.0));
        }
    }
}
