//! Single random-shift grid tree (the paper's §2 "tree embedding",
//! a la Indyk '04).
//!
//! Construction (verbatim from the paper):
//! * compute `MAXDIST`, an upper bound on the max pairwise distance within
//!   a factor 2 (`O(nd)`: twice the max distance to an arbitrary pivot);
//! * draw one random shift `s_j in [0, MAXDIST)` per coordinate;
//! * the root (height 0) is an axis-aligned cube of side `2*MAXDIST`
//!   containing all shifted points; each level halves the side; a node is
//!   a non-empty grid cell; recursion stops when a cell holds a single
//!   point (or only coincident points).
//!
//! `TREEDIST(p, q)` depends only on the height of the lowest common
//! ancestor `i` and the (virtual) common leaf height `H`:
//!
//! ```text
//!   TREEDIST(p,q) = 2 * sqrt(d) * MAXDIST * (2^(1-i) - 2^(1-H))
//! ```
//!
//! (sum of the geometric edge weights from height `i` down to `H`, twice).
//! Singleton cells are real leaves; conceptually they continue as a chain
//! of degree-1 nodes down to height `H`, which only affects the constant
//! `2^(1-H)` term, so we never materialize the chain.
//!
//! The grid cells at consecutive heights are nested by construction
//! (fixed origin, halving side), so the parent of a cell is its
//! half-resolution cell — no explicit geometry is stored, only the node
//! forest with child lists, which `MultiTree` walks during
//! `MultiTreeOpen`.

use std::collections::HashMap;

use crate::data::matrix::PointSet;
use crate::rng::{splitmix64, Pcg64};

/// Sentinel for "no node".
pub const NIL: u32 = u32::MAX;

/// Hard cap on tree height — 2*MAXDIST/2^60 underflows any f32 gap, so
/// this is unreachable for distinct points; it guards degenerate inputs.
const MAX_HEIGHT: usize = 60;

/// One node of the shift tree.
#[derive(Clone, Debug)]
pub struct Node {
    pub parent: u32,
    pub first_child: u32,
    pub next_sibling: u32,
    /// Height in the embedding (root = 0).
    pub height: u16,
    /// Leaf payload: index of the first point in this cell, `NIL` for
    /// internal nodes. Coincident points share a leaf (see `leaf_points`).
    pub point: u32,
    /// Marked flag used by `MultiTreeOpen` (invariant 3 of §4).
    pub marked: bool,
}

/// A built random-shift grid tree over a point set.
pub struct ShiftTree {
    pub nodes: Vec<Node>,
    /// Leaf node id for every point.
    pub leaf_of: Vec<u32>,
    /// Points per leaf (coincident points share one leaf).
    pub leaf_points: HashMap<u32, Vec<u32>>,
    /// Upper bound on max pairwise distance used for the grid.
    pub max_dist: f32,
    /// `max_dist` as f64 (cached for the hot distance formula).
    max_dist_f64: f64,
    /// `sqrt(d)` cached.
    sqrt_d: f64,
    /// Virtual common leaf height `H` (>= deepest real leaf height + 1).
    pub height: usize,
}

impl ShiftTree {
    /// Build with a fresh random shift drawn from `rng`.
    ///
    /// `O(n d H)` for `H` levels: each level recomputes one grid
    /// coordinate per point dimension and buckets by hashed cell id.
    pub fn build(ps: &PointSet, rng: &mut Pcg64) -> Self {
        let max_dist = ps.max_dist_upper_bound().max(f32::MIN_POSITIVE);
        let d = ps.dim();
        // Random shift per coordinate in [0, MAXDIST).
        let shift: Vec<f64> = (0..d).map(|_| rng.next_f64() * max_dist as f64).collect();
        // Root cube origin: pivot (point 0) minus MAXDIST/2 per coordinate
        // guarantees every shifted point lies in [0, 2*MAXDIST)^d.
        let origin: Vec<f64> = (0..d)
            .map(|j| ps.row(0)[j] as f64 - 0.5 * max_dist as f64)
            .collect();

        // Fixed-point normalized coordinates, computed ONCE (O(nd) float
        // work): fp in [0, 2^FP_BITS) such that the grid cell of point i
        // in dim j at height h is `fp >> (FP_BITS - h)`. Each level then
        // costs only shifts/masks instead of float mul + floor + mix
        // (the §Perf log records a ~4x build speedup from this).
        const FP_BITS: u32 = 60;
        let span = 2.0 * max_dist as f64;
        let inv_span = 1.0 / span;
        let scale = (1u64 << FP_BITS) as f64;
        let mut fp = vec![0u64; ps.len() * d];
        for i in 0..ps.len() {
            let row = ps.row(i);
            let out = &mut fp[i * d..(i + 1) * d];
            for j in 0..d {
                let t = (row[j] as f64 + shift[j] - origin[j]) * inv_span;
                out[j] = ((t * scale) as u64).min((1u64 << FP_BITS) - 1);
            }
        }
        let words = d.div_ceil(64);

        let mut nodes = Vec::with_capacity(2 * ps.len());
        let mut leaf_of = vec![NIL; ps.len()];
        let mut leaf_points: HashMap<u32, Vec<u32>> = HashMap::new();

        // Root holds all points.
        nodes.push(Node {
            parent: NIL,
            first_child: NIL,
            next_sibling: NIL,
            height: 0,
            point: NIL,
            marked: false,
        });

        // Iterative level-by-level split. `groups`: (node id, point ids).
        let all: Vec<u32> = (0..ps.len() as u32).collect();
        let mut groups: Vec<(u32, Vec<u32>)> = vec![(0, all)];
        let mut height = 1usize;
        let mut deepest = 1usize;
        let mut bit_words = vec![0u64; words];
        while !groups.is_empty() && height <= MAX_HEIGHT.min(FP_BITS as usize) {
            let bit_shift = FP_BITS - height as u32;
            let mut next_groups = Vec::new();
            for (parent_id, pts) in groups {
                // Bucket by this level's NEW grid bit per dimension
                // (within a parent cell, the child cell is determined by
                // exactly those d bits), packed into u64 words.
                let mut cells: HashMap<u64, Vec<u32>> = HashMap::with_capacity(pts.len());
                for &p in &pts {
                    let coords = &fp[p as usize * d..(p as usize + 1) * d];
                    bit_words.iter_mut().for_each(|w| *w = 0);
                    for (j, &c) in coords.iter().enumerate() {
                        bit_words[j >> 6] |= ((c >> bit_shift) & 1) << (j & 63);
                    }
                    let mut h = 0xcbf2_9ce4_8422_2325u64;
                    for &w in &bit_words {
                        h = splitmix64(h ^ w);
                    }
                    cells.entry(h).or_default().push(p);
                }
                // One child per non-empty cell; order children
                // deterministically (by min point id) for reproducibility.
                let mut children: Vec<Vec<u32>> = cells.into_values().collect();
                children.sort_by_key(|v| *v.iter().min().unwrap());
                for pts_in_cell in children {
                    let id = nodes.len() as u32;
                    let parent = &mut nodes[parent_id as usize];
                    let sibling = parent.first_child;
                    parent.first_child = id;
                    nodes.push(Node {
                        parent: parent_id,
                        first_child: NIL,
                        next_sibling: sibling,
                        height: height as u16,
                        point: NIL,
                        marked: false,
                    });
                    deepest = deepest.max(height);
                    let singleton = pts_in_cell.len() == 1
                        || all_coincident(ps, &pts_in_cell)
                        || height >= MAX_HEIGHT.min(FP_BITS as usize);
                    if singleton {
                        nodes[id as usize].point = pts_in_cell[0];
                        for &p in &pts_in_cell {
                            leaf_of[p as usize] = id;
                        }
                        leaf_points.insert(id, pts_in_cell);
                    } else {
                        next_groups.push((id, pts_in_cell));
                    }
                }
            }
            groups = next_groups;
            height += 1;
        }

        ShiftTree {
            nodes,
            leaf_of,
            leaf_points,
            max_dist,
            sqrt_d: (d as f64).sqrt(),
            // Virtual common leaf height: one below the deepest real
            // split, so fdist(i) is positive for every real LCA height.
            height: deepest + 1,
            max_dist_f64: max_dist as f64,
        }
    }

    /// Tree distance for an LCA at `height` (see module docs).
    #[inline]
    pub fn dist_at_height(&self, height: usize) -> f64 {
        if height >= self.height {
            return 0.0;
        }
        let h = self.height as i32;
        let i = height as i32;
        2.0 * self.sqrt_d
            * self.max_dist_f64
            * ((2.0f64).powi(1 - i) - (2.0f64).powi(1 - h))
    }

    /// `TREEDIST(p, q)`: walk both leaves to their LCA.
    pub fn tree_dist(&self, p: usize, q: usize) -> f64 {
        if p == q {
            return 0.0;
        }
        let (mut a, mut b) = (self.leaf_of[p], self.leaf_of[q]);
        if a == b {
            return 0.0; // coincident points share a leaf
        }
        // Lift the deeper node until heights match, then lift both.
        while self.nodes[a as usize].height > self.nodes[b as usize].height {
            a = self.nodes[a as usize].parent;
        }
        while self.nodes[b as usize].height > self.nodes[a as usize].height {
            b = self.nodes[b as usize].parent;
        }
        while a != b {
            a = self.nodes[a as usize].parent;
            b = self.nodes[b as usize].parent;
        }
        self.dist_at_height(self.nodes[a as usize].height as usize)
    }

    /// Number of nodes.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    /// Iterate the point ids in the subtree of `v` (DFS, child lists).
    /// `skip` (if not `NIL`) prunes one child subtree — used by
    /// `MultiTreeOpen` to enumerate `P_T(v_i) \ P_T(v_{i-1})`.
    pub fn for_each_point_in_subtree<F: FnMut(u32)>(&self, v: u32, skip: u32, f: &mut F) {
        // Explicit stack: trees can be deep and thin after quantization.
        let mut stack = vec![v];
        while let Some(u) = stack.pop() {
            if u == skip {
                continue;
            }
            let node = &self.nodes[u as usize];
            if node.point != NIL {
                for &p in &self.leaf_points[&u] {
                    f(p);
                }
                continue;
            }
            let mut c = node.first_child;
            while c != NIL {
                stack.push(c);
                c = self.nodes[c as usize].next_sibling;
            }
        }
    }
}

fn all_coincident(ps: &PointSet, pts: &[u32]) -> bool {
    let first = ps.row(pts[0] as usize);
    pts[1..]
        .iter()
        .all(|&p| ps.row(p as usize) == first)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, uniform_box, SynthSpec};

    fn small_set(seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n: 200,
                d: 6,
                k_true: 5,
                ..Default::default()
            },
            seed,
        )
    }

    #[test]
    fn every_point_has_a_leaf() {
        let ps = small_set(1);
        let mut rng = Pcg64::seed_from(2);
        let t = ShiftTree::build(&ps, &mut rng);
        for p in 0..ps.len() {
            let leaf = t.leaf_of[p];
            assert_ne!(leaf, NIL);
            assert_ne!(t.nodes[leaf as usize].point, NIL);
            assert!(t.leaf_points[&leaf].contains(&(p as u32)));
        }
    }

    #[test]
    fn parent_child_structure_consistent() {
        let ps = small_set(3);
        let mut rng = Pcg64::seed_from(4);
        let t = ShiftTree::build(&ps, &mut rng);
        for (id, node) in t.nodes.iter().enumerate() {
            if node.parent != NIL {
                let parent = &t.nodes[node.parent as usize];
                assert_eq!(parent.height + 1, node.height, "node {id}");
                // id must appear in parent's child list
                let mut c = parent.first_child;
                let mut found = false;
                while c != NIL {
                    if c as usize == id {
                        found = true;
                        break;
                    }
                    c = t.nodes[c as usize].next_sibling;
                }
                assert!(found, "node {id} missing from parent child list");
            } else {
                assert_eq!(id, 0, "only the root lacks a parent");
            }
        }
    }

    #[test]
    fn tree_dist_dominates_euclidean() {
        // Lemma 3.1 part 1 (exact, not probabilistic): DIST <= TREEDIST.
        for seed in 0..5u64 {
            let ps = small_set(10 + seed);
            let mut rng = Pcg64::seed_from(20 + seed);
            let t = ShiftTree::build(&ps, &mut rng);
            let mut rng2 = Pcg64::seed_from(30 + seed);
            for _ in 0..300 {
                let (i, j) = (rng2.index(ps.len()), rng2.index(ps.len()));
                let euclid = (ps.d2_rows(i, j) as f64).sqrt();
                let td = t.tree_dist(i, j);
                assert!(
                    td + 1e-6 >= euclid,
                    "seed={seed} i={i} j={j} tree={td} euclid={euclid}"
                );
            }
        }
    }

    #[test]
    fn tree_dist_symmetric_and_reflexive() {
        let ps = small_set(5);
        let mut rng = Pcg64::seed_from(6);
        let t = ShiftTree::build(&ps, &mut rng);
        assert_eq!(t.tree_dist(7, 7), 0.0);
        for (i, j) in [(0usize, 1usize), (10, 150), (42, 43)] {
            assert_eq!(t.tree_dist(i, j), t.tree_dist(j, i));
        }
    }

    #[test]
    fn tree_dist_bounded_by_m() {
        // MULTITREEDIST(p,q)^2 <= M = 16 d MAXDIST^2 (paper §4).
        let ps = small_set(7);
        let mut rng = Pcg64::seed_from(8);
        let t = ShiftTree::build(&ps, &mut rng);
        let m = 16.0 * ps.dim() as f64 * (t.max_dist as f64) * (t.max_dist as f64);
        for i in 0..50 {
            for j in 0..50 {
                let d = t.tree_dist(i, j);
                assert!(d * d <= m * (1.0 + 1e-9), "d^2={} M={m}", d * d);
            }
        }
    }

    #[test]
    fn expected_distortion_reasonable() {
        // Lemma 3.1 part 2 gives E[min over 3 trees]^2 = O(d^2) DIST^2;
        // a single tree has no such bound, but the *median over many
        // builds* should still be within a polynomial factor. This is a
        // sanity check that distances are not absurdly inflated.
        let ps = uniform_box(100, 4, 100.0, 9);
        let mut ratios = Vec::new();
        for seed in 0..9u64 {
            let mut rng = Pcg64::seed_from(40 + seed);
            let t = ShiftTree::build(&ps, &mut rng);
            let euclid = (ps.d2_rows(0, 1) as f64).sqrt();
            ratios.push(t.tree_dist(0, 1) / euclid);
        }
        ratios.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = ratios[ratios.len() / 2];
        assert!(median >= 1.0 - 1e-9);
        assert!(median < 2_000.0, "median distortion {median}");
    }

    #[test]
    fn coincident_points_share_leaf() {
        let mut rows = vec![vec![1.0f32, 2.0]; 3];
        rows.push(vec![50.0, 50.0]);
        rows.push(vec![-30.0, 10.0]);
        let ps = PointSet::from_rows(&rows);
        let mut rng = Pcg64::seed_from(11);
        let t = ShiftTree::build(&ps, &mut rng);
        assert_eq!(t.leaf_of[0], t.leaf_of[1]);
        assert_eq!(t.leaf_of[0], t.leaf_of[2]);
        assert_eq!(t.tree_dist(0, 2), 0.0);
        assert!(t.tree_dist(0, 3) > 0.0);
    }

    #[test]
    fn subtree_enumeration_covers_all_points_once() {
        let ps = small_set(13);
        let mut rng = Pcg64::seed_from(14);
        let t = ShiftTree::build(&ps, &mut rng);
        let mut seen = vec![0u32; ps.len()];
        t.for_each_point_in_subtree(0, NIL, &mut |p| seen[p as usize] += 1);
        assert!(seen.iter().all(|&c| c == 1), "each point exactly once");
        // Skipping a child subtree removes exactly its points.
        let leaf = t.leaf_of[0];
        let parent = t.nodes[leaf as usize].parent;
        let mut seen2 = Vec::new();
        t.for_each_point_in_subtree(parent, leaf, &mut |p| seen2.push(p));
        assert!(!seen2.contains(&0));
    }

    #[test]
    fn dist_at_height_monotone_decreasing() {
        let ps = small_set(15);
        let mut rng = Pcg64::seed_from(16);
        let t = ShiftTree::build(&ps, &mut rng);
        for h in 1..t.height {
            assert!(t.dist_at_height(h) <= t.dist_at_height(h - 1));
        }
        assert_eq!(t.dist_at_height(t.height), 0.0);
    }

    #[test]
    fn single_point_tree() {
        let ps = PointSet::from_rows(&[vec![3.0f32, 4.0]]);
        let mut rng = Pcg64::seed_from(17);
        let t = ShiftTree::build(&ps, &mut rng);
        assert_eq!(t.tree_dist(0, 0), 0.0);
        assert_ne!(t.leaf_of[0], NIL);
    }
}
