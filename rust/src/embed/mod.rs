//! Tree embeddings (paper §2–§4): the single random-shift grid tree and
//! the 3-tree *multi-tree* embedding with the `MultiTreeOpen` /
//! `MultiTreeSample` data structure.

pub mod multitree;
pub mod tree;

pub use multitree::{MultiTree, MultiTreeConfig};
pub use tree::ShiftTree;
