//! Stub PJRT runtime — compiled when the `pjrt` feature is **off** (the
//! default: the offline image cannot vendor the `xla` crate).
//!
//! The stub keeps the exact public surface of the real
//! `runtime/pjrt.rs` so callers (`Backend::auto`, the benches, the
//! integration tests) compile unchanged: `load` always fails with a
//! descriptive error, which makes every caller fall back to the native
//! backend, and the entry points delegate to [`crate::runtime::native`]
//! so they stay well-defined even if constructed by hand in the future.

use std::path::Path;

use crate::data::matrix::PointSet;
use crate::error::Result;
use crate::runtime::manifest::Manifest;
use crate::runtime::native;

pub use crate::runtime::padding::PAD_CENTER_COORD;

/// Placeholder for the PJRT CPU runtime (see module docs).
pub struct PjrtRuntime {
    manifest: Manifest,
}

impl PjrtRuntime {
    /// Always fails: the `pjrt` feature (and the `xla` crate behind it)
    /// is not enabled in this build.
    pub fn load(_artifacts_dir: &Path) -> Result<Self> {
        Err(crate::anyhow!(
            "PJRT backend unavailable: built without the `pjrt` feature \
             (vendor the `xla` crate, add it to [dependencies] in \
             Cargo.toml, then rebuild with --features pjrt)"
        ))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Native fallback (the stub can never hold compiled artifacts).
    pub fn cost(&self, ps: &PointSet, centers: &PointSet) -> Result<f64> {
        Ok(native::cost(ps, centers))
    }

    /// Native fallback.
    pub fn assign(&self, ps: &PointSet, centers: &PointSet) -> Result<(Vec<u32>, Vec<f32>)> {
        Ok(native::assign(ps, centers))
    }

    /// Native fallback.
    pub fn lloyd_step(
        &self,
        ps: &PointSet,
        centers: &PointSet,
    ) -> Result<(Vec<f64>, Vec<u64>, f64)> {
        Ok(native::lloyd_step(ps, centers))
    }

    /// Native fallback.
    pub fn d2_update(&self, ps: &PointSet, center: &[f32], cur_d2: &mut [f32]) -> Result<()> {
        crate::kernels::d2::d2_update_min(ps, center, cur_d2);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = PjrtRuntime::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    }
}
