//! PJRT execution of the AOT JAX/Pallas artifacts (**`pjrt` feature
//! only** — requires the vendored `xla` crate; the default build uses
//! the stub in `pjrt_stub.rs`).
//!
//! Load path (see /opt/xla-example and DESIGN.md): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::cpu().compile` → `execute`. Compilation is lazy per
//! shape variant and cached for the life of the runtime.
//!
//! The padding contract lives in [`crate::runtime::padding`] (shared
//! with the stub build so it stays unit-tested everywhere).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::anyhow;
use crate::error::Result;

use crate::data::matrix::PointSet;
use crate::runtime::manifest::{Manifest, Variant};
use crate::runtime::native;
use crate::runtime::padding::{pad_centers, pad_points, tail_points};

pub use crate::runtime::padding::PAD_CENTER_COORD;

/// A loaded PJRT CPU runtime over an artifacts directory.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Lazy executable cache keyed by artifact path.
    cache: RefCell<HashMap<PathBuf, xla::PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    /// Load the manifest and bring up the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for a variant, then
    /// run it on `literals`, returning the flattened output tuple.
    fn run(&self, variant: &Variant, literals: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        {
            let cache = self.cache.borrow();
            if let Some(exe) = cache.get(&variant.file) {
                return exec(exe, literals);
            }
        }
        let proto = xla::HloModuleProto::from_text_file(
            variant
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(|e| anyhow!("parse {:?}: {e:?}", variant.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {:?}: {e:?}", variant.file))?;
        let out = exec(&exe, literals)?;
        self.cache.borrow_mut().insert(variant.file.clone(), exe);
        Ok(out)
    }

    /// k-means cost via the `cost` artifact (tail natively).
    ///
    /// Shapes beyond the AOT variant grid (e.g. k > the largest compiled
    /// k) fall back to the native backend — identical contract.
    pub fn cost(&self, ps: &PointSet, centers: &PointSet) -> Result<f64> {
        let Some(variant) = self
            .manifest
            .select("cost", ps.len(), ps.dim(), centers.len())
            .cloned()
        else {
            return Ok(native::cost(ps, centers));
        };
        let centers_lit = xla::Literal::vec1(&pad_centers(centers, variant.k, variant.d))
            .reshape(&[variant.k as i64, variant.d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let mut total = 0.0f64;
        let mut buf = vec![0.0f32; variant.chunk * variant.d];
        let full_chunks = ps.len() / variant.chunk;
        for c in 0..full_chunks {
            pad_points(ps, c * variant.chunk, variant.chunk, variant.d, &mut buf);
            let pts = xla::Literal::vec1(&buf)
                .reshape(&[variant.chunk as i64, variant.d as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let out = self.run(&variant, &[pts, centers_lit.clone()])?;
            let v: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            total += v[0] as f64;
        }
        let tail_start = full_chunks * variant.chunk;
        if tail_start < ps.len() {
            total += native::cost(&tail_points(ps, tail_start), centers);
        }
        Ok(total)
    }

    /// Nearest-center assignment via the `assign` artifact (native
    /// fallback outside the variant grid).
    pub fn assign(&self, ps: &PointSet, centers: &PointSet) -> Result<(Vec<u32>, Vec<f32>)> {
        let Some(variant) = self
            .manifest
            .select("assign", ps.len(), ps.dim(), centers.len())
            .cloned()
        else {
            return Ok(native::assign(ps, centers));
        };
        let centers_lit = xla::Literal::vec1(&pad_centers(centers, variant.k, variant.d))
            .reshape(&[variant.k as i64, variant.d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let n = ps.len();
        let mut idx = Vec::with_capacity(n);
        let mut mind2 = Vec::with_capacity(n);
        let mut buf = vec![0.0f32; variant.chunk * variant.d];
        let full_chunks = n / variant.chunk;
        for c in 0..full_chunks {
            pad_points(ps, c * variant.chunk, variant.chunk, variant.d, &mut buf);
            let pts = xla::Literal::vec1(&buf)
                .reshape(&[variant.chunk as i64, variant.d as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let out = self.run(&variant, &[pts, centers_lit.clone()])?;
            let ids: Vec<i32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let dd: Vec<f32> = out[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            idx.extend(ids.into_iter().map(|i| i as u32));
            mind2.extend(dd);
        }
        let tail_start = full_chunks * variant.chunk;
        if tail_start < n {
            let (ti, td) = native::assign(&tail_points(ps, tail_start), centers);
            idx.extend(ti);
            mind2.extend(td);
        }
        Ok((idx, mind2))
    }

    /// One Lloyd step via the `lloyd_step` artifact: `(sums k*d, counts, cost)`.
    pub fn lloyd_step(
        &self,
        ps: &PointSet,
        centers: &PointSet,
    ) -> Result<(Vec<f64>, Vec<u64>, f64)> {
        let Some(variant) = self
            .manifest
            .select("lloyd_step", ps.len(), ps.dim(), centers.len())
            .cloned()
        else {
            return Ok(native::lloyd_step(ps, centers));
        };
        let k = centers.len();
        let d = ps.dim();
        let centers_lit = xla::Literal::vec1(&pad_centers(centers, variant.k, variant.d))
            .reshape(&[variant.k as i64, variant.d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0u64; k];
        let mut cost = 0.0f64;
        let mut buf = vec![0.0f32; variant.chunk * variant.d];
        let full_chunks = ps.len() / variant.chunk;
        for c in 0..full_chunks {
            pad_points(ps, c * variant.chunk, variant.chunk, variant.d, &mut buf);
            let pts = xla::Literal::vec1(&buf)
                .reshape(&[variant.chunk as i64, variant.d as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let out = self.run(&variant, &[pts, centers_lit.clone()])?;
            let s: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let cnt: Vec<f32> = out[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let co: Vec<f32> = out[2].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            for j in 0..k {
                for t in 0..d {
                    sums[j * d + t] += s[j * variant.d + t] as f64;
                }
                counts[j] += cnt[j] as u64;
            }
            cost += co[0] as f64;
        }
        let tail_start = full_chunks * variant.chunk;
        if tail_start < ps.len() {
            let (ts, tc, tcost) = native::lloyd_step(&tail_points(ps, tail_start), centers);
            for (a, b) in sums.iter_mut().zip(&ts) {
                *a += b;
            }
            for (a, b) in counts.iter_mut().zip(&tc) {
                *a += b;
            }
            cost += tcost;
        }
        Ok((sums, counts, cost))
    }

    /// k-means++ distance min-update via the `d2_update` artifact.
    pub fn d2_update(&self, ps: &PointSet, center: &[f32], cur_d2: &mut [f32]) -> Result<()> {
        assert_eq!(center.len(), ps.dim());
        assert_eq!(cur_d2.len(), ps.len());
        let Some(variant) = self
            .manifest
            .select("d2_update", ps.len(), ps.dim(), 0)
            .cloned()
        else {
            crate::kernels::d2::d2_update_min(ps, center, cur_d2);
            return Ok(());
        };
        let mut c_buf = vec![0.0f32; variant.d];
        c_buf[..center.len()].copy_from_slice(center);
        let center_lit = xla::Literal::vec1(&c_buf)
            .reshape(&[1, variant.d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let mut buf = vec![0.0f32; variant.chunk * variant.d];
        let full_chunks = ps.len() / variant.chunk;
        for c in 0..full_chunks {
            let start = c * variant.chunk;
            pad_points(ps, start, variant.chunk, variant.d, &mut buf);
            let pts = xla::Literal::vec1(&buf)
                .reshape(&[variant.chunk as i64, variant.d as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let cur = xla::Literal::vec1(&cur_d2[start..start + variant.chunk]);
            let out = self.run(&variant, &[pts, center_lit.clone(), cur])?;
            let updated: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            cur_d2[start..start + variant.chunk].copy_from_slice(&updated);
        }
        let tail_start = full_chunks * variant.chunk;
        for i in tail_start..ps.len() {
            let dd = crate::data::matrix::d2(ps.row(i), center);
            if dd < cur_d2[i] {
                cur_d2[i] = dd;
            }
        }
        Ok(())
    }
}

/// Execute and flatten the 1-tuple-of-outputs convention from aot.py
/// (`return_tuple=True`).
fn exec(exe: &xla::PjRtLoadedExecutable, literals: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<xla::Literal>(literals)
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    lit.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))
}
