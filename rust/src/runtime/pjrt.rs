//! PJRT execution of the AOT JAX/Pallas artifacts.
//!
//! Load path (see /opt/xla-example and DESIGN.md): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::cpu().compile` → `execute`. Compilation is lazy per
//! shape variant and cached for the life of the runtime.
//!
//! Padding contract (mirrors `python/compile/model.py`):
//! * point dims zero-padded to the variant's `d` (adds 0 to distances);
//! * center rows padded with `PAD_CENTER_COORD` (never argmin-selected,
//!   attract no Lloyd mass);
//! * only *full* chunks go through PJRT; the tail chunk runs on the
//!   native backend (identical contract, negligible work).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::data::matrix::PointSet;
use crate::runtime::manifest::{Manifest, Variant};
use crate::runtime::native;

/// Sentinel coordinate for padded center rows (see model.py).
pub const PAD_CENTER_COORD: f32 = 1.0e15;

/// A loaded PJRT CPU runtime over an artifacts directory.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Lazy executable cache keyed by artifact path.
    cache: RefCell<HashMap<PathBuf, xla::PjRtLoadedExecutable>>,
}

impl PjrtRuntime {
    /// Load the manifest and bring up the PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(PjrtRuntime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) the executable for a variant, then
    /// run it on `literals`, returning the flattened output tuple.
    fn run(&self, variant: &Variant, literals: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        {
            let cache = self.cache.borrow();
            if let Some(exe) = cache.get(&variant.file) {
                return exec(exe, literals);
            }
        }
        let proto = xla::HloModuleProto::from_text_file(
            variant
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .map_err(|e| anyhow!("parse {:?}: {e:?}", variant.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {:?}: {e:?}", variant.file))?;
        let out = exec(&exe, literals)?;
        self.cache.borrow_mut().insert(variant.file.clone(), exe);
        Ok(out)
    }

    /// Pack `centers` into a `[k_v, d_v]` buffer per the padding contract.
    fn pad_centers(centers: &PointSet, k_v: usize, d_v: usize) -> Vec<f32> {
        let mut buf = vec![0.0f32; k_v * d_v];
        for j in 0..centers.len() {
            buf[j * d_v..j * d_v + centers.dim()].copy_from_slice(centers.row(j));
        }
        for j in centers.len()..k_v {
            for v in buf[j * d_v..(j + 1) * d_v].iter_mut() {
                *v = PAD_CENTER_COORD;
            }
        }
        buf
    }

    /// Pack points `[start, start+chunk)` into a `[chunk, d_v]` buffer.
    fn pad_points(ps: &PointSet, start: usize, chunk: usize, d_v: usize, buf: &mut [f32]) {
        debug_assert_eq!(buf.len(), chunk * d_v);
        let d = ps.dim();
        if d == d_v {
            buf.copy_from_slice(&ps.flat()[start * d..(start + chunk) * d]);
        } else {
            buf.fill(0.0);
            for i in 0..chunk {
                buf[i * d_v..i * d_v + d].copy_from_slice(ps.row(start + i));
            }
        }
    }

    fn tail_points(ps: &PointSet, start: usize) -> PointSet {
        let d = ps.dim();
        PointSet::from_flat(
            ps.len() - start,
            d,
            ps.flat()[start * d..].to_vec(),
        )
    }

    /// k-means cost via the `cost` artifact (tail natively).
    ///
    /// Shapes beyond the AOT variant grid (e.g. k > the largest compiled
    /// k) fall back to the native backend — identical contract.
    pub fn cost(&self, ps: &PointSet, centers: &PointSet) -> Result<f64> {
        let Some(variant) = self
            .manifest
            .select("cost", ps.len(), ps.dim(), centers.len())
            .cloned()
        else {
            return Ok(native::cost(ps, centers));
        };
        let centers_lit = xla::Literal::vec1(&Self::pad_centers(centers, variant.k, variant.d))
            .reshape(&[variant.k as i64, variant.d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let mut total = 0.0f64;
        let mut buf = vec![0.0f32; variant.chunk * variant.d];
        let full_chunks = ps.len() / variant.chunk;
        for c in 0..full_chunks {
            Self::pad_points(ps, c * variant.chunk, variant.chunk, variant.d, &mut buf);
            let pts = xla::Literal::vec1(&buf)
                .reshape(&[variant.chunk as i64, variant.d as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let out = self.run(&variant, &[pts, centers_lit.clone()])?;
            let v: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            total += v[0] as f64;
        }
        let tail_start = full_chunks * variant.chunk;
        if tail_start < ps.len() {
            total += native::cost(&Self::tail_points(ps, tail_start), centers);
        }
        Ok(total)
    }

    /// Nearest-center assignment via the `assign` artifact (native
    /// fallback outside the variant grid).
    pub fn assign(&self, ps: &PointSet, centers: &PointSet) -> Result<(Vec<u32>, Vec<f32>)> {
        let Some(variant) = self
            .manifest
            .select("assign", ps.len(), ps.dim(), centers.len())
            .cloned()
        else {
            return Ok(native::assign(ps, centers));
        };
        let centers_lit = xla::Literal::vec1(&Self::pad_centers(centers, variant.k, variant.d))
            .reshape(&[variant.k as i64, variant.d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let n = ps.len();
        let mut idx = Vec::with_capacity(n);
        let mut mind2 = Vec::with_capacity(n);
        let mut buf = vec![0.0f32; variant.chunk * variant.d];
        let full_chunks = n / variant.chunk;
        for c in 0..full_chunks {
            Self::pad_points(ps, c * variant.chunk, variant.chunk, variant.d, &mut buf);
            let pts = xla::Literal::vec1(&buf)
                .reshape(&[variant.chunk as i64, variant.d as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let out = self.run(&variant, &[pts, centers_lit.clone()])?;
            let ids: Vec<i32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let dd: Vec<f32> = out[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            idx.extend(ids.into_iter().map(|i| i as u32));
            mind2.extend(dd);
        }
        let tail_start = full_chunks * variant.chunk;
        if tail_start < n {
            let (ti, td) = native::assign(&Self::tail_points(ps, tail_start), centers);
            idx.extend(ti);
            mind2.extend(td);
        }
        Ok((idx, mind2))
    }

    /// One Lloyd step via the `lloyd_step` artifact: `(sums k*d, counts, cost)`.
    pub fn lloyd_step(
        &self,
        ps: &PointSet,
        centers: &PointSet,
    ) -> Result<(Vec<f64>, Vec<u64>, f64)> {
        let Some(variant) = self
            .manifest
            .select("lloyd_step", ps.len(), ps.dim(), centers.len())
            .cloned()
        else {
            return Ok(native::lloyd_step(ps, centers));
        };
        let k = centers.len();
        let d = ps.dim();
        let centers_lit = xla::Literal::vec1(&Self::pad_centers(centers, variant.k, variant.d))
            .reshape(&[variant.k as i64, variant.d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0u64; k];
        let mut cost = 0.0f64;
        let mut buf = vec![0.0f32; variant.chunk * variant.d];
        let full_chunks = ps.len() / variant.chunk;
        for c in 0..full_chunks {
            Self::pad_points(ps, c * variant.chunk, variant.chunk, variant.d, &mut buf);
            let pts = xla::Literal::vec1(&buf)
                .reshape(&[variant.chunk as i64, variant.d as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let out = self.run(&variant, &[pts, centers_lit.clone()])?;
            let s: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let cnt: Vec<f32> = out[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let co: Vec<f32> = out[2].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            for j in 0..k {
                for t in 0..d {
                    sums[j * d + t] += s[j * variant.d + t] as f64;
                }
                counts[j] += cnt[j] as u64;
            }
            cost += co[0] as f64;
        }
        let tail_start = full_chunks * variant.chunk;
        if tail_start < ps.len() {
            let (ts, tc, tcost) =
                native::lloyd_step(&Self::tail_points(ps, tail_start), centers);
            for (a, b) in sums.iter_mut().zip(&ts) {
                *a += b;
            }
            for (a, b) in counts.iter_mut().zip(&tc) {
                *a += b;
            }
            cost += tcost;
        }
        Ok((sums, counts, cost))
    }

    /// k-means++ distance min-update via the `d2_update` artifact.
    pub fn d2_update(&self, ps: &PointSet, center: &[f32], cur_d2: &mut [f32]) -> Result<()> {
        assert_eq!(center.len(), ps.dim());
        assert_eq!(cur_d2.len(), ps.len());
        let Some(variant) = self
            .manifest
            .select("d2_update", ps.len(), ps.dim(), 0)
            .cloned()
        else {
            crate::seeding::kmeanspp::update_d2_parallel_to(ps, center, cur_d2);
            return Ok(());
        };
        let mut c_buf = vec![0.0f32; variant.d];
        c_buf[..center.len()].copy_from_slice(center);
        let center_lit = xla::Literal::vec1(&c_buf)
            .reshape(&[1, variant.d as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let mut buf = vec![0.0f32; variant.chunk * variant.d];
        let full_chunks = ps.len() / variant.chunk;
        for c in 0..full_chunks {
            let start = c * variant.chunk;
            Self::pad_points(ps, start, variant.chunk, variant.d, &mut buf);
            let pts = xla::Literal::vec1(&buf)
                .reshape(&[variant.chunk as i64, variant.d as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let cur = xla::Literal::vec1(&cur_d2[start..start + variant.chunk]);
            let out = self.run(&variant, &[pts, center_lit.clone(), cur])?;
            let updated: Vec<f32> = out[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            cur_d2[start..start + variant.chunk].copy_from_slice(&updated);
        }
        let tail_start = full_chunks * variant.chunk;
        for i in tail_start..ps.len() {
            let dd = crate::data::matrix::d2(ps.row(i), center);
            if dd < cur_d2[i] {
                cur_d2[i] = dd;
            }
        }
        Ok(())
    }
}

/// Execute and flatten the 1-tuple-of-outputs convention from aot.py
/// (`return_tuple=True`).
fn exec(exe: &xla::PjRtLoadedExecutable, literals: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
    let result = exe
        .execute::<xla::Literal>(literals)
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    lit.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))
}

#[cfg(test)]
mod tests {
    //! Unit tests needing compiled artifacts are in
    //! `rust/tests/pjrt_integration.rs` (they skip gracefully when
    //! `artifacts/` is absent). Here: padding logic only.
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    #[test]
    fn pad_centers_layout() {
        let cs = PointSet::from_rows(&[vec![1.0f32, 2.0], vec![3.0, 4.0]]);
        let buf = PjrtRuntime::pad_centers(&cs, 4, 3);
        assert_eq!(&buf[0..3], &[1.0, 2.0, 0.0]);
        assert_eq!(&buf[3..6], &[3.0, 4.0, 0.0]);
        assert!(buf[6..].iter().all(|&v| v == PAD_CENTER_COORD));
    }

    #[test]
    fn pad_points_fast_path_and_padded_path() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 10,
                d: 4,
                k_true: 2,
                ..Default::default()
            },
            1,
        );
        let mut buf = vec![9.0f32; 2 * 4];
        PjrtRuntime::pad_points(&ps, 3, 2, 4, &mut buf);
        assert_eq!(&buf[0..4], ps.row(3));
        assert_eq!(&buf[4..8], ps.row(4));
        let mut buf6 = vec![9.0f32; 2 * 6];
        PjrtRuntime::pad_points(&ps, 3, 2, 6, &mut buf6);
        assert_eq!(&buf6[0..4], ps.row(3));
        assert_eq!(&buf6[4..6], &[0.0, 0.0]);
        assert_eq!(&buf6[6..10], ps.row(4));
    }

    #[test]
    fn tail_points_slices() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 7,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            2,
        );
        let tail = PjrtRuntime::tail_points(&ps, 5);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail.row(0), ps.row(5));
        assert_eq!(tail.row(1), ps.row(6));
    }
}
