//! `artifacts/manifest.tsv` parsing + shape-variant selection.
//!
//! Format (written by `python/compile/aot.py`):
//!
//! ```text
//! # entry\tfile\tchunk\td\tk
//! assign\tassign_n16384_d96_k1024.hlo.txt\t16384\t96\t1024
//! ```
//!
//! PJRT executables are shape-specialized; `select` picks, for a request
//! `(entry, n, d, k)`, the variant with the smallest `d_v >= d` and
//! `k_v >= k`, preferring the large streaming chunk when `n` fills it.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::error::{Context, Result};

/// One AOT-compiled HLO module.
#[derive(Clone, Debug, PartialEq)]
pub struct Variant {
    pub entry: String,
    pub file: PathBuf,
    pub chunk: usize,
    pub d: usize,
    /// 0 for k-independent entries (d2_update).
    pub k: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub variants: Vec<Variant>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {path:?} — run `make artifacts`"))?;
        let mut variants = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 5 {
                bail!("{path:?}:{}: expected 5 columns", lineno + 1);
            }
            variants.push(Variant {
                entry: cols[0].to_string(),
                file: dir.join(cols[1]),
                chunk: cols[2].parse().context("chunk")?,
                d: cols[3].parse().context("d")?,
                k: cols[4].parse().context("k")?,
            });
        }
        if variants.is_empty() {
            bail!("{path:?}: no variants");
        }
        Ok(Manifest { variants })
    }

    /// Pick the best variant for `(entry, n, d, k)`; `k = 0` means the
    /// entry is k-independent.
    pub fn select(&self, entry: &str, n: usize, d: usize, k: usize) -> Option<&Variant> {
        let feasible = self
            .variants
            .iter()
            .filter(|v| v.entry == entry && v.d >= d && v.k >= k);
        // Prefer: smallest (d_v, k_v) waste; among those, the largest
        // chunk not bigger than n (falling back to the smallest chunk).
        let mut best: Option<&Variant> = None;
        for v in feasible {
            let better = match best {
                None => true,
                Some(b) => {
                    let key_v = (v.d, v.k);
                    let key_b = (b.d, b.k);
                    if key_v != key_b {
                        key_v < key_b
                    } else {
                        // Same padding waste: prefer chunk fitting n.
                        let fit = |c: usize| {
                            if c <= n.max(1) {
                                (0usize, usize::MAX - c) // larger fitting chunk wins
                            } else {
                                (1usize, c) // otherwise smallest chunk
                            }
                        };
                        fit(v.chunk) < fit(b.chunk)
                    }
                }
            };
            if better {
                best = Some(v);
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        let mk = |entry: &str, chunk: usize, d: usize, k: usize| Variant {
            entry: entry.to_string(),
            file: PathBuf::from(format!("{entry}_{chunk}_{d}_{k}")),
            chunk,
            d,
            k,
        };
        Manifest {
            variants: vec![
                mk("assign", 2048, 32, 128),
                mk("assign", 2048, 96, 128),
                mk("assign", 16384, 96, 128),
                mk("assign", 16384, 96, 1024),
                mk("d2_update", 2048, 96, 0),
                mk("d2_update", 16384, 96, 0),
            ],
        }
    }

    #[test]
    fn selects_tightest_dims() {
        let m = manifest();
        let v = m.select("assign", 100_000, 74, 100).unwrap();
        assert_eq!((v.d, v.k, v.chunk), (96, 128, 16384));
        let v = m.select("assign", 100_000, 74, 500).unwrap();
        assert_eq!((v.d, v.k), (96, 1024));
        let v = m.select("assign", 1_000, 20, 64).unwrap();
        assert_eq!((v.d, v.k, v.chunk), (32, 128, 2048));
    }

    #[test]
    fn k_independent_entry() {
        let m = manifest();
        let v = m.select("d2_update", 50_000, 74, 0).unwrap();
        assert_eq!(v.chunk, 16384);
        let v = m.select("d2_update", 1_000, 74, 0).unwrap();
        assert_eq!(v.chunk, 2048);
    }

    #[test]
    fn infeasible_returns_none() {
        let m = manifest();
        assert!(m.select("assign", 1000, 200, 10).is_none());
        assert!(m.select("assign", 1000, 10, 5000).is_none());
        assert!(m.select("nope", 1000, 10, 10).is_none());
    }

    #[test]
    fn load_roundtrip() {
        let dir = std::env::temp_dir().join("fkmpp_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.tsv"),
            "# entry\tfile\tchunk\td\tk\nassign\ta.hlo.txt\t2048\t32\t128\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.variants.len(), 1);
        assert_eq!(m.variants[0].entry, "assign");
        assert_eq!(m.variants[0].file, dir.join("a.hlo.txt"));
    }

    #[test]
    fn load_missing_fails() {
        let dir = std::env::temp_dir().join("fkmpp_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
