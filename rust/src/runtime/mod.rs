//! Execution backends for the dense distance algebra.
//!
//! * [`native`] — tuned pure-rust implementations delegating to the
//!   parallel kernel engine ([`crate::kernels`]). Always available; also
//!   the tail-chunk handler for PJRT.
//! * [`pjrt`] — loads the AOT-compiled JAX/Pallas HLO artifacts
//!   (`artifacts/*.hlo.txt`, built once by `make artifacts`) and runs them
//!   on the PJRT CPU client via the `xla` crate. Python never runs here.
//!   Compiled only with the **`pjrt` feature** (which needs the vendored
//!   `xla` crate); the default build substitutes a stub whose `load`
//!   always fails, so `Backend::auto` falls back to native.
//! * [`padding`] — the shape-padding contract shared by both PJRT paths.
//! * [`manifest`] — the `artifacts/manifest.tsv` parser and shape-variant
//!   selection logic.
//!
//! [`Backend`] is the dispatch point the coordinator and Lloyd use.

pub mod manifest;
pub mod native;
pub mod padding;
#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

use crate::data::matrix::PointSet;
use crate::error::Result;

/// Compute backend selector.
pub enum Backend {
    Native,
    Pjrt(pjrt::PjrtRuntime),
}

impl Backend {
    /// Load the PJRT backend if artifacts exist, else native.
    pub fn auto(artifacts_dir: &std::path::Path) -> Backend {
        match pjrt::PjrtRuntime::load(artifacts_dir) {
            Ok(rt) => Backend::Pjrt(rt),
            Err(_) => Backend::Native,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// Nearest-center assignment: `(index, min squared distance)` per point.
    pub fn assign(&self, ps: &PointSet, centers: &PointSet) -> Result<(Vec<u32>, Vec<f32>)> {
        match self {
            Backend::Native => Ok(native::assign(ps, centers)),
            Backend::Pjrt(rt) => rt.assign(ps, centers),
        }
    }

    /// [`Backend::assign`] with a precomputed point-norm cache
    /// ([`crate::kernels::norms::squared_norms`] of `ps`). The native
    /// path hands it to the autotuned v2 kernels; PJRT artifacts have no
    /// norm-cache contract and ignore it.
    pub fn assign_cached(
        &self,
        ps: &PointSet,
        point_norms: &[f32],
        centers: &PointSet,
    ) -> Result<(Vec<u32>, Vec<f32>)> {
        match self {
            Backend::Native => Ok(native::assign_cached(ps, point_norms, centers)),
            Backend::Pjrt(rt) => rt.assign(ps, centers),
        }
    }

    /// k-means objective under `centers`.
    pub fn cost(&self, ps: &PointSet, centers: &PointSet) -> Result<f64> {
        match self {
            Backend::Native => Ok(native::cost(ps, centers)),
            Backend::Pjrt(rt) => rt.cost(ps, centers),
        }
    }

    /// [`Backend::cost`] with a precomputed point-norm cache (see
    /// [`Backend::assign_cached`] for the PJRT caveat).
    pub fn cost_cached(
        &self,
        ps: &PointSet,
        point_norms: &[f32],
        centers: &PointSet,
    ) -> Result<f64> {
        match self {
            Backend::Native => Ok(native::cost_cached(ps, point_norms, centers)),
            Backend::Pjrt(rt) => rt.cost(ps, centers),
        }
    }

    /// One Lloyd step: per-cluster coordinate sums, counts, and the cost
    /// under the *input* centers.
    pub fn lloyd_step(
        &self,
        ps: &PointSet,
        centers: &PointSet,
    ) -> Result<(Vec<f64>, Vec<u64>, f64)> {
        match self {
            Backend::Native => Ok(native::lloyd_step(ps, centers)),
            Backend::Pjrt(rt) => rt.lloyd_step(ps, centers),
        }
    }

    /// [`Backend::lloyd_step`] with a precomputed point-norm cache (see
    /// [`Backend::assign_cached`] for the PJRT caveat).
    pub fn lloyd_step_cached(
        &self,
        ps: &PointSet,
        point_norms: &[f32],
        centers: &PointSet,
    ) -> Result<(Vec<f64>, Vec<u64>, f64)> {
        match self {
            Backend::Native => Ok(native::lloyd_step_cached(ps, point_norms, centers)),
            Backend::Pjrt(rt) => rt.lloyd_step(ps, centers),
        }
    }
}
