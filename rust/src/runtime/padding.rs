//! Shape-padding contract for the AOT PJRT artifacts (mirrors
//! `python/compile/model.py`), shared by the real `pjrt` runtime and
//! kept compiled (and unit-tested) in the default build:
//!
//! * point dims zero-padded to the variant's `d` (adds 0 to distances);
//! * center rows padded with [`PAD_CENTER_COORD`] (never argmin-selected,
//!   attract no Lloyd mass);
//! * only *full* chunks go through PJRT; the tail chunk runs on the
//!   native backend (identical contract, negligible work).

use crate::data::matrix::PointSet;

/// Sentinel coordinate for padded center rows (see model.py).
pub const PAD_CENTER_COORD: f32 = 1.0e15;

/// Pack `centers` into a `[k_v, d_v]` buffer per the padding contract.
pub fn pad_centers(centers: &PointSet, k_v: usize, d_v: usize) -> Vec<f32> {
    let mut buf = vec![0.0f32; k_v * d_v];
    for j in 0..centers.len() {
        buf[j * d_v..j * d_v + centers.dim()].copy_from_slice(centers.row(j));
    }
    for j in centers.len()..k_v {
        for v in buf[j * d_v..(j + 1) * d_v].iter_mut() {
            *v = PAD_CENTER_COORD;
        }
    }
    buf
}

/// Pack points `[start, start+chunk)` into a `[chunk, d_v]` buffer.
pub fn pad_points(ps: &PointSet, start: usize, chunk: usize, d_v: usize, buf: &mut [f32]) {
    debug_assert_eq!(buf.len(), chunk * d_v);
    let d = ps.dim();
    if d == d_v {
        buf.copy_from_slice(&ps.flat()[start * d..(start + chunk) * d]);
    } else {
        buf.fill(0.0);
        for i in 0..chunk {
            buf[i * d_v..i * d_v + d].copy_from_slice(ps.row(start + i));
        }
    }
}

/// The tail slice `[start, n)` as an owned `PointSet` (handled natively).
pub fn tail_points(ps: &PointSet, start: usize) -> PointSet {
    let d = ps.dim();
    PointSet::from_flat(ps.len() - start, d, ps.flat()[start * d..].to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    #[test]
    fn pad_centers_layout() {
        let cs = PointSet::from_rows(&[vec![1.0f32, 2.0], vec![3.0, 4.0]]);
        let buf = pad_centers(&cs, 4, 3);
        assert_eq!(&buf[0..3], &[1.0, 2.0, 0.0]);
        assert_eq!(&buf[3..6], &[3.0, 4.0, 0.0]);
        assert!(buf[6..].iter().all(|&v| v == PAD_CENTER_COORD));
    }

    #[test]
    fn pad_points_fast_path_and_padded_path() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 10,
                d: 4,
                k_true: 2,
                ..Default::default()
            },
            1,
        );
        let mut buf = vec![9.0f32; 2 * 4];
        pad_points(&ps, 3, 2, 4, &mut buf);
        assert_eq!(&buf[0..4], ps.row(3));
        assert_eq!(&buf[4..8], ps.row(4));
        let mut buf6 = vec![9.0f32; 2 * 6];
        pad_points(&ps, 3, 2, 6, &mut buf6);
        assert_eq!(&buf6[0..4], ps.row(3));
        assert_eq!(&buf6[4..6], &[0.0, 0.0]);
        assert_eq!(&buf6[6..10], ps.row(4));
    }

    #[test]
    fn tail_points_slices() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 7,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            2,
        );
        let tail = tail_points(&ps, 5);
        assert_eq!(tail.len(), 2);
        assert_eq!(tail.row(0), ps.row(5));
        assert_eq!(tail.row(1), ps.row(6));
    }
}
