//! Native (pure-rust) implementations of the dense entry points — the
//! same contracts as the AOT JAX/Pallas artifacts, used as the default
//! backend, the PJRT tail-chunk handler, and the cross-check oracle in
//! the `runtime_pjrt_matches_native` integration test.
//!
//! Assignment and cost delegate to the shared parallel kernel engine
//! ([`crate::kernels`], autotuned v1/v2 since the kernels-v2 rework);
//! `lloyd_step` keeps its fused fold here (its per-cluster accumulators
//! are backend-contract specific) but routes its inner distance loop
//! through [`crate::kernels::assign::nearest_center`]. The `*_cached`
//! variants accept the caller's point-norm cache (one `O(nd)` pass per
//! Lloyd run, reused by every iteration) so the v2 kernels skip their
//! norm pass.

use crate::data::matrix::PointSet;
use crate::kernels::assign::nearest_center;
use crate::kernels::reduce;
use crate::parallel::parallel_reduce;

/// Nearest center per point: `(argmin index, min squared distance)`.
pub fn assign(ps: &PointSet, centers: &PointSet) -> (Vec<u32>, Vec<f32>) {
    crate::kernels::assign::assign_argmin(ps, centers)
}

/// Empty slice = "no cache" (the Backend convention — PJRT callers pass
/// `&[]`): map it to `None` so the kernels compute norms themselves
/// instead of asserting on the length.
fn cache_of(point_norms: &[f32]) -> Option<&[f32]> {
    (!point_norms.is_empty()).then_some(point_norms)
}

/// [`assign`] with a precomputed point-norm cache.
pub fn assign_cached(
    ps: &PointSet,
    point_norms: &[f32],
    centers: &PointSet,
) -> (Vec<u32>, Vec<f32>) {
    crate::kernels::assign::assign_argmin_cached(ps, cache_of(point_norms), centers, None)
}

/// k-means cost (sum over points of the min squared distance).
pub fn cost(ps: &PointSet, centers: &PointSet) -> f64 {
    reduce::cost(ps, centers)
}

/// [`cost`] with a precomputed point-norm cache.
pub fn cost_cached(ps: &PointSet, point_norms: &[f32], centers: &PointSet) -> f64 {
    reduce::cost_cached(ps, cache_of(point_norms), centers, None)
}

/// [`lloyd_step`] with a precomputed point-norm cache: the assignment
/// runs through the autotuned kernel engine (v2 blocked when it wins),
/// then a second `O(nd)` pass folds the per-cluster sums/counts from the
/// label array. At `k ≥ 8` the assignment pass dominates, so the extra
/// pass costs a few percent and the blocked argmin pays for it severalfold.
pub fn lloyd_step_cached(
    ps: &PointSet,
    point_norms: &[f32],
    centers: &PointSet,
) -> (Vec<f64>, Vec<u64>, f64) {
    assert_eq!(ps.dim(), centers.dim());
    assert!(!centers.is_empty());
    let k = centers.len();
    let d = ps.dim();
    let (idx, mind2) = assign_cached(ps, point_norms, centers);
    parallel_reduce(
        ps.len(),
        2048,
        (vec![0.0f64; k * d], vec![0u64; k], 0.0f64),
        |range| {
            let mut sums = vec![0.0f64; k * d];
            let mut counts = vec![0u64; k];
            let mut cost = 0.0f64;
            for i in range {
                let j = idx[i] as usize;
                cost += mind2[i] as f64;
                counts[j] += 1;
                let s = &mut sums[j * d..(j + 1) * d];
                for (acc, &v) in s.iter_mut().zip(ps.row(i)) {
                    *acc += v as f64;
                }
            }
            (sums, counts, cost)
        },
        |(mut sa, mut ca, costa), (sb, cb, costb)| {
            for (a, b) in sa.iter_mut().zip(&sb) {
                *a += b;
            }
            for (a, b) in ca.iter_mut().zip(&cb) {
                *a += b;
            }
            (sa, ca, costa + costb)
        },
    )
}

/// One Lloyd step over the whole set: per-cluster coordinate sums (f64,
/// `k*d` row-major), member counts, and the cost under the input centers.
pub fn lloyd_step(ps: &PointSet, centers: &PointSet) -> (Vec<f64>, Vec<u64>, f64) {
    assert_eq!(ps.dim(), centers.dim());
    assert!(!centers.is_empty());
    let k = centers.len();
    let d = ps.dim();
    parallel_reduce(
        ps.len(),
        2048,
        (vec![0.0f64; k * d], vec![0u64; k], 0.0f64),
        |range| {
            let mut sums = vec![0.0f64; k * d];
            let mut counts = vec![0u64; k];
            let mut cost = 0.0f64;
            for i in range {
                let row = ps.row(i);
                let (best_j, best) = nearest_center(row, centers);
                let best_j = best_j as usize;
                cost += best as f64;
                counts[best_j] += 1;
                let s = &mut sums[best_j * d..(best_j + 1) * d];
                for (acc, &v) in s.iter_mut().zip(row) {
                    *acc += v as f64;
                }
            }
            (sums, counts, cost)
        },
        |(mut sa, mut ca, costa), (sb, cb, costb)| {
            for (a, b) in sa.iter_mut().zip(&sb) {
                *a += b;
            }
            for (a, b) in ca.iter_mut().zip(&cb) {
                *a += b;
            }
            (sa, ca, costa + costb)
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::d2;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn case() -> (PointSet, PointSet) {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 5000,
                d: 9,
                k_true: 6,
                ..Default::default()
            },
            1,
        );
        let centers = ps.gather(&[0, 100, 2000, 4999]);
        (ps, centers)
    }

    #[test]
    fn assign_matches_bruteforce() {
        let (ps, cs) = case();
        let (idx, mind2) = assign(&ps, &cs);
        for i in (0..ps.len()).step_by(333) {
            let (bj, bd) = (0..cs.len())
                .map(|j| (j, d2(ps.row(i), cs.row(j))))
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            assert_eq!(idx[i] as usize, bj, "i={i}");
            assert!((mind2[i] - bd).abs() <= 1e-6 * bd.max(1.0));
        }
    }

    #[test]
    fn cost_equals_sum_of_assignment() {
        let (ps, cs) = case();
        let (_, mind2) = assign(&ps, &cs);
        let want: f64 = mind2.iter().map(|&x| x as f64).sum();
        let got = cost(&ps, &cs);
        assert!((got - want).abs() <= 1e-6 * want);
    }

    #[test]
    fn cost_zero_when_centers_cover_points() {
        let ps = PointSet::from_rows(&[vec![1.0f32, 2.0], vec![3.0, 4.0]]);
        assert_eq!(cost(&ps, &ps), 0.0);
    }

    #[test]
    fn lloyd_step_conserves_mass() {
        let (ps, cs) = case();
        let (sums, counts, c) = lloyd_step(&ps, &cs);
        assert_eq!(counts.iter().sum::<u64>(), ps.len() as u64);
        // Sum of per-cluster sums = global coordinate sum.
        let d = ps.dim();
        for j in 0..d {
            let global: f64 = (0..ps.len()).map(|i| ps.row(i)[j] as f64).sum();
            let parts: f64 = (0..cs.len()).map(|q| sums[q * d + j]).sum();
            assert!((global - parts).abs() < 1e-3 * global.abs().max(1.0));
        }
        assert!((c - cost(&ps, &cs)).abs() <= 1e-6 * c);
    }

    #[test]
    fn lloyd_step_cached_matches_fused() {
        let (ps, cs) = case();
        let pn = crate::kernels::norms::squared_norms(&ps);
        let (sums_a, counts_a, cost_a) = lloyd_step(&ps, &cs);
        let (sums_b, counts_b, cost_b) = lloyd_step_cached(&ps, &pn, &cs);
        assert_eq!(counts_a, counts_b);
        for (a, b) in sums_a.iter().zip(&sums_b) {
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1.0), "{a} vs {b}");
        }
        assert!((cost_a - cost_b).abs() <= 1e-6 * cost_a.max(1.0));
    }

    #[test]
    fn single_center_everything_assigned_to_it() {
        let (ps, _) = case();
        let one = ps.gather(&[42]);
        let (idx, _) = assign(&ps, &one);
        assert!(idx.iter().all(|&i| i == 0));
        let (_, counts, _) = lloyd_step(&ps, &one);
        assert_eq!(counts, vec![ps.len() as u64]);
    }
}
