//! Named-dataset registry: maps the CLI/bench `--dataset` names to
//! generators + size profiles, and caches materialized datasets on disk
//! (`data/*.fbin`) so repeated bench runs skip generation.

use std::path::{Path, PathBuf};

use crate::bail;
use crate::data::io::{read_fbin, write_fbin};
use crate::data::matrix::PointSet;
use crate::data::synth;
use crate::error::Result;

/// Size profile: the paper's full n, or a scaled n that fits a laptop-
/// class time budget (DESIGN.md §2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Profile {
    /// Full paper-scale n.
    Paper,
    /// Scaled-down n (default for benches in this session).
    Scaled,
    /// Tiny — integration tests and smoke runs.
    Smoke,
}

impl Profile {
    pub fn parse(s: &str) -> Result<Profile> {
        Ok(match s {
            "paper" => Profile::Paper,
            "scaled" => Profile::Scaled,
            "smoke" => Profile::Smoke,
            _ => bail!("unknown profile {s:?} (paper|scaled|smoke)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Profile::Paper => "paper",
            Profile::Scaled => "scaled",
            Profile::Smoke => "smoke",
        }
    }
}

/// The three paper datasets (synthetic stand-ins) + extras for tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetId {
    KddSim,
    SongSim,
    CensusSim,
}

impl DatasetId {
    pub fn parse(s: &str) -> Result<DatasetId> {
        Ok(match s {
            "kdd_sim" | "kdd" => DatasetId::KddSim,
            "song_sim" | "song" => DatasetId::SongSim,
            "census_sim" | "census" => DatasetId::CensusSim,
            _ => bail!("unknown dataset {s:?} (kdd_sim|song_sim|census_sim)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DatasetId::KddSim => "kdd_sim",
            DatasetId::SongSim => "song_sim",
            DatasetId::CensusSim => "census_sim",
        }
    }

    pub fn all() -> [DatasetId; 3] {
        [DatasetId::KddSim, DatasetId::SongSim, DatasetId::CensusSim]
    }

    /// Paper table this dataset's runtime/cost rows correspond to.
    pub fn runtime_table(self) -> u8 {
        match self {
            DatasetId::KddSim => 1,
            DatasetId::SongSim => 2,
            DatasetId::CensusSim => 3,
        }
    }

    pub fn cost_table(self) -> u8 {
        match self {
            DatasetId::KddSim => 4,
            DatasetId::SongSim => 5,
            DatasetId::CensusSim => 6,
        }
    }

    /// n for a profile (paper sizes from §6; scaled sizes fit the session
    /// budget; smoke is for tests).
    pub fn n(self, profile: Profile) -> usize {
        match (self, profile) {
            (DatasetId::KddSim, Profile::Paper) => 311_029,
            (DatasetId::SongSim, Profile::Paper) => 515_345,
            (DatasetId::CensusSim, Profile::Paper) => 2_458_285,
            (DatasetId::KddSim, Profile::Scaled) => 60_000,
            (DatasetId::SongSim, Profile::Scaled) => 80_000,
            (DatasetId::CensusSim, Profile::Scaled) => 120_000,
            (DatasetId::KddSim, Profile::Smoke) => 3_000,
            (DatasetId::SongSim, Profile::Smoke) => 3_000,
            (DatasetId::CensusSim, Profile::Smoke) => 3_000,
        }
    }

    pub fn dim(self) -> usize {
        match self {
            DatasetId::KddSim => 74,
            DatasetId::SongSim => 90,
            DatasetId::CensusSim => 68,
        }
    }

    /// Generate in memory (deterministic in seed).
    pub fn generate(self, profile: Profile, seed: u64) -> PointSet {
        let n = self.n(profile);
        match self {
            DatasetId::KddSim => synth::kdd_sim(n, seed),
            DatasetId::SongSim => synth::song_sim(n, seed),
            DatasetId::CensusSim => synth::census_sim(n, seed),
        }
    }

    fn cache_path(self, dir: &Path, profile: Profile, seed: u64) -> PathBuf {
        dir.join(format!(
            "{}_{}_s{}.fbin",
            self.name(),
            profile.name(),
            seed
        ))
    }

    /// Load from the on-disk cache, generating + writing it on first use.
    pub fn load_cached(self, dir: &Path, profile: Profile, seed: u64) -> Result<PointSet> {
        let path = self.cache_path(dir, profile, seed);
        if path.exists() {
            return read_fbin(&path);
        }
        let ps = self.generate(profile, seed);
        std::fs::create_dir_all(dir)?;
        write_fbin(&ps, &path)?;
        Ok(ps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for id in DatasetId::all() {
            assert_eq!(DatasetId::parse(id.name()).unwrap(), id);
        }
        assert!(DatasetId::parse("nope").is_err());
        assert_eq!(Profile::parse("paper").unwrap(), Profile::Paper);
        assert!(Profile::parse("x").is_err());
    }

    #[test]
    fn smoke_generation_shapes() {
        for id in DatasetId::all() {
            let ps = id.generate(Profile::Smoke, 7);
            assert_eq!(ps.len(), 3_000);
            assert_eq!(ps.dim(), id.dim());
        }
    }

    #[test]
    fn table_numbers_match_paper() {
        assert_eq!(DatasetId::KddSim.runtime_table(), 1);
        assert_eq!(DatasetId::SongSim.runtime_table(), 2);
        assert_eq!(DatasetId::CensusSim.runtime_table(), 3);
        assert_eq!(DatasetId::KddSim.cost_table(), 4);
        assert_eq!(DatasetId::SongSim.cost_table(), 5);
        assert_eq!(DatasetId::CensusSim.cost_table(), 6);
    }

    #[test]
    fn cache_roundtrip() {
        let dir = std::env::temp_dir().join("fkmpp_registry_test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = DatasetId::KddSim
            .load_cached(&dir, Profile::Smoke, 3)
            .unwrap();
        // second load hits the cache and must be byte-identical
        let b = DatasetId::KddSim
            .load_cached(&dir, Profile::Smoke, 3)
            .unwrap();
        assert_eq!(a, b);
    }
}
