//! Synthetic dataset generators — the offline stand-ins for the paper's
//! UCI datasets (KDD-Cup protein homology, Million Song, US Census).
//!
//! DESIGN.md §2 documents the substitution. The generators are shaped so
//! the *qualitative* structure the paper's tables depend on is present:
//!
//! * clustered mass (so D^2 seeding beats uniform seeding clearly on the
//!   KDD-like set — Table 4's 5-15x gap);
//! * heavy-tailed outliers (KDD-Cup's protein-homology features are very
//!   skewed; this is what makes uniform seeding catastrophic there);
//! * moderate separation for the Song-like set (Table 5's gap is small);
//! * discretized coordinates for the Census-like set (categorical coding).
//!
//! All generators are deterministic in (spec, seed).

use crate::data::matrix::PointSet;
use crate::rng::Pcg64;

/// Parameters for the Gaussian-mixture family.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Number of points.
    pub n: usize,
    /// Dimension.
    pub d: usize,
    /// Number of true mixture components.
    pub k_true: usize,
    /// Std-dev of cluster centers around the origin.
    pub center_spread: f64,
    /// Within-cluster std-dev.
    pub cluster_std: f64,
    /// Fraction of points replaced by heavy-tailed outliers.
    pub outlier_frac: f64,
    /// Scale multiplier for outliers (relative to `center_spread`).
    pub outlier_scale: f64,
    /// Zipf exponent for cluster sizes (0 = balanced clusters).
    pub size_skew: f64,
    /// If >0, round every coordinate to this grid step (census-style
    /// categorical coding).
    pub grid_step: f64,
    /// Number of dimensions carrying full within-cluster variance
    /// (0 = all). Real UCI feature sets are strongly anisotropic: most
    /// features are near-constant within a cluster. Inactive dims get
    /// `cluster_std / 20`.
    pub active_dims: usize,
}

impl Default for SynthSpec {
    fn default() -> Self {
        SynthSpec {
            n: 10_000,
            d: 16,
            k_true: 50,
            center_spread: 10.0,
            cluster_std: 1.0,
            outlier_frac: 0.0,
            outlier_scale: 10.0,
            size_skew: 0.0,
            grid_step: 0.0,
            active_dims: 0,
        }
    }
}

/// General Gaussian mixture with optional skewed cluster sizes, outliers
/// and coordinate gridding.
pub fn gaussian_mixture(spec: &SynthSpec, seed: u64) -> PointSet {
    assert!(spec.k_true >= 1 && spec.n >= spec.k_true);
    let mut rng = Pcg64::seed_from(seed);

    // Component centers.
    let mut centers = vec![0.0f64; spec.k_true * spec.d];
    for c in centers.iter_mut() {
        *c = rng.next_gaussian() * spec.center_spread;
    }

    // Per-cluster active-dimension masks (anisotropic variance).
    let active = spec.active_dims.min(spec.d);
    let masks: Vec<Vec<bool>> = (0..spec.k_true)
        .map(|_| {
            let mut mask = vec![false; spec.d];
            if active == 0 {
                mask.iter_mut().for_each(|m| *m = true);
            } else {
                let mut dims: Vec<usize> = (0..spec.d).collect();
                rng.shuffle(&mut dims);
                for &j in dims.iter().take(active) {
                    mask[j] = true;
                }
            }
            mask
        })
        .collect();

    // Component weights: Zipf-like if skewed, else uniform.
    let weights: Vec<f64> = (0..spec.k_true)
        .map(|i| {
            if spec.size_skew > 0.0 {
                1.0 / ((i + 1) as f64).powf(spec.size_skew)
            } else {
                1.0
            }
        })
        .collect();

    let mut data = vec![0.0f32; spec.n * spec.d];
    for i in 0..spec.n {
        let row = &mut data[i * spec.d..(i + 1) * spec.d];
        if spec.outlier_frac > 0.0 && rng.next_bool(spec.outlier_frac) {
            // Heavy tail: gaussian direction, Pareto-ish radius (capped
            // at 100x the outlier scale to keep the aspect ratio in the
            // regime of the real UCI sets).
            let r = spec.center_spread * spec.outlier_scale
                / rng.next_f64().max(1e-4).powf(0.5);
            let mut norm2 = 0.0f64;
            let dir: Vec<f64> = (0..spec.d)
                .map(|_| {
                    let g = rng.next_gaussian();
                    norm2 += g * g;
                    g
                })
                .collect();
            let inv = if norm2 > 0.0 { r / norm2.sqrt() } else { 0.0 };
            for (dst, g) in row.iter_mut().zip(&dir) {
                *dst = (g * inv) as f32;
            }
        } else {
            let comp = rng.weighted_index(&weights).unwrap();
            let base = &centers[comp * spec.d..(comp + 1) * spec.d];
            let mask = &masks[comp];
            for ((dst, &mu), &on) in row.iter_mut().zip(base).zip(mask) {
                let std = if on {
                    spec.cluster_std
                } else {
                    spec.cluster_std / 20.0
                };
                *dst = (mu + rng.next_gaussian() * std) as f32;
            }
        }
        if spec.grid_step > 0.0 {
            for v in row.iter_mut() {
                *v = ((*v as f64 / spec.grid_step).round() * spec.grid_step) as f32;
            }
        }
    }
    PointSet::from_flat(spec.n, spec.d, data)
}

/// KDD-Cup-like (311,029 x 74 at the paper profile): skewed cluster
/// sizes + heavy-tailed outliers. This is the set where uniform seeding
/// collapses (Table 4).
pub fn kdd_sim(n: usize, seed: u64) -> PointSet {
    gaussian_mixture(
        &SynthSpec {
            n,
            d: 74,
            k_true: 200.min(n.max(2) / 2).max(1),
            center_spread: 20.0,
            cluster_std: 1.0,
            outlier_frac: 0.01,
            outlier_scale: 25.0,
            size_skew: 1.2,
            grid_step: 0.0,
            active_dims: 12,
        },
        seed ^ 0x6b64_64,
    )
}

/// Song-like (515,345 x 90): mild separation, balanced clusters — the
/// regime where all D^2-family seeders are within a few percent
/// (Table 5) and even uniform is not catastrophic.
pub fn song_sim(n: usize, seed: u64) -> PointSet {
    gaussian_mixture(
        &SynthSpec {
            n,
            d: 90,
            k_true: 500.min(n.max(2) / 2).max(1),
            center_spread: 3.0,
            cluster_std: 1.5,
            outlier_frac: 0.0,
            outlier_scale: 1.0,
            size_skew: 0.0,
            grid_step: 0.0,
            active_dims: 18,
        },
        seed ^ 0x736f_6e67,
    )
}

/// Census-like (2,458,285 x 68 at the paper profile): discretized
/// coordinates (categorical coding), moderately clustered.
pub fn census_sim(n: usize, seed: u64) -> PointSet {
    gaussian_mixture(
        &SynthSpec {
            n,
            d: 68,
            k_true: 300.min(n.max(2) / 2).max(1),
            center_spread: 8.0,
            cluster_std: 1.0,
            outlier_frac: 0.002,
            outlier_scale: 10.0,
            size_skew: 0.8,
            grid_step: 0.5,
            active_dims: 10,
        },
        seed ^ 0x6365_6e73,
    )
}

/// Uniform noise in a box — a worst case for tree embeddings (no cluster
/// structure) used by tests/ablations.
pub fn uniform_box(n: usize, d: usize, side: f64, seed: u64) -> PointSet {
    let mut rng = Pcg64::seed_from(seed);
    let data = (0..n * d)
        .map(|_| (rng.next_f64() * side) as f32)
        .collect();
    PointSet::from_flat(n, d, data)
}

/// Well-separated clusters on a grid — ground truth is unambiguous;
/// used by quality tests (a D^2 seeder must find every cluster).
pub fn separated_grid(k: usize, per_cluster: usize, d: usize, seed: u64) -> PointSet {
    let mut rng = Pcg64::seed_from(seed);
    let mut rows = Vec::with_capacity(k * per_cluster);
    for c in 0..k {
        // Place cluster centers on an axis-aligned lattice, spacing 100.
        let mut center = vec![0.0f32; d];
        let mut idx = c;
        for coord in center.iter_mut() {
            *coord = (idx % 10) as f32 * 100.0;
            idx /= 10;
            if idx == 0 {
                break;
            }
        }
        for _ in 0..per_cluster {
            let row: Vec<f32> = center
                .iter()
                .map(|&mu| mu + rng.next_gaussian() as f32 * 0.5)
                .collect();
            rows.push(row);
        }
    }
    PointSet::from_rows(&rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = gaussian_mixture(&SynthSpec::default(), 1);
        let b = gaussian_mixture(&SynthSpec::default(), 1);
        assert_eq!(a, b);
        let c = gaussian_mixture(&SynthSpec::default(), 2);
        assert_ne!(a, c);
    }

    #[test]
    fn shapes() {
        let spec = SynthSpec {
            n: 123,
            d: 7,
            k_true: 3,
            ..Default::default()
        };
        let ps = gaussian_mixture(&spec, 0);
        assert_eq!(ps.len(), 123);
        assert_eq!(ps.dim(), 7);
    }

    #[test]
    fn grid_step_quantizes() {
        let spec = SynthSpec {
            n: 100,
            d: 4,
            k_true: 2,
            grid_step: 0.5,
            ..Default::default()
        };
        let ps = gaussian_mixture(&spec, 3);
        for v in ps.flat() {
            let q = (v / 0.5).round() * 0.5;
            assert!((v - q).abs() < 1e-4, "v={v}");
        }
    }

    #[test]
    fn outliers_inflate_radius() {
        let base = SynthSpec {
            n: 2000,
            d: 8,
            k_true: 5,
            ..Default::default()
        };
        let no_outl = gaussian_mixture(&base, 7);
        let with_outl = gaussian_mixture(
            &SynthSpec {
                outlier_frac: 0.05,
                outlier_scale: 50.0,
                ..base
            },
            7,
        );
        assert!(with_outl.max_dist_upper_bound() > 3.0 * no_outl.max_dist_upper_bound());
    }

    #[test]
    fn dataset_profiles_have_paper_dims() {
        assert_eq!(kdd_sim(100, 0).dim(), 74);
        assert_eq!(song_sim(100, 0).dim(), 90);
        assert_eq!(census_sim(100, 0).dim(), 68);
    }

    #[test]
    fn separated_grid_is_separated() {
        let ps = separated_grid(4, 10, 3, 5);
        assert_eq!(ps.len(), 40);
        // Points within a cluster are near; across clusters far.
        assert!(ps.d2_rows(0, 1) < 25.0);
        assert!(ps.d2_rows(0, 11) > 1000.0);
    }
}
