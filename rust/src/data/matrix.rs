//! `PointSet`: the dense row-major `n x d` f32 container every layer
//! shares, plus the scalar squared-distance kernel [`d2`] that dominates
//! the exact-`D^2` baseline's runtime.
//!
//! [`d2`] is the crate's native hot path (the PJRT artifacts are the
//! other implementation of the same contract). It is written to
//! autovectorize: contiguous rows, a 4-lane unrolled accumulator, and no
//! bounds checks in the inner loop (checked slices hoisted out). All
//! *loops over points* around it live in [`crate::kernels`].

/// Dense row-major point matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct PointSet {
    n: usize,
    d: usize,
    data: Vec<f32>,
}

impl PointSet {
    /// Build from a flat row-major buffer. Panics if `data.len() != n*d`.
    pub fn from_flat(n: usize, d: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * d, "flat buffer length mismatch");
        assert!(d > 0, "dimension must be positive");
        PointSet { n, d, data }
    }

    /// Build from row slices.
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            assert_eq!(r.len(), d, "ragged rows");
            data.extend_from_slice(r);
        }
        PointSet {
            n: rows.len(),
            d,
            data,
        }
    }

    /// All-zeros point set.
    pub fn zeros(n: usize, d: usize) -> Self {
        PointSet {
            n,
            d,
            data: vec![0.0; n * d],
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Gather the given rows into a new `PointSet` (e.g. chosen centers).
    pub fn gather(&self, idx: &[usize]) -> PointSet {
        let mut data = Vec::with_capacity(idx.len() * self.d);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        PointSet {
            n: idx.len(),
            d: self.d,
            data,
        }
    }

    /// Squared Euclidean distance between row `i` and an arbitrary point.
    #[inline]
    pub fn d2_to(&self, i: usize, q: &[f32]) -> f32 {
        d2(self.row(i), q)
    }

    /// Squared Euclidean distance between rows `i` and `j`.
    #[inline]
    pub fn d2_rows(&self, i: usize, j: usize) -> f32 {
        d2(self.row(i), self.row(j))
    }

    /// Coordinate-wise min/max over the whole set (bounding box).
    pub fn bounding_box(&self) -> (Vec<f32>, Vec<f32>) {
        let mut lo = vec![f32::INFINITY; self.d];
        let mut hi = vec![f32::NEG_INFINITY; self.d];
        for i in 0..self.n {
            let r = self.row(i);
            for j in 0..self.d {
                lo[j] = lo[j].min(r[j]);
                hi[j] = hi[j].max(r[j]);
            }
        }
        (lo, hi)
    }

    /// Upper bound on the max pairwise distance within a factor 2
    /// (paper §2: max distance from an arbitrary point, times 2).
    /// Runs in `O(nd)`, parallel over point chunks
    /// ([`crate::kernels::reduce::max_d2_to`]).
    pub fn max_dist_upper_bound(&self) -> f32 {
        if self.n <= 1 {
            return 0.0;
        }
        let pivot = self.row(0).to_vec();
        let max_d2 = crate::kernels::reduce::max_d2_to(self, &pivot);
        2.0 * max_d2.sqrt()
    }

    /// Exact minimum pairwise distance — `O(n^2 d)`; test/diagnostic only.
    pub fn min_pairwise_dist(&self) -> f32 {
        let mut best = f32::INFINITY;
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                best = best.min(self.d2_rows(i, j));
            }
        }
        best.sqrt()
    }
}

/// Squared Euclidean distance between two equal-length slices.
///
/// 4-way unrolled so LLVM vectorizes it into fused multiply-subtract
/// lanes; this single function is the native hot path of the exact
/// baseline, Lloyd and cost evaluation.
#[inline]
pub fn d2(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    // SAFETY-free formulation: slice patterns keep bounds checks out of
    // the loop body.
    let (a4, a_rest) = a.split_at(chunks * 4);
    let (b4, b_rest) = b.split_at(chunks * 4);
    for (ca, cb) in a4.chunks_exact(4).zip(b4.chunks_exact(4)) {
        let d0 = ca[0] - cb[0];
        let d1 = ca[1] - cb[1];
        let d2_ = ca[2] - cb[2];
        let d3 = ca[3] - cb[3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2_ * d2_;
        s3 += d3 * d3;
    }
    let mut tail = 0.0f32;
    for (x, y) in a_rest.iter().zip(b_rest) {
        let d = x - y;
        tail += d * d;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Plain (non-squared) Euclidean distance.
#[inline]
pub fn dist(a: &[f32], b: &[f32]) -> f32 {
    d2(a, b).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn construction_and_access() {
        let ps = PointSet::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(ps.len(), 2);
        assert_eq!(ps.dim(), 2);
        assert_eq!(ps.row(1), &[3.0, 4.0]);
        assert_eq!(ps.flat(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "flat buffer length mismatch")]
    fn from_flat_checks_len() {
        PointSet::from_flat(2, 3, vec![0.0; 5]);
    }

    #[test]
    fn d2_matches_naive_all_lengths() {
        let mut rng = Pcg64::seed_from(1);
        for len in [1usize, 2, 3, 4, 5, 7, 8, 13, 64, 65, 96] {
            let a: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.next_gaussian() as f32).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            let got = d2(&a, &b);
            assert!(
                (got - naive).abs() <= 1e-4 * naive.max(1.0),
                "len={len} got={got} naive={naive}"
            );
        }
    }

    #[test]
    fn d2_zero_for_identical() {
        let a = vec![1.5f32; 31];
        assert_eq!(d2(&a, &a), 0.0);
    }

    #[test]
    fn gather_selects_rows() {
        let ps = PointSet::from_rows(&[vec![0.0], vec![1.0], vec![2.0]]);
        let g = ps.gather(&[2, 0]);
        assert_eq!(g.row(0), &[2.0]);
        assert_eq!(g.row(1), &[0.0]);
    }

    #[test]
    fn bounding_box() {
        let ps = PointSet::from_rows(&[vec![1.0, -5.0], vec![-2.0, 7.0]]);
        let (lo, hi) = ps.bounding_box();
        assert_eq!(lo, vec![-2.0, -5.0]);
        assert_eq!(hi, vec![1.0, 7.0]);
    }

    #[test]
    fn max_dist_upper_bound_is_valid() {
        let mut rng = Pcg64::seed_from(2);
        let rows: Vec<Vec<f32>> = (0..50)
            .map(|_| (0..4).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let ps = PointSet::from_rows(&rows);
        let ub = ps.max_dist_upper_bound();
        // brute-force true max
        let mut true_max = 0.0f32;
        for i in 0..50 {
            for j in 0..50 {
                true_max = true_max.max(ps.d2_rows(i, j).sqrt());
            }
        }
        assert!(ub >= true_max, "ub={ub} true={true_max}");
        assert!(ub <= 2.0 * true_max + 1e-5);
    }

    #[test]
    fn min_pairwise() {
        let ps = PointSet::from_rows(&[vec![0.0], vec![10.0], vec![10.5]]);
        assert!((ps.min_pairwise_dist() - 0.5).abs() < 1e-6);
    }
}
