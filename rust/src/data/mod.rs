//! Datasets: the point-set container, synthetic generators that stand in
//! for the paper's UCI datasets (offline image — see DESIGN.md §2), binary
//! IO, the Appendix-F aspect-ratio quantization, JL random projection, and
//! the named-dataset registry used by the CLI/benches.

pub mod io;
pub mod matrix;
pub mod project;
pub mod quantize;
pub mod registry;
pub mod synth;

pub use matrix::PointSet;
