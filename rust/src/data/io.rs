//! Binary + CSV point-set IO.
//!
//! The canonical on-disk format is `.fbin`, the little-endian layout used
//! by the ANN-benchmarks ecosystem: `u32 n, u32 d, then n*d f32`. Benches
//! materialize the synthetic datasets once (`fkmpp datasets gen`) so the
//! timed region measures seeding, not generation. CSV import exists so
//! users can feed the real UCI files when they have them.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::bail;
use crate::data::matrix::PointSet;
use crate::error::{Context, Result};

/// Write `.fbin` (u32 n, u32 d, n*d little-endian f32).
pub fn write_fbin(ps: &PointSet, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(&(ps.len() as u32).to_le_bytes())?;
    w.write_all(&(ps.dim() as u32).to_le_bytes())?;
    // Bulk write: f32 -> LE bytes chunk-wise to avoid a 4x copy blowup.
    let mut buf = Vec::with_capacity(1 << 20);
    for v in ps.flat() {
        buf.extend_from_slice(&v.to_le_bytes());
        if buf.len() >= (1 << 20) {
            w.write_all(&buf)?;
            buf.clear();
        }
    }
    w.write_all(&buf)?;
    w.flush()?;
    Ok(())
}

/// Read `.fbin`.
pub fn read_fbin(path: &Path) -> Result<PointSet> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut hdr = [0u8; 8];
    r.read_exact(&mut hdr).context("fbin header")?;
    let n = u32::from_le_bytes(hdr[0..4].try_into().unwrap()) as usize;
    let d = u32::from_le_bytes(hdr[4..8].try_into().unwrap()) as usize;
    if d == 0 || n.checked_mul(d).is_none() {
        bail!("corrupt fbin header n={n} d={d}");
    }
    let mut bytes = vec![0u8; n * d * 4];
    r.read_exact(&mut bytes)
        .with_context(|| format!("fbin body: expected {} floats", n * d))?;
    let data = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(PointSet::from_flat(n, d, data))
}

/// Encode a point set as in-memory `.fbin` bytes — the same layout as
/// [`write_fbin`], used as the request body of the binary assign route.
pub fn encode_fbin(ps: &PointSet) -> Vec<u8> {
    let mut out = Vec::with_capacity(8 + ps.flat().len() * 4);
    out.extend_from_slice(&(ps.len() as u32).to_le_bytes());
    out.extend_from_slice(&(ps.dim() as u32).to_le_bytes());
    for v in ps.flat() {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Decode in-memory `.fbin` bytes. Stricter than [`read_fbin`]: trailing
/// bytes after the declared `n*d` floats are rejected — an HTTP body is
/// a complete message, so extra bytes mean a framing bug, not padding.
pub fn decode_fbin(bytes: &[u8]) -> Result<PointSet> {
    if bytes.len() < 8 {
        bail!("fbin body too short for header ({} bytes)", bytes.len());
    }
    let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    let d = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let want = n
        .checked_mul(d)
        .and_then(|e| e.checked_mul(4))
        .and_then(|b| b.checked_add(8));
    let Some(want) = want.filter(|_| d > 0) else {
        bail!("corrupt fbin header n={n} d={d}");
    };
    if bytes.len() != want {
        bail!(
            "fbin body is {} bytes, header n={n} d={d} implies {want}",
            bytes.len()
        );
    }
    let data = bytes[8..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(PointSet::from_flat(n, d, data))
}

/// Read a headerless numeric CSV (comma or whitespace separated), the
/// format the UCI dumps use after stripping ids/labels.
pub fn read_csv(path: &Path) -> Result<PointSet> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let r = BufReader::new(f);
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let row: Result<Vec<f32>, _> = trimmed
            .split(|c: char| c == ',' || c.is_whitespace())
            .filter(|t| !t.is_empty())
            .map(|t| t.parse::<f32>())
            .collect();
        let row = row.with_context(|| format!("{path:?}:{} parse", lineno + 1))?;
        if let Some(first) = rows.first() {
            if row.len() != first.len() {
                bail!(
                    "{path:?}:{}: ragged row ({} cols, expected {})",
                    lineno + 1,
                    row.len(),
                    first.len()
                );
            }
        }
        rows.push(row);
    }
    if rows.is_empty() {
        bail!("{path:?}: no data rows");
    }
    Ok(PointSet::from_rows(&rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fkmpp_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn fbin_roundtrip() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 257,
                d: 13,
                k_true: 4,
                ..Default::default()
            },
            9,
        );
        let p = tmp("roundtrip.fbin");
        write_fbin(&ps, &p).unwrap();
        let back = read_fbin(&p).unwrap();
        assert_eq!(ps, back);
    }

    #[test]
    fn fbin_rejects_truncated() {
        let p = tmp("trunc.fbin");
        std::fs::write(&p, [5u8, 0, 0, 0, 2, 0, 0, 0, 1, 2, 3]).unwrap();
        assert!(read_fbin(&p).is_err());
    }

    #[test]
    fn fbin_memory_roundtrip_matches_disk_bytes() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 33,
                d: 5,
                k_true: 2,
                ..Default::default()
            },
            4,
        );
        let bytes = encode_fbin(&ps);
        assert_eq!(decode_fbin(&bytes).unwrap(), ps);
        // The in-memory encoding is byte-identical to the on-disk one.
        let p = tmp("mem.fbin");
        write_fbin(&ps, &p).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), bytes);
    }

    #[test]
    fn decode_fbin_rejects_bad_framing() {
        // Too short for a header.
        assert!(decode_fbin(&[1, 0, 0]).is_err());
        // Truncated body.
        assert!(decode_fbin(&[5, 0, 0, 0, 2, 0, 0, 0, 1, 2, 3]).is_err());
        // Zero dimension.
        assert!(decode_fbin(&[1, 0, 0, 0, 0, 0, 0, 0]).is_err());
        // Trailing garbage after the declared floats.
        let ps = PointSet::from_flat(1, 2, vec![1.0, 2.0]);
        let mut bytes = encode_fbin(&ps);
        bytes.push(0xFF);
        assert!(decode_fbin(&bytes).is_err());
    }

    #[test]
    fn csv_parses_mixed_separators() {
        let p = tmp("pts.csv");
        std::fs::write(&p, "# comment\n1.0,2.0,3.0\n4 5 6\n\n7.5,8.5,9.5\n").unwrap();
        let ps = read_csv(&p).unwrap();
        assert_eq!(ps.len(), 3);
        assert_eq!(ps.dim(), 3);
        assert_eq!(ps.row(1), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "1,2\n3,4,5\n").unwrap();
        assert!(read_csv(&p).is_err());
    }

    #[test]
    fn csv_rejects_empty() {
        let p = tmp("empty.csv");
        std::fs::write(&p, "# nothing\n").unwrap();
        assert!(read_csv(&p).is_err());
    }
}
