//! Appendix-F aspect-ratio bounding.
//!
//! The paper's `O(log Delta)` terms assume a bounded ratio between the max
//! and min pairwise distance. Appendix F gives the practical recipe, which
//! we implement verbatim:
//!
//! 1. estimate the optimum by sampling 20 random centers and evaluating
//!    the k-means cost of that solution;
//! 2. divide by `n * d * 200` — the per-coordinate error budget (0.5% of
//!    the estimate in total) — to get the *scaling factor*;
//! 3. divide every coordinate by the scaling factor and truncate to an
//!    integer.
//!
//! After this, `log(Delta)` is `O(log(nd))` and tree heights are bounded.

use crate::data::matrix::PointSet;
use crate::rng::Pcg64;

/// Result of quantization: the rescaled points plus the factor used
/// (callers multiply distances by `scale` to get back to input units;
/// costs scale by `scale^2`).
#[derive(Clone, Debug)]
pub struct Quantized {
    pub points: PointSet,
    pub scale: f64,
}

/// Estimate the k-means optimum cost by evaluating `sample_k` uniformly
/// random centers (Appendix F step 1). The `O(n * sample_k * d)` cost
/// evaluation runs on the parallel kernel engine.
///
/// Distinct indices come from a partial Fisher–Yates over `0..n`: `k`
/// swaps, one bounded RNG draw each — `O(n + k)` total. The previous
/// rejection loop (`idx.contains(&cand)` retry) was `O(k²)` in scans
/// and its retry count diverged as `sample_k → n` (the last index
/// needed `~n` draws in expectation at `sample_k = n`). Note the draw
/// stream differs from the old scheme (bounds shrink per step and
/// duplicates no longer consume extra draws), so fixed-seed outputs of
/// quantization changed once at this commit.
pub fn estimate_opt_cost(ps: &PointSet, sample_k: usize, rng: &mut Pcg64) -> f64 {
    let n = ps.len();
    let k = sample_k.min(n).max(1);
    let mut idx: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + rng.index(n - i);
        idx.swap(i, j);
    }
    idx.truncate(k);
    let centers = ps.gather(&idx);
    crate::kernels::reduce::cost(ps, &centers)
}

/// Appendix-F quantization. `error_divisor` is the paper's 200.
pub fn quantize(ps: &PointSet, rng: &mut Pcg64) -> Quantized {
    quantize_with(ps, 20, 200.0, rng)
}

/// Parameterized version (tests/ablations).
pub fn quantize_with(
    ps: &PointSet,
    sample_k: usize,
    error_divisor: f64,
    rng: &mut Pcg64,
) -> Quantized {
    let est = estimate_opt_cost(ps, sample_k, rng);
    // Per-coordinate error budget; est can be 0 for degenerate inputs
    // (all points identical) — keep scale 1 in that case.
    let denom = (ps.len() * ps.dim()) as f64 * error_divisor;
    // The cost estimate is in squared units; the per-coordinate grid step
    // must be in linear units.
    let scale = if est > 0.0 { (est / denom).sqrt() } else { 1.0 };
    let mut out = ps.clone();
    for v in out.flat_mut() {
        *v = (*v as f64 / scale).trunc() as f32;
    }
    Quantized { points: out, scale }
}

/// Aspect ratio `Delta` = max pairwise distance / min *nonzero* pairwise
/// distance. Exact (`O(n^2 d)`) — diagnostics and tests only.
pub fn aspect_ratio_exact(ps: &PointSet) -> f64 {
    let mut max_d2 = 0.0f32;
    let mut min_d2 = f32::INFINITY;
    for i in 0..ps.len() {
        for j in (i + 1)..ps.len() {
            let d2 = ps.d2_rows(i, j);
            max_d2 = max_d2.max(d2);
            if d2 > 0.0 {
                min_d2 = min_d2.min(d2);
            }
        }
    }
    if min_d2.is_infinite() || min_d2 == 0.0 {
        return 1.0;
    }
    (max_d2 as f64 / min_d2 as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    #[test]
    fn quantized_coordinates_are_integers() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 500,
                d: 8,
                k_true: 5,
                ..Default::default()
            },
            1,
        );
        let mut rng = Pcg64::seed_from(2);
        let q = quantize(&ps, &mut rng);
        for v in q.points.flat() {
            assert_eq!(v.fract(), 0.0, "coordinate {v} not integral");
        }
        assert!(q.scale > 0.0);
    }

    #[test]
    fn quantization_preserves_cost_within_budget() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 800,
                d: 6,
                k_true: 8,
                center_spread: 20.0,
                ..Default::default()
            },
            3,
        );
        let mut rng = Pcg64::seed_from(4);
        let q = quantize(&ps, &mut rng);
        // Distances in rescaled space, multiplied back by scale, should be
        // close to the originals (relative to the dataset radius).
        let radius = ps.max_dist_upper_bound() as f64;
        for (i, j) in [(0usize, 1usize), (5, 100), (17, 400), (2, 799)] {
            let orig = (ps.d2_rows(i, j) as f64).sqrt();
            let quant = (q.points.d2_rows(i, j) as f64).sqrt() * q.scale;
            assert!(
                (orig - quant).abs() < 0.01 * radius + q.scale * (ps.dim() as f64).sqrt() * 2.0,
                "orig={orig} quant={quant} scale={}",
                q.scale
            );
        }
    }

    #[test]
    fn degenerate_identical_points() {
        let ps = PointSet::from_rows(&vec![vec![3.0f32, 3.0]; 10]);
        let mut rng = Pcg64::seed_from(5);
        let q = quantize(&ps, &mut rng);
        assert_eq!(q.scale, 1.0);
    }

    #[test]
    fn estimate_opt_cost_zero_when_k_covers_all() {
        let ps = PointSet::from_rows(&[vec![0.0f32], vec![5.0], vec![9.0]]);
        let mut rng = Pcg64::seed_from(6);
        let est = estimate_opt_cost(&ps, 3, &mut rng);
        assert_eq!(est, 0.0);
    }

    #[test]
    fn estimate_opt_cost_full_coverage_terminates() {
        // The old rejection loop (`idx.contains` retry) needed ~n draws
        // for the last index at sample_k == n; the partial Fisher–Yates
        // does exactly k bounded draws. With every point a center the
        // estimate is exactly zero — and distinctness is what makes it
        // so (a duplicate index would leave some point uncovered).
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 512,
                d: 4,
                k_true: 3,
                ..Default::default()
            },
            8,
        );
        let mut rng = Pcg64::seed_from(9);
        assert_eq!(estimate_opt_cost(&ps, 512, &mut rng), 0.0);
        // sample_k beyond n clamps rather than diverging.
        let mut rng = Pcg64::seed_from(9);
        assert_eq!(estimate_opt_cost(&ps, 100_000, &mut rng), 0.0);
        // Fixed seed → fixed estimate (replay determinism).
        let a = estimate_opt_cost(&ps, 20, &mut Pcg64::seed_from(10));
        let b = estimate_opt_cost(&ps, 20, &mut Pcg64::seed_from(10));
        assert_eq!(a, b);
        assert!(a > 0.0);
    }

    #[test]
    fn aspect_ratio_simple() {
        let ps = PointSet::from_rows(&[vec![0.0f32], vec![1.0], vec![10.0]]);
        assert!((aspect_ratio_exact(&ps) - 10.0).abs() < 1e-6);
    }
}
