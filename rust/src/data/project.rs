//! Johnson–Lindenstrauss random projection (§5 remark).
//!
//! The paper notes that for large `d` one first applies an oblivious
//! dimensionality reduction to `O(log n)` dimensions (Becchetti et al. /
//! Makarychev et al.) which preserves the cost of every clustering up to a
//! constant. We implement the dense Gaussian JL map `x -> Gx / sqrt(t)`
//! with `G ~ N(0,1)^{t x d}` — `O(ndt)` once, independent of `k`.

use crate::data::matrix::PointSet;
use crate::parallel::parallel_chunks_mut;
use crate::rng::Pcg64;

/// Target dimension for a JL map preserving k-means costs to within
/// `1 ± eps` (constant from the standard JL bound, `8 ln n / eps^2`).
pub fn jl_target_dim(n: usize, eps: f64) -> usize {
    let n = n.max(2) as f64;
    ((8.0 * n.ln()) / (eps * eps)).ceil() as usize
}

/// Dense Gaussian random projection to `t` dimensions.
pub struct JlProjection {
    /// `t x d` row-major Gaussian matrix, pre-scaled by `1/sqrt(t)`.
    g: Vec<f32>,
    pub from_dim: usize,
    pub to_dim: usize,
}

impl JlProjection {
    pub fn new(from_dim: usize, to_dim: usize, rng: &mut Pcg64) -> Self {
        assert!(to_dim > 0);
        let scale = 1.0 / (to_dim as f64).sqrt();
        let g = (0..from_dim * to_dim)
            .map(|_| (rng.next_gaussian() * scale) as f32)
            .collect();
        JlProjection {
            g,
            from_dim,
            to_dim,
        }
    }

    /// Project a single point into a caller-provided output row.
    pub fn apply_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.from_dim);
        assert_eq!(out.len(), self.to_dim);
        // Row-major over output dims: g[t*d .. t*d+d] . x
        for (t, o) in out.iter_mut().enumerate() {
            let row = &self.g[t * self.from_dim..(t + 1) * self.from_dim];
            let mut acc = 0.0f32;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            *o = acc;
        }
    }

    /// Project a single point.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.to_dim];
        self.apply_into(x, &mut out);
        out
    }

    /// Project a whole point set — `O(ndt)`, parallel over row-aligned
    /// output chunks (this is the one-time cost the §5 remark trades for
    /// the `O(d^2)` tree distortion, so it sits on the seeding init path).
    pub fn apply_all(&self, ps: &PointSet) -> PointSet {
        assert_eq!(ps.dim(), self.from_dim);
        let t = self.to_dim;
        let mut data = vec![0.0f32; ps.len() * t];
        parallel_chunks_mut(&mut data, t, 512, |start, chunk| {
            let first_row = start / t;
            for (r, out_row) in chunk.chunks_exact_mut(t).enumerate() {
                self.apply_into(ps.row(first_row + r), out_row);
            }
        });
        PointSet::from_flat(ps.len(), t, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::d2;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    #[test]
    fn target_dim_grows_with_n_and_eps() {
        assert!(jl_target_dim(1_000_000, 0.5) > jl_target_dim(1_000, 0.5));
        assert!(jl_target_dim(1_000, 0.1) > jl_target_dim(1_000, 0.5));
    }

    #[test]
    fn preserves_distances_in_expectation() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 60,
                d: 128,
                k_true: 4,
                ..Default::default()
            },
            11,
        );
        let mut rng = Pcg64::seed_from(12);
        let proj = JlProjection::new(128, 64, &mut rng);
        let pps = proj.apply_all(&ps);
        assert_eq!(pps.dim(), 64);
        // Pairwise distance distortion concentrated around 1.
        let mut ratios = Vec::new();
        for i in 0..30 {
            for j in (i + 1)..30 {
                let orig = d2(ps.row(i), ps.row(j));
                if orig > 0.0 {
                    ratios.push((d2(pps.row(i), pps.row(j)) / orig) as f64);
                }
            }
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((mean - 1.0).abs() < 0.15, "mean distortion {mean}");
        // No extreme blowups at t=64.
        assert!(ratios.iter().all(|&r| r > 0.2 && r < 3.0));
    }

    #[test]
    fn apply_matches_apply_all() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 5,
                d: 10,
                k_true: 2,
                ..Default::default()
            },
            13,
        );
        let mut rng = Pcg64::seed_from(14);
        let proj = JlProjection::new(10, 4, &mut rng);
        let all = proj.apply_all(&ps);
        for i in 0..5 {
            assert_eq!(all.row(i), proj.apply(ps.row(i)).as_slice());
        }
    }
}
