//! The distributed fit — k-means‖ across **processes**, not just
//! threads (ROADMAP item 4, the horizontal-scale layer).
//!
//! This module joins the two halves built by earlier PRs: the
//! coordinator/shard split of [`crate::shard`] and the zero-dependency
//! HTTP layer of [`crate::server`]. The round lifecycle of
//! [`crate::shard::kmeanspar::kmeans_par`] is extracted into one
//! transport-generic driver, [`run_rounds`], parameterized over a
//! [`RoundExecutor`]:
//!
//! * [`crate::shard::kmeanspar::LocalShardExecutor`] — the in-process
//!   implementation over [`crate::shard::ShardedDataset`]; the classic
//!   `kmeans_par` entry point now delegates to it, so every existing
//!   caller (and the 21-seed statistical suite) exercises the same
//!   driver as the distributed path.
//! * [`coordinator::DistCoordinator`] — the remote implementation:
//!   `fkmpp worker --port N` processes ([`worker`]) each own a
//!   contiguous, summation-block-aligned slice
//!   ([`crate::shard::aligned_ranges`]) and answer the two per-round
//!   RPCs (`D²` slice update returning fixed-block partial cost sums,
//!   and Poisson candidate sampling on the shared per-(round, global
//!   point index) counter streams) plus the final weigh. Frames travel
//!   as the binary codec of [`wire`] over `POST /rpc` — no JSON float
//!   round-tripping for bulk rows.
//!
//! ## Bitwise parity across processes
//!
//! A multi-process run must reproduce the single-process result
//! bit-for-bit (`rust/tests/dist_parity.rs` is the acceptance gate).
//! The contract stands on four legs:
//!
//! 1. `D²` maintenance is per-point exact and min-folds are order-free,
//!    so slicing rows across processes changes no value — provided every
//!    process runs the *same kernel implementation*. Workers resolve
//!    kernels on the **global** shape shipped in `ShardLoad` (exactly as
//!    the in-process driver resolves once on the global shape), and
//!    cross-process runs must pin `FKMPP_KERNEL` (the PR 3 contract):
//!    the autotuner's runtime probe may resolve differently in different
//!    processes on probe-scale shapes.
//! 2. The round cost is [`crate::kernels::reduce::sum_f32`] — f64 block
//!    partials at fixed [`crate::kernels::reduce::SUM_BLOCK`] boundaries
//!    summed left-to-right. Worker ranges are aligned to those
//!    boundaries, each worker returns its blocks' partials, and the
//!    coordinator concatenates them in range order and sums
//!    left-to-right: the identical f64 additions in the identical
//!    order. (Summing per-worker *totals* would round differently —
//!    that is why the partials, not the totals, are the RPC payload.)
//! 3. Membership coins are pure functions of `(seed, round, global
//!    index)` ([`crate::shard::kmeanspar::point_uniform`]); merging
//!    per-worker candidate lists in range order IS ascending global
//!    order, the same merge the in-process engine does per shard.
//! 4. Candidate weights are exact `u64` assignment counts, summed
//!    order-free; the recluster runs coordinator-side on the run RNG.
//!
//! ## Fault tolerance
//!
//! Workers are stateful but their state is a pure fold of the broadcast
//! history, so recovery is *replay*: the coordinator keeps every
//! candidate batch it has broadcast and, when a worker RPC fails
//! (connection refused/reset, timeout, or a worker restarted into the
//! "no shard loaded" state), re-provisions the worker — `ShardLoad`
//! plus one combined `Update` replaying the full history (min-folds are
//! idempotent and order-free, so replay lands on the identical `D²`
//! bits) — and retries the failed RPC. Retries are bounded by a
//! per-phase deadline ([`coordinator::DistConfig::round_deadline`]);
//! a permanently dead worker yields a typed error naming the endpoint
//! (`"... unreachable ..."`), never a hang. `dist.*` counters and
//! timers land in [`crate::metrics::global`].

pub mod coordinator;
pub mod wire;
pub mod worker;

pub use coordinator::{kmeans_par_dist, DistConfig, DistCoordinator};

use std::time::Instant;

use crate::data::matrix::PointSet;
use crate::error::Result;
use crate::metrics;
use crate::rng::{splitmix64, Pcg64};
use crate::seeding::{Seeding, SeedingStats};
use crate::shard::weighted::{weighted_kmeanspp, WeightedPointSet};
use crate::trace;

/// The per-round operations of k-means‖, abstracted over *where the
/// rows live*. One implementation holds shards in-process
/// ([`crate::shard::kmeanspar::LocalShardExecutor`]); the other fans
/// out to worker processes ([`DistCoordinator`]). [`run_rounds`] is
/// written against this trait only, so the two transports cannot drift.
///
/// Implementations own the `D²` array and the candidate marks for their
/// rows; the driver owns the run RNG, the candidate list, and the
/// recluster.
pub trait RoundExecutor {
    /// Broadcast newly accepted candidates (global `indices`, with their
    /// `rows` gathered by the driver) and min-fold them into the `D²`
    /// state. Returns the **global fixed-block partial cost sums**: the
    /// f64 per-[`crate::kernels::reduce::SUM_BLOCK`] partials of the
    /// full `D²` array, in global block order, so
    /// `partials.iter().sum()` equals
    /// [`crate::kernels::reduce::sum_f32`] bitwise.
    fn update(&mut self, indices: &[usize], rows: &PointSet) -> Result<Vec<f64>>;

    /// Flip the per-(round, global index) membership coins over every
    /// non-candidate row: accept `i` when
    /// `point_uniform(round_tag, i) * cost < ell * D²(i)`. Returns
    /// accepted global indices in ascending order.
    fn sample(&mut self, round_tag: u64, cost: f64, ell: f64) -> Result<Vec<usize>>;

    /// Assign every row to its nearest candidate and return exact
    /// per-candidate `u64` assignment counts (the recluster weights).
    fn weigh(&mut self, candidates: &PointSet) -> Result<Vec<u64>>;

    /// Observability hook: the driver announces each oversampling round
    /// before issuing its RPCs, so a transport can tag its trace spans
    /// with the round number. Must not affect computation — the default
    /// is a no-op and [`run_rounds`] calls it outside all RNG use.
    fn on_round(&mut self, _round: usize) {}
}

/// The transport-generic k-means‖ driver: oversampling rounds over any
/// [`RoundExecutor`], then the coordinator-side weighted k-means++
/// recluster. This is the round lifecycle formerly inlined in
/// [`crate::shard::kmeanspar::kmeans_par`], verbatim — same RNG
/// discipline (exactly two run-RNG draws before the recluster), same
/// `shard.*` metrics, same degenerate top-up — so both transports are
/// bitwise interchangeable. Callers must have handled `k == 0`
/// (`k.min(ps.len()) > 0` is a precondition) and pass the time they
/// spent provisioning the executor as `init_secs`.
pub fn run_rounds(
    ps: &PointSet,
    k: usize,
    rounds: usize,
    oversample: f64,
    exec: &mut dyn RoundExecutor,
    init_secs: f64,
    rng: &mut Pcg64,
) -> Result<Seeding> {
    let m = metrics::global();
    m.incr("shard.runs", 1);
    let k = k.min(ps.len());
    assert!(k > 0, "run_rounds precondition: k.min(n) > 0");
    let n = ps.len();
    let mut stats = SeedingStats {
        init_secs,
        ..SeedingStats::default()
    };

    let t1 = Instant::now();
    // RNG discipline: exactly two run-RNG draws before the recluster.
    let stream_root = rng.next_u64();
    let first = rng.index(n);
    let mut candidates = vec![first];
    stats.proposals += 1;
    // The executor returns the global fixed-block cost partials after
    // every fold; summing them left-to-right IS sum_f32 on the global
    // D² array, so the driver never needs the array itself.
    // Trace spans below sit at the same coarse boundaries as the
    // timers — they read only the clock, never the RNG.
    let mut partials = {
        let _s = trace::Span::enter("shard.update");
        exec.update(&[first], &ps.gather(&[first]))?
    };

    let ell = oversample * k as f64;
    for round in 0..rounds.max(1) {
        exec.on_round(round);
        let mut round_span = trace::Span::enter_with("shard.round", vec![("round", round.into())]);
        let timer = m.timer("shard.round_secs");
        // Global cost at fixed block boundaries: layout-invariant.
        let cost: f64 = partials.iter().sum();
        if !(cost > 0.0) || !cost.is_finite() {
            // Candidates already cover every point exactly.
            timer.stop();
            break;
        }
        let round_tag = splitmix64(stream_root ^ splitmix64(round as u64 ^ 0x9E37_79B9_7F4A_7C15));
        let new = {
            let _s = trace::Span::enter_with("shard.sample", vec![("round", round.into())]);
            exec.sample(round_tag, cost, ell)?
        };
        m.incr("shard.rounds", 1);
        m.incr("shard.candidates", new.len() as u64);
        stats.proposals += new.len() as u64;
        round_span.arg("candidates", new.len());
        if !new.is_empty() {
            let _s = trace::Span::enter_with("shard.update", vec![("round", round.into())]);
            partials = exec.update(&new, &ps.gather(&new))?;
            candidates.extend_from_slice(&new);
        }
        timer.stop();
    }

    // Candidate weights = per-candidate assignment counts, exact u64.
    let weigh_span =
        trace::Span::enter_with("shard.weigh", vec![("candidates", candidates.len().into())]);
    let weights_timer = m.timer("shard.weights_secs");
    let cand_ps = ps.gather(&candidates);
    let counts = exec.weigh(&cand_ps)?;
    let weights: Vec<f32> = counts.into_iter().map(|w| w as f32).collect();
    weights_timer.stop();
    drop(weigh_span);

    // Weighted recluster of the small candidate set down to k, resuming
    // the run RNG.
    let _recluster_span = trace::Span::enter("shard.recluster");
    let recluster_timer = m.timer("shard.recluster_secs");
    let wps = WeightedPointSet::new(cand_ps, weights);
    let sub = weighted_kmeanspp(&wps, k, rng);
    let mut indices: Vec<usize> = sub.indices.iter().map(|&ci| candidates[ci]).collect();
    // Degenerate top-up (fewer candidates than k on tiny inputs): honor
    // the k-distinct contract with arbitrary unchosen indices.
    if indices.len() < k {
        for i in 0..n {
            if indices.len() >= k {
                break;
            }
            if !indices.contains(&i) {
                indices.push(i);
            }
        }
    }
    recluster_timer.stop();
    stats.select_secs = t1.elapsed().as_secs_f64();
    Ok(Seeding::from_indices(ps, indices, stats))
}
