//! Binary wire codec for the distributed-fit RPC frames.
//!
//! Bulk rows are f32 matrices and cost partials are f64s that must
//! survive transport **bit-exactly** — JSON float round-tripping is both
//! overhead and a parity hazard — so frames are a little-endian binary
//! format: a `u32` magic, a fixed 24-byte [`TraceCtx`] envelope
//! (`trace_id`, `parent_span`, `round` — all-zero when untraced), a
//! `u8` frame tag, then tag-specific fields. Variable-length fields
//! carry explicit lengths (`u32` for row counts and strings, matching
//! `data/io.rs`'s `.fbin` header; `u64` for index and partial vectors).
//! Floats travel as `to_le_bytes` words, so NaNs and signed zeros
//! round-trip bit-for-bit.
//!
//! Decoding follows the same strictness discipline as
//! [`crate::server::json`]: a frame must consume the buffer *exactly* —
//! truncation, trailing garbage, a bad magic, an unknown tag, a `d = 0`
//! matrix, or a length field pointing past the buffer are all hard
//! errors, never best-effort parses.

use crate::bail;
use crate::data::matrix::PointSet;
use crate::error::{Context, Result};
use crate::trace::TraceArg;

/// Frame magic (`"FKM1"` little-endian) — a version bump is a new magic.
pub const MAGIC: u32 = 0x464B_4D31;

/// Trace context carried in every frame envelope, right after the
/// magic. All-zero means "untraced" — a worker receiving a nonzero
/// `trace_id` adopts it and starts recording; `parent_span` names the
/// coordinator-side `dist.rpc` span this RPC runs under and `round` the
/// k-means‖ round, both re-exported as span args so the merged timeline
/// links coordinator wire-time to worker compute-time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    pub parent_span: u64,
    pub round: u64,
}

/// One span crossing the wire in a [`Frame::TraceEvents`] response:
/// the worker-side [`crate::trace::SpanEvent`] with owned names/keys.
/// Timestamps are microseconds against the *worker's* trace epoch; the
/// coordinator shifts them using `epoch_unix_us` before merging.
#[derive(Clone, Debug, PartialEq)]
pub struct WireSpan {
    pub name: String,
    pub tid: u64,
    pub ts_us: f64,
    pub dur_us: f64,
    pub args: Vec<(String, TraceArg)>,
}

/// One RPC frame. Requests (coordinator → worker): [`Frame::ShardLoad`],
/// [`Frame::Update`], [`Frame::Sample`], [`Frame::Weigh`]. Responses
/// (worker → coordinator): [`Frame::Ack`], [`Frame::Partials`],
/// [`Frame::Candidates`], [`Frame::Counts`], [`Frame::Error`].
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Provision a worker: adopt `points` as the contiguous global row
    /// slice `[offset, offset + points.len())` of an `n_global`-row
    /// dataset. Resets all worker state; kernels are resolved on the
    /// *global* shape (the shard-engine invariance contract).
    ShardLoad {
        n_global: u64,
        offset: u64,
        points: PointSet,
    },
    /// Candidate broadcast: min-fold `rows` into the worker's `D²`
    /// slice and mark the in-range `indices` (global) as candidates.
    /// Response: [`Frame::Partials`].
    Update { indices: Vec<u64>, rows: PointSet },
    /// Poisson round: flip the per-(round, global index) coins.
    /// Response: [`Frame::Candidates`].
    Sample { round_tag: u64, cost: f64, ell: f64 },
    /// Final weigh: assign each local row to its nearest candidate row.
    /// Response: [`Frame::Counts`].
    Weigh { rows: PointSet },
    /// `ShardLoad` acknowledgement, echoing the adopted slice length.
    Ack { len: u64 },
    /// Fixed-[`crate::kernels::reduce::SUM_BLOCK`] f64 partial cost
    /// sums of the worker's `D²` slice, in ascending block order.
    Partials { sums: Vec<f64> },
    /// Accepted global indices, ascending.
    Candidates { indices: Vec<u64> },
    /// Per-candidate `u64` assignment counts over the worker's rows.
    Counts { counts: Vec<u64> },
    /// Typed failure (bad request, no shard loaded, ...): the message
    /// joins the coordinator's error chain.
    Error { message: String },
    /// End-of-run trace collection: ship back every span buffered since
    /// adoption (and clear the buffer). Response: [`Frame::TraceEvents`].
    TraceDump,
    /// The worker's buffered spans, plus the trace id it recorded under
    /// and its trace epoch as unix microseconds (the wall anchor the
    /// coordinator uses to shift `ts_us` onto its own timeline).
    TraceEvents {
        trace_id: u64,
        epoch_unix_us: f64,
        spans: Vec<WireSpan>,
    },
}

impl Frame {
    /// Variant name for logs, metrics, and trace span tags.
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::ShardLoad { .. } => "shard_load",
            Frame::Update { .. } => "update",
            Frame::Sample { .. } => "sample",
            Frame::Weigh { .. } => "weigh",
            Frame::Ack { .. } => "ack",
            Frame::Partials { .. } => "partials",
            Frame::Candidates { .. } => "candidates",
            Frame::Counts { .. } => "counts",
            Frame::Error { .. } => "error",
            Frame::TraceDump => "trace_dump",
            Frame::TraceEvents { .. } => "trace_events",
        }
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64s(out: &mut Vec<u8>, vs: &[u64]) {
    put_u64(out, vs.len() as u64);
    for &v in vs {
        put_u64(out, v);
    }
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    put_u64(out, vs.len() as u64);
    for &v in vs {
        put_f64(out, v);
    }
}

/// `.fbin`-shaped matrix payload: `u32 n`, `u32 d`, then `n·d` f32 LE.
fn put_points(out: &mut Vec<u8>, ps: &PointSet) {
    put_u32(out, ps.len() as u32);
    put_u32(out, ps.dim() as u32);
    for &x in ps.flat() {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

const ARG_U64: u8 = 0;
const ARG_F64: u8 = 1;
const ARG_STR: u8 = 2;

fn put_spans(out: &mut Vec<u8>, spans: &[WireSpan]) {
    put_u64(out, spans.len() as u64);
    for s in spans {
        put_str(out, &s.name);
        put_u64(out, s.tid);
        put_f64(out, s.ts_us);
        put_f64(out, s.dur_us);
        put_u64(out, s.args.len() as u64);
        for (k, v) in &s.args {
            put_str(out, k);
            match v {
                TraceArg::U64(u) => {
                    out.push(ARG_U64);
                    put_u64(out, *u);
                }
                TraceArg::F64(f) => {
                    out.push(ARG_F64);
                    put_f64(out, *f);
                }
                TraceArg::Str(t) => {
                    out.push(ARG_STR);
                    put_str(out, t);
                }
            }
        }
    }
}

/// Strict cursor over an encoded frame: every read is bounds-checked,
/// every length field is validated against the bytes actually present
/// (a corrupt length can never trigger a huge allocation), and
/// [`Reader::finish`] rejects trailing garbage.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "frame truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let len = self.u64()? as usize;
        if len > self.remaining() / 8 {
            bail!("vector length {len} exceeds frame");
        }
        (0..len).map(|_| self.u64()).collect()
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let len = self.u64()? as usize;
        if len > self.remaining() / 8 {
            bail!("vector length {len} exceeds frame");
        }
        (0..len).map(|_| self.f64()).collect()
    }

    fn points(&mut self) -> Result<PointSet> {
        let n = self.u32()? as usize;
        let d = self.u32()? as usize;
        if d == 0 {
            bail!("matrix payload with d = 0");
        }
        let total = n.checked_mul(d).context("matrix payload size overflow")?;
        if total > self.remaining() / 4 {
            bail!("matrix payload {n}x{d} exceeds frame");
        }
        let mut data = Vec::with_capacity(total);
        for _ in 0..total {
            data.push(self.f32()?);
        }
        Ok(PointSet::from_flat(n, d, data))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).context("frame string is not UTF-8")
    }

    fn spans(&mut self) -> Result<Vec<WireSpan>> {
        let len = self.u64()? as usize;
        // A span is at least 36 bytes (empty name + tid + ts + dur +
        // arg count) — reject corrupt lengths before allocating.
        if len > self.remaining() / 36 {
            bail!("span-vector length {len} exceeds frame");
        }
        let mut spans = Vec::with_capacity(len);
        for _ in 0..len {
            let name = self.string()?;
            let tid = self.u64()?;
            let ts_us = self.f64()?;
            let dur_us = self.f64()?;
            let n_args = self.u64()? as usize;
            // An arg is at least 9 bytes (empty key + tag + payload).
            if n_args > self.remaining() / 9 {
                bail!("arg-vector length {n_args} exceeds frame");
            }
            let mut args = Vec::with_capacity(n_args);
            for _ in 0..n_args {
                let key = self.string()?;
                let value = match self.u8()? {
                    ARG_U64 => TraceArg::U64(self.u64()?),
                    ARG_F64 => TraceArg::F64(self.f64()?),
                    ARG_STR => TraceArg::Str(self.string()?),
                    other => bail!("unknown span-arg tag {other}"),
                };
                args.push((key, value));
            }
            spans.push(WireSpan {
                name,
                tid,
                ts_us,
                dur_us,
                args,
            });
        }
        Ok(spans)
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes after frame", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

const TAG_SHARD_LOAD: u8 = 0;
const TAG_UPDATE: u8 = 1;
const TAG_SAMPLE: u8 = 2;
const TAG_WEIGH: u8 = 3;
const TAG_ACK: u8 = 4;
const TAG_PARTIALS: u8 = 5;
const TAG_CANDIDATES: u8 = 6;
const TAG_COUNTS: u8 = 7;
const TAG_ERROR: u8 = 8;
const TAG_TRACE_DUMP: u8 = 9;
const TAG_TRACE_EVENTS: u8 = 10;

impl Frame {
    /// Serialize with an all-zero (untraced) envelope.
    pub fn encode(&self) -> Vec<u8> {
        self.encode_with(&TraceCtx::default())
    }

    /// Serialize to the binary wire form under `ctx`.
    pub fn encode_with(&self, ctx: &TraceCtx) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, MAGIC);
        put_u64(&mut out, ctx.trace_id);
        put_u64(&mut out, ctx.parent_span);
        put_u64(&mut out, ctx.round);
        match self {
            Frame::ShardLoad {
                n_global,
                offset,
                points,
            } => {
                out.push(TAG_SHARD_LOAD);
                put_u64(&mut out, *n_global);
                put_u64(&mut out, *offset);
                put_points(&mut out, points);
            }
            Frame::Update { indices, rows } => {
                out.push(TAG_UPDATE);
                put_u64s(&mut out, indices);
                put_points(&mut out, rows);
            }
            Frame::Sample {
                round_tag,
                cost,
                ell,
            } => {
                out.push(TAG_SAMPLE);
                put_u64(&mut out, *round_tag);
                put_f64(&mut out, *cost);
                put_f64(&mut out, *ell);
            }
            Frame::Weigh { rows } => {
                out.push(TAG_WEIGH);
                put_points(&mut out, rows);
            }
            Frame::Ack { len } => {
                out.push(TAG_ACK);
                put_u64(&mut out, *len);
            }
            Frame::Partials { sums } => {
                out.push(TAG_PARTIALS);
                put_f64s(&mut out, sums);
            }
            Frame::Candidates { indices } => {
                out.push(TAG_CANDIDATES);
                put_u64s(&mut out, indices);
            }
            Frame::Counts { counts } => {
                out.push(TAG_COUNTS);
                put_u64s(&mut out, counts);
            }
            Frame::Error { message } => {
                out.push(TAG_ERROR);
                put_str(&mut out, message);
            }
            Frame::TraceDump => {
                out.push(TAG_TRACE_DUMP);
            }
            Frame::TraceEvents {
                trace_id,
                epoch_unix_us,
                spans,
            } => {
                out.push(TAG_TRACE_EVENTS);
                put_u64(&mut out, *trace_id);
                put_f64(&mut out, *epoch_unix_us);
                put_spans(&mut out, spans);
            }
        }
        out
    }

    /// Strict decode, discarding the trace envelope.
    pub fn decode(buf: &[u8]) -> Result<Frame> {
        Frame::decode_with(buf).map(|(_, frame)| frame)
    }

    /// Strict decode: the buffer must hold exactly one frame; returns
    /// the trace envelope alongside it.
    pub fn decode_with(buf: &[u8]) -> Result<(TraceCtx, Frame)> {
        let mut r = Reader { buf, pos: 0 };
        let magic = r.u32()?;
        if magic != MAGIC {
            bail!("bad frame magic {magic:#010x} (want {MAGIC:#010x})");
        }
        let ctx = TraceCtx {
            trace_id: r.u64()?,
            parent_span: r.u64()?,
            round: r.u64()?,
        };
        let tag = r.u8()?;
        let frame = match tag {
            TAG_SHARD_LOAD => Frame::ShardLoad {
                n_global: r.u64()?,
                offset: r.u64()?,
                points: r.points()?,
            },
            TAG_UPDATE => Frame::Update {
                indices: r.u64s()?,
                rows: r.points()?,
            },
            TAG_SAMPLE => Frame::Sample {
                round_tag: r.u64()?,
                cost: r.f64()?,
                ell: r.f64()?,
            },
            TAG_WEIGH => Frame::Weigh { rows: r.points()? },
            TAG_ACK => Frame::Ack { len: r.u64()? },
            TAG_PARTIALS => Frame::Partials { sums: r.f64s()? },
            TAG_CANDIDATES => Frame::Candidates { indices: r.u64s()? },
            TAG_COUNTS => Frame::Counts { counts: r.u64s()? },
            TAG_ERROR => Frame::Error {
                message: r.string()?,
            },
            TAG_TRACE_DUMP => Frame::TraceDump,
            TAG_TRACE_EVENTS => Frame::TraceEvents {
                trace_id: r.u64()?,
                epoch_unix_us: r.f64()?,
                spans: r.spans()?,
            },
            other => bail!("unknown frame tag {other}"),
        };
        r.finish()?;
        Ok((ctx, frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ps(n: usize, d: usize) -> PointSet {
        // Deterministic, sign-varied values including exact zeros.
        let data: Vec<f32> = (0..n * d)
            .map(|i| (i as f32 - 3.5) * if i % 2 == 0 { 1.25 } else { -0.75 })
            .collect();
        PointSet::from_flat(n, d, data)
    }

    /// Every frame variant over empty / 1-point / odd-d payloads.
    fn corpus() -> Vec<Frame> {
        let mut frames = Vec::new();
        for &(n, d) in &[(0usize, 3usize), (1, 1), (1, 7), (5, 3), (4, 7)] {
            frames.push(Frame::ShardLoad {
                n_global: 1_000_000,
                offset: 4096,
                points: ps(n, d),
            });
            frames.push(Frame::Update {
                indices: (0..n as u64).map(|i| i * 17 + 3).collect(),
                rows: ps(n, d),
            });
            frames.push(Frame::Weigh { rows: ps(n, d) });
        }
        frames.push(Frame::Sample {
            round_tag: 0xDEAD_BEEF_CAFE_F00D,
            cost: 1.234e12,
            ell: 24.0,
        });
        // Bit-exactness stressors: negative zero, subnormal, NaN-free
        // extremes (NaN breaks PartialEq round-trip assertions; its
        // byte-level fidelity is covered separately below).
        frames.push(Frame::Partials {
            sums: vec![-0.0, f64::MIN_POSITIVE / 2.0, 1e300, -1e-300],
        });
        frames.push(Frame::Partials { sums: Vec::new() });
        frames.push(Frame::Candidates {
            indices: vec![0, 1, u64::MAX],
        });
        frames.push(Frame::Candidates { indices: Vec::new() });
        frames.push(Frame::Counts {
            counts: vec![3, 0, u64::MAX, 7],
        });
        frames.push(Frame::Ack { len: 8192 });
        frames.push(Frame::Error {
            message: "no shard loaded".into(),
        });
        frames.push(Frame::Error {
            message: String::new(),
        });
        frames.push(Frame::TraceDump);
        frames.push(Frame::TraceEvents {
            trace_id: 0,
            epoch_unix_us: 0.0,
            spans: Vec::new(),
        });
        frames.push(Frame::TraceEvents {
            trace_id: 0x1234_5678_9ABC_DEF0,
            epoch_unix_us: 1.7e15,
            spans: vec![
                WireSpan {
                    name: "worker.rpc".into(),
                    tid: 3,
                    ts_us: 12.5,
                    dur_us: 1000.0,
                    args: vec![
                        ("kind".into(), TraceArg::Str("update".into())),
                        ("round".into(), TraceArg::U64(2)),
                        ("secs".into(), TraceArg::F64(-0.0)),
                        ("".into(), TraceArg::Str(String::new())),
                    ],
                },
                WireSpan {
                    name: String::new(),
                    tid: 0,
                    ts_us: 0.0,
                    dur_us: 0.0,
                    args: Vec::new(),
                },
            ],
        });
        frames
    }

    #[test]
    fn round_trips_bit_exactly() {
        for frame in corpus() {
            let buf = frame.encode();
            let (ctx, back) =
                Frame::decode_with(&buf).unwrap_or_else(|e| panic!("{frame:?}: {e:#}"));
            assert_eq!(back, frame);
            assert_eq!(ctx, TraceCtx::default());
            // Encoding is canonical: re-encoding reproduces the bytes.
            assert_eq!(back.encode(), buf, "{frame:?}");
        }
    }

    #[test]
    fn trace_context_round_trips_canonically() {
        let ctx = TraceCtx {
            trace_id: 0xA1B2_C3D4_E5F6_0718,
            parent_span: 42,
            round: 7,
        };
        for frame in corpus() {
            let buf = frame.encode_with(&ctx);
            let (back_ctx, back) =
                Frame::decode_with(&buf).unwrap_or_else(|e| panic!("{frame:?}: {e:#}"));
            assert_eq!(back_ctx, ctx, "{frame:?}");
            assert_eq!(back, frame);
            assert_eq!(back.encode_with(&ctx), buf, "{frame:?}");
            // The envelope never changes the payload length, only the
            // fixed 24-byte header after the magic.
            assert_eq!(buf.len(), frame.encode().len(), "{frame:?}");
        }
    }

    #[test]
    fn nan_partials_round_trip_by_bits() {
        let sums = vec![f64::NAN, -f64::NAN, f64::INFINITY];
        let buf = Frame::Partials { sums: sums.clone() }.encode();
        match Frame::decode(&buf).unwrap() {
            Frame::Partials { sums: back } => {
                for (a, b) in back.iter().zip(&sums) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn every_strict_prefix_is_rejected() {
        // Lengths are explicit, so no prefix of a valid frame can itself
        // decode (the json.rs truncation discipline).
        for frame in corpus() {
            let buf = frame.encode();
            for cut in 0..buf.len() {
                assert!(
                    Frame::decode(&buf[..cut]).is_err(),
                    "{frame:?}: prefix of {cut}/{} bytes decoded",
                    buf.len()
                );
            }
        }
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        for frame in corpus() {
            let mut buf = frame.encode();
            buf.push(0);
            let e = Frame::decode(&buf).unwrap_err();
            assert!(
                format!("{e:#}").contains("trailing"),
                "{frame:?}: wrong error {e:#}"
            );
        }
    }

    #[test]
    fn bad_magic_tag_and_corrupt_lengths_are_rejected() {
        assert!(Frame::decode(&[]).is_err());
        assert!(Frame::decode(&[0x31, 0x4D, 0x4B, 0x46]).is_err()); // magic only
        let mut wrong_magic = Frame::Ack { len: 1 }.encode();
        wrong_magic[0] ^= 0xFF;
        assert!(format!("{:#}", Frame::decode(&wrong_magic).unwrap_err()).contains("magic"));
        // The tag sits after the 4-byte magic + 24-byte trace envelope.
        let mut bad_tag = Frame::Ack { len: 1 }.encode();
        bad_tag[28] = 200;
        assert!(format!("{:#}", Frame::decode(&bad_tag).unwrap_err()).contains("tag"));
        // A length field pointing far past the buffer must error cleanly
        // (no attempted giant allocation).
        let mut huge_len = Frame::Candidates { indices: vec![1] }.encode();
        huge_len[29..37].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Frame::decode(&huge_len).is_err());
        let mut huge_spans = Frame::TraceEvents {
            trace_id: 1,
            epoch_unix_us: 0.0,
            spans: Vec::new(),
        }
        .encode();
        let spans_len_at = huge_spans.len() - 8;
        huge_spans[spans_len_at..].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Frame::decode(&huge_spans).is_err());
        // d = 0 matrices are invalid on the wire as everywhere else.
        let mut zero_d = Frame::Weigh { rows: ps(0, 3) }.encode();
        zero_d[33..37].copy_from_slice(&0u32.to_le_bytes());
        assert!(format!("{:#}", Frame::decode(&zero_d).unwrap_err()).contains("d = 0"));
    }
}
