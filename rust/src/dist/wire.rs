//! Binary wire codec for the distributed-fit RPC frames.
//!
//! Bulk rows are f32 matrices and cost partials are f64s that must
//! survive transport **bit-exactly** — JSON float round-tripping is both
//! overhead and a parity hazard — so frames are a little-endian binary
//! format: a `u32` magic, a `u8` frame tag, then tag-specific fields.
//! Variable-length fields carry explicit lengths (`u32` for row counts
//! and strings, matching `data/io.rs`'s `.fbin` header; `u64` for index
//! and partial vectors). Floats travel as `to_le_bytes` words, so NaNs
//! and signed zeros round-trip bit-for-bit.
//!
//! Decoding follows the same strictness discipline as
//! [`crate::server::json`]: a frame must consume the buffer *exactly* —
//! truncation, trailing garbage, a bad magic, an unknown tag, a `d = 0`
//! matrix, or a length field pointing past the buffer are all hard
//! errors, never best-effort parses.

use crate::bail;
use crate::data::matrix::PointSet;
use crate::error::{Context, Result};

/// Frame magic (`"FKM1"` little-endian) — a version bump is a new magic.
pub const MAGIC: u32 = 0x464B_4D31;

/// One RPC frame. Requests (coordinator → worker): [`Frame::ShardLoad`],
/// [`Frame::Update`], [`Frame::Sample`], [`Frame::Weigh`]. Responses
/// (worker → coordinator): [`Frame::Ack`], [`Frame::Partials`],
/// [`Frame::Candidates`], [`Frame::Counts`], [`Frame::Error`].
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Provision a worker: adopt `points` as the contiguous global row
    /// slice `[offset, offset + points.len())` of an `n_global`-row
    /// dataset. Resets all worker state; kernels are resolved on the
    /// *global* shape (the shard-engine invariance contract).
    ShardLoad {
        n_global: u64,
        offset: u64,
        points: PointSet,
    },
    /// Candidate broadcast: min-fold `rows` into the worker's `D²`
    /// slice and mark the in-range `indices` (global) as candidates.
    /// Response: [`Frame::Partials`].
    Update { indices: Vec<u64>, rows: PointSet },
    /// Poisson round: flip the per-(round, global index) coins.
    /// Response: [`Frame::Candidates`].
    Sample { round_tag: u64, cost: f64, ell: f64 },
    /// Final weigh: assign each local row to its nearest candidate row.
    /// Response: [`Frame::Counts`].
    Weigh { rows: PointSet },
    /// `ShardLoad` acknowledgement, echoing the adopted slice length.
    Ack { len: u64 },
    /// Fixed-[`crate::kernels::reduce::SUM_BLOCK`] f64 partial cost
    /// sums of the worker's `D²` slice, in ascending block order.
    Partials { sums: Vec<f64> },
    /// Accepted global indices, ascending.
    Candidates { indices: Vec<u64> },
    /// Per-candidate `u64` assignment counts over the worker's rows.
    Counts { counts: Vec<u64> },
    /// Typed failure (bad request, no shard loaded, ...): the message
    /// joins the coordinator's error chain.
    Error { message: String },
}

impl Frame {
    /// Variant name for logs, metrics, and trace span tags.
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::ShardLoad { .. } => "shard_load",
            Frame::Update { .. } => "update",
            Frame::Sample { .. } => "sample",
            Frame::Weigh { .. } => "weigh",
            Frame::Ack { .. } => "ack",
            Frame::Partials { .. } => "partials",
            Frame::Candidates { .. } => "candidates",
            Frame::Counts { .. } => "counts",
            Frame::Error { .. } => "error",
        }
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64s(out: &mut Vec<u8>, vs: &[u64]) {
    put_u64(out, vs.len() as u64);
    for &v in vs {
        put_u64(out, v);
    }
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    put_u64(out, vs.len() as u64);
    for &v in vs {
        put_f64(out, v);
    }
}

/// `.fbin`-shaped matrix payload: `u32 n`, `u32 d`, then `n·d` f32 LE.
fn put_points(out: &mut Vec<u8>, ps: &PointSet) {
    put_u32(out, ps.len() as u32);
    put_u32(out, ps.dim() as u32);
    for &x in ps.flat() {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Strict cursor over an encoded frame: every read is bounds-checked,
/// every length field is validated against the bytes actually present
/// (a corrupt length can never trigger a huge allocation), and
/// [`Reader::finish`] rejects trailing garbage.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "frame truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let len = self.u64()? as usize;
        if len > self.remaining() / 8 {
            bail!("vector length {len} exceeds frame");
        }
        (0..len).map(|_| self.u64()).collect()
    }

    fn f64s(&mut self) -> Result<Vec<f64>> {
        let len = self.u64()? as usize;
        if len > self.remaining() / 8 {
            bail!("vector length {len} exceeds frame");
        }
        (0..len).map(|_| self.f64()).collect()
    }

    fn points(&mut self) -> Result<PointSet> {
        let n = self.u32()? as usize;
        let d = self.u32()? as usize;
        if d == 0 {
            bail!("matrix payload with d = 0");
        }
        let total = n.checked_mul(d).context("matrix payload size overflow")?;
        if total > self.remaining() / 4 {
            bail!("matrix payload {n}x{d} exceeds frame");
        }
        let mut data = Vec::with_capacity(total);
        for _ in 0..total {
            data.push(self.f32()?);
        }
        Ok(PointSet::from_flat(n, d, data))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).context("frame string is not UTF-8")
    }

    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes after frame", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

const TAG_SHARD_LOAD: u8 = 0;
const TAG_UPDATE: u8 = 1;
const TAG_SAMPLE: u8 = 2;
const TAG_WEIGH: u8 = 3;
const TAG_ACK: u8 = 4;
const TAG_PARTIALS: u8 = 5;
const TAG_CANDIDATES: u8 = 6;
const TAG_COUNTS: u8 = 7;
const TAG_ERROR: u8 = 8;

impl Frame {
    /// Serialize to the binary wire form.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, MAGIC);
        match self {
            Frame::ShardLoad {
                n_global,
                offset,
                points,
            } => {
                out.push(TAG_SHARD_LOAD);
                put_u64(&mut out, *n_global);
                put_u64(&mut out, *offset);
                put_points(&mut out, points);
            }
            Frame::Update { indices, rows } => {
                out.push(TAG_UPDATE);
                put_u64s(&mut out, indices);
                put_points(&mut out, rows);
            }
            Frame::Sample {
                round_tag,
                cost,
                ell,
            } => {
                out.push(TAG_SAMPLE);
                put_u64(&mut out, *round_tag);
                put_f64(&mut out, *cost);
                put_f64(&mut out, *ell);
            }
            Frame::Weigh { rows } => {
                out.push(TAG_WEIGH);
                put_points(&mut out, rows);
            }
            Frame::Ack { len } => {
                out.push(TAG_ACK);
                put_u64(&mut out, *len);
            }
            Frame::Partials { sums } => {
                out.push(TAG_PARTIALS);
                put_f64s(&mut out, sums);
            }
            Frame::Candidates { indices } => {
                out.push(TAG_CANDIDATES);
                put_u64s(&mut out, indices);
            }
            Frame::Counts { counts } => {
                out.push(TAG_COUNTS);
                put_u64s(&mut out, counts);
            }
            Frame::Error { message } => {
                out.push(TAG_ERROR);
                put_str(&mut out, message);
            }
        }
        out
    }

    /// Strict decode: the buffer must hold exactly one frame.
    pub fn decode(buf: &[u8]) -> Result<Frame> {
        let mut r = Reader { buf, pos: 0 };
        let magic = r.u32()?;
        if magic != MAGIC {
            bail!("bad frame magic {magic:#010x} (want {MAGIC:#010x})");
        }
        let tag = r.u8()?;
        let frame = match tag {
            TAG_SHARD_LOAD => Frame::ShardLoad {
                n_global: r.u64()?,
                offset: r.u64()?,
                points: r.points()?,
            },
            TAG_UPDATE => Frame::Update {
                indices: r.u64s()?,
                rows: r.points()?,
            },
            TAG_SAMPLE => Frame::Sample {
                round_tag: r.u64()?,
                cost: r.f64()?,
                ell: r.f64()?,
            },
            TAG_WEIGH => Frame::Weigh { rows: r.points()? },
            TAG_ACK => Frame::Ack { len: r.u64()? },
            TAG_PARTIALS => Frame::Partials { sums: r.f64s()? },
            TAG_CANDIDATES => Frame::Candidates { indices: r.u64s()? },
            TAG_COUNTS => Frame::Counts { counts: r.u64s()? },
            TAG_ERROR => Frame::Error {
                message: r.string()?,
            },
            other => bail!("unknown frame tag {other}"),
        };
        r.finish()?;
        Ok(frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ps(n: usize, d: usize) -> PointSet {
        // Deterministic, sign-varied values including exact zeros.
        let data: Vec<f32> = (0..n * d)
            .map(|i| (i as f32 - 3.5) * if i % 2 == 0 { 1.25 } else { -0.75 })
            .collect();
        PointSet::from_flat(n, d, data)
    }

    /// Every frame variant over empty / 1-point / odd-d payloads.
    fn corpus() -> Vec<Frame> {
        let mut frames = Vec::new();
        for &(n, d) in &[(0usize, 3usize), (1, 1), (1, 7), (5, 3), (4, 7)] {
            frames.push(Frame::ShardLoad {
                n_global: 1_000_000,
                offset: 4096,
                points: ps(n, d),
            });
            frames.push(Frame::Update {
                indices: (0..n as u64).map(|i| i * 17 + 3).collect(),
                rows: ps(n, d),
            });
            frames.push(Frame::Weigh { rows: ps(n, d) });
        }
        frames.push(Frame::Sample {
            round_tag: 0xDEAD_BEEF_CAFE_F00D,
            cost: 1.234e12,
            ell: 24.0,
        });
        // Bit-exactness stressors: negative zero, subnormal, NaN-free
        // extremes (NaN breaks PartialEq round-trip assertions; its
        // byte-level fidelity is covered separately below).
        frames.push(Frame::Partials {
            sums: vec![-0.0, f64::MIN_POSITIVE / 2.0, 1e300, -1e-300],
        });
        frames.push(Frame::Partials { sums: Vec::new() });
        frames.push(Frame::Candidates {
            indices: vec![0, 1, u64::MAX],
        });
        frames.push(Frame::Candidates { indices: Vec::new() });
        frames.push(Frame::Counts {
            counts: vec![3, 0, u64::MAX, 7],
        });
        frames.push(Frame::Ack { len: 8192 });
        frames.push(Frame::Error {
            message: "no shard loaded".into(),
        });
        frames.push(Frame::Error {
            message: String::new(),
        });
        frames
    }

    #[test]
    fn round_trips_bit_exactly() {
        for frame in corpus() {
            let buf = frame.encode();
            let back = Frame::decode(&buf).unwrap_or_else(|e| panic!("{frame:?}: {e:#}"));
            assert_eq!(back, frame);
            // Encoding is canonical: re-encoding reproduces the bytes.
            assert_eq!(back.encode(), buf, "{frame:?}");
        }
    }

    #[test]
    fn nan_partials_round_trip_by_bits() {
        let sums = vec![f64::NAN, -f64::NAN, f64::INFINITY];
        let buf = Frame::Partials { sums: sums.clone() }.encode();
        match Frame::decode(&buf).unwrap() {
            Frame::Partials { sums: back } => {
                for (a, b) in back.iter().zip(&sums) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn every_strict_prefix_is_rejected() {
        // Lengths are explicit, so no prefix of a valid frame can itself
        // decode (the json.rs truncation discipline).
        for frame in corpus() {
            let buf = frame.encode();
            for cut in 0..buf.len() {
                assert!(
                    Frame::decode(&buf[..cut]).is_err(),
                    "{frame:?}: prefix of {cut}/{} bytes decoded",
                    buf.len()
                );
            }
        }
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        for frame in corpus() {
            let mut buf = frame.encode();
            buf.push(0);
            let e = Frame::decode(&buf).unwrap_err();
            assert!(
                format!("{e:#}").contains("trailing"),
                "{frame:?}: wrong error {e:#}"
            );
        }
    }

    #[test]
    fn bad_magic_tag_and_corrupt_lengths_are_rejected() {
        assert!(Frame::decode(&[]).is_err());
        assert!(Frame::decode(&[0x31, 0x4D, 0x4B, 0x46]).is_err()); // magic only
        let mut wrong_magic = Frame::Ack { len: 1 }.encode();
        wrong_magic[0] ^= 0xFF;
        assert!(format!("{:#}", Frame::decode(&wrong_magic).unwrap_err()).contains("magic"));
        let mut bad_tag = Frame::Ack { len: 1 }.encode();
        bad_tag[4] = 200;
        assert!(format!("{:#}", Frame::decode(&bad_tag).unwrap_err()).contains("tag"));
        // A length field pointing far past the buffer must error cleanly
        // (no attempted giant allocation).
        let mut huge_len = Frame::Candidates { indices: vec![1] }.encode();
        huge_len[5..13].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(Frame::decode(&huge_len).is_err());
        // d = 0 matrices are invalid on the wire as everywhere else.
        let mut zero_d = Frame::Weigh { rows: ps(0, 3) }.encode();
        zero_d[9..13].copy_from_slice(&0u32.to_le_bytes());
        assert!(format!("{:#}", Frame::decode(&zero_d).unwrap_err()).contains("d = 0"));
    }
}
