//! `fkmpp worker` — one distributed-fit shard node.
//!
//! A worker is provisioned with a contiguous global row slice
//! ([`crate::dist::wire::Frame::ShardLoad`]) and then answers the
//! per-round RPCs over the PR 2 HTTP layer (one `POST /rpc` per frame,
//! `Connection: close`, binary bodies — see [`crate::dist::wire`]):
//!
//! * `Update` → `Partials`: min-fold the broadcast candidate rows into
//!   the local `D²` slice and return its fixed-block f64 partial sums.
//!   Because slices are aligned to
//!   [`crate::kernels::reduce::SUM_BLOCK`], the local blocks ARE global
//!   summation blocks.
//! * `Sample` → `Candidates`: flip the per-(round, global index)
//!   membership coins ([`crate::shard::kmeanspar::point_uniform`]) over
//!   the local rows.
//! * `Weigh` → `Counts`: nearest-candidate assignment counts.
//!
//! Kernels are resolved on the **global** shape shipped in `ShardLoad`
//! — never the slice shape — mirroring the in-process engine, so every
//! worker computes identical bits (with `FKMPP_KERNEL` pinned across
//! processes, the PR 3 contract). Worker state is a pure fold of the
//! broadcast history: a restarted worker answers `Error("no shard
//! loaded")` until the coordinator re-provisions it, and replaying the
//! history reconstructs the identical `D²` bits (min-folds are
//! idempotent and order-free) — that is the whole recovery story.
//!
//! `GET /healthz` answers liveness probes; `POST /shutdown` stops the
//! accept loop. `--fail-after N` is the fault-injection hook for the
//! parity harness: after fully serving `N` `/rpc` requests the worker
//! exits *mid-request* on the next one — after reading the request,
//! before writing any response byte — the worst crash point a
//! coordinator can observe.

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use crate::bail;
use crate::data::matrix::PointSet;
use crate::dist::wire::{Frame, WireSpan};
use crate::error::{Context, Result};
use crate::kernels::{assign, blocked, d2 as d2_kernel, norms, reduce, tune};
use crate::metrics;
use crate::server::http::{read_request, write_response, Request, Response};
use crate::shard::kmeanspar::point_uniform;
use crate::trace;

/// Worker knobs (`fkmpp worker --port N [--host H] [--fail-after N]`).
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    /// Bind host.
    pub host: String,
    /// Bind port (`0` = ephemeral; the chosen port is printed on the
    /// ready line).
    pub port: u16,
    /// Fault injection: serve this many `/rpc` requests, then exit the
    /// process (status 3) mid-request on the next one.
    pub fail_after: Option<u64>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        WorkerConfig {
            host: "127.0.0.1".to_string(),
            port: 0,
            fail_after: None,
        }
    }
}

/// The provisioned slice: rows, caches, and the fold state. Installed
/// (and reset) by `ShardLoad`.
struct ShardState {
    n_global: usize,
    offset: usize,
    points: PointSet,
    /// Per-row `‖x‖²` cache (v2 kernel discipline), slice lifetime.
    norms: Vec<f32>,
    /// This worker's slice of the global `D²` array.
    cur_d2: Vec<f32>,
    /// Local candidate marks (indexed by local row).
    is_candidate: Vec<bool>,
    /// Update kernel, resolved once on the global shape at load time.
    upd_kernel: tune::Kernel,
}

/// Dispatch one request frame against the worker state. Failures come
/// back as [`Frame::Error`] so the transport layer stays infallible.
fn handle_frame(state: &mut Option<ShardState>, frame: Frame) -> Frame {
    match run_frame(state, frame) {
        Ok(resp) => resp,
        Err(e) => Frame::Error {
            message: format!("{e:#}"),
        },
    }
}

fn run_frame(state: &mut Option<ShardState>, frame: Frame) -> Result<Frame> {
    match frame {
        Frame::ShardLoad {
            n_global,
            offset,
            points,
        } => {
            let n_global = n_global as usize;
            let offset = offset as usize;
            if points.is_empty() {
                bail!("refusing to load an empty shard slice");
            }
            if offset + points.len() > n_global {
                bail!(
                    "slice [{offset}, {}) exceeds n_global {n_global}",
                    offset + points.len()
                );
            }
            let _span =
                trace::Span::enter_with("worker.load", vec![("rows", points.len().into())]);
            let norms = norms::squared_norms(&points);
            // GLOBAL shape, not the slice shape: per-worker dispatch on
            // slice sizes would break cross-layout bit-invariance.
            let upd_kernel = tune::kernel_for(tune::Op::Update, n_global, points.dim(), 1);
            let len = points.len();
            *state = Some(ShardState {
                n_global,
                offset,
                norms,
                cur_d2: vec![f32::INFINITY; len],
                is_candidate: vec![false; len],
                upd_kernel,
                points,
            });
            Ok(Frame::Ack { len: len as u64 })
        }
        Frame::Update { indices, rows } => {
            let st = state.as_mut().context("no shard loaded")?;
            if rows.dim() != st.points.dim() {
                bail!(
                    "update dimension {} != shard dimension {}",
                    rows.dim(),
                    st.points.dim()
                );
            }
            if indices.len() != rows.len() {
                bail!("{} indices for {} rows", indices.len(), rows.len());
            }
            let _span =
                trace::Span::enter_with("worker.update", vec![("candidates", rows.len().into())]);
            for &i in &indices {
                let i = i as usize;
                if i >= st.offset && i < st.offset + st.points.len() {
                    st.is_candidate[i - st.offset] = true;
                }
            }
            for c in 0..rows.len() {
                let row = rows.row(c);
                match st.upd_kernel {
                    tune::Kernel::Naive => d2_kernel::d2_update_min(&st.points, row, &mut st.cur_d2),
                    tune::Kernel::Blocked => {
                        blocked::d2_update_min_blocked(&st.points, row, &st.norms, &mut st.cur_d2)
                    }
                }
            }
            // Aligned slices make local blocks global blocks, so these
            // partials concatenate into the global sum_f32 bit-for-bit.
            Ok(Frame::Partials {
                sums: reduce::block_sums(&st.cur_d2, reduce::SUM_BLOCK),
            })
        }
        Frame::Sample {
            round_tag,
            cost,
            ell,
        } => {
            let st = state.as_ref().context("no shard loaded")?;
            let mut span = trace::Span::enter("worker.sample");
            let mut accepted = Vec::new();
            for r in 0..st.points.len() {
                if st.is_candidate[r] {
                    continue;
                }
                let di = st.cur_d2[r] as f64;
                if di <= 0.0 {
                    continue;
                }
                let i = (st.offset + r) as u64;
                if point_uniform(round_tag, i) * cost < ell * di {
                    accepted.push(i);
                }
            }
            span.arg("accepted", accepted.len());
            Ok(Frame::Candidates { indices: accepted })
        }
        Frame::Weigh { rows } => {
            let st = state.as_ref().context("no shard loaded")?;
            if rows.is_empty() {
                bail!("weigh with no candidate rows");
            }
            if rows.dim() != st.points.dim() {
                bail!(
                    "weigh dimension {} != shard dimension {}",
                    rows.dim(),
                    st.points.dim()
                );
            }
            let _span =
                trace::Span::enter_with("worker.weigh", vec![("candidates", rows.len().into())]);
            // Global shape again — the same resolution the in-process
            // engine performs once per weigh.
            let asg_kernel =
                tune::kernel_for(tune::Op::Assign, st.n_global, st.points.dim(), rows.len());
            let (labels, _) = match asg_kernel {
                tune::Kernel::Naive => assign::assign_argmin_naive(&st.points, &rows),
                tune::Kernel::Blocked => {
                    let cand_norms = norms::squared_norms(&rows);
                    blocked::assign_argmin_blocked(&st.points, &st.norms, &rows, &cand_norms)
                }
            };
            let mut counts = vec![0u64; rows.len()];
            for &l in &labels {
                counts[l as usize] += 1;
            }
            Ok(Frame::Counts { counts })
        }
        other => bail!("unexpected request frame {other:?}"),
    }
}

fn binary_response(status: u16, body: Vec<u8>) -> Response {
    Response::binary(status, body)
}

/// Answer a `TraceDump`: everything buffered since this worker adopted
/// the coordinator's trace, then drop it so the next run starts clean.
/// A worker that never adopted (tracing belongs to the host process —
/// the in-process worker-thread tests) answers empty and leaves the
/// shared sink alone.
fn trace_dump_frame(trace_adopted: bool) -> Frame {
    if !trace_adopted {
        return Frame::TraceEvents {
            trace_id: 0,
            epoch_unix_us: 0.0,
            spans: Vec::new(),
        };
    }
    let spans = trace::snapshot_events()
        .into_iter()
        .map(|e| WireSpan {
            name: e.name.to_string(),
            tid: e.tid,
            ts_us: e.ts_us,
            dur_us: e.dur_us,
            args: e
                .args
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        })
        .collect();
    trace::clear();
    Frame::TraceEvents {
        trace_id: trace::trace_id(),
        epoch_unix_us: trace::epoch_unix_us(),
        spans,
    }
}

fn route(
    state: &mut Option<ShardState>,
    served: &mut u64,
    trace_adopted: &mut bool,
    cfg: &WorkerConfig,
    req: &Request,
) -> (Response, bool) {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => (Response::text(200, "ok\n"), false),
        ("POST", "/shutdown") => (Response::text(200, "bye\n"), true),
        ("POST", "/rpc") => {
            if let Some(limit) = cfg.fail_after {
                if *served >= limit {
                    // Fault injection: the request is fully read but no
                    // response byte is ever written — the coordinator
                    // sees a connection reset mid-RPC.
                    std::process::exit(3);
                }
            }
            *served += 1;
            metrics::global().incr("dist.worker.rpcs", 1);
            let decoded = Frame::decode_with(&req.body);
            if let Ok((ctx, _)) = &decoded {
                // Adopt the coordinator's trace context exactly once —
                // and never when tracing is already live in this process
                // (worker threads in the parity tests share the host's
                // sink; stealing it would wipe the host's spans on
                // dump).
                if ctx.trace_id != 0 && !*trace_adopted && !trace::enabled() {
                    trace::set_trace_id(ctx.trace_id);
                    trace::set_enabled(true);
                    *trace_adopted = true;
                }
            }
            let mut span = crate::trace::Span::enter_with(
                "worker.rpc",
                vec![("bytes_in", req.body.len().into())],
            );
            let resp = match decoded {
                Ok((_, Frame::TraceDump)) => {
                    span.arg("kind", "trace_dump");
                    trace_dump_frame(*trace_adopted)
                }
                Ok((ctx, frame)) => {
                    span.arg("kind", frame.kind());
                    if ctx.parent_span != 0 {
                        span.arg("parent_span", ctx.parent_span);
                    }
                    if ctx.trace_id != 0 {
                        span.arg("round", ctx.round);
                    }
                    handle_frame(state, frame)
                }
                Err(e) => Frame::Error {
                    message: format!("{e:#}"),
                },
            };
            let status = if matches!(resp, Frame::Error { .. }) {
                400
            } else {
                200
            };
            span.arg("status", status as u64);
            let body = resp.encode();
            span.arg("bytes_out", body.len());
            (binary_response(status, body), false)
        }
        _ => (Response::text(404, "not found\n"), false),
    }
}

/// Accept loop over an already-bound listener — the test-friendly entry
/// point (bind port 0 yourself, keep the address). Serves one request
/// per connection (the coordinator's RPCs are strictly sequential) and
/// returns after `POST /shutdown`.
pub fn serve(listener: TcpListener, cfg: &WorkerConfig) -> Result<()> {
    let m = metrics::global();
    let mut state: Option<ShardState> = None;
    let mut served: u64 = 0;
    let mut trace_adopted = false;
    for conn in listener.incoming() {
        let mut stream: TcpStream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
        stream.set_write_timeout(Some(Duration::from_secs(30))).ok();
        // One request per connection by design (the coordinator's RPCs
        // are strictly sequential and open a fresh connection each), so
        // the per-connection reader lives only for this iteration and
        // every response announces `Connection: close`.
        let mut reader = match stream.try_clone() {
            Ok(clone) => std::io::BufReader::new(clone),
            Err(_) => continue,
        };
        let req = match read_request(&mut reader, &mut stream) {
            Ok(crate::server::http::ReadOutcome::Request(r)) => r,
            Ok(crate::server::http::ReadOutcome::Closed) => continue,
            Ok(crate::server::http::ReadOutcome::Malformed { status, reason }) => {
                m.incr("dist.worker.bad_requests", 1);
                let _ = write_response(&mut stream, &Response::text(status, reason), false);
                continue;
            }
            Err(_) => {
                m.incr("dist.worker.bad_requests", 1);
                continue;
            }
        };
        let (resp, shutdown) = route(&mut state, &mut served, &mut trace_adopted, cfg, &req);
        let _ = write_response(&mut stream, &resp, false);
        if shutdown {
            break;
        }
    }
    Ok(())
}

/// Bind, announce, block: the `fkmpp worker` entry point. The ready
/// line (`[worker] listening on http://HOST:PORT`) goes to stdout and is
/// flushed *before* the accept loop, so a spawner can parse the
/// ephemeral port without racing the bind.
pub fn run_worker(cfg: &WorkerConfig) -> Result<()> {
    let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
        .with_context(|| format!("bind worker on {}:{}", cfg.host, cfg.port))?;
    let addr = listener.local_addr().context("worker local addr")?;
    println!("[worker] listening on http://{addr}");
    std::io::stdout().flush().ok();
    serve(listener, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};
    use crate::kernels::d2 as d2k;

    fn ps(n: usize, d: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 4,
                ..Default::default()
            },
            seed,
        )
    }

    fn load(state: &mut Option<ShardState>, full: &PointSet, lo: usize, hi: usize) {
        let d = full.dim();
        let slice = PointSet::from_flat(hi - lo, d, full.flat()[lo * d..hi * d].to_vec());
        let resp = handle_frame(
            state,
            Frame::ShardLoad {
                n_global: full.len() as u64,
                offset: lo as u64,
                points: slice,
            },
        );
        assert_eq!(resp, Frame::Ack { len: (hi - lo) as u64 });
    }

    #[test]
    fn rpc_before_load_is_a_typed_error() {
        let mut state = None;
        for frame in [
            Frame::Sample {
                round_tag: 1,
                cost: 1.0,
                ell: 2.0,
            },
            Frame::Weigh {
                rows: ps(2, 3, 0),
            },
            Frame::Update {
                indices: vec![0],
                rows: ps(1, 3, 0),
            },
        ] {
            match handle_frame(&mut state, frame) {
                Frame::Error { message } => {
                    assert!(message.contains("no shard loaded"), "{message}")
                }
                other => panic!("expected Error, got {other:?}"),
            }
        }
    }

    #[test]
    fn update_partials_match_direct_fold() {
        // The worker's D² fold and block partials must equal a direct
        // in-process fold over the same slice.
        let full = ps(600, 5, 1);
        let (lo, hi) = (100, 420);
        let mut state = None;
        load(&mut state, &full, lo, hi);
        let cands = [7usize, 250, 599];
        let rows = full.gather(&cands);
        let resp = handle_frame(
            &mut state,
            Frame::Update {
                indices: cands.iter().map(|&i| i as u64).collect(),
                rows: rows.clone(),
            },
        );
        let mut want = vec![f32::INFINITY; hi - lo];
        let slice = PointSet::from_flat(
            hi - lo,
            full.dim(),
            full.flat()[lo * full.dim()..hi * full.dim()].to_vec(),
        );
        for c in 0..rows.len() {
            d2k::d2_update_min(&slice, rows.row(c), &mut want);
        }
        match resp {
            Frame::Partials { sums } => {
                let expect = reduce::block_sums(&want, reduce::SUM_BLOCK);
                assert_eq!(sums.len(), expect.len());
                for (a, b) in sums.iter().zip(&expect) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("expected Partials, got {other:?}"),
        }
        // In-range broadcast indices are excluded from sampling; the
        // candidate at 250 sits in [100, 420) and must never come back.
        match handle_frame(
            &mut state,
            Frame::Sample {
                round_tag: 99,
                cost: 1e-12, // accept essentially everything
                ell: 1e12,
            },
        ) {
            Frame::Candidates { indices } => {
                assert!(!indices.is_empty());
                assert!(!indices.contains(&250));
                assert!(indices.iter().all(|&i| i >= lo as u64 && i < hi as u64));
                assert!(indices.windows(2).all(|w| w[0] < w[1]), "not ascending");
            }
            other => panic!("expected Candidates, got {other:?}"),
        }
    }

    #[test]
    fn sampling_is_deterministic_and_weigh_counts_cover_slice() {
        let full = ps(500, 4, 2);
        let mut state = None;
        load(&mut state, &full, 0, 500);
        let rows = full.gather(&[3, 77]);
        handle_frame(
            &mut state,
            Frame::Update {
                indices: vec![3, 77],
                rows,
            },
        );
        let sample = Frame::Sample {
            round_tag: 0xABCD,
            cost: 5_000.0,
            ell: 10.0,
        };
        let a = handle_frame(&mut state, sample.clone());
        let b = handle_frame(&mut state, sample);
        assert_eq!(a, b, "sampling must be a pure function of the state");
        match handle_frame(
            &mut state,
            Frame::Weigh {
                rows: full.gather(&[3, 77, 401]),
            },
        ) {
            Frame::Counts { counts } => {
                assert_eq!(counts.len(), 3);
                assert_eq!(counts.iter().sum::<u64>(), 500);
            }
            other => panic!("expected Counts, got {other:?}"),
        }
    }

    #[test]
    fn load_validation() {
        let full = ps(50, 3, 3);
        let mut state = None;
        // Slice exceeding n_global is rejected.
        match handle_frame(
            &mut state,
            Frame::ShardLoad {
                n_global: 10,
                offset: 8,
                points: full.gather(&[0, 1, 2]),
            },
        ) {
            Frame::Error { message } => assert!(message.contains("exceeds"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
        // A dimension-mismatched update is rejected after a good load.
        load(&mut state, &full, 0, 50);
        match handle_frame(
            &mut state,
            Frame::Update {
                indices: vec![0],
                rows: ps(1, 7, 0),
            },
        ) {
            Frame::Error { message } => assert!(message.contains("dimension"), "{message}"),
            other => panic!("expected Error, got {other:?}"),
        }
    }
}
