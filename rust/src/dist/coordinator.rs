//! The coordinator side of the distributed fit: a [`RoundExecutor`]
//! that fans each phase out to `fkmpp worker` processes.
//!
//! Workers are assigned contiguous, summation-block-aligned global row
//! ranges ([`crate::shard::aligned_ranges`]) in endpoint order. Every
//! phase is a serial fan-out in that order — RPC latency is not the
//! regime this subsystem optimizes yet; bitwise-correct merges are:
//! `Update` partials concatenate in range order (= global block order),
//! `Sample` candidates concatenate in range order (= ascending global
//! index), `Weigh` counts sum element-wise in `u64`.
//!
//! ## Retry / deadline contract
//!
//! Every failed RPC — connect/read/write error, timeout, or a worker
//! `Error` frame (a restarted worker answers `"no shard loaded"`) —
//! marks the worker unprovisioned, counts a `dist.retries`, sleeps a
//! short backoff, and retries: re-provision (`ShardLoad` + one combined
//! `Update` replaying the full broadcast history, which reconstructs
//! the worker's `D²` bits exactly — min-folds are idempotent and
//! order-free) and then re-send the failed frame. Each executor phase
//! is bounded by [`DistConfig::round_deadline`]; when it expires the
//! run fails with a typed error naming the unreachable endpoint. The
//! history is appended **before** a batch is first broadcast, so a
//! worker that dies mid-broadcast replays the batch it never saw.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use crate::bail;
use crate::data::matrix::PointSet;
use crate::dist::wire::{Frame, TraceCtx};
use crate::dist::{run_rounds, RoundExecutor};
use crate::error::{Context, Error, Result};
use crate::kernels::reduce;
use crate::metrics;
use crate::rng::Pcg64;
use crate::seeding::{Seeding, SeedingStats};
use crate::shard::aligned_ranges;
use crate::trace;

/// Distributed-fit knobs (`fkmpp seed --algo kmeans-par --workers
/// host:port,...`).
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// Worker endpoints (`host:port`), in partition order. With more
    /// endpoints than aligned ranges (tiny datasets), trailing workers
    /// idle — determinism over utilization.
    pub workers: Vec<String>,
    /// Oversampling rounds (same meaning as
    /// [`crate::shard::kmeanspar::KMeansParConfig::rounds`]).
    pub rounds: usize,
    /// Oversampling factor `ℓ = oversample · k`.
    pub oversample: f64,
    /// Per-RPC connect/read/write timeout.
    pub rpc_timeout: Duration,
    /// Retry budget per executor phase (provision, update, sample,
    /// weigh): failed workers are re-provisioned and retried until this
    /// much time has elapsed, then the run fails with a typed
    /// "unreachable" error.
    pub round_deadline: Duration,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig {
            workers: Vec::new(),
            rounds: 5,
            oversample: 2.0,
            rpc_timeout: Duration::from_secs(10),
            round_deadline: Duration::from_secs(30),
        }
    }
}

/// Pause between retry attempts against a failing worker.
const RETRY_BACKOFF: Duration = Duration::from_millis(150);

struct WorkerSlot {
    endpoint: String,
    /// Owned global row range `[lo, hi)`, aligned to
    /// [`crate::kernels::reduce::SUM_BLOCK`].
    lo: usize,
    hi: usize,
    /// Whether the worker currently holds its slice + fold state (goes
    /// false on any RPC failure, triggering replay re-provisioning).
    provisioned: bool,
}

/// The remote [`RoundExecutor`]: owns the worker fleet for one run.
pub struct DistCoordinator<'a> {
    ps: &'a PointSet,
    cfg: DistConfig,
    workers: Vec<WorkerSlot>,
    /// Every candidate batch ever broadcast (global indices + rows,
    /// flat), appended before first send — the replay log.
    history_indices: Vec<u64>,
    history_rows: Vec<f32>,
    /// Current driver round, for trace span tags only (set via
    /// [`RoundExecutor::on_round`]; `Cell` because [`Self::rpc_raw`]
    /// reads it through `&self`). Never feeds computation.
    round: std::cell::Cell<u64>,
}

impl<'a> DistCoordinator<'a> {
    /// Partition `ps` over `cfg.workers` (aligned, balanced, in
    /// endpoint order). No RPCs yet — workers are provisioned lazily or
    /// via [`DistCoordinator::provision_all`].
    pub fn new(ps: &'a PointSet, cfg: &DistConfig) -> Result<DistCoordinator<'a>> {
        if cfg.workers.is_empty() {
            bail!("distributed fit needs at least one worker endpoint");
        }
        if ps.is_empty() {
            bail!("distributed fit over an empty dataset");
        }
        let ranges = aligned_ranges(ps.len(), cfg.workers.len(), reduce::SUM_BLOCK);
        let workers = ranges
            .iter()
            .zip(&cfg.workers)
            .map(|(&(lo, hi), ep)| WorkerSlot {
                endpoint: ep.clone(),
                lo,
                hi,
                provisioned: false,
            })
            .collect();
        Ok(DistCoordinator {
            ps,
            cfg: cfg.clone(),
            workers,
            history_indices: Vec::new(),
            history_rows: Vec::new(),
            round: std::cell::Cell::new(0),
        })
    }

    /// Number of workers actually holding rows (≤ endpoint count).
    pub fn active_workers(&self) -> usize {
        self.workers.len()
    }

    /// Eagerly provision the whole fleet (with the usual retry/deadline
    /// discipline) so provisioning time lands in `init_secs`, not the
    /// first round.
    pub fn provision_all(&mut self) -> Result<()> {
        let deadline = Instant::now() + self.cfg.round_deadline;
        for w in 0..self.workers.len() {
            self.call_with_recovery(w, None, deadline)?;
        }
        Ok(())
    }

    /// One raw RPC: connect, POST the frame, decode the response frame.
    /// A worker `Error` frame becomes an `Err` here so the retry loop
    /// treats it like any other failure.
    fn rpc_raw(&self, endpoint: &str, frame: &Frame) -> Result<Frame> {
        let m = metrics::global();
        m.incr("dist.rpcs", 1);
        // Round-trip latency goes to the log₂ histogram (p50/p99 at
        // `/metrics`); the span tags round/endpoint/kind/bytes. Both
        // record on the error path too (the guard drops record).
        let mut span = trace::Span::enter_with(
            "dist.rpc",
            vec![
                ("endpoint", endpoint.into()),
                ("kind", frame.kind().into()),
                ("round", self.round.get().into()),
            ],
        );
        let timer = m.latency_timer("dist.rpc_secs");
        let addr: SocketAddr = endpoint
            .to_socket_addrs()
            .with_context(|| format!("resolve worker {endpoint:?}"))?
            .next()
            .with_context(|| format!("worker {endpoint:?} resolved to no address"))?;
        let mut stream = TcpStream::connect_timeout(&addr, self.cfg.rpc_timeout)
            .with_context(|| format!("connect worker {endpoint}"))?;
        stream.set_read_timeout(Some(self.cfg.rpc_timeout)).ok();
        stream.set_write_timeout(Some(self.cfg.rpc_timeout)).ok();
        // Traced runs stamp every frame with the wire trace context:
        // this process's trace id, this RPC's span id as the remote
        // parent, and the driver round. Untraced runs send the all-zero
        // context (bitwise identical to the pre-trace wire bytes aside
        // from the fixed envelope).
        let body = if trace::enabled() {
            let span_id = trace::next_span_id();
            span.arg("span_id", span_id);
            frame.encode_with(&TraceCtx {
                trace_id: trace::trace_id(),
                parent_span: span_id,
                round: self.round.get(),
            })
        } else {
            frame.encode()
        };
        span.arg("bytes_out", body.len());
        let head = format!(
            "POST /rpc HTTP/1.1\r\nHost: {endpoint}\r\nContent-Type: application/octet-stream\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        stream
            .write_all(head.as_bytes())
            .and_then(|()| stream.write_all(&body))
            .with_context(|| format!("send rpc to worker {endpoint}"))?;
        let (status, resp_body) = read_response(&mut stream)
            .with_context(|| format!("read rpc response from worker {endpoint}"))?;
        timer.stop();
        span.arg("bytes_in", resp_body.len());
        let resp = Frame::decode(&resp_body)
            .with_context(|| format!("decode rpc response from worker {endpoint} (HTTP {status})"))?;
        if let Frame::Error { message } = resp {
            bail!("worker {endpoint}: {message}");
        }
        Ok(resp)
    }

    /// (Re-)install a worker's slice and replay the broadcast history.
    fn ensure_provisioned(&mut self, w: usize) -> Result<()> {
        if self.workers[w].provisioned {
            return Ok(());
        }
        metrics::global().incr("dist.provisions", 1);
        let (lo, hi) = (self.workers[w].lo, self.workers[w].hi);
        let ep = self.workers[w].endpoint.clone();
        let dim = self.ps.dim();
        let slice = PointSet::from_flat(hi - lo, dim, self.ps.flat()[lo * dim..hi * dim].to_vec());
        let resp = self.rpc_raw(
            &ep,
            &Frame::ShardLoad {
                n_global: self.ps.len() as u64,
                offset: lo as u64,
                points: slice,
            },
        )?;
        match resp {
            Frame::Ack { len } if len as usize == hi - lo => {}
            other => bail!("worker {ep}: unexpected ShardLoad response {other:?}"),
        }
        if !self.history_indices.is_empty() {
            // One combined replay fold; min-folds are idempotent and
            // order-free, so this lands on the identical D² bits the
            // worker would hold had it seen every broadcast live.
            let rows =
                PointSet::from_flat(self.history_indices.len(), dim, self.history_rows.clone());
            let resp = self.rpc_raw(
                &ep,
                &Frame::Update {
                    indices: self.history_indices.clone(),
                    rows,
                },
            )?;
            if !matches!(resp, Frame::Partials { .. }) {
                bail!("worker {ep}: unexpected replay response {resp:?}");
            }
        }
        self.workers[w].provisioned = true;
        Ok(())
    }

    /// Provision-then-send with the retry/deadline discipline. `frame:
    /// None` provisions only (the response is a synthetic `Ack`).
    fn call_with_recovery(
        &mut self,
        w: usize,
        frame: Option<&Frame>,
        deadline: Instant,
    ) -> Result<Frame> {
        let m = metrics::global();
        let mut span = trace::Span::enter_with(
            "dist.call",
            vec![
                ("endpoint", self.workers[w].endpoint.as_str().into()),
                ("round", self.round.get().into()),
            ],
        );
        let mut retries = 0u64;
        loop {
            let result = match self.ensure_provisioned(w) {
                Ok(()) => match frame {
                    Some(f) => {
                        let ep = self.workers[w].endpoint.clone();
                        self.rpc_raw(&ep, f)
                    }
                    None => {
                        let len = (self.workers[w].hi - self.workers[w].lo) as u64;
                        Ok(Frame::Ack { len })
                    }
                },
                Err(e) => Err(e),
            };
            match result {
                Ok(resp) => {
                    span.arg("retries", retries);
                    return Ok(resp);
                }
                Err(e) => {
                    self.workers[w].provisioned = false;
                    m.incr("dist.retries", 1);
                    retries += 1;
                    if Instant::now() >= deadline {
                        span.arg("retries", retries);
                        return Err(self.unreachable(w, e));
                    }
                    std::thread::sleep(RETRY_BACKOFF);
                }
            }
        }
    }

    /// End-of-run trace merge: ask every worker to dump its buffered
    /// spans and fold them in as foreign spans under per-worker pid
    /// rows (`LOCAL_PID` + 1 + slot index, labelled `worker-{i+1}`),
    /// with timestamps shifted onto this process's epoch via the
    /// wall-clock anchors exchanged in `TraceEvents`. Failures are
    /// swallowed — a lost trace dump must never fail a finished run.
    fn collect_worker_traces(&self) {
        let coord_epoch = trace::epoch_unix_us();
        for (w, slot) in self.workers.iter().enumerate() {
            let resp = match self.rpc_raw(&slot.endpoint, &Frame::TraceDump) {
                Ok(r) => r,
                Err(_) => continue,
            };
            let Frame::TraceEvents {
                trace_id,
                epoch_unix_us,
                spans,
            } = resp
            else {
                continue;
            };
            if spans.is_empty() {
                // An in-process worker thread (shared sink) or a worker
                // that never adopted the trace answers empty.
                continue;
            }
            let shift = epoch_unix_us - coord_epoch;
            let foreign = spans
                .into_iter()
                .map(|s| trace::ForeignSpan {
                    pid: w as u32 + trace::LOCAL_PID + 1,
                    process: format!("worker-{}", w + 1),
                    trace_id,
                    name: s.name,
                    tid: s.tid,
                    ts_us: s.ts_us + shift,
                    dur_us: s.dur_us,
                    args: s.args,
                })
                .collect();
            trace::add_foreign(foreign);
        }
    }

    /// The typed give-up error: names the endpoint and the deadline.
    /// "unreachable" is load-bearing — `dist_parity.rs` asserts on it.
    fn unreachable(&self, w: usize, cause: Error) -> Error {
        cause.wrap(format!(
            "worker {} unreachable: no successful rpc within the {:?} retry deadline",
            self.workers[w].endpoint, self.cfg.round_deadline
        ))
    }
}

impl RoundExecutor for DistCoordinator<'_> {
    fn on_round(&mut self, round: usize) {
        self.round.set(round as u64);
    }

    fn update(&mut self, indices: &[usize], rows: &PointSet) -> Result<Vec<f64>> {
        // Log before broadcasting: a worker that dies mid-fan-out gets
        // this batch replayed at re-provision time.
        self.history_indices.extend(indices.iter().map(|&i| i as u64));
        self.history_rows.extend_from_slice(rows.flat());
        let frame = Frame::Update {
            indices: indices.iter().map(|&i| i as u64).collect(),
            rows: rows.clone(),
        };
        let deadline = Instant::now() + self.cfg.round_deadline;
        let mut partials = Vec::new();
        for w in 0..self.workers.len() {
            match self.call_with_recovery(w, Some(&frame), deadline)? {
                // Range order = global block order: concatenation IS the
                // global block_sums vector.
                Frame::Partials { sums } => partials.extend(sums),
                other => bail!(
                    "worker {}: unexpected update response {other:?}",
                    self.workers[w].endpoint
                ),
            }
        }
        Ok(partials)
    }

    fn sample(&mut self, round_tag: u64, cost: f64, ell: f64) -> Result<Vec<usize>> {
        let frame = Frame::Sample {
            round_tag,
            cost,
            ell,
        };
        let deadline = Instant::now() + self.cfg.round_deadline;
        let mut accepted = Vec::new();
        for w in 0..self.workers.len() {
            match self.call_with_recovery(w, Some(&frame), deadline)? {
                Frame::Candidates { indices } => {
                    for i in indices {
                        let i = i as usize;
                        if i < self.workers[w].lo || i >= self.workers[w].hi {
                            bail!(
                                "worker {} returned out-of-range candidate {i}",
                                self.workers[w].endpoint
                            );
                        }
                        // Range order = ascending global order.
                        accepted.push(i);
                    }
                }
                other => bail!(
                    "worker {}: unexpected sample response {other:?}",
                    self.workers[w].endpoint
                ),
            }
        }
        Ok(accepted)
    }

    fn weigh(&mut self, candidates: &PointSet) -> Result<Vec<u64>> {
        let frame = Frame::Weigh {
            rows: candidates.clone(),
        };
        let deadline = Instant::now() + self.cfg.round_deadline;
        let mut totals = vec![0u64; candidates.len()];
        for w in 0..self.workers.len() {
            match self.call_with_recovery(w, Some(&frame), deadline)? {
                Frame::Counts { counts } => {
                    if counts.len() != totals.len() {
                        bail!(
                            "worker {}: {} counts for {} candidates",
                            self.workers[w].endpoint,
                            counts.len(),
                            totals.len()
                        );
                    }
                    for (t, c) in totals.iter_mut().zip(counts) {
                        *t += c;
                    }
                }
                other => bail!(
                    "worker {}: unexpected weigh response {other:?}",
                    self.workers[w].endpoint
                ),
            }
        }
        Ok(totals)
    }
}

/// Distributed k-means‖: the shared round driver
/// ([`crate::dist::run_rounds`]) over a worker fleet. For a fixed seed
/// (and `FKMPP_KERNEL` pinned across processes) the result is bitwise
/// identical to the in-process [`crate::shard::kmeanspar::kmeans_par`]
/// at any worker count — `rust/tests/dist_parity.rs` is the gate.
pub fn kmeans_par_dist(
    ps: &PointSet,
    k: usize,
    cfg: &DistConfig,
    rng: &mut Pcg64,
) -> Result<Seeding> {
    let m = metrics::global();
    m.incr("dist.runs", 1);
    if k.min(ps.len()) == 0 {
        m.incr("shard.runs", 1);
        return Ok(Seeding::from_indices(
            ps,
            Vec::new(),
            SeedingStats::default(),
        ));
    }
    let t0 = Instant::now();
    let mut coord = DistCoordinator::new(ps, cfg)?;
    coord.provision_all()?;
    let init_secs = t0.elapsed().as_secs_f64();
    let result = run_rounds(ps, k, cfg.rounds, cfg.oversample, &mut coord, init_secs, rng);
    if trace::enabled() {
        // Merge worker timelines even when the run failed — a partial
        // trace of a failed run is exactly when you want one.
        coord.collect_worker_traces();
    }
    result
}

/// Minimal HTTP/1.1 response reader for the coordinator's RPC client
/// (the request side lives in [`crate::server::http`]): status line,
/// headers, then a body framed by `Content-Length` (or read-to-EOF —
/// workers always answer `Connection: close`).
fn read_response<S: Read>(stream: &mut S) -> Result<(u16, Vec<u8>)> {
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).context("read status line")?;
    let mut parts = line.split_whitespace();
    let version = parts.next().context("empty response")?;
    if !version.starts_with("HTTP/1.") {
        bail!("bad response version {version:?}");
    }
    let status: u16 = parts
        .next()
        .context("response missing status code")?
        .parse()
        .context("malformed status code")?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h).context("read response header")? == 0 {
            bail!("connection closed mid-headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = Some(
                    value
                        .trim()
                        .parse()
                        .with_context(|| format!("bad Content-Length {value:?}"))?,
                );
            }
        }
    }
    let body = match content_length {
        Some(len) => {
            if len > crate::server::http::MAX_BODY_BYTES {
                bail!("response body of {len} bytes exceeds limit");
            }
            let mut body = vec![0u8; len];
            reader.read_exact(&mut body).context("read response body")?;
            body
        }
        None => {
            let mut body = Vec::new();
            reader
                .read_to_end(&mut body)
                .context("read response body")?;
            body
        }
    };
    Ok((status, body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};
    use crate::dist::worker::{serve, WorkerConfig};
    use crate::shard::kmeanspar::{kmeans_par, KMeansParConfig};

    /// Spawn an in-process worker thread on an ephemeral port. Same
    /// process ⇒ same kernel dispatch on both sides, so no env pinning
    /// is needed here (the cross-process case is `dist_parity.rs`).
    fn spawn_worker_thread() -> String {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let _ = serve(listener, &WorkerConfig::default());
        });
        addr
    }

    fn shutdown(addr: &str) {
        if let Ok(mut s) = TcpStream::connect(addr) {
            let _ = s.write_all(b"POST /shutdown HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\n\r\n");
            let mut sink = Vec::new();
            let _ = std::io::Read::read_to_end(&mut s, &mut sink);
        }
    }

    #[test]
    fn two_thread_workers_match_in_process_bitwise() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 9_000,
                d: 5,
                k_true: 6,
                ..Default::default()
            },
            17,
        );
        let pcfg = KMeansParConfig {
            shards: 3,
            rounds: 3,
            oversample: 2.0,
        };
        let mut rng = Pcg64::seed_from(21);
        let base = kmeans_par(&ps, 8, &pcfg, &mut rng);
        let base_next = rng.next_u64();

        let addrs = vec![spawn_worker_thread(), spawn_worker_thread()];
        let dcfg = DistConfig {
            workers: addrs.clone(),
            rounds: pcfg.rounds,
            oversample: pcfg.oversample,
            ..DistConfig::default()
        };
        let mut rng = Pcg64::seed_from(21);
        let got = kmeans_par_dist(&ps, 8, &dcfg, &mut rng).expect("distributed run");
        let got_next = rng.next_u64();
        assert_eq!(got.indices, base.indices);
        assert_eq!(got.centers, base.centers);
        assert_eq!(got_next, base_next, "run RNG stream diverged");
        assert_eq!(got.stats.proposals, base.stats.proposals);
        for a in &addrs {
            shutdown(a);
        }
    }

    #[test]
    fn empty_fleet_and_empty_k_are_clean() {
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 100,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            1,
        );
        let mut rng = Pcg64::seed_from(1);
        let err = kmeans_par_dist(&ps, 5, &DistConfig::default(), &mut rng).unwrap_err();
        assert!(format!("{err:#}").contains("worker"), "{err:#}");
        // k = 0 never touches the network.
        let dcfg = DistConfig {
            workers: vec!["127.0.0.1:1".to_string()],
            ..DistConfig::default()
        };
        let s = kmeans_par_dist(&ps, 0, &dcfg, &mut rng).unwrap();
        assert_eq!(s.k(), 0);
    }

    #[test]
    fn aligned_partition_engages_at_most_range_count_workers() {
        // 9000 rows = 3 summation blocks: a 5-endpoint fleet keeps only
        // 3 active slots.
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 9_000,
                d: 4,
                k_true: 2,
                ..Default::default()
            },
            2,
        );
        let dcfg = DistConfig {
            workers: (0..5).map(|i| format!("127.0.0.1:{}", 40_000 + i)).collect(),
            ..DistConfig::default()
        };
        let coord = DistCoordinator::new(&ps, &dcfg).unwrap();
        assert_eq!(coord.active_workers(), 3);
    }
}
