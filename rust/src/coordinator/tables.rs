//! Table emitters: render a [`GridResults`] as the paper's Tables 1–8.
//!
//! * Tables 1–3 — running time of each algorithm divided by the running
//!   time of FASTK-MEANS++ (per dataset);
//! * Tables 4–6 — seeding costs (scaled by the paper's per-table factor);
//! * Tables 7–8 — variance of the costs over the repetitions.
//!
//! Output is GitHub-flavored markdown (also fine on a terminal).

use crate::coordinator::config::ExperimentConfig;
use crate::coordinator::runner::GridResults;
use crate::data::registry::DatasetId;
use crate::metrics::Stats;
use crate::seeding::SeedingAlgorithm;
use crate::server::json::{stats_json, Json};

/// Paper cost-scale factors: Table 4 ×10³, Table 5 ×10⁵, Table 6 ×10⁴.
pub fn cost_scale(dataset: DatasetId) -> f64 {
    match dataset {
        DatasetId::KddSim => 1e3,
        DatasetId::SongSim => 1e5,
        DatasetId::CensusSim => 1e4,
    }
}

/// Non-paper algorithms appended to table renderings when (and only
/// when) they have at least one cell for the dataset — the paper's five
/// rows stay pinned to [`SeedingAlgorithm::paper_order`].
fn extension_rows(res: &GridResults, dataset: DatasetId, ks: &[usize]) -> Vec<SeedingAlgorithm> {
    [
        SeedingAlgorithm::KMeansPar,
        SeedingAlgorithm::KMeansPPGreedy,
        SeedingAlgorithm::RejectionExact,
        SeedingAlgorithm::RejectionLshRigorous,
    ]
    .into_iter()
    .filter(|&a| ks.iter().any(|&k| res.get(dataset, a, k).is_some()))
    .collect()
}

fn header(ks: &[usize]) -> String {
    let mut s = String::from("| Algorithm |");
    for k in ks {
        s.push_str(&format!(" k = {k} |"));
    }
    s.push('\n');
    s.push_str("|---|");
    for _ in ks {
        s.push_str("---|");
    }
    s.push('\n');
    s
}

/// Tables 1–3: runtime ratios vs FASTK-MEANS++.
pub fn runtime_table(res: &GridResults, dataset: DatasetId, ks: &[usize]) -> String {
    let mut out = format!(
        "### Table {}: running time / FASTK-MEANS++ ({})\n\n",
        dataset.runtime_table(),
        dataset.name()
    );
    out.push_str(&header(ks));
    let mut algos = vec![
        SeedingAlgorithm::FastKMeansPP,
        SeedingAlgorithm::Rejection,
        SeedingAlgorithm::KMeansPP,
        SeedingAlgorithm::Afkmc2,
    ];
    algos.extend(extension_rows(res, dataset, ks));
    for algo in algos {
        let mut row = format!("| {} |", algo.paper_name());
        for &k in ks {
            let base = res
                .get(dataset, SeedingAlgorithm::FastKMeansPP, k)
                .map(|c| c.seconds.mean());
            let cell = res.get(dataset, algo, k).map(|c| c.seconds.mean());
            match (base, cell) {
                (Some(b), Some(c)) if b > 0.0 => {
                    row.push_str(&format!(" {:.2}x |", c / b));
                }
                _ => row.push_str(" — |"),
            }
        }
        out.push_str(&row);
        out.push('\n');
    }
    out
}

/// Tables 4–6: seeding costs, scaled by the paper's factor.
pub fn cost_table(res: &GridResults, dataset: DatasetId, ks: &[usize]) -> String {
    let scale = cost_scale(dataset);
    let mut out = format!(
        "### Table {}: seeding cost / {:.0e} ({})\n\n",
        dataset.cost_table(),
        scale,
        dataset.name()
    );
    out.push_str(&header(ks));
    let algos = SeedingAlgorithm::paper_order()
        .into_iter()
        .chain(extension_rows(res, dataset, ks));
    for algo in algos {
        let mut row = format!("| {} |", algo.paper_name());
        for &k in ks {
            match res.get(dataset, algo, k) {
                Some(c) => row.push_str(&format!(" {:.0} |", c.cost.mean() / scale)),
                None => row.push_str(" — |"),
            }
        }
        out.push_str(&row);
        out.push('\n');
    }
    out
}

/// Tables 7–8: variance of the costs over the repetitions (paper scales:
/// Song ×10⁵, KDD ×10²).
pub fn variance_table(res: &GridResults, dataset: DatasetId, ks: &[usize]) -> String {
    let (table_no, scale) = match dataset {
        DatasetId::SongSim => (7, 1e5),
        DatasetId::KddSim => (8, 1e2),
        DatasetId::CensusSim => (0, 1e4), // not in the paper; extra
    };
    let label = if table_no == 0 {
        format!("### Extra: cost variance ({})\n\n", dataset.name())
    } else {
        format!(
            "### Table {}: cost variance / {:.0e} ({})\n\n",
            table_no,
            scale,
            dataset.name()
        )
    };
    let mut out = label;
    out.push_str(&header(ks));
    for algo in SeedingAlgorithm::paper_order() {
        let mut row = format!("| {} |", algo.paper_name());
        for &k in ks {
            match res.get(dataset, algo, k) {
                // The paper reports the variance of the scaled costs: with
                // costs reported as cost/S, variance scales by 1/S^2; it
                // then scales the variance column by its own factor. We
                // report var(cost / cost_scale) / scale to match
                // magnitudes.
                Some(c) => {
                    let cs = cost_scale(dataset);
                    let v = c.cost.sample_variance() / (cs * cs);
                    row.push_str(&format!(" {:.0} |", v / scale * 1e5));
                }
                None => row.push_str(" — |"),
            }
        }
        out.push_str(&row);
        out.push('\n');
    }
    out
}

/// Machine-readable sweep artifact (`fkmpp grid --json out.json`): the
/// full cell grid with per-statistic mean/min/max/stddev, emitted through
/// the crate's single JSON point ([`crate::server::json`]). This is the
/// format the `BENCH_*.json` perf-trajectory files accumulate.
pub fn grid_json(res: &GridResults, cfg: &ExperimentConfig) -> Json {
    let cells: Vec<Json> = res
        .cells
        .iter()
        .map(|(key, cell)| {
            Json::obj(vec![
                ("dataset", Json::str(key.dataset.name())),
                ("algorithm", Json::str(key.algorithm.name())),
                ("k", Json::num(key.k as f64)),
                ("seconds", stats_json(&cell.seconds)),
                ("cost", stats_json(&cell.cost)),
                ("lloyd_cost", stats_json(&cell.lloyd_cost)),
                (
                    "proposals_per_center",
                    stats_json(&cell.proposals_per_center),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("profile", Json::str(cfg.profile.name())),
        ("reps", Json::num(cfg.reps as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("quantize", Json::Bool(cfg.quantize)),
        ("lloyd_iters", Json::num(cfg.lloyd_iters as f64)),
        ("backend", Json::str(res.backend_name)),
        ("cells", Json::Arr(cells)),
    ])
}

/// One cell of the kernel micro-bench sweep
/// (`benches/micro_runtime.rs --kernels-only`).
pub struct KernelCell {
    /// Synthetic instance label, e.g. `synth_n100000_d128`.
    pub dataset: String,
    /// Kernel + implementation, e.g. `assign_argmin_v2_blocked`.
    pub algorithm: String,
    pub k: usize,
    /// Per-rep wall-clock seconds.
    pub seconds: Stats,
    /// Single-thread speedup vs the v1 naive kernel on the same cell
    /// (1.0 for the v1 rows themselves).
    pub speedup_vs_naive: f64,
}

/// Shared `BENCH_*.json` envelope: every bench emitter wraps its cells
/// in the same top-level fields as [`grid_json`] (`profile`/`reps`/
/// `seed`/`quantize`/`lloyd_iters`/`backend`/`threads`/`cells`), so one
/// consumer reads every artifact in the perf trajectory and the contract
/// lives in exactly one place.
fn bench_json(
    profile: &'static str,
    cells: Vec<Json>,
    reps: usize,
    seed: u64,
    threads: usize,
) -> Json {
    Json::obj(vec![
        ("profile", Json::str(profile)),
        ("reps", Json::num(reps as f64)),
        ("seed", Json::num(seed as f64)),
        ("quantize", Json::Bool(false)),
        ("lloyd_iters", Json::num(0.0)),
        ("backend", Json::str("native")),
        ("threads", Json::num(threads as f64)),
        ("cells", Json::Arr(cells)),
    ])
}

/// `BENCH_kernels.json` — the kernel micro-bench artifact, first entry of
/// the perf trajectory. Same top-level shape and cell fields as
/// [`grid_json`] (`profile`/`reps`/`seed`/`backend`/`cells` with
/// `dataset`/`algorithm`/`k`/`seconds`), so one consumer reads every
/// `BENCH_*.json`; kernel cells carry no cost statistics (null, like
/// unpopulated grid stats) and add `speedup_vs_naive`.
pub fn kernels_json(cells: &[KernelCell], reps: usize, seed: u64, threads: usize) -> Json {
    let cell_docs: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("dataset", Json::str(c.dataset.clone())),
                ("algorithm", Json::str(c.algorithm.clone())),
                ("k", Json::num(c.k as f64)),
                ("seconds", stats_json(&c.seconds)),
                ("cost", Json::Null),
                ("lloyd_cost", Json::Null),
                ("proposals_per_center", Json::Null),
                ("speedup_vs_naive", Json::num(c.speedup_vs_naive)),
            ])
        })
        .collect();
    bench_json("kernel_bench", cell_docs, reps, seed, threads)
}

/// One cell of the shard bench sweep
/// (`benches/micro_runtime.rs --shard-only`): a seeder timed at one
/// shard count.
pub struct ShardCell {
    /// Synthetic instance label, e.g. `synth_n100000_d128`.
    pub dataset: String,
    /// Seeder + shard count, e.g. `kmeans-par_s4` (`kmeanspp` /
    /// `fastkmeanspp` rows carry their plain names — shards don't apply).
    pub algorithm: String,
    pub k: usize,
    /// Shard count the cell ran with (1 for the unsharded baselines).
    pub shards: usize,
    /// Per-rep seeding wall-clock seconds.
    pub seconds: Stats,
    /// Per-rep seeding cost (k-means objective of the chosen centers).
    pub cost: Stats,
}

/// `BENCH_shard.json` — the sharded-seeding bench artifact. Same
/// top-level shape and per-cell field names as [`grid_json`] /
/// [`kernels_json`] (one consumer reads every `BENCH_*.json`); shard
/// cells add `shards` and carry real cost statistics.
pub fn shard_json(cells: &[ShardCell], reps: usize, seed: u64, threads: usize) -> Json {
    let cell_docs: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("dataset", Json::str(c.dataset.clone())),
                ("algorithm", Json::str(c.algorithm.clone())),
                ("k", Json::num(c.k as f64)),
                ("shards", Json::num(c.shards as f64)),
                ("seconds", stats_json(&c.seconds)),
                ("cost", stats_json(&c.cost)),
                ("lloyd_cost", Json::Null),
                ("proposals_per_center", Json::Null),
            ])
        })
        .collect();
    bench_json("shard_bench", cell_docs, reps, seed, threads)
}

/// One cell of the distributed-fit bench sweep
/// (`benches/micro_runtime.rs --dist-only`): the k-means|| seeder timed
/// against one transport (in-process executor or worker processes).
pub struct DistCell {
    /// Synthetic instance label, e.g. `synth_n100000_d64`.
    pub dataset: String,
    /// Seeder + transport, e.g. `kmeans-par_w2` (`kmeans-par` for the
    /// in-process row — workers don't apply).
    pub algorithm: String,
    pub k: usize,
    /// Worker-process count the cell ran with (0 for the in-process
    /// [`crate::shard::kmeanspar::LocalShardExecutor`] baseline).
    pub workers: usize,
    /// Per-rep seeding wall-clock seconds.
    pub seconds: Stats,
    /// Per-rep seeding cost (k-means objective of the chosen centers).
    pub cost: Stats,
}

/// `BENCH_dist.json` — the distributed-fit bench artifact. Same
/// top-level shape and per-cell field names as [`grid_json`] /
/// [`shard_json`] (one consumer reads every `BENCH_*.json`); dist cells
/// add `workers` and carry real cost statistics.
pub fn dist_json(cells: &[DistCell], reps: usize, seed: u64, threads: usize) -> Json {
    let cell_docs: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("dataset", Json::str(c.dataset.clone())),
                ("algorithm", Json::str(c.algorithm.clone())),
                ("k", Json::num(c.k as f64)),
                ("workers", Json::num(c.workers as f64)),
                ("seconds", stats_json(&c.seconds)),
                ("cost", stats_json(&c.cost)),
                ("lloyd_cost", Json::Null),
                ("proposals_per_center", Json::Null),
            ])
        })
        .collect();
    bench_json("dist_bench", cell_docs, reps, seed, threads)
}

/// One cell of the rejection-oracle bench sweep
/// (`benches/micro_runtime.rs --rejection-only`): Algorithm 4 timed with
/// one ANN oracle backing the acceptance test.
pub struct RejectionCell {
    /// Synthetic instance label, e.g. `synth_n100000_d128`.
    pub dataset: String,
    /// Always `rejection` — the oracle is the swept axis.
    pub algorithm: String,
    /// Oracle name (`exact` / `lsh` / `lsh-rigorous`).
    pub oracle: String,
    pub k: usize,
    /// Per-rep seeding wall-clock seconds.
    pub seconds: Stats,
    /// Per-rep seeding cost (k-means objective of the chosen centers).
    pub cost: Stats,
    /// Per-rep proposals per accepted center (Lemma 5.3 check).
    pub proposals_per_center: Stats,
}

/// `BENCH_rejection.json` — the oracle-sweep bench artifact. Same
/// top-level shape and per-cell field names as [`grid_json`] /
/// [`kernels_json`] / [`shard_json`] (one consumer reads every
/// `BENCH_*.json`); rejection cells add `oracle` and carry real cost +
/// proposals statistics.
pub fn rejection_json(cells: &[RejectionCell], reps: usize, seed: u64, threads: usize) -> Json {
    let cell_docs: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("dataset", Json::str(c.dataset.clone())),
                ("algorithm", Json::str(c.algorithm.clone())),
                ("oracle", Json::str(c.oracle.clone())),
                ("k", Json::num(c.k as f64)),
                ("seconds", stats_json(&c.seconds)),
                ("cost", stats_json(&c.cost)),
                ("lloyd_cost", Json::Null),
                ("proposals_per_center", stats_json(&c.proposals_per_center)),
            ])
        })
        .collect();
    bench_json("rejection_bench", cell_docs, reps, seed, threads)
}

/// One cell of the serving-path load sweep (`fkmpp loadgen`): one
/// (route, connection mode, connection count) combination driven against
/// a live `fkmpp serve` instance.
pub struct ServeCell {
    /// Payload label, e.g. `payload_n256_d16` (points × dims per request).
    pub dataset: String,
    /// Route + connection mode, e.g. `assign_binary_keepalive`.
    pub algorithm: String,
    /// Request body encoding: `json` or `binary` (.fbin / FKA1 frame).
    pub route: String,
    /// Connection discipline: `keepalive` (reused) or `close` (per request).
    pub mode: String,
    /// Concurrent client connections.
    pub connections: usize,
    /// Centers in the served model.
    pub k: usize,
    /// Per-rep wall-clock seconds for the whole request batch.
    pub seconds: Stats,
    /// Exact per-request latency percentiles over all reps, milliseconds.
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Completed requests per second of wall clock, summed over reps.
    pub throughput_rps: f64,
}

/// `BENCH_serve.json` — the serving-path load artifact. Same top-level
/// shape and per-cell field names as [`grid_json`] / [`kernels_json`]
/// (one consumer reads every `BENCH_*.json`); serve cells carry no cost
/// statistics (null, like unpopulated grid stats) and add the
/// route/mode/connections axes plus latency percentiles and throughput.
pub fn serve_json(cells: &[ServeCell], reps: usize, seed: u64, threads: usize) -> Json {
    let cell_docs: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("dataset", Json::str(c.dataset.clone())),
                ("algorithm", Json::str(c.algorithm.clone())),
                ("route", Json::str(c.route.clone())),
                ("mode", Json::str(c.mode.clone())),
                ("connections", Json::num(c.connections as f64)),
                ("k", Json::num(c.k as f64)),
                ("seconds", stats_json(&c.seconds)),
                ("cost", Json::Null),
                ("lloyd_cost", Json::Null),
                ("proposals_per_center", Json::Null),
                ("p50_ms", Json::num(c.p50_ms)),
                ("p99_ms", Json::num(c.p99_ms)),
                ("throughput_rps", Json::num(c.throughput_rps)),
            ])
        })
        .collect();
    bench_json("serve_bench", cell_docs, reps, seed, threads)
}

/// Lemma 5.3 diagnostic: proposals per accepted center for the rejection
/// sampler (expected `O(c^2 d^2)`, far smaller in practice).
pub fn rejection_diagnostics(res: &GridResults, dataset: DatasetId, ks: &[usize]) -> String {
    let mut out = format!(
        "### Rejection-loop proposals per accepted center ({})\n\n",
        dataset.name()
    );
    out.push_str(&header(ks));
    for algo in [
        SeedingAlgorithm::Rejection,
        SeedingAlgorithm::RejectionExact,
        SeedingAlgorithm::RejectionLshRigorous,
    ] {
        let mut row = format!("| {} |", algo.paper_name());
        let mut any = false;
        for &k in ks {
            match res.get(dataset, algo, k) {
                Some(c) if c.proposals_per_center.count() > 0 => {
                    any = true;
                    row.push_str(&format!(" {:.2} |", c.proposals_per_center.mean()));
                }
                _ => row.push_str(" — |"),
            }
        }
        if any {
            out.push_str(&row);
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::runner::{CellKey, CellResult};
    use crate::metrics::Stats;

    fn fake_results() -> GridResults {
        let mut res = GridResults::default();
        let mut add = |algo, k: usize, secs: f64, cost: f64| {
            let mut cell = CellResult::default();
            let mut s = Stats::new();
            s.push(secs);
            cell.seconds = s;
            let mut c = Stats::new();
            c.push(cost);
            c.push(cost * 1.1);
            cell.cost = c;
            res.cells.insert(
                CellKey {
                    dataset: DatasetId::KddSim,
                    algorithm: algo,
                    k,
                },
                cell,
            );
        };
        add(SeedingAlgorithm::FastKMeansPP, 100, 1.0, 3.0e7);
        add(SeedingAlgorithm::KMeansPP, 100, 6.58, 2.4e7);
        add(SeedingAlgorithm::Rejection, 100, 1.04, 2.9e7);
        add(SeedingAlgorithm::Afkmc2, 100, 3.8, 2.5e7);
        add(SeedingAlgorithm::Uniform, 100, 0.01, 1.5e8);
        res
    }

    #[test]
    fn runtime_table_shows_ratios() {
        let res = fake_results();
        let t = runtime_table(&res, DatasetId::KddSim, &[100]);
        assert!(t.contains("Table 1"));
        assert!(t.contains("| FASTK-MEANS++ | 1.00x |"), "{t}");
        assert!(t.contains("| K-MEANS++ | 6.58x |"), "{t}");
    }

    #[test]
    fn cost_table_scales() {
        let res = fake_results();
        let t = cost_table(&res, DatasetId::KddSim, &[100]);
        assert!(t.contains("Table 4"));
        // 3.0e7 avg with the 1.1 factor -> 31500 at x10^3 scale
        assert!(t.contains("31500") || t.contains("31499"), "{t}");
        assert!(t.contains("UNIFORMSAMPLING"));
    }

    #[test]
    fn variance_table_renders() {
        let res = fake_results();
        let t = variance_table(&res, DatasetId::KddSim, &[100]);
        assert!(t.contains("Table 8"));
        assert!(t.contains("K-MEANS++"));
    }

    #[test]
    fn grid_json_structure() {
        let res = fake_results();
        let cfg = crate::coordinator::config::ExperimentConfig::default();
        let doc = grid_json(&res, &cfg);
        // Emit → reparse through the strict parser: the artifact is valid
        // JSON and carries every cell.
        let back = crate::server::json::parse(&doc.emit()).unwrap();
        assert_eq!(back.get("backend").and_then(Json::as_str), Some(""));
        assert_eq!(back.get("reps").and_then(Json::as_usize), Some(5));
        let cells = back.get("cells").and_then(Json::as_array).unwrap();
        assert_eq!(cells.len(), 5);
        let first = &cells[0];
        assert_eq!(first.get("dataset").and_then(Json::as_str), Some("kdd_sim"));
        assert_eq!(first.get("k").and_then(Json::as_usize), Some(100));
        assert!(first.get("seconds").unwrap().get("mean").is_some());
        // Empty stats (no lloyd runs in the fake grid) emit null.
        assert!(first.get("lloyd_cost").map(Json::is_null).unwrap());
    }

    #[test]
    fn kernels_json_round_trips_with_grid_shape() {
        let mut s = Stats::new();
        s.push(0.5);
        s.push(0.6);
        let cells = vec![
            KernelCell {
                dataset: "synth_n100000_d128".to_string(),
                algorithm: "assign_argmin_v1_naive".to_string(),
                k: 64,
                seconds: s.clone(),
                speedup_vs_naive: 1.0,
            },
            KernelCell {
                dataset: "synth_n100000_d128".to_string(),
                algorithm: "assign_argmin_v2_blocked".to_string(),
                k: 64,
                seconds: s,
                speedup_vs_naive: 1.8,
            },
        ];
        let doc = kernels_json(&cells, 2, 7, 1);
        let back = crate::server::json::parse(&doc.emit()).unwrap();
        // Same top-level fields as grid_json...
        assert_eq!(back.get("profile").and_then(Json::as_str), Some("kernel_bench"));
        assert_eq!(back.get("reps").and_then(Json::as_usize), Some(2));
        assert_eq!(back.get("backend").and_then(Json::as_str), Some("native"));
        let arr = back.get("cells").and_then(Json::as_array).unwrap();
        assert_eq!(arr.len(), 2);
        // ...and the same per-cell field names.
        let cell = &arr[1];
        let algo = cell.get("algorithm").and_then(Json::as_str);
        assert_eq!(algo, Some("assign_argmin_v2_blocked"));
        assert_eq!(cell.get("k").and_then(Json::as_usize), Some(64));
        assert!(cell.get("seconds").unwrap().get("mean").is_some());
        assert!(cell.get("cost").map(Json::is_null).unwrap());
        let speedup = cell.get("speedup_vs_naive").and_then(Json::as_f64).unwrap();
        assert!((speedup - 1.8).abs() < 1e-12);
    }

    #[test]
    fn extension_rows_render_only_when_present() {
        let mut res = fake_results();
        // No kmeans-par cells yet: the paper tables stay exactly five rows.
        let t = cost_table(&res, DatasetId::KddSim, &[100]);
        assert!(!t.contains("KMEANSPAR"), "{t}");
        // Add one kmeans-par cell: it appears after the paper rows.
        let mut cell = CellResult::default();
        cell.seconds.push(1.1);
        cell.cost.push(2.8e7);
        res.cells.insert(
            CellKey {
                dataset: DatasetId::KddSim,
                algorithm: SeedingAlgorithm::KMeansPar,
                k: 100,
            },
            cell,
        );
        let t = cost_table(&res, DatasetId::KddSim, &[100]);
        assert!(t.contains("KMEANSPAR"), "{t}");
        let rt = runtime_table(&res, DatasetId::KddSim, &[100]);
        assert!(rt.contains("KMEANSPAR"), "{rt}");
    }

    #[test]
    fn shard_json_round_trips_with_grid_shape() {
        let mut s = Stats::new();
        s.push(0.4);
        let mut c = Stats::new();
        c.push(3.1e7);
        let cells = vec![ShardCell {
            dataset: "synth_n100000_d128".to_string(),
            algorithm: "kmeans-par_s4".to_string(),
            k: 64,
            shards: 4,
            seconds: s,
            cost: c,
        }];
        let doc = shard_json(&cells, 3, 7, 4);
        let back = crate::server::json::parse(&doc.emit()).unwrap();
        assert_eq!(back.get("profile").and_then(Json::as_str), Some("shard_bench"));
        assert_eq!(back.get("reps").and_then(Json::as_usize), Some(3));
        let arr = back.get("cells").and_then(Json::as_array).unwrap();
        assert_eq!(arr.len(), 1);
        let cell = &arr[0];
        assert_eq!(cell.get("algorithm").and_then(Json::as_str), Some("kmeans-par_s4"));
        assert_eq!(cell.get("shards").and_then(Json::as_usize), Some(4));
        assert!(cell.get("seconds").unwrap().get("mean").is_some());
        assert!(cell.get("cost").unwrap().get("mean").is_some());
        assert!(cell.get("lloyd_cost").map(Json::is_null).unwrap());
    }

    #[test]
    fn dist_json_round_trips_with_grid_shape() {
        let mut s = Stats::new();
        s.push(0.6);
        let mut c = Stats::new();
        c.push(2.2e7);
        let cells = vec![DistCell {
            dataset: "synth_n100000_d64".to_string(),
            algorithm: "kmeans-par_w2".to_string(),
            k: 32,
            workers: 2,
            seconds: s,
            cost: c,
        }];
        let doc = dist_json(&cells, 2, 7, 4);
        let back = crate::server::json::parse(&doc.emit()).unwrap();
        assert_eq!(back.get("profile").and_then(Json::as_str), Some("dist_bench"));
        assert_eq!(back.get("reps").and_then(Json::as_usize), Some(2));
        let arr = back.get("cells").and_then(Json::as_array).unwrap();
        assert_eq!(arr.len(), 1);
        let cell = &arr[0];
        assert_eq!(cell.get("algorithm").and_then(Json::as_str), Some("kmeans-par_w2"));
        assert_eq!(cell.get("workers").and_then(Json::as_usize), Some(2));
        assert!(cell.get("seconds").unwrap().get("mean").is_some());
        assert!(cell.get("cost").unwrap().get("mean").is_some());
        assert!(cell.get("lloyd_cost").map(Json::is_null).unwrap());
    }

    #[test]
    fn rejection_json_round_trips_with_grid_shape() {
        let mut s = Stats::new();
        s.push(0.8);
        let mut c = Stats::new();
        c.push(2.9e7);
        let mut p = Stats::new();
        p.push(3.5);
        let cells = vec![RejectionCell {
            dataset: "synth_n100000_d128".to_string(),
            algorithm: "rejection".to_string(),
            oracle: "lsh-rigorous".to_string(),
            k: 64,
            seconds: s,
            cost: c,
            proposals_per_center: p,
        }];
        let doc = rejection_json(&cells, 2, 7, 4);
        let back = crate::server::json::parse(&doc.emit()).unwrap();
        assert_eq!(
            back.get("profile").and_then(Json::as_str),
            Some("rejection_bench")
        );
        assert_eq!(back.get("reps").and_then(Json::as_usize), Some(2));
        let arr = back.get("cells").and_then(Json::as_array).unwrap();
        assert_eq!(arr.len(), 1);
        let cell = &arr[0];
        assert_eq!(cell.get("algorithm").and_then(Json::as_str), Some("rejection"));
        assert_eq!(cell.get("oracle").and_then(Json::as_str), Some("lsh-rigorous"));
        assert_eq!(cell.get("k").and_then(Json::as_usize), Some(64));
        assert!(cell.get("seconds").unwrap().get("mean").is_some());
        assert!(cell.get("cost").unwrap().get("mean").is_some());
        assert!(cell.get("proposals_per_center").unwrap().get("mean").is_some());
        assert!(cell.get("lloyd_cost").map(Json::is_null).unwrap());
    }

    #[test]
    fn serve_json_round_trips_with_grid_shape() {
        let mut s = Stats::new();
        s.push(0.2);
        s.push(0.25);
        let cells = vec![ServeCell {
            dataset: "payload_n256_d16".to_string(),
            algorithm: "assign_binary_keepalive".to_string(),
            route: "binary".to_string(),
            mode: "keepalive".to_string(),
            connections: 8,
            k: 64,
            seconds: s,
            p50_ms: 0.8,
            p99_ms: 2.5,
            throughput_rps: 1234.5,
        }];
        let doc = serve_json(&cells, 2, 7, 4);
        let back = crate::server::json::parse(&doc.emit()).unwrap();
        assert_eq!(back.get("profile").and_then(Json::as_str), Some("serve_bench"));
        assert_eq!(back.get("reps").and_then(Json::as_usize), Some(2));
        assert_eq!(back.get("threads").and_then(Json::as_usize), Some(4));
        let arr = back.get("cells").and_then(Json::as_array).unwrap();
        assert_eq!(arr.len(), 1);
        let cell = &arr[0];
        assert_eq!(
            cell.get("algorithm").and_then(Json::as_str),
            Some("assign_binary_keepalive")
        );
        assert_eq!(cell.get("route").and_then(Json::as_str), Some("binary"));
        assert_eq!(cell.get("mode").and_then(Json::as_str), Some("keepalive"));
        assert_eq!(cell.get("connections").and_then(Json::as_usize), Some(8));
        assert!(cell.get("seconds").unwrap().get("mean").is_some());
        assert!(cell.get("cost").map(Json::is_null).unwrap());
        let rps = cell.get("throughput_rps").and_then(Json::as_f64).unwrap();
        assert!((rps - 1234.5).abs() < 1e-9);
        assert!(cell.get("p50_ms").and_then(Json::as_f64).is_some());
        assert!(cell.get("p99_ms").and_then(Json::as_f64).is_some());
    }

    #[test]
    fn rejection_rigorous_renders_as_extension_row() {
        let mut res = fake_results();
        let t = cost_table(&res, DatasetId::KddSim, &[100]);
        assert!(!t.contains("REJECTION-RIGOROUS"), "{t}");
        let mut cell = CellResult::default();
        cell.seconds.push(1.2);
        cell.cost.push(3.0e7);
        res.cells.insert(
            CellKey {
                dataset: DatasetId::KddSim,
                algorithm: SeedingAlgorithm::RejectionLshRigorous,
                k: 100,
            },
            cell,
        );
        let t = cost_table(&res, DatasetId::KddSim, &[100]);
        assert!(t.contains("REJECTION-RIGOROUS"), "{t}");
        let rt = runtime_table(&res, DatasetId::KddSim, &[100]);
        assert!(rt.contains("REJECTION-RIGOROUS"), "{rt}");
    }

    #[test]
    fn missing_cells_render_dashes() {
        let res = GridResults::default();
        let t = runtime_table(&res, DatasetId::SongSim, &[100, 500]);
        assert!(t.contains("—"));
        assert!(t.contains("Table 2"));
    }
}
