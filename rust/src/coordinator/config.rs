//! Experiment configuration: every knob of the paper's evaluation in one
//! struct, buildable from CLI flags (no serde in the offline build — the
//! CLI parser in `cli.rs` fills this in).

use std::path::PathBuf;

use crate::data::registry::{DatasetId, Profile};
use crate::seeding::afkmc2::Afkmc2Config;
use crate::seeding::rejection::RejectionConfig;
use crate::seeding::SeedingAlgorithm;
use crate::shard::kmeanspar::KMeansParConfig;

/// Full sweep specification.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub datasets: Vec<DatasetId>,
    pub profile: Profile,
    pub algorithms: Vec<SeedingAlgorithm>,
    /// The paper's k grid: 100, 500, 1000, 2000, 3000, 5000.
    pub ks: Vec<usize>,
    /// Repetitions per cell (paper: 5).
    pub reps: usize,
    /// Base seed; rep r of cell uses `seed + r`.
    pub seed: u64,
    /// Apply Appendix-F quantization before seeding (costs are still
    /// evaluated on the original coordinates).
    pub quantize: bool,
    /// Dataset cache directory.
    pub data_dir: PathBuf,
    /// AOT artifacts directory (PJRT backend; falls back to native).
    pub artifacts_dir: PathBuf,
    pub rejection: RejectionConfig,
    pub afkmc2: Afkmc2Config,
    /// Sharded k-means‖ knobs (`--shards`, `--rounds`, `--oversample`).
    pub kmeanspar: KMeansParConfig,
    /// Lloyd refinement iterations after seeding (0 = seeding only, as in
    /// the paper's tables).
    pub lloyd_iters: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            datasets: vec![DatasetId::KddSim],
            profile: Profile::Scaled,
            algorithms: SeedingAlgorithm::paper_order().to_vec(),
            ks: paper_k_grid(),
            reps: 5,
            seed: 42,
            quantize: true,
            data_dir: PathBuf::from("data"),
            artifacts_dir: PathBuf::from("artifacts"),
            rejection: RejectionConfig::default(),
            afkmc2: Afkmc2Config::default(),
            kmeanspar: KMeansParConfig::default(),
            lloyd_iters: 0,
        }
    }
}

/// The paper's k grid (Tables 1–8).
pub fn paper_k_grid() -> Vec<usize> {
    vec![100, 500, 1000, 2000, 3000, 5000]
}

/// A k grid scaled to a dataset size: keep the paper's shape but cap at
/// n/10 so smoke/scaled profiles stay meaningful.
pub fn k_grid_for(n: usize) -> Vec<usize> {
    paper_k_grid()
        .into_iter()
        .filter(|&k| k <= n / 10)
        .collect::<Vec<_>>()
        .into_iter()
        .collect()
}

/// Default k grid for the table benches: `k_grid_for(n)` additionally
/// capped at 2000 — the `Θ(mk^2 d)` AFK-MC2 baseline dominates a default
/// `cargo bench` run beyond that. `--full` (or `--ks`) restores the
/// paper's complete grid.
pub fn bench_default_k_grid(n: usize) -> Vec<usize> {
    k_grid_for(n).into_iter().filter(|&k| k <= 2000).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_grid() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.ks, vec![100, 500, 1000, 2000, 3000, 5000]);
        assert_eq!(cfg.reps, 5);
        assert_eq!(cfg.algorithms.len(), 5);
    }

    #[test]
    fn k_grid_caps_at_n_over_10() {
        assert_eq!(k_grid_for(60_000), vec![100, 500, 1000, 2000, 3000, 5000]);
        assert_eq!(k_grid_for(12_000), vec![100, 500, 1000]);
        assert_eq!(k_grid_for(500), Vec::<usize>::new());
    }
}
