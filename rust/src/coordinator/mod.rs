//! The experiment coordinator — the L3 "system" layer that turns the
//! algorithm library into the paper's evaluation: configuration, the
//! sweep runner (dataset × algorithm × k × repetition grid), and the
//! table emitters that regenerate Tables 1–8.

pub mod config;
pub mod runner;
pub mod tables;

pub use config::ExperimentConfig;
pub use runner::{run_grid, CellKey, CellResult, GridResults};
