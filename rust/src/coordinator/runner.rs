//! The sweep runner: executes the (dataset × algorithm × k × rep) grid,
//! timing seeding wall-clock and evaluating costs, and aggregates the
//! per-cell statistics the table emitters render.
//!
//! Cost evaluation goes through [`crate::runtime::Backend`], whose native
//! path is the parallel kernel engine ([`crate::kernels`]) — the runner
//! owns *no* distance loops of its own, so every timed cell reflects the
//! same hot paths the benches measure.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::coordinator::config::ExperimentConfig;
use crate::data::matrix::PointSet;
use crate::data::quantize::quantize;
use crate::data::registry::DatasetId;
use crate::error::Result;
use crate::lloyd::{lloyd, LloydConfig};
use crate::metrics::Stats;
use crate::rng::Pcg64;
use crate::runtime::Backend;
use crate::seeding::{
    afkmc2::afkmc2, fastkmeanspp::fast_kmeanspp, kmeanspp::kmeanspp,
    rejection::rejection_sampling, uniform::uniform_sampling, Seeding, SeedingAlgorithm,
};

/// Grid cell key.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct CellKey {
    pub dataset: DatasetId,
    pub algorithm: SeedingAlgorithm,
    pub k: usize,
}

// Derive-free Ord support for the enums (they are small and fixed).
impl PartialOrd for DatasetId {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for DatasetId {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (*self as u8).cmp(&(*other as u8))
    }
}
impl PartialOrd for SeedingAlgorithm {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for SeedingAlgorithm {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (*self as u8).cmp(&(*other as u8))
    }
}

/// Aggregated results for one grid cell over `reps` runs.
#[derive(Clone, Debug, Default)]
pub struct CellResult {
    /// Seeding wall-clock seconds (init + select, as the paper times it).
    pub seconds: Stats,
    /// Seeding cost (k-means objective of the seed centers, original
    /// coordinates).
    pub cost: Stats,
    /// Cost after Lloyd refinement (only if `lloyd_iters > 0`).
    pub lloyd_cost: Stats,
    /// Rejection-loop proposals per accepted center (Lemma 5.3 check).
    pub proposals_per_center: Stats,
}

/// All cells of a sweep.
#[derive(Clone, Debug, Default)]
pub struct GridResults {
    pub cells: BTreeMap<CellKey, CellResult>,
    /// Backend used for cost evaluation.
    pub backend_name: &'static str,
}

impl GridResults {
    pub fn get(&self, dataset: DatasetId, algorithm: SeedingAlgorithm, k: usize) -> Option<&CellResult> {
        self.cells.get(&CellKey {
            dataset,
            algorithm,
            k,
        })
    }
}

/// Run one seeding with the per-algorithm config from `cfg`.
pub fn run_seeding(
    cfg: &ExperimentConfig,
    algo: SeedingAlgorithm,
    ps: &PointSet,
    k: usize,
    rng: &mut Pcg64,
) -> Seeding {
    match algo {
        SeedingAlgorithm::KMeansPP => kmeanspp(ps, k, rng),
        SeedingAlgorithm::FastKMeansPP => fast_kmeanspp(ps, k, &Default::default(), rng),
        SeedingAlgorithm::Rejection
        | SeedingAlgorithm::RejectionExact
        | SeedingAlgorithm::RejectionLshRigorous => {
            // Plain `rejection` honors the sweep's configured oracle
            // (`--oracle`); the ablation variants pin theirs so grid rows
            // stay comparable across configs.
            let rc = algo.resolved_rejection_config(&cfg.rejection);
            rejection_sampling(ps, k, &rc, rng)
        }
        SeedingAlgorithm::Afkmc2 => afkmc2(ps, k, &cfg.afkmc2, rng),
        SeedingAlgorithm::Uniform => uniform_sampling(ps, k, rng),
        SeedingAlgorithm::KMeansPPGreedy => {
            crate::seeding::kmeanspp::kmeanspp_greedy(ps, k, 5, rng)
        }
        SeedingAlgorithm::KMeansPar => {
            crate::shard::kmeanspar::kmeans_par(ps, k, &cfg.kmeanspar, rng)
        }
    }
}

/// Execute the whole grid. `progress` is called after every completed
/// cell with a human-readable line (the CLI prints it; benches pass a
/// no-op).
pub fn run_grid<F: FnMut(&str)>(cfg: &ExperimentConfig, mut progress: F) -> Result<GridResults> {
    let backend = Backend::auto(&cfg.artifacts_dir);
    let mut results = GridResults {
        backend_name: backend.name(),
        ..Default::default()
    };
    for &dataset in &cfg.datasets {
        let original = dataset.load_cached(&cfg.data_dir, cfg.profile, cfg.seed)?;
        // Appendix-F quantization for seeding; costs on original coords.
        let seed_space = if cfg.quantize {
            let mut qrng = Pcg64::seed_from(cfg.seed ^ 0x5EED_0F00D);
            quantize(&original, &mut qrng).points
        } else {
            original.clone()
        };
        for &k in &cfg.ks {
            if k > original.len() {
                continue;
            }
            for &algo in &cfg.algorithms {
                let mut cell = CellResult::default();
                for rep in 0..cfg.reps {
                    let mut rng = Pcg64::seed_from(
                        cfg.seed
                            .wrapping_add(rep as u64)
                            .wrapping_add((k as u64) << 20)
                            ^ (algo as u64) << 56,
                    );
                    let t0 = Instant::now();
                    let seeding = run_seeding(cfg, algo, &seed_space, k, &mut rng);
                    let secs = t0.elapsed().as_secs_f64();
                    cell.seconds.push(secs);
                    // Cost on ORIGINAL coordinates via the chosen indices.
                    let centers = original.gather(&seeding.indices);
                    cell.cost.push(backend.cost(&original, &centers)?);
                    if seeding.stats.proposals > 0 {
                        cell.proposals_per_center
                            .push(seeding.stats.proposals as f64 / k.max(1) as f64);
                    }
                    if cfg.lloyd_iters > 0 {
                        let res = lloyd(
                            &original,
                            &centers,
                            &LloydConfig {
                                max_iters: cfg.lloyd_iters,
                                tol: 1e-6,
                            },
                            &backend,
                        )?;
                        cell.lloyd_cost.push(*res.history.last().unwrap());
                    }
                }
                progress(&format!(
                    "{} {} k={}: {:.3}s cost={:.4e}",
                    dataset.name(),
                    algo.name(),
                    k,
                    cell.seconds.mean(),
                    cell.cost.mean()
                ));
                results.cells.insert(
                    CellKey {
                        dataset,
                        algorithm: algo,
                        k,
                    },
                    cell,
                );
            }
        }
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::Profile;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig {
            datasets: vec![DatasetId::KddSim],
            profile: Profile::Smoke,
            algorithms: vec![SeedingAlgorithm::Uniform, SeedingAlgorithm::FastKMeansPP],
            ks: vec![10, 20],
            reps: 2,
            seed: 7,
            data_dir: std::env::temp_dir().join("fkmpp_runner_test"),
            artifacts_dir: std::path::PathBuf::from("/nonexistent"),
            ..Default::default()
        }
    }

    #[test]
    fn grid_produces_all_cells() {
        let cfg = tiny_cfg();
        let res = run_grid(&cfg, |_| {}).unwrap();
        assert_eq!(res.cells.len(), 4);
        assert_eq!(res.backend_name, "native");
        for (key, cell) in &res.cells {
            assert_eq!(cell.seconds.count(), 2, "{key:?}");
            assert_eq!(cell.cost.count(), 2);
            assert!(cell.cost.mean() > 0.0);
        }
    }

    #[test]
    fn lloyd_refinement_reduces_cost() {
        let mut cfg = tiny_cfg();
        cfg.algorithms = vec![SeedingAlgorithm::Uniform];
        cfg.ks = vec![15];
        cfg.reps = 2;
        cfg.lloyd_iters = 5;
        let res = run_grid(&cfg, |_| {}).unwrap();
        let cell = res
            .get(DatasetId::KddSim, SeedingAlgorithm::Uniform, 15)
            .unwrap();
        assert!(cell.lloyd_cost.mean() <= cell.cost.mean());
    }

    #[test]
    fn rejection_oracle_variants_all_produce_cells() {
        // The three rejection-family rows run end-to-end through the
        // grid: plain (configured oracle), exact, and rigorous.
        let mut cfg = tiny_cfg();
        cfg.algorithms = vec![
            SeedingAlgorithm::Rejection,
            SeedingAlgorithm::RejectionExact,
            SeedingAlgorithm::RejectionLshRigorous,
        ];
        cfg.ks = vec![10];
        cfg.reps = 1;
        let res = run_grid(&cfg, |_| {}).unwrap();
        assert_eq!(res.cells.len(), 3);
        for algo in cfg.algorithms {
            let cell = res.get(DatasetId::KddSim, algo, 10).unwrap();
            assert!(cell.cost.mean() > 0.0, "{}", algo.name());
            assert!(
                cell.proposals_per_center.count() > 0,
                "{} reported no proposals",
                algo.name()
            );
        }
    }

    #[test]
    fn oversized_k_skipped() {
        let mut cfg = tiny_cfg();
        cfg.ks = vec![10, 1_000_000];
        let res = run_grid(&cfg, |_| {}).unwrap();
        assert_eq!(res.cells.len(), 2); // only k=10 cells
    }
}
