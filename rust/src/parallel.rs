//! Minimal data-parallel helpers on `std::thread::scope` (the offline
//! build has no rayon). This is the **only** module that spawns threads:
//! every distance kernel in [`crate::kernels`] drives its loops through
//! the chunked helpers here, so thread-count policy (`FKMPP_THREADS`),
//! chunk sizing and the unsafe-free slice splitting live in one place.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use (cores, capped; override with
/// `FKMPP_THREADS`).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("FKMPP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(32)
}

/// Shared chunk planning: how many workers for `units` work items given
/// a `min_per_thread` floor, and how many items each worker takes.
/// Returns `(threads, chunk)` with `threads >= 1` and `chunk >= 1`
/// whenever `units > 0`.
fn plan(units: usize, min_per_thread: usize) -> (usize, usize) {
    let threads = num_threads().min(units / min_per_thread.max(1)).max(1);
    (threads, units.div_ceil(threads).max(1))
}

/// Split `[0, n)` into contiguous chunks, one per worker, and run `f` on
/// each in parallel. `f(range)` must be independent across chunks.
/// Falls back to a single inline call for small `n`.
pub fn parallel_ranges<F>(n: usize, min_per_thread: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let (threads, chunk) = plan(n, min_per_thread);
    if threads <= 1 {
        f(0..n);
        return;
    }
    std::thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            s.spawn(move || f(start..end));
        }
    });
}

/// Parallel map-reduce over contiguous chunks: each worker folds its
/// range with `map`, results combined with `reduce`.
pub fn parallel_reduce<T, M, R>(n: usize, min_per_thread: usize, identity: T, map: M, reduce: R) -> T
where
    T: Send,
    M: Fn(std::ops::Range<usize>) -> T + Sync,
    R: Fn(T, T) -> T,
{
    let (threads, chunk) = plan(n, min_per_thread);
    if threads <= 1 {
        return reduce(identity, map(0..n));
    }
    let mut results = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            let map = &map;
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            handles.push(s.spawn(move || map(start..end)));
        }
        for h in handles {
            results.push(h.join().expect("worker panicked"));
        }
    });
    results.into_iter().fold(identity, |a, b| reduce(a, b))
}

/// Split a mutable slice into per-worker contiguous chunks whose lengths
/// are multiples of `align` (the final chunk takes the remainder) and run
/// `f(start_index, chunk)` on each in parallel.
///
/// This is the safe replacement for the raw-pointer `SendPtr` loops the
/// seeders used to carry: ownership of each disjoint sub-slice moves into
/// its worker via `split_at_mut`, so no `unsafe` is needed.
/// `min_per_thread` is measured in `align`-sized units.
pub fn parallel_chunks_mut<T, F>(data: &mut [T], align: usize, min_per_thread: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let align = align.max(1);
    let (threads, unit_chunk) = plan(data.len() / align, min_per_thread);
    if threads <= 1 {
        f(0, data);
        return;
    }
    let chunk = unit_chunk * align;
    std::thread::scope(|s| {
        for (c, part) in data.chunks_mut(chunk).enumerate() {
            let f = &f;
            let start = c * chunk;
            s.spawn(move || f(start, part));
        }
    });
}

/// Like [`parallel_chunks_mut`] over two equal-length slices split at the
/// same boundaries — the shape of the assignment kernel, which fills an
/// index array and a distance array in one pass.
pub fn parallel_chunks_mut2<A, B, F>(a: &mut [A], b: &mut [B], min_per_thread: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert_eq!(a.len(), b.len(), "parallel_chunks_mut2: length mismatch");
    let (threads, chunk) = plan(a.len(), min_per_thread);
    if threads <= 1 {
        f(0, a, b);
        return;
    }
    std::thread::scope(|s| {
        for (c, (part_a, part_b)) in a.chunks_mut(chunk).zip(b.chunks_mut(chunk)).enumerate() {
            let f = &f;
            let start = c * chunk;
            s.spawn(move || f(start, part_a, part_b));
        }
    });
}

/// Parallel `map` over `[0, n)` preserving order: returns
/// `[f(0), f(1), ..., f(n-1)]`. Items are claimed dynamically, so uneven
/// per-item cost (e.g. independent tree builds) balances automatically.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = num_threads().min(n).max(1);
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let f = &f;
            let next = &next;
            let slots = &slots;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker skipped an item"))
        .collect()
}

/// Split a mutable slice at the given end offsets (strictly increasing,
/// last one == `data.len()`) and run `f(piece_index, piece)` on each
/// piece in parallel, pieces claimed dynamically.
///
/// Unlike [`parallel_chunks_mut`] the pieces may be **uneven** — this is
/// the shape of the sharded seeding engine, where each piece is one data
/// shard's slice of a global `D²` array and the last shard takes the
/// remainder. Piece identity (not a flat offset) is passed to `f` so the
/// callback can pair each slice with its shard's context.
pub fn parallel_slices_mut<T, F>(data: &mut [T], ends: &[usize], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert_eq!(ends.last().copied().unwrap_or(0), data.len(), "ends must cover data");
    let threads = num_threads().min(ends.len()).max(1);
    if threads <= 1 {
        let mut lo = 0;
        for (p, &hi) in ends.iter().enumerate() {
            f(p, &mut data[lo..hi]);
            lo = hi;
        }
        return;
    }
    // Pre-split into disjoint pieces; workers pop (index, piece) pairs
    // off a shared iterator, so ownership of each &mut sub-slice moves
    // into exactly one worker without unsafe.
    let mut pieces: Vec<(usize, &mut [T])> = Vec::with_capacity(ends.len());
    let mut rest = data;
    let mut lo = 0;
    for (p, &hi) in ends.iter().enumerate() {
        assert!(hi >= lo, "ends must be non-decreasing");
        let (piece, tail) = rest.split_at_mut(hi - lo);
        pieces.push((p, piece));
        rest = tail;
        lo = hi;
    }
    let queue = Mutex::new(pieces.into_iter());
    std::thread::scope(|s| {
        for _ in 0..threads {
            let f = &f;
            let queue = &queue;
            s.spawn(move || loop {
                let next = queue.lock().unwrap().next();
                match next {
                    Some((p, piece)) => f(p, piece),
                    None => break,
                }
            });
        }
    });
}

/// Work-stealing-ish dynamic parallel-for over indivisible items (used
/// where per-item cost is very uneven, e.g. per-k bench cells).
pub fn parallel_items<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = num_threads().min(n).max(1);
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let f = &f;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn ranges_cover_everything_once() {
        let n = 100_003;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_ranges(n, 64, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn reduce_sums_correctly() {
        let n = 10_000usize;
        let total = parallel_reduce(
            n,
            16,
            0u64,
            |r| r.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn items_run_each_exactly_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_items(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn chunks_mut_writes_every_slot_once() {
        let mut data = vec![0u32; 50_001];
        parallel_chunks_mut(&mut data, 1, 64, |start, chunk| {
            for (slot, i) in chunk.iter_mut().zip(start..) {
                *slot += i as u32 + 1;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32 + 1, "slot {i}");
        }
    }

    #[test]
    fn chunks_mut_respects_alignment() {
        // With align = 7, every split boundary must be a multiple of 7.
        let rows = 1000;
        let mut data = vec![u32::MAX; rows * 7];
        parallel_chunks_mut(&mut data, 7, 1, |start, chunk| {
            assert_eq!(start % 7, 0, "misaligned start {start}");
            if start + chunk.len() < rows * 7 {
                assert_eq!(chunk.len() % 7, 0, "misaligned chunk at {start}");
            }
            for (slot, i) in chunk.iter_mut().zip(start..) {
                *slot = (i / 7) as u32;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v as usize, i / 7);
        }
    }

    #[test]
    fn chunks_mut2_splits_in_lockstep() {
        let n = 30_000;
        let mut a = vec![0u64; n];
        let mut b = vec![0u64; n];
        parallel_chunks_mut2(&mut a, &mut b, 64, |start, ca, cb| {
            assert_eq!(ca.len(), cb.len());
            for (t, (x, y)) in ca.iter_mut().zip(cb.iter_mut()).enumerate() {
                *x = (start + t) as u64;
                *y = 2 * (start + t) as u64;
            }
        });
        for i in 0..n {
            assert_eq!(a[i], i as u64);
            assert_eq!(b[i], 2 * i as u64);
        }
    }

    #[test]
    fn slices_mut_covers_uneven_pieces() {
        // Shard-shaped split: uneven piece lengths, remainder in the last.
        let n = 10_007;
        let mut data = vec![0u32; n];
        let ends = vec![3_000, 3_001, 7_777, n];
        parallel_slices_mut(&mut data, &ends, |p, piece| {
            for slot in piece.iter_mut() {
                *slot = p as u32 + 1;
            }
        });
        let mut lo = 0;
        for (p, &hi) in ends.iter().enumerate() {
            assert!(data[lo..hi].iter().all(|&v| v == p as u32 + 1), "piece {p}");
            lo = hi;
        }
        // Degenerate shapes: empty data, single piece.
        parallel_slices_mut(&mut [] as &mut [u32], &[], |_, _| panic!("no pieces"));
        let mut one = vec![0u8; 5];
        parallel_slices_mut(&mut one, &[5], |p, piece| {
            assert_eq!(p, 0);
            piece.fill(9);
        });
        assert_eq!(one, vec![9; 5]);
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(1000, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
        assert!(parallel_map(0, |i| i).is_empty());
    }

    #[test]
    fn small_n_inline() {
        // n smaller than min_per_thread must still work (single thread).
        let mut seen = vec![false; 3];
        let cell = std::sync::Mutex::new(&mut seen);
        parallel_ranges(3, 1000, |r| {
            let mut guard = cell.lock().unwrap();
            for i in r {
                guard[i] = true;
            }
        });
        assert!(seen.iter().all(|&b| b));
    }
}
