//! Minimal data-parallel helpers on `std::thread::scope` (the offline
//! build has no rayon). Used by the native distance kernels: the exact
//! `D^2` update, assignment and cost loops are embarrassingly parallel
//! over points.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (cores, capped; override with
/// `FKMPP_THREADS`).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("FKMPP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(32)
}

/// Split `[0, n)` into contiguous chunks, one per worker, and run `f` on
/// each in parallel. `f(range)` must be independent across chunks.
/// Falls back to a single inline call for small `n`.
pub fn parallel_ranges<F>(n: usize, min_per_thread: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let threads = num_threads().min(n / min_per_thread.max(1)).max(1);
    if threads <= 1 {
        f(0..n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let f = &f;
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            s.spawn(move || f(start..end));
        }
    });
}

/// Parallel map-reduce over contiguous chunks: each worker folds its
/// range with `map`, results combined with `reduce`.
pub fn parallel_reduce<T, M, R>(n: usize, min_per_thread: usize, identity: T, map: M, reduce: R) -> T
where
    T: Send,
    M: Fn(std::ops::Range<usize>) -> T + Sync,
    R: Fn(T, T) -> T,
{
    let threads = num_threads().min(n / min_per_thread.max(1)).max(1);
    if threads <= 1 {
        return reduce(identity, map(0..n));
    }
    let chunk = n.div_ceil(threads);
    let mut results = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            let map = &map;
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            handles.push(s.spawn(move || map(start..end)));
        }
        for h in handles {
            results.push(h.join().expect("worker panicked"));
        }
    });
    results.into_iter().fold(identity, |a, b| reduce(a, b))
}

/// Work-stealing-ish dynamic parallel-for over indivisible items (used
/// where per-item cost is very uneven, e.g. per-k bench cells).
pub fn parallel_items<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = num_threads().min(n).max(1);
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let f = &f;
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn ranges_cover_everything_once() {
        let n = 100_003;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_ranges(n, 64, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn reduce_sums_correctly() {
        let n = 10_000usize;
        let total = parallel_reduce(
            n,
            16,
            0u64,
            |r| r.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn items_run_each_exactly_once() {
        let n = 1000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_items(n, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn small_n_inline() {
        // n smaller than min_per_thread must still work (single thread).
        let mut seen = vec![false; 3];
        let cell = std::sync::Mutex::new(&mut seen);
        parallel_ranges(3, 1000, |r| {
            let mut guard = cell.lock().unwrap();
            for i in r {
                guard[i] = true;
            }
        });
        assert!(seen.iter().all(|&b| b));
    }
}
