//! # fastkmeanspp — Fast and Accurate k-means++ via Rejection Sampling
//!
//! A production-quality reproduction of Cohen-Addad, Lattanzi,
//! Norouzi-Fard, Sohler & Svensson, *"Fast and Accurate k-means++ via
//! Rejection Sampling"* (NeurIPS 2020).
//!
//! The library implements, from scratch:
//!
//! * the **random-shift grid (quadtree) embedding** and the 3-way
//!   **multi-tree embedding** with its `O(d^2)` expected squared-distance
//!   distortion ([`embed`]);
//! * the **weighted sample-tree** supporting `O(log n)` weight updates and
//!   `O(log n)` proportional sampling ([`sampletree`]);
//! * `MultiTreeOpen` / `MultiTreeSample` and the near-linear-time
//!   [`seeding::fastkmeanspp`] seeder (Algorithm 3);
//! * a **monotone p-stable LSH** approximate-nearest-neighbor structure
//!   (Theorem 5.1 / Appendix D) in [`lsh`];
//! * the **rejection-sampling** seeder that emulates the exact `D^2`
//!   distribution up to `c^2` ([`seeding::rejection`], Algorithm 4);
//! * the paper's baselines: exact [`seeding::kmeanspp`],
//!   [`seeding::afkmc2`] (Bachem et al. 2016) and
//!   [`seeding::uniform`];
//! * the **parallel distance-kernel engine** ([`kernels`]) every exact
//!   `D^2` update, assignment and cost loop routes through — chunked,
//!   cache-blocked, `FKMPP_THREADS`-controllable;
//! * [`lloyd`] refinement and cost evaluation, with both a tuned native
//!   path and (behind the `pjrt` feature) an AOT-compiled JAX/Pallas path
//!   executed through PJRT ([`runtime`]);
//! * dataset generators/registry matching the paper's evaluation scale
//!   ([`data`]) and the experiment [`coordinator`] that regenerates every
//!   table of the paper;
//! * a zero-dependency **serving layer** ([`server`], `fkmpp serve`):
//!   HTTP/1.1 + hand-rolled JSON, an in-memory model registry with disk
//!   persistence, async fit jobs, and batched assignment routed through
//!   the kernel engine;
//! * a **sharded seeding engine** ([`shard`], `--algo kmeans-par`):
//!   k-means‖ oversampling rounds over data shards plus weighted
//!   k-means++ reclustering of the candidate set — the first explicit
//!   coordinator/shard split, with bitwise shard-count and thread-count
//!   invariance;
//! * a **distributed fit** ([`dist`], `fkmpp worker` + `fkmpp seed
//!   --workers host:port,...`): the same k-means‖ rounds over worker
//!   *processes* behind one `RoundExecutor` trait, with a binary RPC
//!   codec, replay-based fault recovery, and bitwise parity with the
//!   in-process run.
//!
//! Python/JAX appears only at build time (`make artifacts`); the request
//! path is pure rust. The crate has **zero external dependencies**: error
//! handling lives in [`error`] and randomness in [`rng`].
//!
//! ## Quickstart
//!
//! ```
//! use fastkmeanspp::prelude::*;
//!
//! let data = fastkmeanspp::data::synth::gaussian_mixture(
//!     &SynthSpec { n: 2_000, d: 16, k_true: 20, ..SynthSpec::default() },
//!     0xC0FFEE,
//! );
//! let mut rng = Pcg64::seed_from(42);
//! let seeding = fastkmeanspp::seeding::rejection::rejection_sampling(
//!     &data, 20, &RejectionConfig::default(), &mut rng,
//! );
//! let cost = fastkmeanspp::lloyd::cost_native(&data, &seeding.centers);
//! assert_eq!(seeding.indices.len(), 20);
//! assert!(cost.is_finite() && cost > 0.0);
//! ```

pub mod cli;
pub mod coordinator;
pub mod data;
pub mod dist;
pub mod embed;
pub mod error;
pub mod kernels;
pub mod lloyd;
pub mod log;
pub mod lsh;
pub mod metrics;
pub mod parallel;
pub mod rng;
pub mod runtime;
pub mod sampletree;
pub mod seeding;
pub mod server;
pub mod shard;
pub mod trace;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::data::matrix::PointSet;
    pub use crate::data::synth::SynthSpec;
    pub use crate::embed::multitree::{MultiTree, MultiTreeConfig};
    pub use crate::lloyd::LloydConfig;
    pub use crate::lsh::multiscale::MonotoneLsh;
    pub use crate::metrics::{Histogram, Metrics};
    pub use crate::rng::Pcg64;
    pub use crate::sampletree::SampleTree;
    pub use crate::seeding::{
        afkmc2::Afkmc2Config,
        rejection::{OracleKind, RejectionConfig},
        Seeding, SeedingAlgorithm,
    };
    pub use crate::dist::DistConfig;
    pub use crate::shard::kmeanspar::KMeansParConfig;
    pub use crate::shard::weighted::WeightedPointSet;
    pub use crate::shard::ShardedDataset;
}
