//! Async fit jobs: `POST /fit` returns immediately with a job id while a
//! bounded worker pool runs the seeder (and optional Lloyd refinement)
//! off-thread.
//!
//! The queue is a `Mutex` + `Condvar` pair — the same std-only discipline
//! as [`crate::parallel`] (which remains the only *data*-parallel thread
//! spawner; the long-lived workers here are control-plane threads that
//! delegate all distance work to the kernel engine via the seeders,
//! [`crate::lloyd`] and [`crate::runtime::Backend`]). Job records are
//! kept forever — the server is long-lived but jobs are few and small;
//! eviction can come later if `/fit` traffic ever warrants it.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::bail;
use crate::data::matrix::PointSet;
use crate::data::registry::{DatasetId, Profile};
use crate::error::Result;
use crate::lloyd::{lloyd, LloydConfig};
use crate::rng::Pcg64;
use crate::runtime::Backend;
use crate::seeding::rejection::{rejection_sampling, RejectionConfig};
use crate::seeding::SeedingAlgorithm;
use crate::server::registry::{ModelMeta, ModelRegistry};
use crate::shard::kmeanspar::{kmeans_par, KMeansParConfig};

/// What a fit job trains on.
#[derive(Clone)]
pub enum FitSource {
    /// A registered dataset (materialized through the on-disk cache).
    Dataset { id: DatasetId, profile: Profile },
    /// Points shipped inline in the request body (shared, not copied,
    /// between the request handler and the fit worker).
    Inline(Arc<PointSet>),
}

impl FitSource {
    pub fn describe(&self) -> String {
        match self {
            FitSource::Dataset { id, profile } => format!("{}:{}", id.name(), profile.name()),
            FitSource::Inline(ps) => format!("inline(n={}, d={})", ps.len(), ps.dim()),
        }
    }
}

/// A fit request, fully resolved (parsing/validation happened at the
/// HTTP layer; workers only execute).
#[derive(Clone)]
pub struct FitSpec {
    pub source: FitSource,
    pub algorithm: SeedingAlgorithm,
    pub k: usize,
    pub seed: u64,
    /// Lloyd iterations after seeding (0 = seeding only).
    pub lloyd_iters: usize,
    /// Sharded-seeding knobs, used when `algorithm` is
    /// [`SeedingAlgorithm::KMeansPar`] (request keys `shards` / `rounds`
    /// / `oversample`; defaults otherwise).
    pub kmeanspar: KMeansParConfig,
    /// Rejection-sampling knobs, used when `algorithm` is in the
    /// rejection family (request keys `oracle` / `c` / `lsh_tables` /
    /// `lsh_m` / `lsh_probe_limit`; defaults otherwise). The
    /// `rejection-exact` / `rejection-rigorous` variants still pin their
    /// oracle over this config's choice.
    pub rejection: RejectionConfig,
    /// The `X-Request-Id` of the `POST /fit` that enqueued this job, so
    /// the fit span and job correlate with the originating request.
    pub request_id: Option<String>,
}

/// Lifecycle of a job.
#[derive(Clone, Debug, PartialEq)]
pub enum JobState {
    Queued,
    Running,
    Done { model_id: String },
    Failed { error: String },
}

impl JobState {
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done { .. } => "done",
            JobState::Failed { .. } => "failed",
        }
    }
}

/// What `GET /jobs/{id}` reports.
#[derive(Clone, Debug)]
pub struct JobInfo {
    pub id: String,
    pub state: JobState,
    pub algorithm: SeedingAlgorithm,
    pub k: usize,
    pub source: String,
    /// Total fit wall-clock seconds, once finished.
    pub secs: Option<f64>,
}

struct QueueInner {
    pending: VecDeque<(String, FitSpec)>,
    jobs: BTreeMap<String, JobInfo>,
}

/// The job queue: submit from HTTP handlers, drain from fit workers.
pub struct JobQueue {
    inner: Mutex<QueueInner>,
    cond: Condvar,
    next_id: AtomicU64,
    shutdown: AtomicBool,
    /// Maximum jobs *pending* (queued, not yet running) before
    /// [`submit`](JobQueue::submit) sheds — the work-queue half of the
    /// serving layer's admission control. Running and finished jobs do
    /// not count against it.
    capacity: usize,
}

impl Default for JobQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl JobQueue {
    /// An unbounded queue (library/test use).
    pub fn new() -> JobQueue {
        Self::with_capacity(usize::MAX)
    }

    /// A queue that sheds once `capacity` jobs are pending.
    pub fn with_capacity(capacity: usize) -> JobQueue {
        JobQueue {
            inner: Mutex::new(QueueInner {
                pending: VecDeque::new(),
                jobs: BTreeMap::new(),
            }),
            cond: Condvar::new(),
            next_id: AtomicU64::new(1),
            shutdown: AtomicBool::new(false),
            capacity: capacity.max(1),
        }
    }

    /// Enqueue a job; returns its id immediately, or `None` if the
    /// pending backlog is at capacity (the caller turns that into a 429).
    pub fn submit(&self, spec: FitSpec) -> Option<String> {
        {
            // Check-and-insert under one lock acquisition so two racing
            // submits cannot both slip past a capacity of 1.
            let mut inner = self.inner.lock().unwrap();
            if inner.pending.len() >= self.capacity {
                return None;
            }
            let id = format!("job-{}", self.next_id.fetch_add(1, Ordering::Relaxed));
            let info = JobInfo {
                id: id.clone(),
                state: JobState::Queued,
                algorithm: spec.algorithm,
                k: spec.k,
                source: spec.source.describe(),
                secs: None,
            };
            inner.jobs.insert(id.clone(), info);
            inner.pending.push_back((id.clone(), spec));
            self.cond.notify_one();
            Some(id)
        }
    }

    pub fn get(&self, id: &str) -> Option<JobInfo> {
        self.inner.lock().unwrap().jobs.get(id).cloned()
    }

    /// `(queued, running, done, failed)` counts for `/metrics`.
    pub fn counts(&self) -> (usize, usize, usize, usize) {
        let inner = self.inner.lock().unwrap();
        let mut c = (0, 0, 0, 0);
        for job in inner.jobs.values() {
            match job.state {
                JobState::Queued => c.0 += 1,
                JobState::Running => c.1 += 1,
                JobState::Done { .. } => c.2 += 1,
                JobState::Failed { .. } => c.3 += 1,
            }
        }
        c
    }

    /// Block until a job is available (marking it running) or shutdown.
    fn next_job(&self) -> Option<(String, FitSpec)> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            if let Some(job) = inner.pending.pop_front() {
                if let Some(info) = inner.jobs.get_mut(&job.0) {
                    info.state = JobState::Running;
                }
                return Some(job);
            }
            inner = self.cond.wait(inner).unwrap();
        }
    }

    fn finish(&self, job_id: &str, secs: f64, result: Result<String>) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(info) = inner.jobs.get_mut(job_id) {
            info.secs = Some(secs);
            info.state = match result {
                Ok(model_id) => JobState::Done { model_id },
                Err(e) => JobState::Failed {
                    error: format!("{e:#}"),
                },
            };
        }
    }

    /// Stop all workers after their current job (idempotent). Jobs still
    /// queued are marked `Failed` — they will never run, and a poller
    /// must see a terminal state rather than `queued` forever.
    pub fn stop(&self) {
        // Hold the queue mutex while flagging: a worker is either inside
        // `next_job`'s flag check (will see `true`) or parked in
        // `cond.wait` (will be notified) — never between the two, so the
        // wakeup cannot be lost.
        let mut inner = self.inner.lock().unwrap();
        self.shutdown.store(true, Ordering::SeqCst);
        while let Some((job_id, _)) = inner.pending.pop_front() {
            if let Some(info) = inner.jobs.get_mut(&job_id) {
                info.state = JobState::Failed {
                    error: "server shut down before the job ran".to_string(),
                };
            }
        }
        drop(inner);
        self.cond.notify_all();
    }
}

/// Spawn the fit worker pool. Workers exit after [`JobQueue::stop`];
/// join the returned handles to wait for in-flight fits.
pub fn spawn_workers(
    queue: &Arc<JobQueue>,
    registry: &Arc<ModelRegistry>,
    data_dir: PathBuf,
    artifacts_dir: PathBuf,
    workers: usize,
) -> Vec<JoinHandle<()>> {
    (0..workers.max(1))
        .map(|_| {
            let queue = Arc::clone(queue);
            let registry = Arc::clone(registry);
            let data_dir = data_dir.clone();
            let artifacts_dir = artifacts_dir.clone();
            std::thread::spawn(move || {
                while let Some((job_id, spec)) = queue.next_job() {
                    let t0 = Instant::now();
                    let mut span = crate::trace::Span::enter_with(
                        "fit.job",
                        vec![
                            ("algo", crate::trace::TraceArg::from(spec.algorithm.name())),
                            ("k", crate::trace::TraceArg::from(spec.k)),
                        ],
                    );
                    if let Some(rid) = &spec.request_id {
                        span.arg("request_id", rid.clone());
                    }
                    // A panicking fit must fail the job, not kill the
                    // worker — with fit_workers=1 a dead worker would
                    // leave every later job queued forever.
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        run_fit(&spec, &registry, &data_dir, &artifacts_dir)
                    }))
                    .unwrap_or_else(|panic| {
                        let msg = panic
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| panic.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".to_string());
                        Err(crate::anyhow!("fit panicked: {msg}"))
                    });
                    span.arg("ok", u64::from(result.is_ok()));
                    drop(span);
                    crate::metrics::global().record_latency("fit.latency_secs", t0.elapsed());
                    queue.finish(&job_id, t0.elapsed().as_secs_f64(), result);
                }
            })
        })
        .collect()
}

/// Execute one fit: load/borrow the points, seed, optionally refine,
/// evaluate the cost, and register the resulting model. Returns the new
/// model id.
fn run_fit(
    spec: &FitSpec,
    registry: &ModelRegistry,
    data_dir: &Path,
    artifacts_dir: &Path,
) -> Result<String> {
    let points: Arc<PointSet> = match &spec.source {
        FitSource::Dataset { id, profile } => {
            Arc::new(id.load_cached(data_dir, *profile, spec.seed)?)
        }
        FitSource::Inline(ps) => Arc::clone(ps),
    };
    if spec.k == 0 || spec.k > points.len() {
        bail!("k={} out of range for n={}", spec.k, points.len());
    }
    let mut rng = Pcg64::seed_from(spec.seed);
    let seeding = match spec.algorithm {
        SeedingAlgorithm::KMeansPar => kmeans_par(&points, spec.k, &spec.kmeanspar, &mut rng),
        algo if algo.is_rejection() => {
            let rc = algo.resolved_rejection_config(&spec.rejection);
            rejection_sampling(&points, spec.k, &rc, &mut rng)
        }
        algo => algo.run(&points, spec.k, &mut rng),
    };
    let backend = Backend::auto(artifacts_dir);
    let mut centers = points.gather(&seeding.indices);
    if spec.lloyd_iters > 0 {
        let refined = lloyd(
            &points,
            &centers,
            &LloydConfig {
                max_iters: spec.lloyd_iters,
                tol: 1e-6,
            },
            &backend,
        )?;
        centers = refined.centers;
    }
    let cost = backend.cost(&points, &centers)?;
    let meta = ModelMeta {
        id: registry.fresh_id(),
        version: 1,
        algorithm: spec.algorithm.name().to_string(),
        k: centers.len(),
        dim: centers.dim(),
        source: spec.source.describe(),
        seed: spec.seed,
        seeding_secs: seeding.stats.init_secs + seeding.stats.select_secs,
        lloyd_iters: spec.lloyd_iters,
        cost,
    };
    let model = registry.insert(meta, centers)?;
    Ok(model.meta.id.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};
    use std::time::Duration;

    fn inline_spec(n: usize, k: usize) -> FitSpec {
        let ps = gaussian_mixture(
            &SynthSpec {
                n,
                d: 5,
                k_true: 4,
                ..Default::default()
            },
            9,
        );
        FitSpec {
            source: FitSource::Inline(Arc::new(ps)),
            algorithm: SeedingAlgorithm::KMeansPP,
            k,
            seed: 3,
            lloyd_iters: 1,
            kmeanspar: KMeansParConfig::default(),
            rejection: RejectionConfig::default(),
            request_id: None,
        }
    }

    fn wait_terminal(queue: &JobQueue, id: &str) -> JobInfo {
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let info = queue.get(id).expect("job exists");
            match info.state {
                JobState::Done { .. } | JobState::Failed { .. } => return info,
                _ => {
                    assert!(Instant::now() < deadline, "job {id} stuck");
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
    }

    #[test]
    fn job_runs_to_done_and_registers_model() {
        let queue = Arc::new(JobQueue::new());
        let registry = Arc::new(ModelRegistry::new(None).unwrap());
        let handles = spawn_workers(
            &queue,
            &registry,
            std::env::temp_dir().join("fkmpp_jobs_test"),
            PathBuf::from("/nonexistent"),
            1,
        );
        let id = queue.submit(inline_spec(300, 6)).expect("unbounded queue accepts");
        assert_eq!(id, "job-1");
        let info = wait_terminal(&queue, &id);
        let JobState::Done { model_id } = &info.state else {
            panic!("expected done, got {:?}", info.state);
        };
        assert!(info.secs.unwrap() >= 0.0);
        let model = registry.get(model_id).expect("model registered");
        assert_eq!(model.meta.k, 6);
        assert_eq!(model.meta.dim, 5);
        assert_eq!(model.meta.algorithm, "kmeanspp");
        assert!(model.meta.cost.is_finite() && model.meta.cost >= 0.0);
        queue.stop();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn kmeans_par_fit_uses_shard_config_and_registers() {
        let queue = Arc::new(JobQueue::new());
        let registry = Arc::new(ModelRegistry::new(None).unwrap());
        let handles = spawn_workers(
            &queue,
            &registry,
            std::env::temp_dir().join("fkmpp_jobs_test"),
            PathBuf::from("/nonexistent"),
            1,
        );
        let mut spec = inline_spec(500, 8);
        spec.algorithm = SeedingAlgorithm::KMeansPar;
        spec.kmeanspar = KMeansParConfig {
            shards: 3,
            rounds: 3,
            oversample: 2.0,
        };
        let before = crate::metrics::CounterSnapshot::of(crate::metrics::global());
        let id = queue.submit(spec).expect("unbounded queue accepts");
        let info = wait_terminal(&queue, &id);
        let JobState::Done { model_id } = &info.state else {
            panic!("expected done, got {:?}", info.state);
        };
        let model = registry.get(model_id).expect("model registered");
        assert_eq!(model.meta.k, 8);
        assert_eq!(model.meta.algorithm, "kmeans-par");
        // The fit drove the sharded engine: round counters advanced
        // (delta via snapshot — counters accumulate process-wide).
        assert!(before.delta(crate::metrics::global(), "shard.rounds") > 0);
        queue.stop();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn rejection_lsh_fit_uses_oracle_config_and_flushes_counters() {
        use crate::seeding::rejection::OracleKind;
        let queue = Arc::new(JobQueue::new());
        let registry = Arc::new(ModelRegistry::new(None).unwrap());
        let handles = spawn_workers(
            &queue,
            &registry,
            std::env::temp_dir().join("fkmpp_jobs_test"),
            PathBuf::from("/nonexistent"),
            1,
        );
        let mut spec = inline_spec(500, 8);
        spec.algorithm = SeedingAlgorithm::Rejection;
        spec.lloyd_iters = 0;
        spec.rejection = RejectionConfig {
            oracle: OracleKind::LshPractical,
            ..Default::default()
        };
        let before = crate::metrics::CounterSnapshot::of(crate::metrics::global());
        let id = queue.submit(spec).expect("unbounded queue accepts");
        let info = wait_terminal(&queue, &id);
        let JobState::Done { model_id } = &info.state else {
            panic!("expected done, got {:?}", info.state);
        };
        let model = registry.get(model_id).expect("model registered");
        assert_eq!(model.meta.k, 8);
        assert_eq!(model.meta.algorithm, "rejection");
        // The fit drove the oracle-backed acceptance loop: counters
        // advanced (delta via snapshot — they accumulate process-wide).
        let m = crate::metrics::global();
        assert!(before.delta(m, "oracle.probes") > 0);
        assert!(before.delta(m, "oracle.accepts") >= 8);
        queue.stop();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn invalid_k_fails_cleanly() {
        let queue = Arc::new(JobQueue::new());
        let registry = Arc::new(ModelRegistry::new(None).unwrap());
        let handles = spawn_workers(
            &queue,
            &registry,
            std::env::temp_dir().join("fkmpp_jobs_test"),
            PathBuf::from("/nonexistent"),
            2,
        );
        let id = queue.submit(inline_spec(50, 500)).expect("unbounded queue accepts");
        let info = wait_terminal(&queue, &id);
        let JobState::Failed { error } = &info.state else {
            panic!("expected failure, got {:?}", info.state);
        };
        assert!(error.contains("out of range"), "{error}");
        assert!(registry.is_empty());
        queue.stop();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn bounded_queue_sheds_at_capacity() {
        // No workers: pending never drains, so capacity 2 accepts two
        // submissions and sheds the third without blocking.
        let queue = JobQueue::with_capacity(2);
        assert!(queue.submit(inline_spec(20, 2)).is_some());
        assert!(queue.submit(inline_spec(20, 2)).is_some());
        assert!(queue.submit(inline_spec(20, 2)).is_none(), "third submit must shed");
        assert_eq!(queue.counts(), (2, 0, 0, 0));
        // Draining (here: shutdown-failing) the backlog reopens admission.
        queue.stop();
        assert_eq!(queue.counts(), (0, 0, 0, 2));
    }

    #[test]
    fn counts_and_unknown_job() {
        let queue = JobQueue::new();
        assert_eq!(queue.counts(), (0, 0, 0, 0));
        assert!(queue.get("job-404").is_none());
        // No workers: submitted jobs stay queued.
        let ps = Arc::new(gaussian_mixture(
            &SynthSpec {
                n: 10,
                d: 2,
                k_true: 2,
                ..Default::default()
            },
            1,
        ));
        queue
            .submit(FitSpec {
                source: FitSource::Inline(ps),
                algorithm: SeedingAlgorithm::Uniform,
                k: 2,
                seed: 1,
                lloyd_iters: 0,
                kmeanspar: KMeansParConfig::default(),
                rejection: RejectionConfig::default(),
                request_id: None,
            })
            .expect("unbounded queue accepts");
        assert_eq!(queue.counts(), (1, 0, 0, 0));
        assert_eq!(queue.get("job-1").unwrap().state.name(), "queued");
        // stop() must give still-queued jobs a terminal state, not
        // abandon them as "queued" forever.
        queue.stop();
        assert_eq!(queue.counts(), (0, 0, 0, 1));
        let info = queue.get("job-1").unwrap();
        let JobState::Failed { error } = &info.state else {
            panic!("expected failed, got {:?}", info.state);
        };
        assert!(error.contains("shut down"), "{error}");
    }
}
