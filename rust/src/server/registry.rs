//! The in-memory model registry behind the serving layer: fitted models
//! (centers + metadata), persisted to disk and reloaded on boot.
//!
//! Persistence reuses the crate's existing formats — centers go through
//! [`crate::data::io`] as `.fbin` (the same layout the dataset cache
//! uses) and metadata through [`crate::server::json`] — so a model
//! directory is inspectable with the same tooling as everything else:
//! `{data_dir}/models/{id}.v{version}.fbin` + `{data_dir}/models/{id}.json`.
//!
//! ## The versioned-swap contract
//!
//! Every model carries a monotone [`ModelMeta::version`]. Online
//! refreshes ([`crate::server::online`]) build a complete new [`Model`]
//! off-thread and [`ModelRegistry::publish`] it: persistence goes to
//! temp names and is `rename`d into place (the `.json` rename is the
//! commit point), then the in-memory entry is swapped under a brief
//! write lock. Readers hold `Arc<Model>` clones, so an in-flight assign
//! finishes on the version it started on and a response is always
//! computed from exactly one published version — there is no moment at
//! which a reader can observe half-swapped centers or a meta/centers
//! mismatch. Publishes that do not raise the version are dropped, so
//! racing refreshes can never roll a model backwards, in memory or on
//! disk. A crash between the two renames leaves the previous committed
//! version intact plus an orphan centers file, which the next boot's
//! [`ModelRegistry::new`] deletes with a warn log.
//!
//! Assignment requests route through the kernel engine
//! ([`crate::kernels::assign::assign_argmin`]); per the PR 1 contract,
//! this module owns **no distance loops**.
//!
//! ## The batch-invariance contract
//!
//! The serving layer coalesces concurrent assigns against one model into
//! a single kernel sweep ([`AssignCoalescer`]), which changes the batch
//! size the kernel sees. The autotuner picks kernels partly **by** batch
//! size, so dispatching per sweep would let an unrelated concurrent
//! request flip a response's bits. Instead every model pins its assign
//! kernel once at registration ([`Model::new`], evaluated at the
//! canonical batch size [`ASSIGN_PIN_N`]): assign results are a pure
//! function of `(model, query points)` — independent of batch
//! composition, concurrency, and route (JSON vs binary).

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use crate::bail;
use crate::data::io::{read_fbin, write_fbin};
use crate::data::matrix::PointSet;
use crate::error::{Context, Result};
use crate::kernels::tune;
use crate::server::json::{self, Json};

/// Everything about a fitted model except the centers themselves.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    /// Registry id (`m-<seq>`).
    pub id: String,
    /// Monotone model version, starting at 1 for a fresh fit and bumped
    /// by every online refresh publish ([`ModelRegistry::publish`]).
    /// Persisted meta written before versioning carries no field and
    /// reloads as 1.
    pub version: u64,
    /// Seeding algorithm name (as in [`crate::seeding::SeedingAlgorithm`]).
    pub algorithm: String,
    /// Number of centers.
    pub k: usize,
    /// Center dimensionality.
    pub dim: usize,
    /// Where the training data came from (`dataset:profile` or
    /// `inline(n=.., d=..)`).
    pub source: String,
    /// RNG seed the fit ran with.
    pub seed: u64,
    /// Wall-clock seconds spent seeding (init + select).
    pub seeding_secs: f64,
    /// Lloyd refinement iterations requested (0 = seeding only).
    pub lloyd_iters: usize,
    /// k-means objective of the final centers on the training data.
    pub cost: f64,
}

impl ModelMeta {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("version", Json::num(self.version as f64)),
            ("algorithm", Json::str(self.algorithm.clone())),
            ("k", Json::num(self.k as f64)),
            ("dim", Json::num(self.dim as f64)),
            ("source", Json::str(self.source.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("seeding_secs", Json::num(self.seeding_secs)),
            ("lloyd_iters", Json::num(self.lloyd_iters as f64)),
            ("cost", Json::num(self.cost)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ModelMeta> {
        let text = |key: &str| -> Result<String> {
            Ok(v.get(key)
                .and_then(Json::as_str)
                .with_context(|| format!("model meta: missing {key:?}"))?
                .to_string())
        };
        Ok(ModelMeta {
            id: text("id")?,
            version: v.get("version").and_then(Json::as_u64).unwrap_or(1),
            algorithm: text("algorithm")?,
            k: v.get("k").and_then(Json::as_usize).context("model meta: k")?,
            dim: v
                .get("dim")
                .and_then(Json::as_usize)
                .context("model meta: dim")?,
            source: text("source")?,
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(0),
            seeding_secs: v
                .get("seeding_secs")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            lloyd_iters: v
                .get("lloyd_iters")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            cost: v.get("cost").and_then(Json::as_f64).unwrap_or(f64::NAN),
        })
    }
}

/// Canonical batch size at which a model's assign kernel is pinned.
/// Chosen as a "sustained traffic" shape: small-k/small-d models stay on
/// the naive kernel (same choice a solo small request would get below
/// the autotuner's small-work floor), large models go blocked. The exact
/// value matters less than it being **fixed** — see the module docs on
/// batch invariance.
pub const ASSIGN_PIN_N: usize = 8192;

/// A fitted model: metadata + the `k × d` center matrix + the squared
/// center norms the v2 assignment kernel consumes.
#[derive(Clone, Debug)]
pub struct Model {
    pub meta: ModelMeta,
    pub centers: PointSet,
    /// `‖c_j‖²` per center, computed **once** at registration/load
    /// ([`Model::new`]) and reused by every assign request — the
    /// kernels-v2 fix for re-deriving center distances from scratch per
    /// request. Not persisted: it is a pure function of `centers`, so a
    /// reload recomputes identical bits.
    pub center_norms: Vec<f32>,
    /// Kernel implementation every assign against this model runs,
    /// pinned at registration/load so coalesced batch size cannot flip
    /// the choice mid-flight. Not persisted: a reload re-derives the
    /// same pin from the same shape (and the same `FKMPP_KERNEL` env, if
    /// set).
    pub assign_kernel: tune::Kernel,
}

impl Model {
    /// Build a model, deriving the center-norm cache and pinning the
    /// assign kernel.
    pub fn new(meta: ModelMeta, centers: PointSet) -> Model {
        let center_norms = crate::kernels::norms::squared_norms(&centers);
        let assign_kernel =
            tune::kernel_for(tune::Op::Assign, ASSIGN_PIN_N, centers.dim(), centers.len());
        Model {
            meta,
            centers,
            center_norms,
            assign_kernel,
        }
    }

    /// Metadata plus the full center matrix (the `GET /models/{id}` body).
    pub fn full_json(&self) -> Json {
        match self.meta.to_json() {
            Json::Obj(mut fields) => {
                fields.push(("centers".to_string(), json::points_to_json(&self.centers)));
                Json::Obj(fields)
            }
            other => other,
        }
    }
}

/// Batched nearest-center assignment against a model — the serving
/// layer's only path to distances, routed through the kernel engine
/// with the model's cached center norms and its **pinned** kernel
/// (query-point norms are derived per sweep when the v2 kernel runs; the
/// labels and distances are bitwise identical to an uncached
/// [`crate::kernels::assign::assign_argmin`] call resolving to the same
/// kernel on the same bits, so repeated identical requests serve
/// byte-identical responses regardless of what else is in flight).
pub fn assign(model: &Model, points: &PointSet) -> Result<(Vec<u32>, Vec<f32>)> {
    check_dim(model, points)?;
    Ok(assign_pinned(model, points))
}

fn check_dim(model: &Model, points: &PointSet) -> Result<()> {
    if points.dim() != model.centers.dim() {
        bail!(
            "dimension mismatch: model {} has d={}, query has d={}",
            model.meta.id,
            model.centers.dim(),
            points.dim()
        );
    }
    Ok(())
}

/// The one kernel sweep everything funnels into: dispatch on the model's
/// pinned kernel, never on the sweep's batch size. Per-row results are
/// independent of batch composition (both kernels are row-parallel with
/// no cross-row state), which is what makes scatter-after-coalesce
/// legitimate.
fn assign_pinned(model: &Model, points: &PointSet) -> (Vec<u32>, Vec<f32>) {
    match model.assign_kernel {
        tune::Kernel::Naive => crate::kernels::assign::assign_argmin_naive(points, &model.centers),
        tune::Kernel::Blocked => {
            let pn = crate::kernels::norms::squared_norms(points);
            crate::kernels::blocked::assign_argmin_blocked(
                points,
                &pn,
                &model.centers,
                &model.center_norms,
            )
        }
    }
}

/// Per-request slot a coalesced assign parks on: the leader takes the
/// points, runs the batch, and deposits the result.
struct WaitSlot {
    state: Mutex<SlotState>,
}

enum SlotState {
    Pending(PointSet),
    Running,
    Done(Vec<u32>, Vec<f32>),
}

impl WaitSlot {
    fn new(points: PointSet) -> WaitSlot {
        WaitSlot {
            state: Mutex::new(SlotState::Pending(points)),
        }
    }

    fn take_done(&self) -> Option<(Vec<u32>, Vec<f32>)> {
        let mut state = self.state.lock().unwrap();
        if matches!(*state, SlotState::Done(..)) {
            match std::mem::replace(&mut *state, SlotState::Running) {
                SlotState::Done(labels, d2s) => Some((labels, d2s)),
                _ => unreachable!(),
            }
        } else {
            None
        }
    }
}

#[derive(Default)]
struct ModelLane {
    /// A leader is currently sweeping this model; arrivals must park.
    leader_active: bool,
    /// Requests parked while the leader sweeps, drained by the next one.
    waiting: Vec<Arc<WaitSlot>>,
}

/// Per-model request coalescing: concurrent assigns against the same
/// model batch into **one** pinned-kernel sweep instead of competing
/// sweeps.
///
/// Leader/follower protocol, no timers: the first request for an idle
/// model becomes the leader and sweeps immediately (zero added latency
/// for uncontended traffic). Requests arriving while a leader sweeps
/// park on a [`Condvar`]; when the leader finishes it publishes results
/// and wakes everyone — a woken waiter whose result is already deposited
/// returns it, otherwise it promotes itself to leader and drains the
/// parked queue (itself included) in one concatenated sweep. Every
/// parked request is thus swept by the *next* batch at the latest:
/// nothing can wait forever.
#[derive(Default)]
pub struct AssignCoalescer {
    lanes: Mutex<HashMap<String, ModelLane>>,
    cond: Condvar,
}

/// Lanes are keyed by `(id, version)`, not id alone: a parked request is
/// only ever swept by a leader holding the **same** published version,
/// so a coalesced batch never mixes versions and every response comes
/// from exactly the version its handler captured — the versioned-swap
/// contract extends through coalescing.
fn lane_key(model: &Model) -> String {
    format!("{}@v{}", model.meta.id, model.meta.version)
}

impl AssignCoalescer {
    /// Assign `points` to `model`'s centers, batching with any concurrent
    /// requests against the same model. Bitwise identical to a solo
    /// [`assign`] call (see the module docs on batch invariance).
    pub fn assign(&self, model: &Model, points: PointSet) -> Result<(Vec<u32>, Vec<f32>)> {
        // Validate before parking: a bad request must fail alone, never
        // poison a batch (past this check the sweep is infallible).
        check_dim(model, &points)?;
        let slot = Arc::new(WaitSlot::new(points));
        let key = lane_key(model);
        let mut lanes = self.lanes.lock().unwrap();
        let lane = lanes.entry(key.clone()).or_default();
        if !lane.leader_active {
            // Idle lane: lead a batch of any already-parked requests plus
            // our own, without waiting.
            lane.leader_active = true;
            let mut batch = std::mem::take(&mut lane.waiting);
            batch.push(Arc::clone(&slot));
            drop(lanes);
            return Ok(self.lead(model, batch, &slot));
        }
        lane.waiting.push(Arc::clone(&slot));
        loop {
            lanes = self.cond.wait(lanes).unwrap();
            if let Some(result) = slot.take_done() {
                return Ok(result);
            }
            let lane = lanes.entry(key.clone()).or_default();
            if !lane.leader_active {
                // The previous leader finished without us (we parked
                // after its drain): take over and sweep the queue.
                lane.leader_active = true;
                let batch = std::mem::take(&mut lane.waiting);
                drop(lanes);
                return Ok(self.lead(model, batch, &slot));
            }
        }
    }

    /// Run one sweep over `batch` (which contains `own`), deposit every
    /// result, release the lane and wake the parked requests.
    fn lead(
        &self,
        model: &Model,
        batch: Vec<Arc<WaitSlot>>,
        own: &WaitSlot,
    ) -> (Vec<u32>, Vec<f32>) {
        let mut parts: Vec<PointSet> = Vec::with_capacity(batch.len());
        for slot in &batch {
            let mut state = slot.state.lock().unwrap();
            match std::mem::replace(&mut *state, SlotState::Running) {
                SlotState::Pending(points) => parts.push(points),
                _ => unreachable!("a parked slot is always Pending when drained"),
            }
        }
        let mut span = crate::trace::Span::enter("assign.batch");
        span.arg("requests", batch.len() as u64);
        let own_result = if parts.len() == 1 {
            // The common uncontended case: no concatenation, no scatter
            // copy — the batch is exactly the leader's own request.
            span.arg("points", parts[0].len() as u64);
            Some(assign_pinned(model, &parts[0]))
        } else {
            let dim = model.centers.dim();
            let total: usize = parts.iter().map(PointSet::len).sum();
            let mut flat = Vec::with_capacity(total * dim);
            for part in &parts {
                flat.extend_from_slice(part.flat());
            }
            span.arg("points", total as u64);
            let merged = PointSet::from_flat(total, dim, flat);
            crate::metrics::global().incr("assign.coalesced_batches", 1);
            crate::metrics::global().incr("assign.coalesced_requests", batch.len() as u64);
            let (labels, d2s) = assign_pinned(model, &merged);
            // Scatter the per-request slices back onto their slots.
            let mut own_result = None;
            let mut offset = 0usize;
            for (slot, part) in batch.iter().zip(&parts) {
                let n = part.len();
                let result = (
                    labels[offset..offset + n].to_vec(),
                    d2s[offset..offset + n].to_vec(),
                );
                offset += n;
                if std::ptr::eq(slot.as_ref(), own) {
                    own_result = Some(result);
                } else {
                    *slot.state.lock().unwrap() = SlotState::Done(result.0, result.1);
                }
            }
            own_result
        };
        drop(span);
        let key = lane_key(model);
        let mut lanes = self.lanes.lock().unwrap();
        if let Some(lane) = lanes.get_mut(&key) {
            lane.leader_active = false;
            if lane.waiting.is_empty() {
                lanes.remove(&key);
            }
        }
        drop(lanes);
        self.cond.notify_all();
        own_result.expect("leader's own slot is in the batch")
    }
}

/// Thread-safe id → model map with optional on-disk persistence.
pub struct ModelRegistry {
    /// Persistence root (`{dir}/models/`); `None` = memory only.
    dir: Option<PathBuf>,
    models: RwLock<BTreeMap<String, Arc<Model>>>,
    next_id: AtomicU64,
    /// Serializes check-version → persist → swap in [`Self::publish`] so
    /// two racing publishes for one id cannot commit out of order on
    /// disk. Held only by writers — readers take the `models` lock alone.
    publish_lock: Mutex<()>,
}

/// On-disk name of a version's center matrix.
fn centers_file(id: &str, version: u64) -> String {
    format!("{id}.v{version}.fbin")
}

fn entry_name(path: &Path) -> String {
    path.file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default()
}

/// Parse a centers-file name into `(id, version)`; `version` is `None`
/// for the legacy unversioned `{id}.fbin` layout.
fn parse_centers_file(name: &str) -> Option<(&str, Option<u64>)> {
    let stem = name.strip_suffix(".fbin")?;
    if let Some(dot_v) = stem.rfind(".v") {
        if let Ok(version) = stem[dot_v + 2..].parse::<u64>() {
            return Some((&stem[..dot_v], Some(version)));
        }
    }
    Some((stem, None))
}

impl ModelRegistry {
    /// Create a registry, reloading any models persisted under
    /// `{dir}/models/` from a previous run and deleting orphaned
    /// centers/temp files a crash mid-persist may have stranded.
    pub fn new(dir: Option<PathBuf>) -> Result<ModelRegistry> {
        let reg = ModelRegistry {
            dir,
            models: RwLock::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
            publish_lock: Mutex::new(()),
        };
        reg.load_persisted()?;
        Ok(reg)
    }

    fn models_dir(&self) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join("models"))
    }

    fn load_persisted(&self) -> Result<()> {
        let Some(models_dir) = self.models_dir() else {
            return Ok(());
        };
        if !models_dir.exists() {
            return Ok(());
        }
        // Committed versions: id → version of the model the `.json`
        // (the commit point) references. Everything else is an orphan.
        let mut committed: HashMap<String, u64> = HashMap::new();
        for entry in std::fs::read_dir(&models_dir)
            .with_context(|| format!("read {models_dir:?}"))?
        {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            match Self::load_model(&path) {
                Ok(model) => {
                    // Keep fresh ids above every persisted one.
                    if let Some(n) = model
                        .meta
                        .id
                        .strip_prefix("m-")
                        .and_then(|s| s.parse::<u64>().ok())
                    {
                        self.next_id.fetch_max(n + 1, Ordering::Relaxed);
                    }
                    committed.insert(model.meta.id.clone(), model.meta.version);
                    self.models
                        .write()
                        .unwrap()
                        .insert(model.meta.id.clone(), Arc::new(model));
                }
                // A corrupt file must not take the whole server down.
                Err(e) => crate::log::warn(
                    "registry.skip_model",
                    &[
                        ("path", Json::str(path.display().to_string())),
                        ("error", Json::str(format!("{e:#}"))),
                    ],
                ),
            }
        }
        // Orphan sweep: a crash between the centers rename and the meta
        // rename leaves a committed-looking `.fbin` no meta references;
        // a crash mid-write leaves `.tmp` files. Before this sweep they
        // sat on disk forever, silently skipped. Delete them loudly.
        for entry in std::fs::read_dir(&models_dir)
            .with_context(|| format!("read {models_dir:?}"))?
        {
            let path = entry?.path();
            let name = entry_name(&path);
            let orphan = if name.ends_with(".tmp") {
                true
            } else if let Some((id, version)) = parse_centers_file(&name) {
                match (committed.get(id), version) {
                    // Versioned centers survive only when the committed
                    // meta points at exactly this version.
                    (Some(&v), Some(file_v)) => file_v != v,
                    // Legacy unversioned centers survive while a meta
                    // for the id exists at all.
                    (Some(_), None) => false,
                    (None, _) => true,
                }
            } else {
                false
            };
            if orphan {
                let _ = std::fs::remove_file(&path);
                crate::log::warn(
                    "registry.orphan_cleanup",
                    &[("path", Json::str(path.display().to_string()))],
                );
            }
        }
        Ok(())
    }

    fn load_model(meta_path: &Path) -> Result<Model> {
        let text = std::fs::read_to_string(meta_path)?;
        let meta = ModelMeta::from_json(&json::parse(&text)?)?;
        // Versioned layout first; fall back to the pre-version `{id}.fbin`.
        let dir = meta_path.parent().unwrap_or_else(|| Path::new("."));
        let versioned = dir.join(centers_file(&meta.id, meta.version));
        let centers_path = if versioned.exists() {
            versioned
        } else {
            meta_path.with_extension("fbin")
        };
        let centers = read_fbin(&centers_path)?;
        if centers.len() != meta.k || centers.dim() != meta.dim {
            bail!(
                "centers shape {}x{} disagrees with meta k={} dim={}",
                centers.len(),
                centers.dim(),
                meta.k,
                meta.dim
            );
        }
        Ok(Model::new(meta, centers))
    }

    /// Allocate the next model id (`m-<seq>`).
    pub fn fresh_id(&self) -> String {
        format!("m-{}", self.next_id.fetch_add(1, Ordering::Relaxed))
    }

    /// Register a model (persisting it first when a directory is set, so
    /// a model is never visible in memory but missing on disk). The
    /// common `POST /fit` path: callers pass a fresh id at version 1.
    pub fn insert(&self, meta: ModelMeta, centers: PointSet) -> Result<Arc<Model>> {
        self.publish(Model::new(meta, centers))
    }

    /// Publish a model version: persist durably, then swap the in-memory
    /// entry atomically. Monotone — a publish whose version does not
    /// exceed the installed one is dropped (the installed model is
    /// returned), so racing refreshes can never roll a model backwards.
    /// Readers are never blocked by persistence I/O: they clone `Arc`s
    /// under a read lock and the write lock is held only for the map
    /// swap itself; in-flight assigns keep their old `Arc` and finish on
    /// the version they started on.
    pub fn publish(&self, model: Model) -> Result<Arc<Model>> {
        let model = Arc::new(model);
        let _serialized = self.publish_lock.lock().unwrap();
        if let Some(current) = self.get(&model.meta.id) {
            if current.meta.version >= model.meta.version {
                return Ok(current);
            }
        }
        self.persist(&model)?;
        self.models
            .write()
            .unwrap()
            .insert(model.meta.id.clone(), Arc::clone(&model));
        Ok(model)
    }

    /// Crash-safe persistence: both files are written to temp names and
    /// `rename`d into place — centers first, meta (`.json`) last as the
    /// commit point. A crash at any instant leaves either the previous
    /// committed version intact or the new one fully committed, never a
    /// meta/centers mismatch; stranded temp or centers files are swept
    /// by the next boot's [`ModelRegistry::new`].
    fn persist(&self, model: &Model) -> Result<()> {
        let Some(models_dir) = self.models_dir() else {
            return Ok(());
        };
        std::fs::create_dir_all(&models_dir).with_context(|| format!("create {models_dir:?}"))?;
        let id = &model.meta.id;
        let fbin_name = centers_file(id, model.meta.version);
        let fbin_tmp = models_dir.join(format!("{fbin_name}.tmp"));
        write_fbin(&model.centers, &fbin_tmp)?;
        std::fs::rename(&fbin_tmp, models_dir.join(&fbin_name)).context("commit centers")?;
        let json_tmp = models_dir.join(format!("{id}.json.tmp"));
        std::fs::write(&json_tmp, model.meta.to_json().emit()).context("write model meta")?;
        std::fs::rename(&json_tmp, models_dir.join(format!("{id}.json")))
            .context("commit model meta")?;
        // Best-effort: drop center files superseded by this commit (old
        // versions and the legacy unversioned layout).
        if let Ok(entries) = std::fs::read_dir(&models_dir) {
            for entry in entries.flatten() {
                let name = entry_name(&entry.path());
                if name != fbin_name && matches!(parse_centers_file(&name), Some((fid, _)) if fid == id)
                {
                    let _ = std::fs::remove_file(entry.path());
                }
            }
        }
        Ok(())
    }

    pub fn get(&self, id: &str) -> Option<Arc<Model>> {
        self.models.read().unwrap().get(id).cloned()
    }

    /// All models, id-ordered.
    pub fn list(&self) -> Vec<Arc<Model>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};
    use crate::kernels::assign::nearest_center;

    fn centers(n: usize, d: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 3,
                ..Default::default()
            },
            seed,
        )
    }

    fn meta(id: &str, k: usize, dim: usize) -> ModelMeta {
        ModelMeta {
            id: id.to_string(),
            version: 1,
            algorithm: "rejection".to_string(),
            k,
            dim,
            source: "inline(n=100, d=4)".to_string(),
            seed: 7,
            seeding_secs: 0.25,
            lloyd_iters: 2,
            cost: 123.5,
        }
    }

    #[test]
    fn meta_json_roundtrip() {
        let m = meta("m-9", 5, 4);
        let back = ModelMeta::from_json(&json::parse(&m.to_json().emit()).unwrap()).unwrap();
        assert_eq!(back.id, "m-9");
        assert_eq!(back.algorithm, "rejection");
        assert_eq!(back.k, 5);
        assert_eq!(back.dim, 4);
        assert_eq!(back.seed, 7);
        assert_eq!(back.lloyd_iters, 2);
        assert!((back.cost - 123.5).abs() < 1e-12);
        assert!(ModelMeta::from_json(&json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn insert_get_list_memory_only() {
        let reg = ModelRegistry::new(None).unwrap();
        assert!(reg.is_empty());
        let id = reg.fresh_id();
        assert_eq!(id, "m-1");
        reg.insert(meta(&id, 6, 4), centers(6, 4, 1)).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get("m-1").unwrap().meta.k, 6);
        assert!(reg.get("m-404").is_none());
        assert_eq!(reg.list().len(), 1);
        assert_eq!(reg.fresh_id(), "m-2");
    }

    #[test]
    fn persistence_roundtrip() {
        let dir = std::env::temp_dir().join("fkmpp_registry_persist_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cs = centers(5, 3, 2);
        {
            let reg = ModelRegistry::new(Some(dir.clone())).unwrap();
            let id = reg.fresh_id();
            reg.insert(meta(&id, 5, 3), cs.clone()).unwrap();
        }
        // Fresh registry over the same dir sees the model, bit-exact, and
        // continues the id sequence past it.
        let reg = ModelRegistry::new(Some(dir.clone())).unwrap();
        assert_eq!(reg.len(), 1);
        let m = reg.get("m-1").unwrap();
        assert_eq!(m.centers, cs);
        assert_eq!(m.meta.source, "inline(n=100, d=4)");
        assert_eq!(reg.fresh_id(), "m-2");
    }

    #[test]
    fn corrupt_persisted_model_skipped() {
        let dir = std::env::temp_dir().join("fkmpp_registry_corrupt_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("models")).unwrap();
        std::fs::write(dir.join("models/m-1.json"), "{ not json").unwrap();
        let reg = ModelRegistry::new(Some(dir)).unwrap();
        assert!(reg.is_empty());
    }

    #[test]
    fn assign_routes_through_kernel() {
        let cs = centers(4, 3, 3);
        let model = Model::new(meta("m-1", 4, 3), cs.clone());
        let queries = centers(50, 3, 4);
        let (labels, d2s) = assign(&model, &queries).unwrap();
        for i in 0..queries.len() {
            let (want_j, want_d) = nearest_center(queries.row(i), &cs);
            assert_eq!(labels[i], want_j);
            assert_eq!(d2s[i], want_d);
        }
        // Dimension mismatch is a client error, not a panic.
        let bad = centers(3, 7, 5);
        assert!(assign(&model, &bad).is_err());
    }

    #[test]
    fn coalescer_matches_solo_assign_bitwise() {
        // Results must be a pure function of (model, query points):
        // the same queries through the coalescer — alone or raced by 7
        // other threads hammering the same model — must reproduce a solo
        // registry::assign call bit for bit.
        let cs = centers(4, 3, 3);
        let model = Arc::new(Model::new(meta("m-1", 4, 3), cs));
        let coalescer = Arc::new(AssignCoalescer::default());
        let queries: Vec<PointSet> = (0..8).map(|i| centers(40 + i, 3, 10 + i as u64)).collect();
        let solo: Vec<_> = queries.iter().map(|q| assign(&model, q).unwrap()).collect();
        let got = coalescer.assign(&model, queries[0].clone()).unwrap();
        assert_eq!(got, solo[0], "uncontended coalescer path");
        let handles: Vec<_> = queries
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, q)| {
                let model = Arc::clone(&model);
                let coalescer = Arc::clone(&coalescer);
                std::thread::spawn(move || (i, coalescer.assign(&model, q).unwrap()))
            })
            .collect();
        for h in handles {
            let (i, got) = h.join().unwrap();
            assert_eq!(got, solo[i], "raced request {i}");
        }
        // A dimension mismatch fails alone, before parking.
        assert!(coalescer.assign(&model, centers(3, 7, 5)).is_err());
    }

    #[test]
    fn coalescer_batches_parked_requests() {
        // Deterministic contention: park requests behind an active
        // leader by holding the lane, then check they all complete and
        // the coalesced-batch counters moved.
        let cs = centers(4, 3, 3);
        let model = Arc::new(Model::new(meta("m-1", 4, 3), cs));
        let coalescer = Arc::new(AssignCoalescer::default());
        let before = crate::metrics::CounterSnapshot::of(crate::metrics::global());
        let rounds = 20;
        let threads = 6;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let model = Arc::clone(&model);
                let coalescer = Arc::clone(&coalescer);
                std::thread::spawn(move || {
                    for r in 0..rounds {
                        let q = centers(25, 3, (t * rounds + r) as u64);
                        let want = assign(&model, &q).unwrap();
                        let got = coalescer.assign(&model, q).unwrap();
                        assert_eq!(got, want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // With 6 threads × 20 rounds racing one model, at least one
        // multi-request batch must have formed (each sweep is orders of
        // magnitude slower than an enqueue).
        let batches = before.delta(crate::metrics::global(), "assign.coalesced_batches");
        assert!(batches >= 1, "no coalesced batch formed in {rounds} rounds");
    }

    #[test]
    fn assign_kernel_pinned_at_registration() {
        // The pin is a pure function of model shape (+ env), evaluated at
        // the canonical batch size — and a reload re-derives it.
        let cs = centers(4, 3, 3);
        let model = Model::new(meta("m-1", 4, 3), cs);
        assert_eq!(
            model.assign_kernel,
            tune::kernel_for(tune::Op::Assign, ASSIGN_PIN_N, 3, 4)
        );
    }

    #[test]
    fn center_norm_cache_survives_reload() {
        // The cache is derived, not persisted: a reload must recompute
        // identical bits from the identical center matrix.
        let dir = std::env::temp_dir().join("fkmpp_registry_norms_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cs = centers(6, 4, 9);
        {
            let reg = ModelRegistry::new(Some(dir.clone())).unwrap();
            let id = reg.fresh_id();
            let m = reg.insert(meta(&id, 6, 4), cs.clone()).unwrap();
            assert_eq!(m.center_norms, crate::kernels::norms::squared_norms(&cs));
        }
        let reg = ModelRegistry::new(Some(dir)).unwrap();
        let m = reg.get("m-1").unwrap();
        assert_eq!(m.center_norms, crate::kernels::norms::squared_norms(&cs));
    }

    #[test]
    fn meta_version_defaults_to_one_for_legacy_json() {
        // Persisted meta written before versioning has no "version"
        // field; it must reload as version 1, not fail.
        let mut m = meta("m-3", 4, 2);
        m.version = 7;
        let v = json::parse(&m.to_json().emit()).unwrap();
        assert_eq!(ModelMeta::from_json(&v).unwrap().version, 7);
        let legacy = r#"{"id":"m-3","algorithm":"uniform","k":4,"dim":2,"source":"s"}"#;
        let back = ModelMeta::from_json(&json::parse(legacy).unwrap()).unwrap();
        assert_eq!(back.version, 1);
    }

    #[test]
    fn publish_swaps_atomically_and_is_monotone() {
        let reg = ModelRegistry::new(None).unwrap();
        let cs1 = centers(4, 3, 11);
        let v1 = reg.insert(meta("m-1", 4, 3), cs1.clone()).unwrap();
        assert_eq!(v1.meta.version, 1);

        // A reader captured before the refresh finishes on its version.
        let reader = reg.get("m-1").unwrap();

        let cs2 = centers(4, 3, 12);
        let mut m2 = meta("m-1", 4, 3);
        m2.version = 2;
        let v2 = reg.publish(Model::new(m2, cs2.clone())).unwrap();
        assert_eq!(v2.meta.version, 2);
        assert_eq!(reg.get("m-1").unwrap().meta.version, 2);
        assert_eq!(reg.get("m-1").unwrap().centers, cs2);
        assert_eq!(reader.centers, cs1, "in-flight reader keeps its version");

        // A stale publish (same or lower version) is dropped.
        let stale = reg.publish(Model::new(meta("m-1", 4, 3), cs1)).unwrap();
        assert_eq!(stale.meta.version, 2);
        assert_eq!(reg.get("m-1").unwrap().centers, cs2);
    }

    #[test]
    fn refresh_version_persists_and_reloads() {
        let dir = std::env::temp_dir().join("fkmpp_registry_version_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cs2 = centers(5, 3, 21);
        {
            let reg = ModelRegistry::new(Some(dir.clone())).unwrap();
            reg.insert(meta("m-1", 5, 3), centers(5, 3, 20)).unwrap();
            let mut m2 = meta("m-1", 5, 3);
            m2.version = 2;
            reg.publish(Model::new(m2, cs2.clone())).unwrap();
            // The superseded v1 centers file is gone after the commit.
            assert!(!dir.join("models").join(centers_file("m-1", 1)).exists());
        }
        let reg = ModelRegistry::new(Some(dir)).unwrap();
        let m = reg.get("m-1").unwrap();
        assert_eq!(m.meta.version, 2);
        assert_eq!(m.centers, cs2);
    }

    #[test]
    fn crash_mid_persist_recovers_last_committed_version() {
        // Simulate a crash between the centers rename and the meta
        // rename: v1 fully committed, v2 centers on disk with no meta
        // referencing them, plus stranded temp files. Reload must come
        // back at v1 and sweep the orphans.
        let dir = std::env::temp_dir().join("fkmpp_registry_crash_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cs1 = centers(4, 3, 30);
        {
            let reg = ModelRegistry::new(Some(dir.clone())).unwrap();
            reg.insert(meta("m-1", 4, 3), cs1.clone()).unwrap();
        }
        let models_dir = dir.join("models");
        write_fbin(&centers(4, 3, 31), &models_dir.join(centers_file("m-1", 2))).unwrap();
        std::fs::write(models_dir.join("m-1.json.tmp"), "{partial").unwrap();
        write_fbin(&centers(2, 2, 32), &models_dir.join("m-9.fbin")).unwrap();

        let reg = ModelRegistry::new(Some(dir.clone())).unwrap();
        assert_eq!(reg.len(), 1);
        let m = reg.get("m-1").unwrap();
        assert_eq!(m.meta.version, 1);
        assert_eq!(m.centers, cs1, "last committed version wins");
        assert!(
            !models_dir.join(centers_file("m-1", 2)).exists(),
            "uncommitted centers swept"
        );
        assert!(!models_dir.join("m-1.json.tmp").exists(), "temp swept");
        assert!(!models_dir.join("m-9.fbin").exists(), "orphan fbin swept");
    }

    #[test]
    fn concurrent_assign_during_refresh_single_version() {
        // While a publisher swaps versions under assign load, every
        // response must be bitwise-explainable by exactly one published
        // version: the one the request captured. A torn read (labels
        // from v_i, distances from v_j) would match neither.
        let reg = Arc::new(ModelRegistry::new(None).unwrap());
        reg.insert(meta("m-1", 4, 3), centers(4, 3, 40)).unwrap();
        let coalescer = Arc::new(AssignCoalescer::default());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let reg = Arc::clone(&reg);
                let coalescer = Arc::clone(&coalescer);
                let stop = Arc::clone(&stop);
                std::thread::spawn(move || {
                    let q = centers(30, 3, 50 + t as u64);
                    while !stop.load(Ordering::Relaxed) {
                        let m = reg.get("m-1").unwrap();
                        let got = coalescer.assign(&m, q.clone()).unwrap();
                        let want = assign(&m, &q).unwrap();
                        assert_eq!(got, want, "response from the captured version");
                    }
                })
            })
            .collect();
        for v in 2..40u64 {
            let mut m = meta("m-1", 4, 3);
            m.version = v;
            reg.publish(Model::new(m, centers(4, 3, 40 + v))).unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.get("m-1").unwrap().meta.version, 39);
    }

    #[test]
    fn full_json_contains_centers() {
        let cs = centers(3, 2, 6);
        let model = Model::new(meta("m-2", 3, 2), cs.clone());
        let v = model.full_json();
        assert_eq!(v.get("id").and_then(Json::as_str), Some("m-2"));
        let back = json::points_from_json(v.get("centers").unwrap()).unwrap();
        assert_eq!(back, cs);
    }
}
