//! The in-memory model registry behind the serving layer: fitted models
//! (centers + metadata), persisted to disk and reloaded on boot.
//!
//! Persistence reuses the crate's existing formats — centers go through
//! [`crate::data::io`] as `.fbin` (the same layout the dataset cache
//! uses) and metadata through [`crate::server::json`] — so a model
//! directory is inspectable with the same tooling as everything else:
//! `{data_dir}/models/{id}.fbin` + `{data_dir}/models/{id}.json`.
//!
//! Assignment requests route through the kernel engine
//! ([`crate::kernels::assign::assign_argmin`]); per the PR 1 contract,
//! this module owns **no distance loops**.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::bail;
use crate::data::io::{read_fbin, write_fbin};
use crate::data::matrix::PointSet;
use crate::error::{Context, Result};
use crate::kernels::assign::assign_argmin_cached;
use crate::server::json::{self, Json};

/// Everything about a fitted model except the centers themselves.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    /// Registry id (`m-<seq>`).
    pub id: String,
    /// Seeding algorithm name (as in [`crate::seeding::SeedingAlgorithm`]).
    pub algorithm: String,
    /// Number of centers.
    pub k: usize,
    /// Center dimensionality.
    pub dim: usize,
    /// Where the training data came from (`dataset:profile` or
    /// `inline(n=.., d=..)`).
    pub source: String,
    /// RNG seed the fit ran with.
    pub seed: u64,
    /// Wall-clock seconds spent seeding (init + select).
    pub seeding_secs: f64,
    /// Lloyd refinement iterations requested (0 = seeding only).
    pub lloyd_iters: usize,
    /// k-means objective of the final centers on the training data.
    pub cost: f64,
}

impl ModelMeta {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("algorithm", Json::str(self.algorithm.clone())),
            ("k", Json::num(self.k as f64)),
            ("dim", Json::num(self.dim as f64)),
            ("source", Json::str(self.source.clone())),
            ("seed", Json::num(self.seed as f64)),
            ("seeding_secs", Json::num(self.seeding_secs)),
            ("lloyd_iters", Json::num(self.lloyd_iters as f64)),
            ("cost", Json::num(self.cost)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<ModelMeta> {
        let text = |key: &str| -> Result<String> {
            Ok(v.get(key)
                .and_then(Json::as_str)
                .with_context(|| format!("model meta: missing {key:?}"))?
                .to_string())
        };
        Ok(ModelMeta {
            id: text("id")?,
            algorithm: text("algorithm")?,
            k: v.get("k").and_then(Json::as_usize).context("model meta: k")?,
            dim: v
                .get("dim")
                .and_then(Json::as_usize)
                .context("model meta: dim")?,
            source: text("source")?,
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(0),
            seeding_secs: v
                .get("seeding_secs")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            lloyd_iters: v
                .get("lloyd_iters")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            cost: v.get("cost").and_then(Json::as_f64).unwrap_or(f64::NAN),
        })
    }
}

/// A fitted model: metadata + the `k × d` center matrix + the squared
/// center norms the v2 assignment kernel consumes.
#[derive(Clone, Debug)]
pub struct Model {
    pub meta: ModelMeta,
    pub centers: PointSet,
    /// `‖c_j‖²` per center, computed **once** at registration/load
    /// ([`Model::new`]) and reused by every assign request — the
    /// kernels-v2 fix for re-deriving center distances from scratch per
    /// request. Not persisted: it is a pure function of `centers`, so a
    /// reload recomputes identical bits.
    pub center_norms: Vec<f32>,
}

impl Model {
    /// Build a model, deriving the center-norm cache.
    pub fn new(meta: ModelMeta, centers: PointSet) -> Model {
        let center_norms = crate::kernels::norms::squared_norms(&centers);
        Model {
            meta,
            centers,
            center_norms,
        }
    }

    /// Metadata plus the full center matrix (the `GET /models/{id}` body).
    pub fn full_json(&self) -> Json {
        match self.meta.to_json() {
            Json::Obj(mut fields) => {
                fields.push(("centers".to_string(), json::points_to_json(&self.centers)));
                Json::Obj(fields)
            }
            other => other,
        }
    }
}

/// Batched nearest-center assignment against a model — the serving
/// layer's only path to distances, routed through the kernel engine
/// with the model's cached center norms (query-point norms are derived
/// per request when the autotuned v2 kernel runs; the labels and
/// distances are bitwise identical to an uncached
/// [`crate::kernels::assign::assign_argmin`] call on the same bits, so
/// repeated identical requests serve byte-identical responses).
pub fn assign(model: &Model, points: &PointSet) -> Result<(Vec<u32>, Vec<f32>)> {
    if points.dim() != model.centers.dim() {
        bail!(
            "dimension mismatch: model {} has d={}, query has d={}",
            model.meta.id,
            model.centers.dim(),
            points.dim()
        );
    }
    Ok(assign_argmin_cached(points, None, &model.centers, Some(&model.center_norms)))
}

/// Thread-safe id → model map with optional on-disk persistence.
pub struct ModelRegistry {
    /// Persistence root (`{dir}/models/`); `None` = memory only.
    dir: Option<PathBuf>,
    models: RwLock<BTreeMap<String, Arc<Model>>>,
    next_id: AtomicU64,
}

impl ModelRegistry {
    /// Create a registry, reloading any models persisted under
    /// `{dir}/models/` from a previous run.
    pub fn new(dir: Option<PathBuf>) -> Result<ModelRegistry> {
        let reg = ModelRegistry {
            dir,
            models: RwLock::new(BTreeMap::new()),
            next_id: AtomicU64::new(1),
        };
        reg.load_persisted()?;
        Ok(reg)
    }

    fn models_dir(&self) -> Option<PathBuf> {
        self.dir.as_ref().map(|d| d.join("models"))
    }

    fn load_persisted(&self) -> Result<()> {
        let Some(models_dir) = self.models_dir() else {
            return Ok(());
        };
        if !models_dir.exists() {
            return Ok(());
        }
        for entry in std::fs::read_dir(&models_dir)
            .with_context(|| format!("read {models_dir:?}"))?
        {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            match Self::load_model(&path) {
                Ok(model) => {
                    // Keep fresh ids above every persisted one.
                    if let Some(n) = model
                        .meta
                        .id
                        .strip_prefix("m-")
                        .and_then(|s| s.parse::<u64>().ok())
                    {
                        self.next_id.fetch_max(n + 1, Ordering::Relaxed);
                    }
                    self.models
                        .write()
                        .unwrap()
                        .insert(model.meta.id.clone(), Arc::new(model));
                }
                // A corrupt file must not take the whole server down.
                Err(e) => eprintln!("[serve] skipping unreadable model {path:?}: {e:#}"),
            }
        }
        Ok(())
    }

    fn load_model(meta_path: &Path) -> Result<Model> {
        let text = std::fs::read_to_string(meta_path)?;
        let meta = ModelMeta::from_json(&json::parse(&text)?)?;
        let centers = read_fbin(&meta_path.with_extension("fbin"))?;
        if centers.len() != meta.k || centers.dim() != meta.dim {
            bail!(
                "centers shape {}x{} disagrees with meta k={} dim={}",
                centers.len(),
                centers.dim(),
                meta.k,
                meta.dim
            );
        }
        Ok(Model::new(meta, centers))
    }

    /// Allocate the next model id (`m-<seq>`).
    pub fn fresh_id(&self) -> String {
        format!("m-{}", self.next_id.fetch_add(1, Ordering::Relaxed))
    }

    /// Register a model (persisting it first when a directory is set, so
    /// a model is never visible in memory but missing on disk).
    pub fn insert(&self, meta: ModelMeta, centers: PointSet) -> Result<Arc<Model>> {
        let model = Arc::new(Model::new(meta, centers));
        if let Some(models_dir) = self.models_dir() {
            std::fs::create_dir_all(&models_dir)
                .with_context(|| format!("create {models_dir:?}"))?;
            write_fbin(
                &model.centers,
                &models_dir.join(format!("{}.fbin", model.meta.id)),
            )?;
            std::fs::write(
                models_dir.join(format!("{}.json", model.meta.id)),
                model.meta.to_json().emit(),
            )
            .context("write model meta")?;
        }
        self.models
            .write()
            .unwrap()
            .insert(model.meta.id.clone(), Arc::clone(&model));
        Ok(model)
    }

    pub fn get(&self, id: &str) -> Option<Arc<Model>> {
        self.models.read().unwrap().get(id).cloned()
    }

    /// All models, id-ordered.
    pub fn list(&self) -> Vec<Arc<Model>> {
        self.models.read().unwrap().values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.models.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};
    use crate::kernels::assign::nearest_center;

    fn centers(n: usize, d: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 3,
                ..Default::default()
            },
            seed,
        )
    }

    fn meta(id: &str, k: usize, dim: usize) -> ModelMeta {
        ModelMeta {
            id: id.to_string(),
            algorithm: "rejection".to_string(),
            k,
            dim,
            source: "inline(n=100, d=4)".to_string(),
            seed: 7,
            seeding_secs: 0.25,
            lloyd_iters: 2,
            cost: 123.5,
        }
    }

    #[test]
    fn meta_json_roundtrip() {
        let m = meta("m-9", 5, 4);
        let back = ModelMeta::from_json(&json::parse(&m.to_json().emit()).unwrap()).unwrap();
        assert_eq!(back.id, "m-9");
        assert_eq!(back.algorithm, "rejection");
        assert_eq!(back.k, 5);
        assert_eq!(back.dim, 4);
        assert_eq!(back.seed, 7);
        assert_eq!(back.lloyd_iters, 2);
        assert!((back.cost - 123.5).abs() < 1e-12);
        assert!(ModelMeta::from_json(&json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn insert_get_list_memory_only() {
        let reg = ModelRegistry::new(None).unwrap();
        assert!(reg.is_empty());
        let id = reg.fresh_id();
        assert_eq!(id, "m-1");
        reg.insert(meta(&id, 6, 4), centers(6, 4, 1)).unwrap();
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get("m-1").unwrap().meta.k, 6);
        assert!(reg.get("m-404").is_none());
        assert_eq!(reg.list().len(), 1);
        assert_eq!(reg.fresh_id(), "m-2");
    }

    #[test]
    fn persistence_roundtrip() {
        let dir = std::env::temp_dir().join("fkmpp_registry_persist_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cs = centers(5, 3, 2);
        {
            let reg = ModelRegistry::new(Some(dir.clone())).unwrap();
            let id = reg.fresh_id();
            reg.insert(meta(&id, 5, 3), cs.clone()).unwrap();
        }
        // Fresh registry over the same dir sees the model, bit-exact, and
        // continues the id sequence past it.
        let reg = ModelRegistry::new(Some(dir.clone())).unwrap();
        assert_eq!(reg.len(), 1);
        let m = reg.get("m-1").unwrap();
        assert_eq!(m.centers, cs);
        assert_eq!(m.meta.source, "inline(n=100, d=4)");
        assert_eq!(reg.fresh_id(), "m-2");
    }

    #[test]
    fn corrupt_persisted_model_skipped() {
        let dir = std::env::temp_dir().join("fkmpp_registry_corrupt_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("models")).unwrap();
        std::fs::write(dir.join("models/m-1.json"), "{ not json").unwrap();
        let reg = ModelRegistry::new(Some(dir)).unwrap();
        assert!(reg.is_empty());
    }

    #[test]
    fn assign_routes_through_kernel() {
        let cs = centers(4, 3, 3);
        let model = Model::new(meta("m-1", 4, 3), cs.clone());
        let queries = centers(50, 3, 4);
        let (labels, d2s) = assign(&model, &queries).unwrap();
        for i in 0..queries.len() {
            let (want_j, want_d) = nearest_center(queries.row(i), &cs);
            assert_eq!(labels[i], want_j);
            assert_eq!(d2s[i], want_d);
        }
        // Dimension mismatch is a client error, not a panic.
        let bad = centers(3, 7, 5);
        assert!(assign(&model, &bad).is_err());
    }

    #[test]
    fn center_norm_cache_survives_reload() {
        // The cache is derived, not persisted: a reload must recompute
        // identical bits from the identical center matrix.
        let dir = std::env::temp_dir().join("fkmpp_registry_norms_test");
        let _ = std::fs::remove_dir_all(&dir);
        let cs = centers(6, 4, 9);
        {
            let reg = ModelRegistry::new(Some(dir.clone())).unwrap();
            let id = reg.fresh_id();
            let m = reg.insert(meta(&id, 6, 4), cs.clone()).unwrap();
            assert_eq!(m.center_norms, crate::kernels::norms::squared_norms(&cs));
        }
        let reg = ModelRegistry::new(Some(dir)).unwrap();
        let m = reg.get("m-1").unwrap();
        assert_eq!(m.center_norms, crate::kernels::norms::squared_norms(&cs));
    }

    #[test]
    fn full_json_contains_centers() {
        let cs = centers(3, 2, 6);
        let model = Model::new(meta("m-2", 3, 2), cs.clone());
        let v = model.full_json();
        assert_eq!(v.get("id").and_then(Json::as_str), Some("m-2"));
        let back = json::points_from_json(v.get("centers").unwrap()).unwrap();
        assert_eq!(back, cs);
    }
}
