//! `fkmpp loadgen` — the serving-path load driver.
//!
//! Boots an ephemeral-port [`super::Server`] in-process, installs a
//! synthetic model, then sweeps `route × connection-mode × connections`
//! against the live socket with raw-`TcpStream` clients:
//!
//! * **route**: the JSON assign body vs the binary `.fbin`-in /
//!   `FKA1`-out path ([`super::encode_assign_frame`]);
//! * **mode**: `keepalive` (one connection, many requests) vs `close`
//!   (one connection per request — the pre-keep-alive behavior);
//! * **connections**: concurrent client threads.
//!
//! Before timing anything it runs a parity pass asserting the binary
//! route's labels/d² are **bitwise identical** to the JSON route's, so a
//! throughput number can never be quoted for a route that changed
//! result bits. Results render as a text table and, with a JSON path,
//! as the `BENCH_serve.json` artifact
//! ([`crate::coordinator::tables::serve_json`]).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use crate::bail;
use crate::coordinator::tables::{self, ServeCell};
use crate::data::synth::{gaussian_mixture, SynthSpec};
use crate::error::{Context, Result};
use crate::metrics::Stats;
use crate::server::json::{self, Json};

use super::{decode_assign_frame, registry, ServeConfig, Server};

/// `fkmpp loadgen` knobs.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Concurrent-connection counts to sweep.
    pub conns: Vec<usize>,
    /// Points per assign request (the payload size axis).
    pub points: usize,
    /// Dimensions per point.
    pub dim: usize,
    /// Centers in the served model.
    pub k: usize,
    /// Requests per rep, split across the connections.
    pub requests: usize,
    /// Repetitions per cell (per-rep walls feed the `seconds` stats).
    pub reps: usize,
    pub seed: u64,
    /// Observe-burst requests to fire after the assign sweep (0 = off).
    /// Each carries `points` points and the server's refresh cadence is
    /// pinned to one batch, so every burst request publishes a version.
    pub observe: usize,
    /// Write `BENCH_serve.json` here when set.
    pub json_path: Option<String>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            conns: vec![1, 2, 8],
            points: 256,
            dim: 16,
            k: 64,
            requests: 100,
            reps: 2,
            seed: 42,
            observe: 0,
            json_path: None,
        }
    }
}

impl LoadgenConfig {
    /// The `--short` profile: small enough for CI smoke (seconds, not
    /// minutes) while still covering 1-vs-8 connections.
    pub fn short() -> Self {
        LoadgenConfig {
            conns: vec![1, 8],
            points: 64,
            dim: 8,
            k: 16,
            requests: 40,
            reps: 1,
            ..LoadgenConfig::default()
        }
    }
}

/// Run the sweep; returns the human-readable report.
pub fn run(cfg: &LoadgenConfig) -> Result<String> {
    if cfg.conns.is_empty() || cfg.conns.contains(&0) {
        bail!("--conns needs at least one nonzero connection count");
    }
    if cfg.points == 0 || cfg.dim == 0 || cfg.k == 0 || cfg.requests == 0 || cfg.reps == 0 {
        bail!("--points/--dim/-k/--requests/--reps must all be >= 1");
    }
    let max_conns = *cfg.conns.iter().max().unwrap();
    // The driver measures the request path, not admission control: size
    // the worker pool and queues so nothing sheds mid-sweep, and lift
    // the per-connection cap above a rep's worth of requests.
    let scfg = ServeConfig {
        port: 0,
        persist: false,
        http_workers: max_conns.max(4),
        fit_workers: 1,
        queue_depth: max_conns * 4 + 32,
        keepalive_max_requests: cfg.requests * 2 + 16,
        // One observe request carries `points` points; pin the refresh
        // cadence to one batch so each `--observe` burst request can
        // publish a fresh model version.
        observe_refresh_every: cfg.points.max(1),
        ..ServeConfig::default()
    };
    let server = Server::bind(&scfg)?;
    let addr = server.local_addr()?;
    let reg = server.registry();
    let centers = gaussian_mixture(
        &SynthSpec {
            n: cfg.k,
            d: cfg.dim,
            k_true: cfg.k.clamp(1, 8),
            ..Default::default()
        },
        cfg.seed,
    );
    let meta = registry::ModelMeta {
        id: reg.fresh_id(),
        version: 1,
        algorithm: "loadgen".to_string(),
        k: cfg.k,
        dim: cfg.dim,
        source: "synthetic".to_string(),
        seed: cfg.seed,
        seeding_secs: 0.0,
        lloyd_iters: 0,
        cost: 0.0,
    };
    let model_id = meta.id.clone();
    reg.insert(meta, centers)?;
    // Regression guard: registration must pin the same assign kernel the
    // fit path would — a model that slipped past `Model::new` would make
    // every throughput number below incomparable to served fits.
    let installed = reg
        .get(&model_id)
        .context("loadgen model vanished after insert")?;
    let pinned = crate::kernels::tune::kernel_for(
        crate::kernels::tune::Op::Assign,
        registry::ASSIGN_PIN_N,
        cfg.dim,
        cfg.k,
    );
    if installed.assign_kernel != pinned {
        bail!(
            "loadgen model registered with kernel {:?}, fit path pins {:?}",
            installed.assign_kernel,
            pinned
        );
    }
    let srv = std::thread::spawn(move || server.run());

    let queries = gaussian_mixture(
        &SynthSpec {
            n: cfg.points,
            d: cfg.dim,
            k_true: cfg.k.clamp(1, 8),
            ..Default::default()
        },
        cfg.seed ^ 0x10AD_9E37,
    );
    let bin_body = crate::data::io::encode_fbin(&queries);
    let json_body = Json::obj(vec![("points", json::points_to_json(&queries))])
        .emit()
        .into_bytes();

    // The sweep aborts on any error past this point; make sure the
    // server is told to stop either way so the process can exit.
    let result = sweep(cfg, addr, &model_id, &json_body, &bin_body).and_then(|mut report| {
        if cfg.observe > 0 {
            report.push_str(&observe_burst(cfg, addr, &model_id, &bin_body)?);
        }
        Ok(report)
    });
    let _ = one_shot(addr, &request_bytes("/shutdown", "", &[], true));
    let _ = srv.join();
    result
}

/// `--observe N`: fire N ingest requests at the served model, then wait
/// for the off-thread refresher to publish a bumped version. Runs after
/// the assign sweep so every timed cell answered from version 1.
fn observe_burst(
    cfg: &LoadgenConfig,
    addr: SocketAddr,
    model_id: &str,
    bin_body: &[u8],
) -> Result<String> {
    let path = format!("/models/{model_id}/observe");
    for _ in 0..cfg.observe {
        let (status, _) = one_shot(
            addr,
            &request_bytes(&path, "application/octet-stream", bin_body, true),
        )?;
        if status != 200 {
            bail!("observe request answered HTTP {status}");
        }
    }
    // Every burst request crossed the refresh cadence (pinned to one
    // batch above), so a publish is in flight; poll until a bump lands.
    let meta_path = format!("/models/{model_id}");
    let deadline = Instant::now() + std::time::Duration::from_secs(10);
    loop {
        let (status, body) = one_shot(addr, &get_bytes(&meta_path))?;
        if status != 200 {
            bail!("GET {meta_path} answered HTTP {status}");
        }
        let v = json::parse(std::str::from_utf8(&body).context("model doc")?)?;
        let version = v.get("version").and_then(Json::as_u64).unwrap_or(0);
        if version >= 2 {
            return Ok(format!(
                "\nobserve: {} requests x {} points ingested; model refreshed to version {version}\n",
                cfg.observe, cfg.points
            ));
        }
        if Instant::now() > deadline {
            bail!("observe burst: model version never bumped past 1 (still {version})");
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
}

/// Serialize a bodyless GET (the observe burst's version poll).
fn get_bytes(path: &str) -> Vec<u8> {
    format!("GET {path} HTTP/1.1\r\nHost: loadgen\r\nConnection: close\r\n\r\n").into_bytes()
}

fn sweep(
    cfg: &LoadgenConfig,
    addr: SocketAddr,
    model_id: &str,
    json_body: &[u8],
    bin_body: &[u8],
) -> Result<String> {
    let path = format!("/models/{model_id}/assign");
    // Parity pass first: the binary route must answer bit-identically to
    // the JSON route before either is worth timing.
    let (status, body) = one_shot(
        addr,
        &request_bytes(&path, "application/octet-stream", bin_body, true),
    )?;
    if status != 200 {
        bail!("parity pass: binary assign answered HTTP {status}");
    }
    let (bin_labels, bin_d2s) = decode_assign_frame(&body)?;
    let (status, body) = one_shot(
        addr,
        &request_bytes(&path, "application/json", json_body, true),
    )?;
    if status != 200 {
        bail!("parity pass: JSON assign answered HTTP {status}");
    }
    let v = json::parse(std::str::from_utf8(&body).context("JSON assign body")?)?;
    let json_labels: Vec<u32> = v
        .get("labels")
        .and_then(Json::as_array)
        .context("JSON assign: labels")?
        .iter()
        .map(|x| x.as_f64().unwrap_or(-1.0) as u32)
        .collect();
    let json_d2s: Vec<u32> = v
        .get("d2")
        .and_then(Json::as_array)
        .context("JSON assign: d2")?
        .iter()
        .map(|x| (x.as_f64().unwrap_or(f64::NAN) as f32).to_bits())
        .collect();
    let bin_bits: Vec<u32> = bin_d2s.iter().map(|d| d.to_bits()).collect();
    if bin_labels != json_labels || bin_bits != json_d2s {
        bail!("binary and JSON assign routes disagree bitwise — refusing to benchmark");
    }

    let mut report = format!(
        "loadgen: payload n={} d={} (json {} B, binary {} B), k={}, {} requests x {} reps\n\
         binary/JSON parity: ok (bitwise)\n\n\
         | route | mode | conns | req/s | p50 ms | p99 ms |\n|---|---|---|---|---|---|\n",
        cfg.points,
        cfg.dim,
        json_body.len(),
        bin_body.len(),
        cfg.k,
        cfg.requests,
        cfg.reps
    );
    let mut cells = Vec::new();
    for (route, body) in [("json", json_body), ("binary", bin_body)] {
        let content_type = match route {
            "binary" => "application/octet-stream",
            _ => "application/json",
        };
        for mode in ["close", "keepalive"] {
            for &conns in &cfg.conns {
                let mut span = crate::trace::Span::enter("loadgen.cell");
                span.arg("route", route.to_string());
                span.arg("mode", mode.to_string());
                span.arg("conns", conns as u64);
                let mut secs = Stats::new();
                let mut lats: Vec<f64> = Vec::new();
                let mut wall_sum = 0.0f64;
                for _ in 0..cfg.reps {
                    let (wall, mut rep_lats) = run_rep(
                        addr,
                        &path,
                        content_type,
                        body,
                        mode == "close",
                        conns,
                        cfg.requests,
                    )?;
                    secs.push(wall);
                    wall_sum += wall;
                    lats.append(&mut rep_lats);
                }
                drop(span);
                lats.sort_by(f64::total_cmp);
                let throughput_rps = lats.len() as f64 / wall_sum.max(f64::MIN_POSITIVE);
                let p50_ms = percentile(&lats, 0.50);
                let p99_ms = percentile(&lats, 0.99);
                report.push_str(&format!(
                    "| {route} | {mode} | {conns} | {throughput_rps:.0} | {p50_ms:.2} | {p99_ms:.2} |\n"
                ));
                cells.push(ServeCell {
                    dataset: format!("payload_n{}_d{}", cfg.points, cfg.dim),
                    algorithm: format!("assign_{route}_{mode}"),
                    route: route.to_string(),
                    mode: mode.to_string(),
                    connections: conns,
                    k: cfg.k,
                    seconds: secs,
                    p50_ms,
                    p99_ms,
                    throughput_rps,
                });
            }
        }
    }
    if let Some(out_path) = &cfg.json_path {
        let doc = tables::serve_json(&cells, cfg.reps, cfg.seed, crate::parallel::num_threads());
        std::fs::write(out_path, doc.emit()).with_context(|| format!("write {out_path:?}"))?;
        report.push_str(&format!("\nwrote {out_path}\n"));
    }
    Ok(report)
}

/// One rep of one cell: `conns` client threads splitting `requests`
/// requests, each asserting HTTP 200. Returns (wall seconds, per-request
/// latencies in ms).
fn run_rep(
    addr: SocketAddr,
    path: &str,
    content_type: &str,
    body: &[u8],
    close_per_request: bool,
    conns: usize,
    requests: usize,
) -> Result<(f64, Vec<f64>)> {
    let req = request_bytes(path, content_type, body, close_per_request);
    let t0 = Instant::now();
    let joined: Vec<std::thread::Result<Result<Vec<f64>>>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..conns {
            let n = requests / conns + usize::from(i < requests % conns);
            if n == 0 {
                continue;
            }
            let req = &req;
            handles.push(s.spawn(move || client_thread(addr, req, n, close_per_request)));
        }
        handles.into_iter().map(|h| h.join()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut lats = Vec::with_capacity(requests);
    for r in joined {
        let thread_lats = r.map_err(|_| crate::anyhow!("loadgen client thread panicked"))??;
        lats.extend(thread_lats);
    }
    Ok((wall, lats))
}

/// One client: either one kept-alive connection for all `n` requests, or
/// a fresh connection per request (the `close` discipline under test).
fn client_thread(
    addr: SocketAddr,
    req: &[u8],
    n: usize,
    close_per_request: bool,
) -> Result<Vec<f64>> {
    let mut lats = Vec::with_capacity(n);
    if close_per_request {
        for _ in 0..n {
            let t = Instant::now();
            let (status, _) = one_shot(addr, req)?;
            if status != 200 {
                bail!("loadgen request answered HTTP {status}");
            }
            lats.push(t.elapsed().as_secs_f64() * 1e3);
        }
    } else {
        let stream = TcpStream::connect(addr).context("loadgen connect")?;
        let mut writer = stream.try_clone().context("loadgen clone stream")?;
        let mut reader = BufReader::new(stream);
        for _ in 0..n {
            let t = Instant::now();
            writer.write_all(req).context("loadgen write")?;
            let (status, _) = read_response(&mut reader)?;
            if status != 200 {
                bail!("loadgen request answered HTTP {status}");
            }
            lats.push(t.elapsed().as_secs_f64() * 1e3);
        }
    }
    Ok(lats)
}

/// Serialize one request. An empty `content_type` omits the header
/// (the shutdown poke).
fn request_bytes(path: &str, content_type: &str, body: &[u8], close: bool) -> Vec<u8> {
    let mut head = format!("POST {path} HTTP/1.1\r\nHost: loadgen\r\n");
    if !content_type.is_empty() {
        head.push_str(&format!("Content-Type: {content_type}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\n", body.len()));
    if close {
        head.push_str("Connection: close\r\n");
    }
    head.push_str("\r\n");
    let mut out = head.into_bytes();
    out.extend_from_slice(body);
    out
}

/// Fresh connection, one request, full response.
fn one_shot(addr: SocketAddr, req: &[u8]) -> Result<(u16, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr).context("loadgen connect")?;
    stream.write_all(req).context("loadgen write")?;
    let mut reader = BufReader::new(stream);
    read_response(&mut reader)
}

/// Minimal HTTP/1.1 response reader: status line, headers for
/// `Content-Length`, exact body. Enough for this server's responses
/// (which always carry a Content-Length and never chunk).
fn read_response<R: BufRead>(reader: &mut R) -> Result<(u16, Vec<u8>)> {
    let mut line = String::new();
    if reader.read_line(&mut line).context("loadgen read status")? == 0 {
        bail!("connection closed before a response arrived");
    }
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .with_context(|| format!("malformed status line {line:?}"))?
        .parse()
        .with_context(|| format!("malformed status line {line:?}"))?;
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header).context("loadgen read header")? == 0 {
            bail!("connection closed inside response headers");
        }
        let trimmed = header.trim_end();
        if trimmed.is_empty() {
            break;
        }
        if let Some((name, value)) = trimmed.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .with_context(|| format!("response Content-Length {value:?}"))?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("loadgen read body")?;
    Ok((status, body))
}

/// Nearest-rank percentile over a sorted slice (exact, no interpolation).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_exact_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 1.0), 4.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn request_bytes_wire_format() {
        let req = request_bytes("/models/m-1/assign", "application/json", b"{}", true);
        let text = String::from_utf8(req).unwrap();
        assert!(text.starts_with("POST /models/m-1/assign HTTP/1.1\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
        let keep = String::from_utf8(request_bytes("/x", "t", b"", false)).unwrap();
        assert!(!keep.contains("Connection:"), "{keep}");
    }

    #[test]
    fn loadgen_smoke_sweep_and_artifact() {
        // A miniature sweep against a real in-process server: covers the
        // parity pass, both routes, both connection modes, and the
        // BENCH_serve.json emission.
        let path = std::env::temp_dir().join("fkmpp_loadgen_test.json");
        let _ = std::fs::remove_file(&path);
        let cfg = LoadgenConfig {
            conns: vec![1, 2],
            points: 8,
            dim: 3,
            k: 4,
            requests: 6,
            reps: 1,
            seed: 7,
            observe: 2,
            json_path: Some(path.display().to_string()),
        };
        let out = run(&cfg).unwrap();
        assert!(out.contains("parity: ok"), "{out}");
        assert!(out.contains("| binary | keepalive | 2 |"), "{out}");
        // The observe mix ran, and the pinned-kernel regression guard in
        // run() passed (a bypassed registration would have errored out).
        assert!(
            out.contains("observe: 2 requests x 8 points"),
            "{out}"
        );
        assert!(out.contains("refreshed to version"), "{out}");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = json::parse(&text).unwrap();
        assert_eq!(doc.get("profile").and_then(Json::as_str), Some("serve_bench"));
        let cells = doc.get("cells").and_then(Json::as_array).unwrap();
        // 2 routes x 2 modes x 2 connection counts.
        assert_eq!(cells.len(), 8);
        for cell in cells {
            assert_eq!(
                cell.get("dataset").and_then(Json::as_str),
                Some("payload_n8_d3")
            );
            let rps = cell.get("throughput_rps").and_then(Json::as_f64).unwrap();
            assert!(rps > 0.0, "{cell:?}");
            assert!(cell.get("seconds").unwrap().get("mean").is_some());
        }
    }
}
