//! Minimal HTTP/1.1 protocol support for the serving layer — request
//! parsing and response writing over plain `std::io` streams, zero
//! dependencies.
//!
//! Scope is deliberately small: one request per connection
//! (`Connection: close`), bodies framed by `Content-Length` only (no
//! chunked transfer), no TLS. That covers `curl`, load-balancer health
//! checks and the integration harness; anything fancier belongs in a
//! fronting proxy. Parsing is generic over [`Read`]/[`Write`] so unit
//! tests drive it with byte slices instead of sockets.

use std::io::{BufRead, BufReader, Read, Write};

use crate::bail;
use crate::error::{Context, Result};

/// Maximum accepted request body. Inline datasets can be sizeable, but
/// the JSON layer materializes a parse tree several times the text size,
/// so the cap stays conservative — ship bigger data via the named
/// `dataset` fit path (disk-cached `.fbin`) instead of inline points.
pub const MAX_BODY_BYTES: usize = 64 << 20;

/// Maximum total header bytes before we drop the connection.
const MAX_HEADER_BYTES: usize = 64 << 10;

/// A parsed HTTP request. Headers other than `Content-Length` are
/// skipped — the routes are path + body shaped.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string (e.g. `/models/m-1/assign`).
    pub path: String,
    /// Raw query string (without the `?`), empty if none.
    pub query: String,
    pub body: Vec<u8>,
}

impl Request {
    /// Body as UTF-8 text (JSON bodies).
    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }
}

/// Read one `\n`-terminated line with a hard byte cap, so a client that
/// streams an endless request/header line is cut off instead of growing
/// the buffer without bound (`BufRead::read_line` has no such cap).
fn read_line_capped<R: BufRead>(reader: &mut R, cap: usize) -> Result<String> {
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        if reader.read(&mut byte).context("read header byte")? == 0 {
            break; // EOF
        }
        buf.push(byte[0]);
        if byte[0] == b'\n' {
            break;
        }
        if buf.len() > cap {
            bail!("header line exceeds {cap} bytes");
        }
    }
    String::from_utf8(buf).context("header is not UTF-8")
}

/// Read and parse one request from `stream`.
pub fn read_request<S: Read>(stream: &mut S) -> Result<Request> {
    let mut reader = BufReader::new(stream);
    let line = read_line_capped(&mut reader, MAX_HEADER_BYTES).context("read request line")?;
    if line.trim_end().is_empty() {
        bail!("empty request");
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .context("missing method")?
        .to_ascii_uppercase();
    let target = parts.next().context("missing request target")?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.0");
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version:?}");
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut content_length = 0usize;
    let mut header_bytes = line.len();
    loop {
        let budget = MAX_HEADER_BYTES.saturating_sub(header_bytes);
        let header = read_line_capped(&mut reader, budget).context("read header")?;
        if header.is_empty() {
            bail!("connection closed mid-headers");
        }
        header_bytes += header.len();
        if header_bytes > MAX_HEADER_BYTES {
            bail!("headers exceed {MAX_HEADER_BYTES} bytes");
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .with_context(|| format!("Content-Length {value:?}"))?;
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("body of {content_length} bytes exceeds limit {MAX_BODY_BYTES}");
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("read body")?;
    Ok(Request {
        method,
        path,
        query,
        body,
    })
}

/// An HTTP response about to be written.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    /// A JSON response.
    pub fn json(status: u16, v: &super::json::Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.emit().into_bytes(),
        }
    }

    /// A plain-text response.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
        }
    }
}

/// Reason phrase for the status codes the server emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write `resp` (status line + minimal headers + body) to `stream`.
pub fn write_response<S: Write>(stream: &mut S, resp: &Response) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        status_reason(resp.status),
        resp.content_type,
        resp.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json::Json;

    fn parse_bytes(raw: &str) -> Result<Request> {
        let mut cursor = raw.as_bytes();
        read_request(&mut cursor)
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse_bytes(
            "POST /fit?x=1 HTTP/1.1\r\nHost: localhost\r\nContent-Length: 11\r\n\r\nhello world",
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/fit");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.body_str().unwrap(), "hello world");
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse_bytes("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.query, "");
        assert!(req.body.is_empty());
    }

    #[test]
    fn content_length_case_insensitive() {
        let req =
            parse_bytes("POST /x HTTP/1.1\r\ncontent-length: 3\r\n\r\nabc").unwrap();
        assert_eq!(req.body, b"abc");
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("\r\n").is_err());
        assert!(parse_bytes("GET\r\n\r\n").is_err(), "missing target");
        assert!(parse_bytes("GET / SPDY/3\r\n\r\n").is_err(), "bad version");
        assert!(
            parse_bytes("POST /x HTTP/1.1\r\nContent-Length: zap\r\n\r\n").is_err(),
            "unparseable length"
        );
        assert!(
            parse_bytes("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort").is_err(),
            "truncated body"
        );
        assert!(
            parse_bytes(&format!(
                "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                MAX_BODY_BYTES + 1
            ))
            .is_err(),
            "oversized body"
        );
        // A request line that never terminates must be cut off at the
        // cap, not buffered without bound.
        let endless = "GET /".to_string() + &"a".repeat(80 << 10);
        assert!(parse_bytes(&endless).is_err(), "unterminated request line");
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        let resp = Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]));
        write_response(&mut out, &resp).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"));
    }

    #[test]
    fn text_response_and_reasons() {
        let mut out = Vec::new();
        write_response(&mut out, &Response::text(404, "nope")).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(text.ends_with("nope"));
        assert_eq!(status_reason(500), "Internal Server Error");
        assert_eq!(status_reason(999), "Unknown");
    }
}
