//! Minimal HTTP/1.1 protocol support for the serving layer — request
//! parsing and response writing over plain `std::io` streams, zero
//! dependencies.
//!
//! Scope is deliberately small: sequential (pipelined) requests on a
//! kept-alive connection, bodies framed by `Content-Length` only (no
//! chunked transfer), no TLS. That covers `curl`, load-balancer health
//! checks, the `fkmpp loadgen` driver and the integration harness;
//! anything fancier belongs in a fronting proxy. Parsing is generic over
//! [`BufRead`]/[`Write`] so unit tests drive it with byte slices instead
//! of sockets — and so the caller owns the buffered reader, which MUST
//! survive across requests on one connection (bytes of the next
//! pipelined request may already sit in its buffer).
//!
//! Protocol notes (the keep-alive bugfix set):
//!
//! * Leading bare CRLFs before the request line are skipped (RFC 7230
//!   §3.5) up to [`MAX_LEADING_BLANKS`] — keep-alive clients emit stray
//!   CRLFs between pipelined requests.
//! * Clean EOF between requests is [`ReadOutcome::Closed`], not an
//!   error: under keep-alive the peer hanging up is the normal end of a
//!   connection's life.
//! * Duplicate `Content-Length` headers with conflicting values are a
//!   request-smuggling hazard on reused connections and are rejected
//!   with 400 (identical duplicates are tolerated); `Transfer-Encoding`
//!   is not supported and likewise rejected rather than ignored.
//! * `Expect: 100-continue` gets the interim `100 Continue` before the
//!   body is read — without it `curl` stalls ~1s on any body > 1 KiB.

use std::io::{BufRead, Read, Write};

use crate::error::{Context, Result};

/// Maximum accepted request body. Inline datasets can be sizeable, but
/// the JSON layer materializes a parse tree several times the text size,
/// so the cap stays conservative — ship bigger data via the named
/// `dataset` fit path (disk-cached `.fbin`) instead of inline points.
pub const MAX_BODY_BYTES: usize = 64 << 20;

/// Maximum total header bytes before we reject the request.
const MAX_HEADER_BYTES: usize = 64 << 10;

/// How many bare CRLF/LF lines may precede the request line (RFC 7230
/// §3.5 says to ignore "at least one"; a bounded few keeps a blank-line
/// flood from spinning the parser).
const MAX_LEADING_BLANKS: usize = 4;

/// A parsed HTTP request. Headers other than the framing/connection set
/// (`Content-Length`, `Content-Type`, `Connection`, `Expect`,
/// `Transfer-Encoding`) are skipped — the routes are path + body shaped.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string (e.g. `/models/m-1/assign`).
    pub path: String,
    /// Raw query string (without the `?`), empty if none.
    pub query: String,
    /// Lowercased `Content-Type` value, empty if absent. Routes that
    /// accept both JSON and binary bodies dispatch on it.
    pub content_type: String,
    /// Whether the client allows the connection to be reused after this
    /// request (HTTP/1.1 defaults to yes unless `Connection: close`;
    /// HTTP/1.0 defaults to no unless `Connection: keep-alive`).
    pub keep_alive: bool,
    /// Client-supplied `X-Request-Id`, trimmed, if any. The server
    /// generates one when absent and echoes it on every response.
    pub request_id: Option<String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Body as UTF-8 text (JSON bodies).
    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }
}

/// What [`read_request`] saw on the stream. `Err` is reserved for
/// transport-level failures (idle timeout, reset) where no response can
/// usefully be written.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete, well-formed request.
    Request(Request),
    /// Clean EOF before any request bytes — the peer closed the
    /// connection between requests. Not an error under keep-alive.
    Closed,
    /// A malformed request: the caller should write a response with
    /// `status`/`reason` and close the connection (framing can no longer
    /// be trusted).
    Malformed { status: u16, reason: String },
}

/// One `\n`-terminated line, classified. `Err` carries only I/O errors.
enum Line {
    /// EOF before any byte of this line.
    Eof,
    /// A line (newline included; EOF-truncated lines come back as-is).
    Text(String),
    /// The line exceeded the byte cap before its newline.
    TooLong,
    /// The line bytes were not UTF-8.
    NotUtf8,
}

/// Read one `\n`-terminated line with a hard byte cap, so a client that
/// streams an endless request/header line is cut off instead of growing
/// the buffer without bound (`BufRead::read_line` has no such cap).
fn read_line_capped<R: BufRead>(reader: &mut R, cap: usize) -> std::io::Result<Line> {
    let mut buf = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        if reader.read(&mut byte)? == 0 {
            if buf.is_empty() {
                return Ok(Line::Eof);
            }
            break;
        }
        buf.push(byte[0]);
        if byte[0] == b'\n' {
            break;
        }
        if buf.len() > cap {
            return Ok(Line::TooLong);
        }
    }
    match String::from_utf8(buf) {
        Ok(s) => Ok(Line::Text(s)),
        Err(_) => Ok(Line::NotUtf8),
    }
}

fn malformed(status: u16, reason: impl Into<String>) -> Result<ReadOutcome> {
    Ok(ReadOutcome::Malformed {
        status,
        reason: reason.into(),
    })
}

/// Read and parse one request from `reader`. The caller owns the
/// [`BufRead`] and must reuse it for every request on the connection —
/// pipelined bytes buffered past the current request live in it.
/// `interim` is the write half of the same connection, used only to emit
/// the `100 Continue` interim response when the client sent
/// `Expect: 100-continue` (pass a `Vec<u8>` in tests).
pub fn read_request<R: BufRead, W: Write>(
    reader: &mut R,
    interim: &mut W,
) -> Result<ReadOutcome> {
    // RFC 7230 §3.5: skip a bounded run of bare CRLFs before the request
    // line. EOF here — including EOF after stray blanks — is the peer
    // closing between requests: clean, not malformed.
    let mut blanks = 0usize;
    let line = loop {
        let line = match read_line_capped(reader, MAX_HEADER_BYTES).context("read request line")? {
            Line::Eof => return Ok(ReadOutcome::Closed),
            Line::TooLong => {
                return malformed(400, format!("request line exceeds {MAX_HEADER_BYTES} bytes"))
            }
            Line::NotUtf8 => return malformed(400, "request line is not UTF-8"),
            Line::Text(s) => s,
        };
        if !line.trim_end().is_empty() {
            break line;
        }
        blanks += 1;
        if blanks > MAX_LEADING_BLANKS {
            return malformed(400, "too many empty lines before request line");
        }
    };
    let mut parts = line.split_whitespace();
    let Some(method) = parts.next() else {
        return malformed(400, "missing method");
    };
    let method = method.to_ascii_uppercase();
    let Some(target) = parts.next() else {
        return malformed(400, "missing request target");
    };
    let target = target.to_string();
    let version = parts.next().unwrap_or("HTTP/1.0");
    if !version.starts_with("HTTP/1.") {
        return malformed(400, format!("unsupported version {version:?}"));
    }
    let http10 = version == "HTTP/1.0";
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };
    let mut content_length: Option<usize> = None;
    let mut content_type = String::new();
    let mut conn_close = false;
    let mut conn_keep = false;
    let mut expect_continue = false;
    let mut request_id: Option<String> = None;
    let mut header_bytes = line.len();
    loop {
        let budget = MAX_HEADER_BYTES.saturating_sub(header_bytes);
        let header = match read_line_capped(reader, budget).context("read header")? {
            Line::Eof => return malformed(400, "connection closed mid-headers"),
            Line::TooLong => {
                return malformed(400, format!("headers exceed {MAX_HEADER_BYTES} bytes"))
            }
            Line::NotUtf8 => return malformed(400, "header is not UTF-8"),
            Line::Text(s) => s,
        };
        header_bytes += header.len();
        if header_bytes > MAX_HEADER_BYTES {
            return malformed(400, format!("headers exceed {MAX_HEADER_BYTES} bytes"));
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        let Some((name, value)) = header.split_once(':') else {
            continue;
        };
        let name = name.trim();
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            let Ok(parsed) = value.parse::<usize>() else {
                return malformed(400, format!("unparseable Content-Length {value:?}"));
            };
            // Conflicting duplicates are the request-smuggling classic:
            // two framings of the same stream. Reject; tolerate exact
            // repeats (some proxies emit them).
            match content_length {
                Some(prev) if prev != parsed => {
                    return malformed(
                        400,
                        format!("conflicting Content-Length headers ({prev} vs {parsed})"),
                    )
                }
                _ => content_length = Some(parsed),
            }
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            // Not supported — and silently ignoring it while framing by
            // Content-Length is exactly the TE/CL smuggling vector.
            return malformed(400, "Transfer-Encoding is not supported (use Content-Length)");
        } else if name.eq_ignore_ascii_case("content-type") {
            content_type = value.to_ascii_lowercase();
        } else if name.eq_ignore_ascii_case("connection") {
            for tok in value.split(',') {
                let tok = tok.trim();
                if tok.eq_ignore_ascii_case("close") {
                    conn_close = true;
                } else if tok.eq_ignore_ascii_case("keep-alive") {
                    conn_keep = true;
                }
            }
        } else if name.eq_ignore_ascii_case("expect") {
            if value.eq_ignore_ascii_case("100-continue") {
                expect_continue = true;
            } else {
                return malformed(417, format!("unsupported expectation {value:?}"));
            }
        } else if name.eq_ignore_ascii_case("x-request-id") {
            if !value.is_empty() {
                request_id = Some(value.to_string());
            }
        }
    }
    let content_length = content_length.unwrap_or(0);
    if content_length > MAX_BODY_BYTES {
        return malformed(
            413,
            format!("body of {content_length} bytes exceeds limit {MAX_BODY_BYTES}"),
        );
    }
    // `close` wins over `keep-alive` if a confused client sends both.
    let keep_alive = if conn_close {
        false
    } else if conn_keep {
        true
    } else {
        !http10
    };
    if expect_continue && content_length > 0 {
        interim
            .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
            .context("write 100 Continue")?;
        interim.flush().context("flush 100 Continue")?;
    }
    let mut body = vec![0u8; content_length];
    if let Err(e) = reader.read_exact(&mut body) {
        return if e.kind() == std::io::ErrorKind::UnexpectedEof {
            malformed(400, "connection closed mid-body")
        } else {
            Err(crate::error::Error::from(e)).context("read body")
        };
    }
    Ok(ReadOutcome::Request(Request {
        method,
        path,
        query,
        content_type,
        keep_alive,
        request_id,
        body,
    }))
}

/// An HTTP response about to be written.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers (e.g. `Retry-After` on a 429), written
    /// verbatim between the framing headers and `Connection:`.
    pub headers: Vec<(&'static str, String)>,
}

impl Response {
    /// A JSON response.
    pub fn json(status: u16, v: &super::json::Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.emit().into_bytes(),
            headers: Vec::new(),
        }
    }

    /// A plain-text response.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
            headers: Vec::new(),
        }
    }

    /// An `application/octet-stream` response (binary frames).
    pub fn binary(status: u16, body: Vec<u8>) -> Response {
        Response {
            status,
            content_type: "application/octet-stream",
            body,
            headers: Vec::new(),
        }
    }

    /// Attach an extra response header.
    pub fn with_header(mut self, name: &'static str, value: impl Into<String>) -> Response {
        self.headers.push((name, value.into()));
        self
    }
}

/// Reason phrase for the status codes the server emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        100 => "Continue",
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        417 => "Expectation Failed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write `resp` (status line + minimal headers + body) to `stream`,
/// announcing whether the server will keep the connection open —
/// `keep_alive` is the *decision*, already folding in the client's
/// `Connection:` preference and the server's per-connection caps.
pub fn write_response<S: Write>(stream: &mut S, resp: &Response, keep_alive: bool) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n",
        resp.status,
        status_reason(resp.status),
        resp.content_type,
        resp.body.len()
    );
    for (name, value) in &resp.headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str(if keep_alive {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    stream.write_all(head.as_bytes())?;
    stream.write_all(&resp.body)?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::json::Json;

    /// Drive the parser with a byte slice, discarding interim writes.
    fn parse_outcome(raw: &str) -> ReadOutcome {
        let mut cursor = raw.as_bytes();
        let mut interim = Vec::new();
        read_request(&mut cursor, &mut interim).expect("no transport error on slices")
    }

    fn parse_ok(raw: &str) -> Request {
        match parse_outcome(raw) {
            ReadOutcome::Request(r) => r,
            other => panic!("expected a request, got {other:?}"),
        }
    }

    /// Status code of the Malformed outcome (panics on anything else).
    fn parse_bad(raw: &str) -> u16 {
        match parse_outcome(raw) {
            ReadOutcome::Malformed { status, .. } => status,
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse_ok(
            "POST /fit?x=1 HTTP/1.1\r\nHost: localhost\r\nContent-Type: Application/JSON\r\n\
             Content-Length: 11\r\n\r\nhello world",
        );
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/fit");
        assert_eq!(req.query, "x=1");
        assert_eq!(req.content_type, "application/json");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        assert_eq!(req.body_str().unwrap(), "hello world");
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse_ok("GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.query, "");
        assert_eq!(req.content_type, "");
        assert!(req.body.is_empty());
    }

    #[test]
    fn content_length_case_insensitive() {
        let req = parse_ok("POST /x HTTP/1.1\r\ncontent-length: 3\r\n\r\nabc");
        assert_eq!(req.body, b"abc");
    }

    #[test]
    fn request_id_header_is_parsed() {
        let req = parse_ok("GET / HTTP/1.1\r\nx-request-id:  abc-123 \r\n\r\n");
        assert_eq!(req.request_id.as_deref(), Some("abc-123"));
        // Absent or empty → None (the server will generate one).
        assert_eq!(parse_ok("GET / HTTP/1.1\r\n\r\n").request_id, None);
        assert_eq!(
            parse_ok("GET / HTTP/1.1\r\nX-Request-Id:\r\n\r\n").request_id,
            None
        );
    }

    #[test]
    fn leading_crlf_skipped_rfc7230() {
        // One stray CRLF (the RFC 7230 §3.5 case) and a small run both
        // parse; an unbounded flood does not.
        let req = parse_ok("\r\nGET /healthz HTTP/1.1\r\n\r\n");
        assert_eq!(req.path, "/healthz");
        let req = parse_ok("\r\n\n\r\n\r\nGET /healthz HTTP/1.1\r\n\r\n");
        assert_eq!(req.path, "/healthz");
        assert_eq!(parse_bad("\r\n\r\n\r\n\r\n\r\nGET / HTTP/1.1\r\n\r\n"), 400);
    }

    #[test]
    fn clean_eof_is_closed_not_error() {
        // EOF before any bytes — and EOF after only stray blanks — is
        // the peer hanging up between keep-alive requests.
        assert!(matches!(parse_outcome(""), ReadOutcome::Closed));
        assert!(matches!(parse_outcome("\r\n"), ReadOutcome::Closed));
        assert!(matches!(parse_outcome("\r\n\r\n"), ReadOutcome::Closed));
    }

    #[test]
    fn two_pipelined_requests_on_one_stream() {
        // The caller-owned BufRead carries the second request's bytes
        // across the first parse — the keep-alive contract.
        let raw = "POST /a HTTP/1.1\r\nContent-Length: 3\r\n\r\nabc\
                   GET /b HTTP/1.1\r\n\r\n";
        let mut cursor = raw.as_bytes();
        let mut interim = Vec::new();
        let first = match read_request(&mut cursor, &mut interim).unwrap() {
            ReadOutcome::Request(r) => r,
            other => panic!("{other:?}"),
        };
        assert_eq!(first.path, "/a");
        assert_eq!(first.body, b"abc");
        let second = match read_request(&mut cursor, &mut interim).unwrap() {
            ReadOutcome::Request(r) => r,
            other => panic!("{other:?}"),
        };
        assert_eq!(second.path, "/b");
        assert!(second.body.is_empty());
        assert!(matches!(
            read_request(&mut cursor, &mut interim).unwrap(),
            ReadOutcome::Closed
        ));
    }

    #[test]
    fn connection_header_negotiation() {
        // HTTP/1.1: keep-alive unless told otherwise.
        assert!(parse_ok("GET / HTTP/1.1\r\n\r\n").keep_alive);
        assert!(!parse_ok("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").keep_alive);
        assert!(!parse_ok("GET / HTTP/1.1\r\nConnection: Close\r\n\r\n").keep_alive);
        // HTTP/1.0: close unless told otherwise.
        assert!(!parse_ok("GET / HTTP/1.0\r\n\r\n").keep_alive);
        assert!(parse_ok("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").keep_alive);
        // Both tokens: close wins.
        assert!(!parse_ok("GET / HTTP/1.1\r\nConnection: keep-alive, close\r\n\r\n").keep_alive);
    }

    #[test]
    fn duplicate_content_length_policy() {
        // Conflicting duplicates: the smuggling vector — rejected.
        assert_eq!(
            parse_bad("POST /x HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 6\r\n\r\nhello6"),
            400
        );
        // Identical duplicates: tolerated (proxy echo).
        let req = parse_ok("POST /x HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 3\r\n\r\nabc");
        assert_eq!(req.body, b"abc");
        // A list value never parses as one integer — rejected.
        assert_eq!(parse_bad("POST /x HTTP/1.1\r\nContent-Length: 3, 3\r\n\r\nabc"), 400);
    }

    #[test]
    fn transfer_encoding_rejected() {
        assert_eq!(
            parse_bad("POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"),
            400
        );
        // TE alongside CL is the classic TE/CL desync — also rejected.
        assert_eq!(
            parse_bad(
                "POST /x HTTP/1.1\r\nContent-Length: 3\r\nTransfer-Encoding: chunked\r\n\r\nabc"
            ),
            400
        );
    }

    #[test]
    fn expect_100_continue_gets_interim_response() {
        let raw = "POST /x HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 3\r\n\r\nabc";
        let mut cursor = raw.as_bytes();
        let mut interim = Vec::new();
        let req = match read_request(&mut cursor, &mut interim).unwrap() {
            ReadOutcome::Request(r) => r,
            other => panic!("{other:?}"),
        };
        assert_eq!(interim, b"HTTP/1.1 100 Continue\r\n\r\n");
        assert_eq!(req.body, b"abc");
        // No body → no interim (there is nothing to wait for).
        let mut cursor = "GET /x HTTP/1.1\r\nExpect: 100-continue\r\n\r\n".as_bytes();
        let mut interim = Vec::new();
        read_request(&mut cursor, &mut interim).unwrap();
        assert!(interim.is_empty());
        // An expectation we cannot meet is 417, per RFC 7231.
        assert_eq!(parse_bad("POST /x HTTP/1.1\r\nExpect: frobnicate\r\n\r\n"), 417);
    }

    #[test]
    fn rejects_bad_requests() {
        assert_eq!(parse_bad("GET\r\n\r\n"), 400, "missing target");
        assert_eq!(parse_bad("GET / SPDY/3\r\n\r\n"), 400, "bad version");
        assert_eq!(
            parse_bad("POST /x HTTP/1.1\r\nContent-Length: zap\r\n\r\n"),
            400,
            "unparseable length"
        );
        assert_eq!(
            parse_bad("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort"),
            400,
            "truncated body"
        );
        assert_eq!(
            parse_bad(&format!(
                "POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                MAX_BODY_BYTES + 1
            )),
            413,
            "oversized body"
        );
        // A request line that never terminates must be cut off at the
        // cap, not buffered without bound.
        let endless = "GET /".to_string() + &"a".repeat(80 << 10);
        assert_eq!(parse_bad(&endless), 400, "unterminated request line");
        // EOF mid-headers is malformed (a request started, then died).
        assert_eq!(parse_bad("GET / HTTP/1.1\r\nHost: x\r\n"), 400);
    }

    #[test]
    fn response_wire_format() {
        let mut out = Vec::new();
        let resp = Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]));
        write_response(&mut out, &resp, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Type: application/json\r\n"));
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"));
    }

    #[test]
    fn keep_alive_and_extra_headers_on_the_wire() {
        let mut out = Vec::new();
        write_response(&mut out, &Response::text(200, "hi"), true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
        let mut out = Vec::new();
        let resp = Response::json(429, &Json::obj(vec![("error", Json::str("busy"))]))
            .with_header("Retry-After", "1");
        write_response(&mut out, &resp, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
    }

    #[test]
    fn text_response_and_reasons() {
        let mut out = Vec::new();
        write_response(&mut out, &Response::text(404, "nope"), false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"));
        assert!(text.ends_with("nope"));
        assert_eq!(status_reason(100), "Continue");
        assert_eq!(status_reason(413), "Payload Too Large");
        assert_eq!(status_reason(417), "Expectation Failed");
        assert_eq!(status_reason(429), "Too Many Requests");
        assert_eq!(status_reason(500), "Internal Server Error");
        assert_eq!(status_reason(999), "Unknown");
    }
}
