//! Clustering-as-a-service: the `fkmpp serve` subsystem — a
//! zero-dependency HTTP/1.1 server exposing the paper's seeders as an
//! online service with a model registry, async fit jobs and batched
//! assignment.
//!
//! ## Routes
//!
//! | Route | What it does |
//! |---|---|
//! | `POST /fit` | enqueue a fit (inline `points` or a named `dataset`); returns a job id immediately |
//! | `GET /jobs/{id}` | job status; `model_id` once done |
//! | `GET /models` | list fitted models (metadata) |
//! | `GET /models/{id}` | one model, centers included |
//! | `POST /models/{id}/assign` | batched nearest-center assignment for `points` (JSON or `.fbin` binary body) |
//! | `POST /models/{id}/observe` | online ingest: mini-batch refresher + streaming-seeder drift signal; publishes a new model version every [`ServeConfig::observe_refresh_every`] points |
//! | `GET /healthz` | liveness + model/job counts |
//! | `GET /metrics` | request counters, latency histograms (p50/p90/p99), job/model gauges |
//! | `GET /metrics?format=prometheus` | the same, as Prometheus text exposition |
//! | `POST /shutdown` | graceful stop (drains fit workers) |
//!
//! ## Contracts
//!
//! * The server owns **no distance loops**: assignment goes through
//!   the kernel engine (via [`registry::assign`] /
//!   [`registry::AssignCoalescer`]) and fits through the
//!   seeders/[`crate::lloyd`], same as the CLI.
//! * Assign responses are a pure function of `(model, query points)`:
//!   the model pins its kernel at registration and concurrent-request
//!   coalescing cannot change result bits (see [`registry`]'s docs), so
//!   the JSON and binary routes answer bit-identically.
//! * [`json`] is the crate's **single serialization point** — every JSON
//!   byte in or out passes through it. The binary assign route reuses
//!   the [`crate::data::io`] `.fbin` codec for its request body and the
//!   documented `FKA1` frame (see [`encode_assign_frame`]) for its
//!   response.
//! * State across requests lives in [`registry::ModelRegistry`]
//!   (persisted under `{data_dir}/models/`) and [`jobs::JobQueue`].
//!
//! ## Connection lifecycle and admission control
//!
//! Connections are **kept alive**: each HTTP worker loops
//! `read → route → write` on one connection, honoring `Connection:`
//! headers, until the client closes, an idle deadline passes
//! ([`ServeConfig::keepalive_idle`]), or a per-connection request cap is
//! reached ([`ServeConfig::keepalive_max_requests`]). The accept queue
//! is **bounded** ([`ServeConfig::queue_depth`]): when it is full, new
//! connections are shed immediately with `429 Too Many Requests` +
//! `Retry-After` instead of queueing without bound; `POST /fit` sheds
//! the same way when the fit backlog is full. Threading mirrors
//! [`crate::parallel`]'s bounded-pool discipline: a fixed set of HTTP
//! workers drains the accept queue, a fixed set of fit workers drains
//! the job queue.

pub mod http;
pub mod jobs;
pub mod json;
pub mod loadgen;
pub mod online;
pub mod registry;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::data::registry::{DatasetId, Profile};
use crate::error::{Context, Result};
use crate::metrics::Metrics;
use crate::seeding::SeedingAlgorithm;
use self::http::{Request, Response};
use self::jobs::{FitSource, FitSpec, JobInfo, JobQueue, JobState};
use self::json::Json;
use self::registry::ModelRegistry;

/// Serving configuration (`fkmpp serve` flags land here).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub host: String,
    /// TCP port; 0 picks an ephemeral port (tests).
    pub port: u16,
    /// Dataset cache + model persistence root.
    pub data_dir: PathBuf,
    /// AOT artifacts directory (PJRT backend probe; falls back to native).
    pub artifacts_dir: PathBuf,
    /// HTTP worker threads (connection handling).
    pub http_workers: usize,
    /// Concurrent fit jobs.
    pub fit_workers: usize,
    /// Persist fitted models under `{data_dir}/models/`, reload on boot.
    pub persist: bool,
    /// Bounded accept queue depth: connections beyond it are shed with
    /// 429 + `Retry-After` instead of queueing without bound.
    pub queue_depth: usize,
    /// Bounded fit backlog: `POST /fit` sheds with 429 once this many
    /// jobs are pending.
    pub fit_queue_depth: usize,
    /// Idle deadline on a kept-alive connection: close it if no new
    /// request arrives within this window.
    pub keepalive_idle: Duration,
    /// Requests served on one connection before the server answers
    /// `Connection: close` — bounds how long a worker can be owned by a
    /// single client.
    pub keepalive_max_requests: usize,
    /// Observed points between online model refreshes: every time a
    /// model's observe stream crosses this many points, a new version
    /// is snapshotted and published (see [`online`]).
    pub observe_refresh_every: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            host: "127.0.0.1".to_string(),
            port: 8080,
            data_dir: PathBuf::from("data"),
            artifacts_dir: PathBuf::from("artifacts"),
            http_workers: 4,
            fit_workers: 1,
            persist: true,
            queue_depth: 128,
            fit_queue_depth: 64,
            keepalive_idle: Duration::from_secs(15),
            keepalive_max_requests: 1000,
            observe_refresh_every: online::DEFAULT_REFRESH_EVERY,
        }
    }
}

/// The per-connection knobs [`handle_connection`] enforces, copied out
/// of [`ServeConfig`] at bind time.
#[derive(Clone, Copy, Debug)]
struct ConnLimits {
    keepalive_idle: Duration,
    keepalive_max_requests: usize,
}

impl Default for ConnLimits {
    fn default() -> Self {
        let cfg = ServeConfig::default();
        ConnLimits {
            keepalive_idle: cfg.keepalive_idle,
            keepalive_max_requests: cfg.keepalive_max_requests,
        }
    }
}

/// Shared state every request handler sees.
pub struct ServerCtx {
    pub registry: Arc<ModelRegistry>,
    pub jobs: Arc<JobQueue>,
    pub metrics: Metrics,
    /// Per-model coalescing of concurrent assigns (see [`registry`]).
    coalescer: registry::AssignCoalescer,
    /// Per-model online ingest state (see [`online`]).
    online: online::OnlineManager,
    started: Instant,
    shutdown: AtomicBool,
    limits: ConnLimits,
}

impl ServerCtx {
    fn new(registry: Arc<ModelRegistry>, jobs: Arc<JobQueue>) -> ServerCtx {
        ServerCtx {
            registry,
            jobs,
            metrics: Metrics::new(),
            coalescer: registry::AssignCoalescer::default(),
            online: online::OnlineManager::new(online::DEFAULT_REFRESH_EVERY),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            limits: ConnLimits::default(),
        }
    }
}

/// A bound (but not yet running) server.
pub struct Server {
    listener: TcpListener,
    ctx: Arc<ServerCtx>,
    cfg: ServeConfig,
}

impl Server {
    /// Bind the listener and build the shared state (reloading persisted
    /// models). The server does not accept connections until [`run`].
    ///
    /// [`run`]: Server::run
    pub fn bind(cfg: &ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))
            .with_context(|| format!("bind {}:{}", cfg.host, cfg.port))?;
        let registry = Arc::new(ModelRegistry::new(if cfg.persist {
            Some(cfg.data_dir.clone())
        } else {
            None
        })?);
        let jobs = Arc::new(JobQueue::with_capacity(cfg.fit_queue_depth));
        let mut ctx = ServerCtx::new(registry, jobs);
        ctx.limits = ConnLimits {
            keepalive_idle: cfg.keepalive_idle,
            keepalive_max_requests: cfg.keepalive_max_requests.max(1),
        };
        ctx.online = online::OnlineManager::new(cfg.observe_refresh_every);
        Ok(Server {
            listener,
            ctx: Arc::new(ctx),
            cfg: cfg.clone(),
        })
    }

    /// The bound address (useful with `port: 0`).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// The model registry behind this server — lets drivers (tests, the
    /// loadgen) install a model without running a fit job.
    pub fn registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.ctx.registry)
    }

    /// Accept and serve until `POST /shutdown`. Blocks the calling
    /// thread; drains both worker pools before returning.
    pub fn run(&self) -> Result<()> {
        let addr = self.local_addr()?;
        let fit_handles = jobs::spawn_workers(
            &self.ctx.jobs,
            &self.ctx.registry,
            self.cfg.data_dir.clone(),
            self.cfg.artifacts_dir.clone(),
            self.cfg.fit_workers,
        );
        // Bounded HTTP pool: accept here, hand streams to workers over a
        // *bounded* channel (the sync_channel buffer is the admission
        // queue). `try_send` never blocks the accept loop: a full queue
        // sheds the connection with a 429 instead of building an
        // unbounded backlog of sockets that will all time out anyway.
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(self.cfg.queue_depth.max(1));
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut http_handles = Vec::new();
        for _ in 0..self.cfg.http_workers.max(1) {
            let conn_rx = Arc::clone(&conn_rx);
            let ctx = Arc::clone(&self.ctx);
            http_handles.push(std::thread::spawn(move || loop {
                let stream = match conn_rx.lock().unwrap().recv() {
                    Ok(s) => s,
                    Err(_) => break, // sender dropped: shutting down
                };
                handle_connection(stream, &ctx, addr);
            }));
        }
        for conn in self.listener.incoming() {
            if self.ctx.shutdown.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => match conn_tx.try_send(stream) {
                    Ok(()) => {}
                    Err(mpsc::TrySendError::Full(stream)) => shed_connection(stream, &self.ctx),
                    Err(mpsc::TrySendError::Disconnected(_)) => break,
                },
                Err(e) => crate::log::warn(
                    "serve.accept_error",
                    &[
                        ("addr", Json::str(addr.to_string())),
                        ("error", Json::str(format!("{e}"))),
                    ],
                ),
            }
        }
        drop(conn_tx);
        for h in http_handles {
            let _ = h.join();
        }
        self.ctx.jobs.stop();
        for h in fit_handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Shed a connection the accept queue has no room for: one short-fused
/// 429 + `Retry-After`, then close. Runs on the accept thread, so the
/// write timeout is tight — a peer that won't take the bytes loses them.
fn shed_connection(mut stream: TcpStream, ctx: &ServerCtx) {
    ctx.metrics.incr("http.conns", 1);
    ctx.metrics.incr("http.requests", 1);
    ctx.metrics.incr("http.errors", 1);
    ctx.metrics.incr("http.shed", 1);
    let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
    let resp = Response::json(429, &error_json("server at capacity, retry shortly"))
        .with_header("Retry-After", "1");
    let _ = http::write_response(&mut stream, &resp, false);
}

/// One connection, many requests: loop `read → route → write` until the
/// client closes, asks for `Connection: close`, goes idle past the
/// deadline, hits the per-connection request cap, or the server shuts
/// down.
///
/// The buffered reader is created **once** per connection and fed to
/// every [`http::read_request`] call — bytes of a pipelined next request
/// that were slurped into its buffer survive to the next loop
/// iteration. The idle deadline rides the socket read timeout, which is
/// per-`read`-syscall (the strongest guarantee `std::net` offers
/// without a poll loop); a deliberately byte-trickling client can still
/// hold a worker for longer, which is an accepted limitation of this
/// std-only layer — front with a real proxy for hostile networks.
fn handle_connection(mut stream: TcpStream, ctx: &ServerCtx, addr: SocketAddr) {
    ctx.metrics.incr("http.conns", 1);
    let _ = stream.set_read_timeout(Some(ctx.limits.keepalive_idle));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
    let mut reader = match stream.try_clone() {
        Ok(clone) => std::io::BufReader::new(clone),
        Err(_) => return,
    };
    let mut served = 0usize;
    loop {
        // `read_request` writes at most an interim `100 Continue` to the
        // raw stream; responses go there too, after routing.
        let outcome = http::read_request(&mut reader, &mut stream);
        let t0 = Instant::now();
        let mut req = match outcome {
            Ok(http::ReadOutcome::Request(req)) => req,
            // Peer hung up between requests: the clean end of a
            // kept-alive connection, nothing to count or answer.
            Ok(http::ReadOutcome::Closed) => break,
            Ok(http::ReadOutcome::Malformed { status, reason }) => {
                // Framing can't be trusted past a malformed request:
                // answer (so the client learns why) and close. Even a
                // request too broken to parse gets a request id, so the
                // flight recorder entry and the response correlate.
                ctx.metrics.incr("http.requests", 1);
                ctx.metrics.incr("http.errors", 1);
                let rid = next_request_id();
                crate::log::warn(
                    "http.malformed",
                    &[
                        ("status", Json::num(status as f64)),
                        ("reason", Json::str(reason.clone())),
                        ("request_id", Json::str(rid.clone())),
                    ],
                );
                let resp = Response::json(status, &error_json(&reason))
                    .with_header("X-Request-Id", rid);
                let _ = http::write_response(&mut stream, &resp, false);
                break;
            }
            // Transport error — most commonly the idle deadline firing
            // between requests. Nobody is listening; close silently.
            Err(_) => break,
        };
        served += 1;
        ctx.metrics.incr("http.requests", 1);
        // The request-id contract: take the client's `X-Request-Id` or
        // generate one, tag the span (and, via `FitSpec`, any fit job it
        // enqueues) with it, and echo it on the response.
        let rid = match &req.request_id {
            Some(id) => id.clone(),
            None => {
                let id = next_request_id();
                req.request_id = Some(id.clone());
                id
            }
        };
        let mut span = crate::trace::Span::enter("http.request");
        span.arg("method", req.method.clone());
        span.arg("path", req.path.clone());
        span.arg("request_id", rid.clone());
        let resp = route(&req, ctx).with_header("X-Request-Id", rid);
        span.arg("status", resp.status as u64);
        if resp.status >= 400 {
            ctx.metrics.incr("http.errors", 1);
        }
        // Keep the connection iff the client allows it, the cap has room
        // and the server isn't shutting down — and tell the client which
        // it is in the response's `Connection:` header.
        let keep = req.keep_alive
            && served < ctx.limits.keepalive_max_requests
            && !ctx.shutdown.load(Ordering::SeqCst);
        let write_ok = http::write_response(&mut stream, &resp, keep).is_ok();
        drop(span);
        ctx.metrics.record_latency("http.latency_secs", t0.elapsed());
        if !keep || !write_ok {
            break;
        }
    }
    // The shutdown route sets the flag (single source of truth); nudge
    // the blocking accept loop so it observes it. Target loopback — the
    // listener may be bound to a wildcard address connect() can't reach
    // on every platform.
    if ctx.shutdown.load(Ordering::SeqCst) {
        let mut nudge = addr;
        if nudge.ip().is_unspecified() {
            nudge.set_ip(match nudge.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(nudge);
    }
}

fn error_json(msg: &str) -> Json {
    Json::obj(vec![("error", Json::str(msg))])
}

/// Server-generated request ids: a process-unique counter, not a UUID.
/// Ids only correlate logs, spans and jobs — they never feed
/// computation, so a deterministic counter is exactly enough.
fn next_request_id() -> String {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    format!("req-{}", NEXT.fetch_add(1, Ordering::Relaxed))
}

/// Handler outcome: a response, or `(status, message)` for the error path.
type RouteResult = std::result::Result<Response, (u16, String)>;

/// Map a crate error onto a client error.
fn bad(e: crate::error::Error) -> (u16, String) {
    (400, format!("{e:#}"))
}

/// Dispatch a parsed request to its handler.
fn route(req: &Request, ctx: &ServerCtx) -> Response {
    let segments: Vec<&str> = req.path.split('/').filter(|s| !s.is_empty()).collect();
    let result: RouteResult = match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => Ok(handle_healthz(ctx)),
        ("GET", ["metrics"]) => Ok(handle_metrics(req, ctx)),
        ("POST", ["fit"]) => handle_fit(req, ctx),
        ("GET", ["jobs", id]) => handle_job(id, ctx),
        ("GET", ["models"]) => Ok(handle_models(ctx)),
        ("GET", ["models", id]) => handle_model(id, ctx),
        ("POST", ["models", id, "assign"]) => handle_assign(id, req, ctx),
        ("POST", ["models", id, "observe"]) => handle_observe(id, req, ctx),
        ("GET", ["debug", "log"]) => Ok(handle_debug_log()),
        ("POST", ["shutdown"]) => Ok(handle_shutdown(ctx)),
        // Wrong method on a known path reads better as 405 than 404.
        (_, ["healthz" | "metrics" | "models" | "fit" | "shutdown" | "debug", ..])
        | (_, ["jobs", ..]) => {
            Err((405, format!("method {} not allowed on {}", req.method, req.path)))
        }
        _ => Err((404, format!("no route for {} {}", req.method, req.path))),
    };
    match result {
        Ok(resp) => resp,
        Err((status, msg)) => Response::json(status, &error_json(&msg)),
    }
}

/// `POST /shutdown`: flag the server to stop. The flag is set here — in
/// the same route arm that produces the 200 — so response and action can
/// never disagree about what counts as the shutdown path.
fn handle_shutdown(ctx: &ServerCtx) -> Response {
    ctx.shutdown.store(true, Ordering::SeqCst);
    Response::json(
        200,
        &Json::obj(vec![("status", Json::str("shutting down"))]),
    )
}

/// `GET /debug/log`: the flight recorder, live. Entries are the ring's
/// rendered JSON lines re-parsed into a JSON array (through [`json`],
/// keeping the single-serialization-point contract); a line that fails
/// to re-parse is dropped rather than corrupting the document.
fn handle_debug_log() -> Response {
    let entries: Vec<Json> = crate::log::flight_recorder_snapshot()
        .iter()
        .filter_map(|line| json::parse(line).ok())
        .collect();
    Response::json(
        200,
        &Json::obj(vec![
            ("count", Json::num(entries.len() as f64)),
            ("capacity", Json::num(crate::log::RING_CAPACITY as f64)),
            ("entries", Json::Arr(entries)),
        ]),
    )
}

fn handle_healthz(ctx: &ServerCtx) -> Response {
    let (queued, running, _, _) = ctx.jobs.counts();
    Response::json(
        200,
        &Json::obj(vec![
            ("status", Json::str("ok")),
            ("uptime_secs", Json::num(ctx.started.elapsed().as_secs_f64())),
            ("models", Json::num(ctx.registry.len() as f64)),
            ("jobs_pending", Json::num((queued + running) as f64)),
        ]),
    )
}

fn handle_metrics(req: &Request, ctx: &ServerCtx) -> Response {
    // `?format=prometheus` selects the text exposition; anything else
    // (including no query) keeps the original JSON document.
    if req.query.split('&').any(|kv| kv == "format=prometheus") {
        return prometheus_metrics(ctx);
    }
    let (queued, running, done, failed) = ctx.jobs.counts();
    // Request-scoped counters live on the server context; engine-level
    // counters (the shard seeding rounds, `shard.*`) accumulate in the
    // process-wide sink because fits run deep inside workers with no
    // context handle. `/metrics` surfaces both, merged name-ordered (the
    // namespaces are disjoint: `http.`/`fit.`/`assign.` vs `shard.`).
    // Latency histograms join the `timings` object under their own
    // names — `histogram_json` keeps the `count`/`mean`/`min`/`max`
    // keys of `stats_json` and adds p50/p90/p99.
    let global = crate::metrics::global();
    let counters: std::collections::BTreeMap<String, Json> = ctx
        .metrics
        .counters_snapshot()
        .into_iter()
        .chain(global.counters_snapshot())
        .map(|(name, v)| (name.to_string(), Json::num(v as f64)))
        .collect();
    let counters = Json::Obj(counters.into_iter().collect());
    let timings: std::collections::BTreeMap<String, Json> = ctx
        .metrics
        .timings_snapshot()
        .into_iter()
        .chain(global.timings_snapshot())
        .map(|(name, stats)| (name.to_string(), json::stats_json(&stats)))
        .chain(
            ctx.metrics
                .histograms_snapshot()
                .into_iter()
                .chain(global.histograms_snapshot())
                .map(|(name, h)| (name.to_string(), json::histogram_json(&h))),
        )
        .collect();
    let timings = Json::Obj(timings.into_iter().collect());
    Response::json(
        200,
        &Json::obj(vec![
            ("uptime_secs", Json::num(ctx.started.elapsed().as_secs_f64())),
            ("models", Json::num(ctx.registry.len() as f64)),
            (
                "jobs",
                Json::obj(vec![
                    ("queued", Json::num(queued as f64)),
                    ("running", Json::num(running as f64)),
                    ("done", Json::num(done as f64)),
                    ("failed", Json::num(failed as f64)),
                ]),
            ),
            ("counters", counters),
            ("timings", timings),
        ]),
    )
}

/// The Prometheus text-exposition (v0.0.4) rendering of the same
/// merged context + process-global metric state as the JSON document.
fn prometheus_metrics(ctx: &ServerCtx) -> Response {
    let (queued, running, done, failed) = ctx.jobs.counts();
    let gauges = vec![
        (
            "uptime_seconds".to_string(),
            ctx.started.elapsed().as_secs_f64(),
        ),
        ("models".to_string(), ctx.registry.len() as f64),
        ("jobs_queued".to_string(), queued as f64),
        ("jobs_running".to_string(), running as f64),
        ("jobs_done".to_string(), done as f64),
        ("jobs_failed".to_string(), failed as f64),
    ];
    let global = crate::metrics::global();
    let counters: Vec<_> = ctx
        .metrics
        .counters_snapshot()
        .into_iter()
        .chain(global.counters_snapshot())
        .collect();
    let timings: Vec<_> = ctx
        .metrics
        .timings_snapshot()
        .into_iter()
        .chain(global.timings_snapshot())
        .collect();
    let histograms: Vec<_> = ctx
        .metrics
        .histograms_snapshot()
        .into_iter()
        .chain(global.histograms_snapshot())
        .collect();
    let body = crate::metrics::render_prometheus(&gauges, &counters, &timings, &histograms);
    Response {
        status: 200,
        content_type: "text/plain; version=0.0.4; charset=utf-8",
        body: body.into_bytes(),
        headers: Vec::new(),
    }
}

/// `POST /fit` body:
/// `{"points": [[..],..] | "dataset": "kdd_sim", "profile": "smoke",
///   "algo": "rejection", "k": 10, "seed": 42, "lloyd": 0}`.
/// With `"algo"/"algorithm": "kmeans_par"` the sharded seeder runs;
/// optional `"shards"`, `"rounds"` and `"oversample"` override its
/// defaults. For the rejection family, optional `"oracle"`
/// (`exact|lsh|lsh-rigorous`), `"c"`, `"lsh_tables"`, `"lsh_m"` and
/// `"lsh_probe_limit"` steer the ANN oracle behind the acceptance test
/// (`rejection-exact`/`rejection-rigorous` still pin their oracle).
fn handle_fit(req: &Request, ctx: &ServerCtx) -> RouteResult {
    let body = req.body_str().map_err(bad)?;
    let v = json::parse(body).map_err(bad)?;
    let algo_name = v
        .get("algo")
        .or_else(|| v.get("algorithm"))
        .and_then(Json::as_str)
        .unwrap_or("rejection");
    let algorithm = SeedingAlgorithm::parse(algo_name).map_err(bad)?;
    let k = match v.get("k").and_then(Json::as_usize) {
        Some(k) if k > 0 => k,
        _ => return Err((400, "missing or invalid \"k\"".to_string())),
    };
    let seed = v.get("seed").and_then(Json::as_u64).unwrap_or(42);
    let lloyd_iters = v.get("lloyd").and_then(Json::as_usize).unwrap_or(0);
    let mut kmeanspar = crate::shard::kmeanspar::KMeansParConfig::default();
    if let Some(s) = v.get("shards").and_then(Json::as_usize) {
        kmeanspar.shards = s;
    }
    if let Some(r) = v.get("rounds").and_then(Json::as_usize) {
        kmeanspar.rounds = r;
    }
    if let Some(l) = v.get("oversample").and_then(Json::as_f64) {
        kmeanspar.oversample = l;
    }
    if kmeanspar.shards == 0 || kmeanspar.rounds == 0 || !(kmeanspar.oversample > 0.0) {
        return Err((
            400,
            "\"shards\"/\"rounds\" must be >= 1 and \"oversample\" > 0".to_string(),
        ));
    }
    let mut rejection = crate::seeding::rejection::RejectionConfig::default();
    if let Some(o) = v.get("oracle").and_then(Json::as_str) {
        rejection.oracle = crate::seeding::rejection::OracleKind::parse(o).map_err(bad)?;
    }
    if let Some(c) = v.get("c").and_then(Json::as_f64) {
        rejection.c = c as f32;
    }
    if let Some(t) = v.get("lsh_tables").and_then(Json::as_usize) {
        rejection.lsh.tables = t;
    }
    if let Some(m) = v.get("lsh_m").and_then(Json::as_usize) {
        rejection.lsh.m = m;
    }
    if let Some(p) = v.get("lsh_probe_limit").and_then(Json::as_usize) {
        rejection.lsh.probe_limit = p;
    }
    // Same bound check as the CLI (`RejectionConfig::validate`), mapped
    // onto a client error.
    rejection.validate().map_err(bad)?;
    let source = if let Some(pts) = v.get("points") {
        FitSource::Inline(Arc::new(json::points_from_json(pts).map_err(bad)?))
    } else if let Some(name) = v.get("dataset").and_then(Json::as_str) {
        let id = DatasetId::parse(name).map_err(bad)?;
        let profile = match v.get("profile").and_then(Json::as_str) {
            Some(p) => Profile::parse(p).map_err(bad)?,
            None => Profile::Smoke,
        };
        FitSource::Dataset { id, profile }
    } else {
        return Err((400, "body needs either \"points\" or \"dataset\"".to_string()));
    };
    let Some(job_id) = ctx.jobs.submit(FitSpec {
        source,
        algorithm,
        k,
        seed,
        lloyd_iters,
        kmeanspar,
        rejection,
        request_id: req.request_id.clone(),
    }) else {
        // Fit backlog full: shed with the same contract as the accept
        // queue — 429 + Retry-After, never an unbounded queue.
        ctx.metrics.incr("fit.shed", 1);
        return Ok(
            Response::json(429, &error_json("fit queue at capacity, retry shortly"))
                .with_header("Retry-After", "1"),
        );
    };
    ctx.metrics.incr("fit.submitted", 1);
    Ok(Response::json(
        202,
        &Json::obj(vec![
            ("job_id", Json::str(job_id.clone())),
            ("status_url", Json::str(format!("/jobs/{job_id}"))),
        ]),
    ))
}

fn job_json(info: &JobInfo) -> Json {
    let mut fields = vec![
        ("id", Json::str(info.id.clone())),
        ("state", Json::str(info.state.name())),
        ("algorithm", Json::str(info.algorithm.name())),
        ("k", Json::num(info.k as f64)),
        ("source", Json::str(info.source.clone())),
    ];
    if let Some(secs) = info.secs {
        fields.push(("secs", Json::num(secs)));
    }
    match &info.state {
        JobState::Done { model_id } => {
            fields.push(("model_id", Json::str(model_id.clone())));
            fields.push(("model_url", Json::str(format!("/models/{model_id}"))));
        }
        JobState::Failed { error } => fields.push(("error", Json::str(error.clone()))),
        _ => {}
    }
    Json::obj(fields)
}

fn handle_job(id: &str, ctx: &ServerCtx) -> RouteResult {
    let info = ctx
        .jobs
        .get(id)
        .ok_or_else(|| (404, format!("unknown job {id:?}")))?;
    Ok(Response::json(200, &job_json(&info)))
}

fn handle_models(ctx: &ServerCtx) -> Response {
    let models = ctx.registry.list();
    Response::json(
        200,
        &Json::obj(vec![
            ("count", Json::num(models.len() as f64)),
            (
                "models",
                Json::Arr(models.iter().map(|m| m.meta.to_json()).collect()),
            ),
        ]),
    )
}

fn handle_model(id: &str, ctx: &ServerCtx) -> RouteResult {
    let model = ctx
        .registry
        .get(id)
        .ok_or_else(|| (404, format!("unknown model {id:?}")))?;
    Ok(Response::json(200, &model.full_json()))
}

/// Magic prefix of the binary assign response frame.
pub const ASSIGN_FRAME_MAGIC: &[u8; 4] = b"FKA1";

/// Encode the binary assign response frame:
///
/// | offset | bytes | field |
/// |---|---|---|
/// | 0 | 4 | magic `"FKA1"` |
/// | 4 | 4 | `n` (u32 LE) |
/// | 8 | 4·n | labels (u32 LE each) |
/// | 8+4n | 4·n | squared distances (f32 LE each) |
///
/// The floats are the kernel's bits verbatim — the frame round-trips
/// bit-exactly, like the JSON route's shortest-round-trip emission.
pub fn encode_assign_frame(labels: &[u32], d2s: &[f32]) -> Vec<u8> {
    assert_eq!(labels.len(), d2s.len());
    let mut out = Vec::with_capacity(8 + labels.len() * 8);
    out.extend_from_slice(ASSIGN_FRAME_MAGIC);
    out.extend_from_slice(&(labels.len() as u32).to_le_bytes());
    for &j in labels {
        out.extend_from_slice(&j.to_le_bytes());
    }
    for &d in d2s {
        out.extend_from_slice(&d.to_le_bytes());
    }
    out
}

/// Decode an [`encode_assign_frame`] body (clients: the loadgen, tests).
/// Trailing bytes are rejected — a frame is a complete message.
pub fn decode_assign_frame(bytes: &[u8]) -> Result<(Vec<u32>, Vec<f32>)> {
    if bytes.len() < 8 || &bytes[0..4] != ASSIGN_FRAME_MAGIC {
        crate::bail!("not an FKA1 assign frame");
    }
    let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let want = n
        .checked_mul(8)
        .and_then(|b| b.checked_add(8))
        .context("assign frame length overflow")?;
    if bytes.len() != want {
        crate::bail!("assign frame is {} bytes, n={n} implies {want}", bytes.len());
    }
    let labels = bytes[8..8 + 4 * n]
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    let d2s = bytes[8 + 4 * n..]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok((labels, d2s))
}

/// `POST /models/{id}/assign`. Two bodies, one kernel path:
///
/// * JSON (default): `{"points": [[..], ..]}` → JSON `labels`/`d2`;
/// * `Content-Type: application/octet-stream`: an `.fbin` body
///   (`u32 n, u32 d, n·d f32`, little-endian — the [`crate::data::io`]
///   layout) → the binary `FKA1` frame ([`encode_assign_frame`]).
///
/// Both routes run the same pinned-kernel sweep through the per-model
/// coalescer, so their results are bitwise identical for the same query
/// points.
fn handle_assign(id: &str, req: &Request, ctx: &ServerCtx) -> RouteResult {
    let model = ctx
        .registry
        .get(id)
        .ok_or_else(|| (404, format!("unknown model {id:?}")))?;
    let binary = req.content_type.starts_with("application/octet-stream");
    let points = if binary {
        crate::data::io::decode_fbin(&req.body).map_err(bad)?
    } else {
        let body = req.body_str().map_err(bad)?;
        let v = json::parse(body).map_err(bad)?;
        let pts = v
            .get("points")
            .ok_or_else(|| (400, "missing \"points\"".to_string()))?;
        json::points_from_json(pts).map_err(bad)?
    };
    let n = points.len();
    let timer = ctx.metrics.latency_timer("assign.latency_secs");
    let (labels, d2s) = ctx.coalescer.assign(&model, points).map_err(bad)?;
    timer.stop();
    ctx.metrics.incr("assign.requests", 1);
    ctx.metrics.incr("assign.points", n as u64);
    if binary {
        return Ok(Response::binary(200, encode_assign_frame(&labels, &d2s)));
    }
    Ok(Response::json(
        200,
        &Json::obj(vec![
            ("model_id", Json::str(model.meta.id.clone())),
            ("n", Json::num(n as f64)),
            (
                "labels",
                Json::Arr(labels.iter().map(|&j| Json::num(j as f64)).collect()),
            ),
            (
                "d2",
                Json::Arr(d2s.iter().map(|&d| Json::num(d as f64)).collect()),
            ),
        ]),
    ))
}

/// `POST /models/{id}/observe`: online ingest. Same two bodies as
/// assign (JSON `{"points": [[..], ..]}` or an `.fbin` binary body);
/// always answers JSON. Points flow into the model's mini-batch
/// refresher and streaming-seeder drift detector ([`online`]); when the
/// stream crosses the refresh cadence a new model version is built
/// off-thread and published atomically — `version` in the response (and
/// in `GET /models/{id}`) is the currently *published* version, while
/// `queued_version` reports the refresh this call triggered, if any.
fn handle_observe(id: &str, req: &Request, ctx: &ServerCtx) -> RouteResult {
    let model = ctx
        .registry
        .get(id)
        .ok_or_else(|| (404, format!("unknown model {id:?}")))?;
    let points = if req.content_type.starts_with("application/octet-stream") {
        crate::data::io::decode_fbin(&req.body).map_err(bad)?
    } else {
        let body = req.body_str().map_err(bad)?;
        let v = json::parse(body).map_err(bad)?;
        let pts = v
            .get("points")
            .ok_or_else(|| (400, "missing \"points\"".to_string()))?;
        json::points_from_json(pts).map_err(bad)?
    };
    let timer = ctx.metrics.latency_timer("observe.latency_secs");
    let outcome = ctx
        .online
        .observe(&ctx.registry, &model, &points)
        .map_err(bad)?;
    timer.stop();
    ctx.metrics.incr("observe.requests", 1);
    ctx.metrics.incr("observe.points", outcome.ingested as u64);
    // The published version may already have advanced past the model
    // Arc this handler captured — report what a client would now see.
    let published = ctx
        .registry
        .get(id)
        .map(|m| m.meta.version)
        .unwrap_or(model.meta.version);
    let mut fields = vec![
        ("model_id", Json::str(model.meta.id.clone())),
        ("ingested", Json::num(outcome.ingested as f64)),
        ("total_observed", Json::num(outcome.total_observed as f64)),
        ("novel", Json::num(outcome.novel as f64)),
        ("version", Json::num(published as f64)),
    ];
    if let Some(v) = outcome.queued_version {
        fields.push(("queued_version", Json::num(v as f64)));
    }
    Ok(Response::json(200, &Json::obj(fields)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::matrix::PointSet;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn test_ctx() -> ServerCtx {
        ServerCtx::new(
            Arc::new(ModelRegistry::new(None).unwrap()),
            Arc::new(JobQueue::new()),
        )
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".to_string(),
            path: path.to_string(),
            query: String::new(),
            content_type: String::new(),
            keep_alive: true,
            request_id: None,
            body: Vec::new(),
        }
    }

    fn post(path: &str, body: &str) -> Request {
        Request {
            method: "POST".to_string(),
            path: path.to_string(),
            query: String::new(),
            content_type: "application/json".to_string(),
            keep_alive: true,
            request_id: None,
            body: body.as_bytes().to_vec(),
        }
    }

    fn post_binary(path: &str, body: Vec<u8>) -> Request {
        Request {
            method: "POST".to_string(),
            path: path.to_string(),
            query: String::new(),
            content_type: "application/octet-stream".to_string(),
            keep_alive: true,
            request_id: None,
            body,
        }
    }

    fn body_json(resp: &Response) -> Json {
        json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
    }

    #[test]
    fn healthz_and_metrics_routes() {
        let ctx = test_ctx();
        let resp = route(&get("/healthz"), &ctx);
        assert_eq!(resp.status, 200);
        let v = body_json(&resp);
        assert_eq!(v.get("status").and_then(Json::as_str), Some("ok"));
        assert_eq!(v.get("models").and_then(Json::as_usize), Some(0));

        ctx.metrics.incr("http.requests", 3);
        let resp = route(&get("/metrics"), &ctx);
        assert_eq!(resp.status, 200);
        let v = body_json(&resp);
        assert_eq!(
            v.get("counters")
                .and_then(|c| c.get("http.requests"))
                .and_then(Json::as_usize),
            Some(3)
        );
        assert!(v.get("jobs").is_some());
    }

    #[test]
    fn unknown_routes_and_methods() {
        let ctx = test_ctx();
        assert_eq!(route(&get("/nope"), &ctx).status, 404);
        assert_eq!(route(&get("/jobs/job-1"), &ctx).status, 404);
        assert_eq!(route(&get("/models/m-1"), &ctx).status, 404);
        assert_eq!(route(&post("/healthz", ""), &ctx).status, 405);
        assert_eq!(route(&get("/fit"), &ctx).status, 405);
        assert_eq!(route(&get("/shutdown"), &ctx).status, 405);
        assert_eq!(route(&post("/debug/log", ""), &ctx).status, 405);
    }

    #[test]
    fn debug_log_route_serves_flight_recorder() {
        let ctx = test_ctx();
        crate::log::set_level(crate::log::Level::Off); // ring still records
        crate::log::warn("servetest.debug_log", &[("n", Json::num(1.0))]);
        let resp = route(&get("/debug/log"), &ctx);
        assert_eq!(resp.status, 200);
        let v = body_json(&resp);
        assert!(v.get("count").and_then(Json::as_usize).unwrap_or(0) >= 1);
        let entries = v.get("entries").and_then(Json::as_array).unwrap();
        // The ring is process-global: filter on this test's own event.
        assert!(
            entries
                .iter()
                .any(|e| e.get("event").and_then(Json::as_str) == Some("servetest.debug_log")),
            "{v:?}"
        );
    }

    #[test]
    fn fit_validation() {
        let ctx = test_ctx();
        // Not JSON.
        assert_eq!(route(&post("/fit", "not json"), &ctx).status, 400);
        // Missing k.
        assert_eq!(
            route(&post("/fit", r#"{"points": [[1,2]]}"#), &ctx).status,
            400
        );
        // Neither points nor dataset.
        assert_eq!(route(&post("/fit", r#"{"k": 3}"#), &ctx).status, 400);
        // Unknown algorithm / dataset / profile.
        assert_eq!(
            route(&post("/fit", r#"{"points": [[1,2]], "k": 1, "algo": "zap"}"#), &ctx).status,
            400
        );
        assert_eq!(
            route(&post("/fit", r#"{"dataset": "zap", "k": 1}"#), &ctx).status,
            400
        );
        assert_eq!(
            route(
                &post("/fit", r#"{"dataset": "kdd_sim", "profile": "zap", "k": 1}"#),
                &ctx
            )
            .status,
            400
        );
        // Valid submissions enqueue (no workers in this test: stays queued).
        let resp = route(
            &post("/fit", r#"{"points": [[1,2],[3,4],[5,6]], "k": 2, "algo": "uniform"}"#),
            &ctx,
        );
        assert_eq!(resp.status, 202);
        let job_id = body_json(&resp)
            .get("job_id")
            .and_then(Json::as_str)
            .unwrap()
            .to_string();
        let resp = route(&get(&format!("/jobs/{job_id}")), &ctx);
        assert_eq!(resp.status, 200);
        assert_eq!(
            body_json(&resp).get("state").and_then(Json::as_str),
            Some("queued")
        );
    }

    #[test]
    fn fit_sheds_429_when_backlog_full() {
        // A bounded fit queue with no workers: the first submit fills
        // it, the second is shed with 429 + Retry-After.
        let ctx = ServerCtx::new(
            Arc::new(ModelRegistry::new(None).unwrap()),
            Arc::new(JobQueue::with_capacity(1)),
        );
        let body = r#"{"points": [[1,2],[3,4],[5,6]], "k": 2, "algo": "uniform"}"#;
        assert_eq!(route(&post("/fit", body), &ctx).status, 202);
        let resp = route(&post("/fit", body), &ctx);
        assert_eq!(resp.status, 429);
        assert!(
            resp.headers.iter().any(|(name, _)| *name == "Retry-After"),
            "{:?}",
            resp.headers
        );
    }

    #[test]
    fn fit_kmeans_par_accepts_shard_knobs() {
        let ctx = test_ctx();
        // The serve-layer spelling plus explicit shard knobs enqueues.
        let resp = route(
            &post(
                "/fit",
                r#"{"points": [[1,2],[3,4],[5,6]], "k": 2, "algorithm": "kmeans_par",
                    "shards": 2, "rounds": 3, "oversample": 1.5}"#,
            ),
            &ctx,
        );
        assert_eq!(resp.status, 202);
        // Degenerate knobs are rejected at the HTTP layer.
        for body in [
            r#"{"points": [[1,2]], "k": 1, "algo": "kmeans-par", "shards": 0}"#,
            r#"{"points": [[1,2]], "k": 1, "algo": "kmeans-par", "rounds": 0}"#,
            r#"{"points": [[1,2]], "k": 1, "algo": "kmeans-par", "oversample": 0}"#,
        ] {
            assert_eq!(route(&post("/fit", body), &ctx).status, 400, "{body}");
        }
    }

    #[test]
    fn fit_rejection_accepts_oracle_knobs() {
        let ctx = test_ctx();
        // Oracle-explicit rejection fits enqueue (no workers: stay queued).
        for body in [
            r#"{"points": [[1,2],[3,4],[5,6]], "k": 2, "algo": "rejection", "oracle": "lsh"}"#,
            r#"{"points": [[1,2],[3,4],[5,6]], "k": 2, "algo": "rejection",
                "oracle": "lsh-rigorous", "c": 2.0, "lsh_tables": 4, "lsh_m": 8,
                "lsh_probe_limit": 12}"#,
            r#"{"points": [[1,2],[3,4],[5,6]], "k": 2, "algo": "rejection-rigorous"}"#,
            r#"{"points": [[1,2],[3,4],[5,6]], "k": 2, "algo": "rejection", "oracle": "exact"}"#,
        ] {
            assert_eq!(route(&post("/fit", body), &ctx).status, 202, "{body}");
        }
        // Degenerate knobs are rejected at the HTTP layer.
        for body in [
            r#"{"points": [[1,2]], "k": 1, "algo": "rejection", "oracle": "bogus"}"#,
            r#"{"points": [[1,2]], "k": 1, "algo": "rejection", "c": 0.5}"#,
            r#"{"points": [[1,2]], "k": 1, "algo": "rejection", "lsh_tables": 0}"#,
            r#"{"points": [[1,2]], "k": 1, "algo": "rejection", "lsh_m": 0}"#,
            r#"{"points": [[1,2]], "k": 1, "algo": "rejection", "lsh_probe_limit": 0}"#,
        ] {
            assert_eq!(route(&post("/fit", body), &ctx).status, 400, "{body}");
        }
    }

    #[test]
    fn metrics_include_global_shard_counters() {
        let ctx = test_ctx();
        // Drive the sharded engine directly; its counters land in the
        // process-wide sink and must surface through /metrics. The sink
        // is shared with every other test in this process, so assert on
        // the delta across this run, never on absolute values.
        let before = crate::metrics::CounterSnapshot::of(crate::metrics::global());
        let ps = gaussian_mixture(
            &SynthSpec {
                n: 200,
                d: 4,
                k_true: 3,
                ..Default::default()
            },
            8,
        );
        let mut rng = crate::rng::Pcg64::seed_from(1);
        crate::shard::kmeanspar::kmeans_par(&ps, 5, &Default::default(), &mut rng);
        assert!(
            before.delta(crate::metrics::global(), "shard.rounds") >= 1,
            "kmeans_par did not bump shard.rounds"
        );
        let resp = route(&get("/metrics"), &ctx);
        assert_eq!(resp.status, 200);
        let v = body_json(&resp);
        let rounds = v
            .get("counters")
            .and_then(|c| c.get("shard.rounds"))
            .and_then(Json::as_usize)
            .unwrap_or(0);
        assert!(rounds >= 1, "{v:?}");
        assert!(
            v.get("timings").and_then(|t| t.get("shard.round_secs")).is_some(),
            "{v:?}"
        );
    }

    #[test]
    fn metrics_prometheus_format() {
        let ctx = test_ctx();
        ctx.metrics.incr("http.requests", 2);
        ctx.metrics
            .record_latency("http.latency_secs", Duration::from_millis(3));
        ctx.metrics
            .record_latency("http.latency_secs", Duration::from_millis(9));
        let req = Request {
            method: "GET".to_string(),
            path: "/metrics".to_string(),
            query: "format=prometheus".to_string(),
            content_type: String::new(),
            keep_alive: true,
            request_id: None,
            body: Vec::new(),
        };
        let resp = route(&req, &ctx);
        assert_eq!(resp.status, 200);
        assert!(resp.content_type.starts_with("text/plain"));
        let body = std::str::from_utf8(&resp.body).unwrap();
        assert!(body.contains("# TYPE fkmpp_uptime_seconds gauge\n"), "{body}");
        assert!(body.contains("fkmpp_http_requests_total"), "{body}");
        assert!(
            body.contains("# TYPE fkmpp_http_latency_secs histogram\n"),
            "{body}"
        );
        assert!(
            body.contains("fkmpp_http_latency_secs_bucket{le=\"+Inf\"}"),
            "{body}"
        );
        assert!(body.contains("fkmpp_http_latency_secs_count"), "{body}");
        // The JSON document still answers when the query asks for
        // anything else, and it carries the histogram quantiles.
        let resp = route(&get("/metrics"), &ctx);
        assert_eq!(resp.content_type, "application/json");
        let v = body_json(&resp);
        let lat = v.get("timings").and_then(|t| t.get("http.latency_secs"));
        let lat = lat.expect("http.latency_secs in timings");
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(2));
        assert!(lat.get("p50").and_then(Json::as_f64).is_some(), "{v:?}");
        assert!(lat.get("p99").and_then(Json::as_f64).is_some(), "{v:?}");
        assert!(lat.get("mean").and_then(Json::as_f64).is_some(), "{v:?}");
    }

    #[test]
    fn assign_via_route_matches_kernel() {
        let ctx = test_ctx();
        let cs = gaussian_mixture(
            &SynthSpec {
                n: 4,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            5,
        );
        let meta = registry::ModelMeta {
            id: ctx.registry.fresh_id(),
            version: 1,
            algorithm: "uniform".to_string(),
            k: 4,
            dim: 3,
            source: "test".to_string(),
            seed: 0,
            seeding_secs: 0.0,
            lloyd_iters: 0,
            cost: 0.0,
        };
        ctx.registry.insert(meta, cs.clone()).unwrap();
        let queries = gaussian_mixture(
            &SynthSpec {
                n: 30,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            6,
        );
        let body = Json::obj(vec![("points", json::points_to_json(&queries))]).emit();
        let resp = route(&post("/models/m-1/assign", &body), &ctx);
        assert_eq!(resp.status, 200);
        let v = body_json(&resp);
        let labels: Vec<u32> = v
            .get("labels")
            .and_then(Json::as_array)
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as u32)
            .collect();
        let (want, _) = crate::kernels::assign::assign_argmin(&queries, &cs);
        assert_eq!(labels, want);
        // Dimension mismatch → 400.
        let bad = route(&post("/models/m-1/assign", r#"{"points": [[1,2]]}"#), &ctx);
        assert_eq!(bad.status, 400);
    }

    #[test]
    fn binary_assign_route_matches_json_bitwise() {
        let ctx = test_ctx();
        let cs = gaussian_mixture(
            &SynthSpec {
                n: 4,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            5,
        );
        let meta = registry::ModelMeta {
            id: ctx.registry.fresh_id(),
            version: 1,
            algorithm: "uniform".to_string(),
            k: 4,
            dim: 3,
            source: "test".to_string(),
            seed: 0,
            seeding_secs: 0.0,
            lloyd_iters: 0,
            cost: 0.0,
        };
        ctx.registry.insert(meta, cs.clone()).unwrap();
        let queries = gaussian_mixture(
            &SynthSpec {
                n: 30,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            6,
        );
        // Binary route: .fbin body in, FKA1 frame out.
        let body = crate::data::io::encode_fbin(&queries);
        let resp = route(&post_binary("/models/m-1/assign", body), &ctx);
        assert_eq!(resp.status, 200, "{:?}", String::from_utf8_lossy(&resp.body));
        assert_eq!(resp.content_type, "application/octet-stream");
        let (bin_labels, bin_d2s) = decode_assign_frame(&resp.body).unwrap();
        // JSON route on the same queries.
        let body = Json::obj(vec![("points", json::points_to_json(&queries))]).emit();
        let resp = route(&post("/models/m-1/assign", &body), &ctx);
        assert_eq!(resp.status, 200);
        let v = body_json(&resp);
        let json_labels: Vec<u32> = v
            .get("labels")
            .and_then(Json::as_array)
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as u32)
            .collect();
        let json_d2s: Vec<f32> = v
            .get("d2")
            .and_then(Json::as_array)
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as f32)
            .collect();
        // Bitwise identity across routes, and against the kernel.
        assert_eq!(bin_labels, json_labels);
        let bin_bits: Vec<u32> = bin_d2s.iter().map(|d| d.to_bits()).collect();
        let json_bits: Vec<u32> = json_d2s.iter().map(|d| d.to_bits()).collect();
        assert_eq!(bin_bits, json_bits);
        let (want_labels, want_d2s) = crate::kernels::assign::assign_argmin(&queries, &cs);
        assert_eq!(bin_labels, want_labels);
        assert_eq!(bin_bits, want_d2s.iter().map(|d| d.to_bits()).collect::<Vec<_>>());
        // Garbage binary bodies are client errors, not panics.
        assert_eq!(
            route(&post_binary("/models/m-1/assign", vec![1, 2, 3]), &ctx).status,
            400
        );
        // Dimension mismatch through the binary route → 400.
        let wrong_d = PointSet::from_flat(2, 7, vec![0.0; 14]);
        let resp = route(
            &post_binary("/models/m-1/assign", crate::data::io::encode_fbin(&wrong_d)),
            &ctx,
        );
        assert_eq!(resp.status, 400);
    }

    #[test]
    fn assign_frame_roundtrip_and_rejects() {
        let labels = vec![3u32, 0, 7];
        let d2s = vec![0.5f32, f32::MIN_POSITIVE, 123.25];
        let frame = encode_assign_frame(&labels, &d2s);
        assert_eq!(&frame[0..4], ASSIGN_FRAME_MAGIC);
        let (l, d) = decode_assign_frame(&frame).unwrap();
        assert_eq!(l, labels);
        assert_eq!(
            d.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            d2s.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert!(decode_assign_frame(b"nope").is_err());
        assert!(decode_assign_frame(b"FKA1\x02\x00\x00\x00short").is_err());
        let mut trailing = frame.clone();
        trailing.push(0);
        assert!(decode_assign_frame(&trailing).is_err());
    }

    #[test]
    fn bind_on_ephemeral_port() {
        let cfg = ServeConfig {
            port: 0,
            persist: false,
            ..Default::default()
        };
        let server = Server::bind(&cfg).unwrap();
        let addr = server.local_addr().unwrap();
        assert_ne!(addr.port(), 0);
    }

    #[test]
    fn observe_route_ingests_and_bumps_version() {
        let mut ctx = test_ctx();
        ctx.online = online::OnlineManager::new(16);
        let cs = gaussian_mixture(
            &SynthSpec {
                n: 4,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            5,
        );
        let meta = registry::ModelMeta {
            id: ctx.registry.fresh_id(),
            version: 1,
            algorithm: "uniform".to_string(),
            k: 4,
            dim: 3,
            source: "test".to_string(),
            seed: 0,
            seeding_secs: 0.0,
            lloyd_iters: 0,
            cost: 0.0,
        };
        ctx.registry.insert(meta, cs).unwrap();
        let batch = gaussian_mixture(
            &SynthSpec {
                n: 20,
                d: 3,
                k_true: 2,
                ..Default::default()
            },
            6,
        );
        let body = Json::obj(vec![("points", json::points_to_json(&batch))]).emit();
        let resp = route(&post("/models/m-1/observe", &body), &ctx);
        assert_eq!(resp.status, 200);
        let v = body_json(&resp);
        assert_eq!(v.get("ingested").and_then(Json::as_usize), Some(20));
        assert_eq!(v.get("total_observed").and_then(Json::as_usize), Some(20));
        assert_eq!(
            v.get("queued_version").and_then(Json::as_u64),
            Some(2),
            "20 points past a cadence of 16 queues version 2"
        );
        // The publish is off-thread: poll until the registry swaps.
        let mut published = 0;
        for _ in 0..500 {
            published = ctx.registry.get("m-1").unwrap().meta.version;
            if published >= 2 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(published, 2, "refresh never published");
        // GET /models/{id} surfaces the bumped version.
        let resp = route(&get("/models/m-1"), &ctx);
        assert_eq!(body_json(&resp).get("version").and_then(Json::as_u64), Some(2));
        // Assign still answers, from the published model.
        let aresp = route(&post("/models/m-1/assign", &body), &ctx);
        assert_eq!(aresp.status, 200);
        // Client errors: unknown model, missing points, bad dims.
        assert_eq!(route(&post("/models/m-404/observe", &body), &ctx).status, 404);
        assert_eq!(route(&post("/models/m-1/observe", "{}"), &ctx).status, 400);
        assert_eq!(
            route(&post("/models/m-1/observe", r#"{"points": [[1,2]]}"#), &ctx).status,
            400
        );
        // Observe counters moved on the request-scoped sink (error
        // requests above fail before the counters and don't show up).
        let counters = ctx.metrics.counters_snapshot();
        let count = |name: &str| {
            counters
                .iter()
                .find(|(k, _)| *k == name)
                .map(|&(_, v)| v)
        };
        assert_eq!(count("observe.requests"), Some(1));
        assert_eq!(count("observe.points"), Some(20));
    }
}
