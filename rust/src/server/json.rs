//! Hand-rolled JSON: parse + emit, zero dependencies (the offline build
//! has no `serde`).
//!
//! This module is the crate's **single serialization point**: every JSON
//! byte the serving layer reads or writes — request bodies, responses,
//! persisted model metadata, `fkmpp grid --json` artifacts — goes through
//! [`parse`] and [`Json::emit`]. Keeping one implementation means escape
//! handling, number formatting and strictness (reject-on-trailing-garbage)
//! are tested once and hold everywhere.
//!
//! Numbers are `f64`. The emitter uses Rust's shortest round-trip float
//! formatting, so an `f32` widened to `f64`, emitted, parsed back and
//! narrowed again is **bit-exact** — the property the serving layer's
//! assignment-parity test relies on. Non-finite numbers emit as `null`
//! (JSON has no `Infinity`/`NaN`).

use std::fmt::Write as _;

use crate::bail;
use crate::data::matrix::PointSet;
use crate::error::Result;
use crate::metrics::Stats;

/// Maximum nesting depth [`parse`] accepts (guards the recursive-descent
/// parser's stack against adversarial request bodies).
const MAX_DEPTH: usize = 128;

/// A JSON value. Object fields keep insertion order (no map type needed;
/// lookups are linear, and serving-layer objects are small).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Build an object from `(key, value)` pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Build a number value.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Object field lookup (`None` on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integer view (rejects fractions and anything past
    /// 2^53, where `f64` stops being exact).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 9_007_199_254_740_992.0 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Compact serialization (no whitespace).
    pub fn emit(&self) -> String {
        let mut out = String::new();
        self.emit_into(&mut out);
        out
    }

    fn emit_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.is_finite() {
                    // `{}` on f64 is the shortest string that parses back
                    // to the same bits (and never exponent notation).
                    let _ = write!(out, "{x}");
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => emit_string(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.emit_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    emit_string(k, out);
                    out.push(':');
                    v.emit_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn emit_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document. Strict: exactly one value, and any
/// non-whitespace after it is an error (reject-on-trailing-garbage).
pub fn parse(src: &str) -> Result<Json> {
    let mut p = Parser {
        src,
        bytes: src.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    src: &'a str,
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, want: u8) -> Result<()> {
        if self.peek() == Some(want) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at byte {}", want as char, self.pos)
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH}");
        }
        let v = match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(c) => bail!("unexpected {:?} at byte {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        };
        self.depth -= 1;
        v
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            // Plain run: stop only at ASCII bytes ('"', '\', controls), so
            // the slice below always lands on char boundaries.
            let start = self.pos;
            while let Some(b) = self.peek() {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            out.push_str(&self.src[start..self.pos]);
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = match self.peek() {
                        Some(b) => b,
                        None => bail!("unterminated escape"),
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{08}'),
                        b'f' => out.push('\u{0C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.unicode_escape()?;
                            out.push(cp);
                        }
                        other => bail!(
                            "invalid escape \\{} at byte {}",
                            other as char,
                            self.pos
                        ),
                    }
                }
                Some(_) => bail!("raw control character in string at byte {}", self.pos),
                None => bail!("unterminated string"),
            }
        }
    }

    /// The 4 hex digits after `\u`, combining surrogate pairs.
    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        let cp = if (0xD800..0xDC00).contains(&hi) {
            if self.peek() != Some(b'\\') {
                bail!("high surrogate not followed by \\u escape");
            }
            self.pos += 1;
            self.expect(b'u')?;
            let lo = self.hex4()?;
            if !(0xDC00..0xE000).contains(&lo) {
                bail!("invalid low surrogate {lo:#06x}");
            }
            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
        } else if (0xDC00..0xE000).contains(&hi) {
            bail!("unpaired low surrogate {hi:#06x}");
        } else {
            hi
        };
        match char::from_u32(cp) {
            Some(c) => Ok(c),
            None => bail!("invalid code point {cp:#x}"),
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let end = self.pos + 4;
        let Some(digits) = self.bytes.get(self.pos..end) else {
            bail!("truncated \\u escape");
        };
        if !digits.iter().all(|b| b.is_ascii_hexdigit()) {
            bail!("bad \\u escape at byte {}", self.pos);
        }
        let s = std::str::from_utf8(digits).expect("hex digits are ASCII");
        self.pos = end;
        Ok(u32::from_str_radix(s, 16).expect("validated hex"))
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => bail!("invalid number at byte {start}"),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                bail!("invalid number at byte {start}");
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                bail!("invalid number at byte {start}");
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        // The scanned span is all ASCII, so the slice is char-safe.
        let text = &self.src[start..self.pos];
        match text.parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => bail!("unparseable number {text:?}"),
        }
    }
}

/// `PointSet` → JSON array of rows. `f32 → f64` widening is exact, and
/// the shortest round-trip emitter means coordinates survive an HTTP
/// round trip bit-exactly.
pub fn points_to_json(ps: &PointSet) -> Json {
    Json::Arr(
        (0..ps.len())
            .map(|i| Json::Arr(ps.row(i).iter().map(|&x| Json::Num(x as f64)).collect()))
            .collect(),
    )
}

/// JSON array of equal-length numeric rows → `PointSet`. Rejects ragged,
/// empty and non-finite input (a serving layer must not let `Infinity`
/// smuggle itself into the kernels).
pub fn points_from_json(v: &Json) -> Result<PointSet> {
    let rows = match v {
        Json::Arr(rows) if !rows.is_empty() => rows,
        _ => bail!("\"points\" must be a non-empty array of rows"),
    };
    let mut out: Vec<Vec<f32>> = Vec::with_capacity(rows.len());
    for (i, row) in rows.iter().enumerate() {
        let vals = match row {
            Json::Arr(vals) => vals,
            _ => bail!("points[{i}] is not an array"),
        };
        if vals.is_empty() {
            bail!("points[{i}] is empty");
        }
        let mut r = Vec::with_capacity(vals.len());
        for (j, val) in vals.iter().enumerate() {
            let x = match val.as_f64() {
                Some(x) if x.is_finite() => x,
                _ => bail!("points[{i}][{j}] is not a finite number"),
            };
            r.push(x as f32);
        }
        if let Some(first) = out.first() {
            if r.len() != first.len() {
                bail!(
                    "ragged points: row {i} has {} cols, expected {}",
                    r.len(),
                    first.len()
                );
            }
        }
        out.push(r);
    }
    Ok(PointSet::from_rows(&out))
}

/// [`Stats`] → JSON (`null` when empty — min/max would be infinities).
/// Shared by `GET /metrics` and the `fkmpp grid --json` artifact.
pub fn stats_json(s: &Stats) -> Json {
    if s.count() == 0 {
        return Json::Null;
    }
    Json::obj(vec![
        ("count", Json::num(s.count() as f64)),
        ("mean", Json::num(s.mean())),
        ("min", Json::num(s.min())),
        ("max", Json::num(s.max())),
        ("stddev", Json::num(s.stddev())),
    ])
}

/// [`Histogram`] → JSON (`null` when empty). Keeps the `count`/`mean`/
/// `min`/`max` keys of [`stats_json`] so readers of `/metrics` survive
/// a timing series migrating from `Stats` to a histogram, and adds the
/// latency quantiles the histogram exists to answer.
pub fn histogram_json(h: &crate::metrics::Histogram) -> Json {
    if h.count() == 0 {
        return Json::Null;
    }
    Json::obj(vec![
        ("count", Json::num(h.count() as f64)),
        ("mean", Json::num(h.mean())),
        ("min", Json::num(h.min())),
        ("max", Json::num(h.max())),
        ("p50", Json::num(h.quantile(0.50))),
        ("p90", Json::num(h.quantile(0.90))),
        ("p99", Json::num(h.quantile(0.99))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Json) {
        let text = v.emit();
        let back = parse(&text).unwrap_or_else(|e| panic!("reparse {text:?}: {e:#}"));
        assert_eq!(&back, v, "round trip of {text:?}");
    }

    #[test]
    fn scalars_roundtrip() {
        for v in [
            Json::Null,
            Json::Bool(true),
            Json::Bool(false),
            Json::Num(0.0),
            Json::Num(-1.5),
            Json::Num(1e-9),
            Json::Num(-2.5e17),
            Json::Num(9_007_199_254_740_992.0),
            Json::Str(String::new()),
            Json::str("plain"),
        ] {
            roundtrip(&v);
        }
    }

    #[test]
    fn escapes_roundtrip() {
        for s in [
            "quote \" backslash \\ slash /",
            "newline\ntab\tcr\rbackspace\u{08}formfeed\u{0C}",
            "control \u{01}\u{1f} chars",
            "unicode: héllo wörld — ∑ 🦀",
        ] {
            roundtrip(&Json::str(s));
        }
    }

    #[test]
    fn unicode_escapes_parse() {
        assert_eq!(parse(r#""\u0041""#).unwrap(), Json::str("A"));
        assert_eq!(parse(r#""\u00e9""#).unwrap(), Json::str("é"));
        // Surrogate pair: U+1F600.
        assert_eq!(parse(r#""\ud83d\ude00""#).unwrap(), Json::str("😀"));
        assert!(parse(r#""\ud83d""#).is_err(), "unpaired high surrogate");
        assert!(parse(r#""\ude00""#).is_err(), "unpaired low surrogate");
        assert!(parse(r#""\uZZZZ""#).is_err());
    }

    #[test]
    fn nested_structures_roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::Arr(vec![Json::Num(1.0), Json::Null, Json::Bool(false)])),
            (
                "nested",
                Json::obj(vec![
                    ("empty_obj", Json::Obj(vec![])),
                    ("empty_arr", Json::Arr(vec![])),
                    ("deep", Json::Arr(vec![Json::Arr(vec![Json::Arr(vec![Json::num(3.0)])])])),
                ]),
            ),
            ("key with \"quotes\"", Json::str("v")),
        ]);
        roundtrip(&v);
    }

    #[test]
    fn number_grammar() {
        assert_eq!(parse("0").unwrap(), Json::Num(0.0));
        assert_eq!(parse("-0.5e+2").unwrap(), Json::Num(-50.0));
        assert_eq!(parse("1E3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("1e-9").unwrap(), Json::Num(1e-9));
        assert!(parse(".5").is_err());
        assert!(parse("1.").is_err());
        assert!(parse("1e").is_err());
        assert!(parse("+1").is_err());
        assert!(parse("--1").is_err());
        assert!(parse("NaN").is_err());
        assert!(parse("Infinity").is_err());
    }

    #[test]
    fn property_style_float_roundtrip() {
        // Pseudo-random f32s (including awkward ones) must survive
        // f32 → f64 → text → f64 → f32 bit-exactly.
        let mut rng = crate::rng::Pcg64::seed_from(0xD1CE);
        for i in 0..500 {
            let x = if i % 7 == 0 {
                (rng.next_f64() * 1e-9) as f32
            } else {
                ((rng.next_f64() - 0.5) * 1e6) as f32
            };
            let text = Json::Num(x as f64).emit();
            let back = parse(&text).unwrap().as_f64().unwrap() as f32;
            assert_eq!(x.to_bits(), back.to_bits(), "value {x} via {text:?}");
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} {}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("[1,2] x").is_err());
        assert!(parse("null,").is_err());
        // ... but trailing whitespace is fine.
        assert!(parse(" [1, 2]\n\t ").is_ok());
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[", "\"", "{\"a\"}", "{\"a\":}", "{a:1}", "[1,]", "{\"a\":1,}",
            "tru", "nul", "\"\\x\"", "\"raw \u{01} control\"", "[1 2]",
        ] {
            assert!(parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn depth_limit_enforced() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn integer_views() {
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(parse("42.5").unwrap().as_usize(), None);
        assert_eq!(parse("-1").unwrap().as_u64(), None);
        assert_eq!(parse("1e300").unwrap().as_u64(), None);
    }

    #[test]
    fn get_and_views() {
        let v = parse(r#"{"a": 1, "b": "x", "c": [true], "d": null}"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_f64), Some(1.0));
        assert_eq!(v.get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("c").and_then(Json::as_array).map(<[Json]>::len), Some(1));
        assert!(v.get("d").map(Json::is_null).unwrap_or(false));
        assert!(v.get("missing").is_none());
        assert_eq!(v.get("c").unwrap().get("a"), None, "get on non-object");
    }

    #[test]
    fn non_finite_emits_null() {
        assert_eq!(Json::Num(f64::NAN).emit(), "null");
        assert_eq!(Json::Num(f64::INFINITY).emit(), "null");
    }

    #[test]
    fn points_roundtrip_and_validation() {
        let ps = PointSet::from_rows(&[
            vec![1.0f32, -2.5, 1e-9],
            vec![0.1, 0.2, 0.3],
            vec![f32::MIN_POSITIVE, f32::MAX, -0.0],
        ]);
        let back = points_from_json(&points_to_json(&ps)).unwrap();
        assert_eq!(ps, back);

        assert!(points_from_json(&parse("[]").unwrap()).is_err());
        assert!(points_from_json(&parse("[[1,2],[3]]").unwrap()).is_err());
        assert!(points_from_json(&parse("[[1,\"x\"]]").unwrap()).is_err());
        assert!(points_from_json(&parse("[[]]").unwrap()).is_err());
        assert!(points_from_json(&parse("3").unwrap()).is_err());
        assert!(points_from_json(&parse("[[1e999]]").unwrap()).is_err(), "inf rejected");
    }

    #[test]
    fn stats_json_shape() {
        let mut s = Stats::new();
        assert!(stats_json(&s).is_null());
        s.push(1.0);
        s.push(3.0);
        let v = stats_json(&s);
        assert_eq!(v.get("count").and_then(Json::as_usize), Some(2));
        assert_eq!(v.get("mean").and_then(Json::as_f64), Some(2.0));
        assert_eq!(v.get("min").and_then(Json::as_f64), Some(1.0));
        assert_eq!(v.get("max").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn histogram_json_shape() {
        let mut h = crate::metrics::Histogram::new();
        assert!(histogram_json(&h).is_null());
        for i in 1..=100u64 {
            h.observe(i as f64 * 1e-3);
        }
        let v = histogram_json(&h);
        assert_eq!(v.get("count").and_then(Json::as_usize), Some(100));
        // The Stats-compatible keys survive the migration…
        assert!(v.get("mean").and_then(Json::as_f64).is_some());
        assert!(v.get("min").and_then(Json::as_f64).is_some());
        assert!(v.get("max").and_then(Json::as_f64).is_some());
        // …and the quantiles are ordered and inside the data range.
        let p50 = v.get("p50").and_then(Json::as_f64).unwrap();
        let p90 = v.get("p90").and_then(Json::as_f64).unwrap();
        let p99 = v.get("p99").and_then(Json::as_f64).unwrap();
        assert!(p50 <= p90 && p90 <= p99);
        assert!(p50 >= 1e-3 && p99 <= 0.1);
        roundtrip(&v);
    }
}
