//! Online ingest: `POST /models/{id}/observe` feeds arriving points
//! into a mini-batch Lloyd refresher and periodically publishes a new
//! model **version** through [`ModelRegistry::publish`].
//!
//! ## The observe → refresh lifecycle
//!
//! Each model with observe traffic owns an [`OnlineState`]: a working
//! copy of the centers, per-center running counts, and a
//! [`StreamingRejection`] drift detector seeded from the published
//! centers. An observe batch is assigned in one pinned-kernel sweep
//! against the working centers (cached assignment, Sculley-style), then
//! applied as sequential per-point updates with learning rate
//! `η_j = 1 / (warm + count_j)` — so centers converge as their counts
//! grow instead of chasing the last batch. Every `refresh_every`
//! observed points the state **snapshots** the working centers under
//! its lock, stamps them with the next monotone version, and queues the
//! snapshot for an off-thread publisher: the publisher builds a
//! complete [`Model`] (norm cache + kernel pin), persists it, and swaps
//! it into the registry atomically. Readers never wait on a refresh —
//! in-flight assigns finish on the `Arc` they captured.
//!
//! ## Determinism contract
//!
//! Snapshots are taken at exact stream positions (every
//! `refresh_every`-th point) while holding the state lock, and the
//! update arithmetic is sequential in stream order, so replaying the
//! same observe stream against the same starting model produces
//! **bitwise-identical centers at every version** — publisher thread
//! timing can delay *when* a version appears, never *what* it contains.
//! Queued snapshots publish in version order through the registry's
//! monotone [`ModelRegistry::publish`].
//!
//! The refreshed meta keeps the original fit's `cost` and
//! `seeding_secs` (they describe the fit, not the stream); `version`
//! is the field that moves.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::bail;
use crate::data::matrix::PointSet;
use crate::error::Result;
use crate::kernels::tune;
use crate::seeding::rejection::{OracleKind, RejectionConfig, StreamingRejection};
use crate::server::registry::{Model, ModelMeta, ModelRegistry, ASSIGN_PIN_N};

/// Default observe count between version publishes.
pub const DEFAULT_REFRESH_EVERY: usize = 256;

/// Warm-start pseudo-count: the fitted centers behave as if they had
/// already absorbed this many points each, so the first observed point
/// nudges its center by `1/(WARM_COUNT+1)` instead of replacing it
/// (bare Sculley counts start at zero and would overwrite the fit).
const WARM_COUNT: u64 = 64;

/// What one observe call did (the `POST /models/{id}/observe` body).
pub struct ObserveOutcome {
    /// Points ingested by this call.
    pub ingested: usize,
    /// Lifetime points observed for this model.
    pub total_observed: u64,
    /// Lifetime centers the streaming seeder opened off the stream — a
    /// drift signal (points near the model almost never open).
    pub novel: u64,
    /// Version the snapshot queued by this call will publish, if one
    /// crossed the refresh threshold.
    pub queued_version: Option<u64>,
}

/// A centers snapshot waiting for the off-thread publisher.
struct Snapshot {
    meta: ModelMeta,
    centers: PointSet,
}

/// Per-model online state. All mutation happens under the owning mutex;
/// the publisher thread only pops [`Snapshot`]s and flips
/// `publisher_running`.
struct OnlineState {
    /// Meta template for refreshes (version overwritten per snapshot).
    base_meta: ModelMeta,
    /// Working centers the mini-batch updates mutate.
    centers: PointSet,
    /// Per-center observed-point counts (drives the learning rate).
    counts: Vec<u64>,
    /// Kernel pinned at state creation from the model shape (same
    /// formula as [`Model::new`]) so observe batch size cannot flip the
    /// sweep implementation.
    kernel: tune::Kernel,
    observed: u64,
    since_refresh: usize,
    /// Version the next snapshot will carry.
    next_version: u64,
    pending: VecDeque<Snapshot>,
    publisher_running: bool,
    /// Streaming rejection seeder over the observe stream, seeded from
    /// the published centers: accepts are drift, surfaced as
    /// `observe.novel`. Uses the exact oracle — its working set is only
    /// ever the opened centers, so scans stay `O(k)`.
    novelty: StreamingRejection,
}

impl OnlineState {
    fn for_model(model: &Model) -> Result<OnlineState> {
        let k = model.centers.len();
        let dim = model.centers.dim();
        let mut novelty = StreamingRejection::new(
            dim,
            // Room for one drifted center per fitted one before the
            // detector saturates.
            k.saturating_mul(2).max(2),
            RejectionConfig {
                oracle: OracleKind::Exact,
                ..Default::default()
            },
            model.meta.seed ^ 0x0B5E_7EED,
        )?;
        novelty.seed_centers(&model.centers)?;
        Ok(OnlineState {
            base_meta: model.meta.clone(),
            centers: model.centers.clone(),
            counts: vec![0; k],
            kernel: tune::kernel_for(tune::Op::Assign, ASSIGN_PIN_N, dim, k),
            observed: 0,
            since_refresh: 0,
            next_version: model.meta.version + 1,
            pending: VecDeque::new(),
            publisher_running: false,
            novelty,
        })
    }

    /// One pinned-kernel sweep over the batch against the working
    /// centers — the module owns no distance loops (PR 1 contract).
    fn assign_working(&self, points: &PointSet) -> (Vec<u32>, Vec<f32>) {
        match self.kernel {
            tune::Kernel::Naive => {
                crate::kernels::assign::assign_argmin_naive(points, &self.centers)
            }
            tune::Kernel::Blocked => {
                let pn = crate::kernels::norms::squared_norms(points);
                let cn = crate::kernels::norms::squared_norms(&self.centers);
                crate::kernels::blocked::assign_argmin_blocked(points, &pn, &self.centers, &cn)
            }
        }
    }

    /// Mini-batch Lloyd step: cached assignment for the whole batch,
    /// then sequential per-point center updates in stream order (the
    /// order is what makes replays bitwise).
    fn ingest(&mut self, points: &PointSet) -> Result<()> {
        let (labels, _) = self.assign_working(points);
        for (i, &label) in labels.iter().enumerate() {
            let j = label as usize;
            self.counts[j] += 1;
            let eta = 1.0f32 / (WARM_COUNT + self.counts[j]) as f32;
            let x = points.row(i);
            let c = self.centers.row_mut(j);
            for (cv, xv) in c.iter_mut().zip(x) {
                *cv += eta * (*xv - *cv);
            }
        }
        self.novelty.observe(points)?;
        self.observed += points.len() as u64;
        self.since_refresh += points.len();
        Ok(())
    }

    /// Snapshot the working centers for the version this call crossed
    /// into. Called with the state lock held, at an exact stream
    /// position — the snapshot's bits are already final here.
    fn queue_snapshot(&mut self) -> u64 {
        let version = self.next_version;
        self.next_version += 1;
        self.since_refresh = 0;
        let mut meta = self.base_meta.clone();
        meta.version = version;
        self.pending.push_back(Snapshot {
            meta,
            centers: self.centers.clone(),
        });
        version
    }
}

/// All per-model online states behind the server, plus the refresh
/// cadence. Owned by `ServerCtx`.
pub struct OnlineManager {
    states: Mutex<HashMap<String, Arc<Mutex<OnlineState>>>>,
    refresh_every: usize,
}

impl OnlineManager {
    pub fn new(refresh_every: usize) -> OnlineManager {
        OnlineManager {
            states: Mutex::new(HashMap::new()),
            refresh_every: refresh_every.max(1),
        }
    }

    /// Ingest one observe batch for `model`, queueing a versioned
    /// refresh whenever the stream crosses the cadence (possibly more
    /// than once for an oversized batch — each snapshot then lands at a
    /// deterministic position only up to batch granularity, which is
    /// why the threshold check runs *after* the whole batch: the
    /// per-version bits depend only on the stream prefix, never on
    /// publisher timing).
    pub fn observe(
        &self,
        registry: &Arc<ModelRegistry>,
        model: &Arc<Model>,
        points: &PointSet,
    ) -> Result<ObserveOutcome> {
        if points.dim() != model.centers.dim() {
            bail!(
                "dimension mismatch: model {} has d={}, observed points have d={}",
                model.meta.id,
                model.centers.dim(),
                points.dim()
            );
        }
        if points.is_empty() {
            bail!("observe batch is empty");
        }
        let state = self.state_for(model)?;
        let mut st = state.lock().unwrap();
        st.ingest(points)?;
        let queued_version = if st.since_refresh >= self.refresh_every {
            Some(st.queue_snapshot())
        } else {
            None
        };
        let outcome = ObserveOutcome {
            ingested: points.len(),
            total_observed: st.observed,
            novel: st.novelty.accepted(),
            queued_version,
        };
        if queued_version.is_some() && !st.publisher_running {
            st.publisher_running = true;
            drop(st);
            spawn_publisher(Arc::clone(registry), state);
        }
        Ok(outcome)
    }

    /// Fetch or create the state for a model id. The state is created
    /// from the *currently published* model on first observe.
    fn state_for(&self, model: &Model) -> Result<Arc<Mutex<OnlineState>>> {
        let mut states = self.states.lock().unwrap();
        if let Some(existing) = states.get(&model.meta.id) {
            return Ok(Arc::clone(existing));
        }
        let state = Arc::new(Mutex::new(OnlineState::for_model(model)?));
        states.insert(model.meta.id.clone(), Arc::clone(&state));
        Ok(state)
    }
}

/// Drain the snapshot queue off-thread: build each snapshot into a full
/// [`Model`] (norm cache + kernel pin run here, not under the state
/// lock), persist + swap it via the registry, and exit once the queue
/// is dry. Publishes happen in version order because the queue is
/// FIFO and only one publisher runs per state.
fn spawn_publisher(registry: Arc<ModelRegistry>, state: Arc<Mutex<OnlineState>>) {
    std::thread::spawn(move || loop {
        let snap = {
            let mut st = state.lock().unwrap();
            match st.pending.pop_front() {
                Some(s) => s,
                None => {
                    st.publisher_running = false;
                    return;
                }
            }
        };
        let mut span = crate::trace::Span::enter("model.refresh");
        span.arg("model", snap.meta.id.clone());
        span.arg("version", snap.meta.version);
        span.arg("k", snap.centers.len() as u64);
        let model = Model::new(snap.meta, snap.centers);
        match registry.publish(model) {
            Ok(_) => crate::metrics::global().incr("observe.refreshes", 1),
            Err(e) => crate::log::warn(
                "observe.refresh_failed",
                &[(
                    "error",
                    crate::server::json::Json::str(format!("{e:#}")),
                )],
            ),
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_mixture, SynthSpec};

    fn install(reg: &Arc<ModelRegistry>, k: usize, d: usize, seed: u64) -> Arc<Model> {
        let meta = ModelMeta {
            id: reg.fresh_id(),
            version: 1,
            algorithm: "uniform".to_string(),
            k,
            dim: d,
            source: "test".to_string(),
            seed,
            seeding_secs: 0.0,
            lloyd_iters: 0,
            cost: 0.0,
        };
        let centers = gaussian_mixture(
            &SynthSpec {
                n: k,
                d,
                k_true: k.min(4),
                ..Default::default()
            },
            seed,
        );
        reg.insert(meta, centers).unwrap()
    }

    fn stream(n: usize, d: usize, seed: u64) -> PointSet {
        gaussian_mixture(
            &SynthSpec {
                n,
                d,
                k_true: 4,
                ..Default::default()
            },
            seed,
        )
    }

    fn wait_for_version(reg: &ModelRegistry, id: &str, version: u64) -> Arc<Model> {
        for _ in 0..500 {
            let m = reg.get(id).unwrap();
            if m.meta.version >= version {
                return m;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        panic!("model {id} never reached version {version}");
    }

    #[test]
    fn observe_refresh_publishes_versions() {
        let reg = Arc::new(ModelRegistry::new(None).unwrap());
        let model = install(&reg, 4, 3, 1);
        let id = model.meta.id.clone();
        let mgr = OnlineManager::new(32);
        let pts = stream(80, 3, 2);
        let out = mgr.observe(&reg, &model, &pts).unwrap();
        assert_eq!(out.ingested, 80);
        assert_eq!(out.total_observed, 80);
        assert_eq!(out.queued_version, Some(2));
        let m2 = wait_for_version(&reg, &id, 2);
        assert_eq!(m2.meta.version, 2);
        assert_ne!(m2.centers, model.centers, "refresh moved the centers");
        // Meta fields other than version carry over from the fit.
        assert_eq!(m2.meta.algorithm, "uniform");
        assert_eq!(m2.meta.k, 4);
        // The original Arc is untouched (readers finish on their version).
        assert_eq!(model.meta.version, 1);
    }

    #[test]
    fn observe_replay_is_bitwise_per_version() {
        // The fixed-seed contract: the same starting model + the same
        // observe stream produce identical center bits at EVERY version,
        // not just the last one. Driving the state machine directly
        // (same module) captures each snapshot at its exact stream
        // position — publisher timing never enters the bits.
        let reg = Arc::new(ModelRegistry::new(None).unwrap());
        let model = install(&reg, 4, 3, 1);
        let chunks: Vec<PointSet> = (0..6).map(|i| stream(25, 3, 100 + i)).collect();
        let run = || {
            let mut st = OnlineState::for_model(&model).unwrap();
            let mut versions: Vec<(u64, PointSet)> = Vec::new();
            for chunk in &chunks {
                st.ingest(chunk).unwrap();
                if st.since_refresh >= 50 {
                    let v = st.queue_snapshot();
                    let snap = st.pending.back().unwrap();
                    versions.push((v, snap.centers.clone()));
                }
            }
            versions
        };
        let a = run();
        let b = run();
        // 150 points at cadence 50 → versions 2, 3, 4.
        assert_eq!(a.len(), 3);
        assert_eq!(a[0].0, 2);
        assert_eq!(a[2].0, 4);
        assert_eq!(a, b, "replay diverged");
        // Successive versions actually differ (the stream moves them).
        assert_ne!(a[0].1, a[1].1);
    }

    #[test]
    fn oversized_batch_queues_single_snapshot_per_call() {
        let reg = Arc::new(ModelRegistry::new(None).unwrap());
        let model = install(&reg, 4, 3, 5);
        let mgr = OnlineManager::new(10);
        let out = mgr.observe(&reg, &model, &stream(35, 3, 6)).unwrap();
        assert_eq!(out.queued_version, Some(2));
        // Dimension mismatch and empty batches are client errors.
        assert!(mgr.observe(&reg, &model, &stream(5, 7, 7)).is_err());
        assert!(mgr
            .observe(&reg, &model, &PointSet::from_flat(0, 3, Vec::new()))
            .is_err());
    }

    #[test]
    fn learning_rate_pulls_center_toward_stream() {
        let reg = Arc::new(ModelRegistry::new(None).unwrap());
        let model = install(&reg, 2, 2, 9);
        let id = model.meta.id.clone();
        let mgr = OnlineManager::new(64);
        // A tight stream at a fixed offset from center 0's basin.
        let target = [50.0f32, -30.0];
        let rows: Vec<Vec<f32>> = (0..64).map(|_| target.to_vec()).collect();
        mgr.observe(&reg, &model, &PointSet::from_rows(&rows)).unwrap();
        let m2 = wait_for_version(&reg, &id, 2);
        // The hit center moved strictly toward the stream point.
        let (j, d2_new) = crate::kernels::assign::nearest_center(&target, &m2.centers);
        let d2_old = crate::data::matrix::d2(model.centers.row(j as usize), &target);
        assert!(
            d2_new < d2_old,
            "center {j} did not move toward the stream ({d2_new} !< {d2_old})"
        );
    }
}
