//! Dependency-free error handling (the offline build has no `anyhow`).
//!
//! A small, source-chained error type plus the ergonomic subset of the
//! `anyhow` API the crate uses:
//!
//! * [`Error`] — an owned message chain (outermost context first);
//! * [`Result`] — `std::result::Result` defaulted to [`Error`];
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * [`crate::anyhow!`] / [`crate::bail!`] — format-style construction and
//!   early return.
//!
//! Any `std::error::Error` converts via `?`; the full source chain is
//! captured eagerly. `{e}` prints the outermost message, `{e:#}` the whole
//! chain separated by `": "` (matching the `anyhow` convention the CLI
//! relies on).

use std::fmt;

/// Crate-wide result type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error: a chain of human-readable messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single message.
    pub fn msg(msg: impl Into<String>) -> Self {
        Error {
            chain: vec![msg.into()],
        }
    }

    /// Prepend a context message (what `.context(..)` does).
    pub fn wrap(mut self, msg: impl Into<String>) -> Self {
        self.chain.insert(0, msg.into());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> &[String] {
        &self.chain
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// Mirrors anyhow's blanket conversion: any std error (and its source
// chain) becomes an `Error`. Coherent because `Error` itself does not
// implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Attach context to failures, `anyhow`-style.
pub trait Context<T> {
    /// Wrap the error with a fixed message.
    fn context<C: fmt::Display>(self, msg: C) -> Result<T>;
    /// Wrap the error with a lazily built message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(msg.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (drop-in for `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (drop-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::error::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42);
    }

    #[test]
    fn bail_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "inner 42");
        assert_eq!(format!("{e:#}"), "inner 42");
    }

    #[test]
    fn context_prepends() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
        assert_eq!(e.chain().len(), 2);
    }

    #[test]
    fn with_context_lazy() {
        let e = fails().with_context(|| format!("step {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 7: inner 42");
    }

    #[test]
    fn std_error_converts_with_sources() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(format!("{e}").contains("gone"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("5").unwrap(), 5);
        assert!(parse("x").is_err());
    }
}
