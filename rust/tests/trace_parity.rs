//! Tracing must be free of observable effect: with the run-trace layer
//! armed, every seeder's fixed-seed output is bitwise identical to the
//! untraced run — the ISSUE 7 acceptance gate for `rust/src/trace.rs`.
//!
//! Spans sit only at coarse phase boundaries and read only the clock,
//! so arming them may not perturb any RNG stream. One `#[test]` drives
//! five legs:
//!
//! 1. **kmeanspp**: untraced baseline vs traced rerun — indices, center
//!    bits, proposal counts, and the next run-RNG draw all equal.
//! 2. **rejection**, for every [`OracleKind`]: same comparison.
//! 3. **afkmc2** and in-process **kmeans-par**: same comparison.
//! 4. **2-worker distributed kmeans-par**, traced, vs the *untraced*
//!    in-process baseline — and the `dist.rpc_secs` latency histogram
//!    has observations with ordered quantiles (the `/metrics` p50/p99
//!    source for RPC round-trips). The merged export then must carry
//!    the worker subprocesses' spans as distinct pid rows under the
//!    coordinator's trace id (the ISSUE 9 propagation gate), with
//!    `worker-1/…` rows in the report.
//! 5. **FKMPP_TRACE through the CLI**: a traced `fkmpp seed` reports the
//!    same seeding cost as the untraced run and writes a strict-parse
//!    valid Chrome trace that `trace::render_report` can summarize.
//!
//! Env-owning discipline (the `kernel_parity.rs` pattern): this file
//! pins `FKMPP_KERNEL=naive` (worker subprocesses inherit it — the
//! cross-process bit-parity precondition) and toggles `FKMPP_TRACE`,
//! so it contains exactly ONE `#[test]` and restores both at the end.

use std::io::BufRead;
use std::process::{Child, Command, Stdio};

use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::dist::{kmeans_par_dist, DistConfig};
use fastkmeanspp::rng::Pcg64;
use fastkmeanspp::seeding::afkmc2::{afkmc2, Afkmc2Config};
use fastkmeanspp::seeding::kmeanspp::kmeanspp;
use fastkmeanspp::seeding::rejection::{rejection_sampling, OracleKind, RejectionConfig};
use fastkmeanspp::seeding::Seeding;
use fastkmeanspp::shard::kmeanspar::{kmeans_par, KMeansParConfig};
use fastkmeanspp::{metrics, trace};

const BIN: &str = env!("CARGO_BIN_EXE_fkmpp");

/// One `fkmpp worker` subprocess; killed on drop so a failing assert
/// can't leak processes.
struct Worker {
    child: Child,
    addr: String,
}

impl Drop for Worker {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn an ephemeral-port worker and wait for its ready line.
fn spawn_worker() -> Worker {
    let mut child = Command::new(BIN)
        .args(["worker", "--port", "0"])
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn fkmpp worker");
    let stdout = child.stdout.take().expect("worker stdout not captured");
    let mut reader = std::io::BufReader::new(stdout);
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    assert!(line.contains("http://"), "bad worker ready line {line:?}");
    let addr = line.rsplit("http://").next().unwrap().trim().to_string();
    // Keep draining stdout so the worker never blocks on a full pipe.
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(b) if b > 0) {
            sink.clear();
        }
    });
    Worker { child, addr }
}

/// The full RNG-visible fingerprint of one seeding run: indices, center
/// bits, proposal count, and the next draw of the run RNG.
struct Fingerprint {
    indices: Vec<usize>,
    center_bits: Vec<u32>,
    proposals: u64,
    next_draw: u64,
}

fn fingerprint(seed: u64, f: impl FnOnce(&mut Pcg64) -> Seeding) -> Fingerprint {
    let mut rng = Pcg64::seed_from(seed);
    let s = f(&mut rng);
    Fingerprint {
        indices: s.indices.clone(),
        center_bits: s.centers.flat().iter().map(|x| x.to_bits()).collect(),
        proposals: s.stats.proposals,
        next_draw: rng.next_u64(),
    }
}

fn assert_same(what: &str, a: &Fingerprint, b: &Fingerprint) {
    assert_eq!(a.indices, b.indices, "{what}: indices diverged under tracing");
    assert_eq!(
        a.center_bits, b.center_bits,
        "{what}: center bits diverged under tracing"
    );
    assert_eq!(
        a.proposals, b.proposals,
        "{what}: proposal count diverged under tracing"
    );
    assert_eq!(
        a.next_draw, b.next_draw,
        "{what}: run RNG stream diverged under tracing"
    );
}

#[test]
fn traced_runs_are_bitwise_identical_to_untraced() {
    // Pinned for the whole test; worker subprocesses inherit it.
    std::env::set_var("FKMPP_KERNEL", "naive");
    std::env::remove_var("FKMPP_TRACE");

    // 6_000 rows = 2 summation blocks, so both distributed workers own
    // aligned, non-empty ranges.
    let ps = gaussian_mixture(
        &SynthSpec {
            n: 6_000,
            d: 8,
            k_true: 10,
            ..Default::default()
        },
        11,
    );
    let k = 15;
    let pcfg = KMeansParConfig {
        shards: 3,
        rounds: 3,
        oversample: 2.0,
    };

    // Untraced baselines first (the recorder is off), then the identical
    // runs with the recorder armed.
    trace::set_enabled(false);
    trace::clear();
    let base_pp = fingerprint(11, |rng| kmeanspp(&ps, k, rng));
    let base_rej: Vec<(OracleKind, Fingerprint)> = OracleKind::all()
        .into_iter()
        .map(|oracle| {
            let cfg = RejectionConfig {
                oracle,
                ..Default::default()
            };
            (oracle, fingerprint(13, |rng| rejection_sampling(&ps, k, &cfg, rng)))
        })
        .collect();
    let base_afk = fingerprint(17, |rng| afkmc2(&ps, k, &Afkmc2Config::default(), rng));
    let base_par = fingerprint(19, |rng| kmeans_par(&ps, k, &pcfg, rng));

    trace::set_enabled(true);

    // Legs 1-3: every in-process seeder, traced, lands on the baseline.
    assert_same("kmeanspp", &base_pp, &fingerprint(11, |rng| kmeanspp(&ps, k, rng)));
    for (oracle, base) in &base_rej {
        let cfg = RejectionConfig {
            oracle: *oracle,
            ..Default::default()
        };
        let traced = fingerprint(13, |rng| rejection_sampling(&ps, k, &cfg, rng));
        assert_same(&format!("rejection/{}", oracle.name()), base, &traced);
    }
    assert_same(
        "afkmc2",
        &base_afk,
        &fingerprint(17, |rng| afkmc2(&ps, k, &Afkmc2Config::default(), rng)),
    );
    assert_same(
        "kmeans-par",
        &base_par,
        &fingerprint(19, |rng| kmeans_par(&ps, k, &pcfg, rng)),
    );

    // Leg 4: the traced 2-worker distributed run reproduces the untraced
    // in-process baseline, and RPC round-trip latencies land in the
    // log-bucketed histogram behind `/metrics` p50/p99.
    {
        let before = metrics::CounterSnapshot::of(metrics::global());
        let rpc_count_before = metrics::global()
            .histogram("dist.rpc_secs")
            .map_or(0, |h| h.count());
        let w1 = spawn_worker();
        let w2 = spawn_worker();
        let dcfg = DistConfig {
            workers: vec![w1.addr.clone(), w2.addr.clone()],
            rounds: pcfg.rounds,
            oversample: pcfg.oversample,
            ..DistConfig::default()
        };
        let traced = fingerprint(19, |rng| {
            kmeans_par_dist(&ps, k, &dcfg, rng)
                .unwrap_or_else(|e| panic!("traced 2-worker run failed: {e:#}"))
        });
        assert_same("dist-2worker", &base_par, &traced);
        assert!(before.delta(metrics::global(), "dist.rpcs") > 0);
        let hist = metrics::global()
            .histogram("dist.rpc_secs")
            .expect("dist.rpc_secs histogram populated");
        assert!(hist.count() > rpc_count_before, "no RPC latencies recorded");
        let (p50, p99) = (hist.quantile(0.50), hist.quantile(0.99));
        assert!(p50 > 0.0 && p99 >= p50, "bad RPC quantiles p50={p50} p99={p99}");
    }

    // The recorded trace round-trips through the strict parser and the
    // report renderer, with the coarse driver phases present.
    let doc = trace::export_json();
    let reparsed = fastkmeanspp::server::json::parse(&doc.emit()).expect("trace JSON reparses");
    let events = reparsed
        .get("traceEvents")
        .and_then(|e| e.as_array())
        .expect("traceEvents array");
    assert!(!events.is_empty(), "trace recorded no spans");
    for name in ["seed.kmeanspp.select", "seed.rejection.init", "shard.round", "dist.rpc"] {
        assert!(
            events
                .iter()
                .any(|e| e.get("name").and_then(|n| n.as_str()) == Some(name)),
            "span {name:?} missing from trace"
        );
    }
    // Tentpole (ISSUE 9): the merged export carries the worker
    // *subprocesses'* spans as distinct pid rows — collected over the
    // TraceDump RPC and shifted onto the coordinator clock — and every
    // one of them sits under the coordinator's trace id.
    let coord_tid = format!("{:016x}", trace::trace_id());
    let worker_pids: std::collections::BTreeSet<u64> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .filter_map(|e| e.get("pid").and_then(|p| p.as_u64()))
        .filter(|&pid| pid > trace::LOCAL_PID as u64)
        .collect();
    assert!(
        worker_pids.len() >= 2,
        "merged trace missing worker-process span rows (pids {worker_pids:?})"
    );
    for e in events {
        if e.get("ph").and_then(|p| p.as_str()) != Some("X")
            || e.get("pid").and_then(|p| p.as_u64()).unwrap_or(0) <= trace::LOCAL_PID as u64
        {
            continue;
        }
        let tid = e
            .get("args")
            .and_then(|a| a.get("trace_id"))
            .and_then(|t| t.as_str());
        assert_eq!(
            tid,
            Some(coord_tid.as_str()),
            "worker span not under the coordinator trace id"
        );
    }
    assert!(
        events.iter().any(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("M")
                && e.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(|n| n.as_str())
                    == Some("worker-1")
        }),
        "merged trace missing worker-1 process_name metadata"
    );
    let report = trace::render_report(&reparsed).expect("report renders");
    assert!(report.contains("shard.round"), "{report}");
    assert!(report.contains("worker-1/"), "{report}");

    // Leg 5: FKMPP_TRACE through the CLI — same seeding cost as the
    // untraced CLI run, plus a strict-parse valid trace file on disk.
    {
        let dir = std::env::temp_dir().join("fkmpp_trace_parity_data");
        let path = std::env::temp_dir().join("fkmpp_trace_parity.json");
        let _ = std::fs::remove_file(&path);
        let args = |extra: &str| -> Vec<String> {
            format!(
                "seed --dataset kdd_sim --algo rejection -k 10 --profile smoke \
                 --data-dir {} --artifacts-dir /nonexistent --seed 5{extra}",
                dir.display()
            )
            .split_whitespace()
            .map(str::to_string)
            .collect()
        };
        std::env::set_var("FKMPP_TRACE", &path);
        let traced_out = fastkmeanspp::cli::run(&args("")).expect("traced CLI seed run");
        std::env::remove_var("FKMPP_TRACE");
        let plain_out = fastkmeanspp::cli::run(&args("")).expect("untraced CLI seed run");
        let cost_line = |out: &str| -> String {
            out.lines()
                .find(|l| l.starts_with("seeding cost"))
                .unwrap_or_else(|| panic!("no cost line in {out:?}"))
                .to_string()
        };
        assert_eq!(
            cost_line(&traced_out),
            cost_line(&plain_out),
            "FKMPP_TRACE changed the seeding result"
        );
        assert!(traced_out.contains("wrote trace"), "{traced_out}");
        let text = std::fs::read_to_string(&path).expect("trace file written");
        let doc = fastkmeanspp::server::json::parse(&text).expect("trace file strict-parses");
        trace::render_report(&doc).expect("trace file reportable");
    }

    std::env::remove_var("FKMPP_KERNEL");
}
