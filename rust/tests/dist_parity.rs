//! Cross-process distributed-fit parity + fault injection — the ISSUE 6
//! acceptance gate for `rust/src/dist/`.
//!
//! One `#[test]` drives four legs against real `fkmpp worker`
//! subprocesses on ephemeral localhost ports:
//!
//! 1. **Worker-count parity**: 1-, 2- and 4-worker distributed runs
//!    reproduce the in-process `kmeans_par` result bit-for-bit — center
//!    indices, center coordinates, proposal counts, and the next draw of
//!    the run RNG (the full RNG-visible state).
//! 2. **Executor seam**: `LocalShardExecutor` and `DistCoordinator` are
//!    driven through one identical scripted round; per-block cost
//!    partials compare by `f64::to_bits`, candidate sets and `u64`
//!    weights compare exactly.
//! 3. **Fault injection**: one worker is told to die mid-run
//!    (`--fail-after`), a respawner brings a replacement up on the same
//!    port, and the coordinator's replay recovery must land on the
//!    baseline bits anyway.
//! 4. **Permanent death**: a fleet whose only endpoint never listens
//!    fails within the retry deadline with a typed "unreachable" error —
//!    never a hang.
//!
//! Env-owning discipline (the `kernel_parity.rs` pattern): this file
//! pins `FKMPP_KERNEL=blocked` for its whole run — worker subprocesses
//! inherit it, which is the cross-process bit-parity precondition — so
//! it contains exactly ONE `#[test]` and restores the variable at the
//! end.

use std::io::BufRead;
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use fastkmeanspp::data::synth::{gaussian_mixture, SynthSpec};
use fastkmeanspp::dist::{kmeans_par_dist, DistConfig, DistCoordinator, RoundExecutor};
use fastkmeanspp::rng::Pcg64;
use fastkmeanspp::shard::kmeanspar::{kmeans_par, KMeansParConfig, LocalShardExecutor};

const BIN: &str = env!("CARGO_BIN_EXE_fkmpp");

/// One `fkmpp worker` subprocess; killed on drop so a failing assert
/// can't leak processes.
struct Worker {
    child: Child,
    addr: String,
    port: u16,
}

impl Drop for Worker {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Spawn a worker (`port` 0 = ephemeral) and wait for its ready line
/// (`[worker] listening on http://ADDR`). With `fail_after = Some(n)`
/// the worker serves `n` RPCs and then exits without replying to the
/// next one — the mid-round crash for the fault-injection leg.
fn try_spawn_worker(port: u16, fail_after: Option<u64>) -> Result<Worker, String> {
    let mut cmd = Command::new(BIN);
    cmd.args(["worker", "--port", &port.to_string()]);
    if let Some(n) = fail_after {
        cmd.args(["--fail-after", &n.to_string()]);
    }
    let mut child = cmd
        .stdout(Stdio::piped())
        .spawn()
        .map_err(|e| format!("spawn {BIN}: {e}"))?;
    let stdout = child.stdout.take().ok_or("worker stdout not captured")?;
    let mut reader = std::io::BufReader::new(stdout);
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    if !line.contains("http://") {
        let _ = child.kill();
        let _ = child.wait();
        return Err(format!("bad worker ready line {line:?}"));
    }
    let addr = line.rsplit("http://").next().unwrap().trim().to_string();
    let port = addr
        .rsplit(':')
        .next()
        .unwrap()
        .parse()
        .map_err(|e| format!("bad worker addr {addr:?}: {e}"))?;
    // Keep draining stdout so the worker never blocks on a full pipe.
    std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(b) if b > 0) {
            sink.clear();
        }
    });
    Ok(Worker { child, addr, port })
}

fn spawn_worker(port: u16, fail_after: Option<u64>) -> Worker {
    try_spawn_worker(port, fail_after).expect("spawn fkmpp worker")
}

#[test]
fn distributed_fit_matches_in_process_bitwise() {
    // Pinned for the whole test: subprocesses inherit it, and identical
    // kernel dispatch on both sides of the wire is a precondition for
    // bit-parity (the weigh phase is above the autotuner's probe
    // threshold at this shape).
    std::env::set_var("FKMPP_KERNEL", "blocked");

    // 20_000 rows = 5 summation blocks, so 4 workers split [2,1,1,1]
    // blocks and every fleet size in the sweep is fully active.
    let ps = gaussian_mixture(
        &SynthSpec {
            n: 20_000,
            d: 12,
            k_true: 12,
            ..Default::default()
        },
        7,
    );
    let k = 12;
    let pcfg = KMeansParConfig {
        shards: 3,
        rounds: 3,
        oversample: 2.0,
    };

    // In-process baseline, plus one extra RNG draw: the distributed runs
    // must leave the run RNG in the identical state.
    let mut rng = Pcg64::seed_from(7);
    let base = kmeans_par(&ps, k, &pcfg, &mut rng);
    let base_next = rng.next_u64();

    // Leg 1: worker-count parity sweep.
    for &nw in &[1usize, 2, 4] {
        let workers: Vec<Worker> = (0..nw).map(|_| spawn_worker(0, None)).collect();
        let dcfg = DistConfig {
            workers: workers.iter().map(|w| w.addr.clone()).collect(),
            rounds: pcfg.rounds,
            oversample: pcfg.oversample,
            ..DistConfig::default()
        };
        let mut rng = Pcg64::seed_from(7);
        let got = kmeans_par_dist(&ps, k, &dcfg, &mut rng)
            .unwrap_or_else(|e| panic!("{nw}-worker run failed: {e:#}"));
        let got_next = rng.next_u64();
        assert_eq!(got.indices, base.indices, "{nw}-worker indices diverged");
        assert_eq!(
            got.centers.flat(),
            base.centers.flat(),
            "{nw}-worker centers diverged"
        );
        assert_eq!(
            got.stats.proposals, base.stats.proposals,
            "{nw}-worker proposal count diverged"
        );
        assert_eq!(got_next, base_next, "{nw}-worker run RNG stream diverged");
    }

    // Leg 2: the executor seam itself — both RoundExecutor
    // implementations through one identical scripted round.
    {
        let w1 = spawn_worker(0, None);
        let w2 = spawn_worker(0, None);
        let dcfg = DistConfig {
            workers: vec![w1.addr.clone(), w2.addr.clone()],
            ..DistConfig::default()
        };
        let mut local = LocalShardExecutor::new(&ps, 4);
        let mut remote = DistCoordinator::new(&ps, &dcfg).expect("coordinator");

        let seed_rows = ps.gather(&[123]);
        let lp = local.update(&[123], &seed_rows).expect("local update");
        let rp = remote.update(&[123], &seed_rows).expect("remote update");
        assert_eq!(lp.len(), rp.len(), "partial block counts differ");
        for (i, (x, y)) in lp.iter().zip(&rp).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "cost partial block {i} differs");
        }

        let cost: f64 = lp.iter().sum();
        let lc = local.sample(0xDEAD_BEEF, cost, 24.0).expect("local sample");
        let rc = remote.sample(0xDEAD_BEEF, cost, 24.0).expect("remote sample");
        assert_eq!(lc, rc, "accepted candidate sets differ");

        // Weigh over the seed candidate plus everything accepted (the
        // driver's candidate list always contains the first center, so
        // this never weighs an empty set).
        let mut sel = vec![123usize];
        sel.extend(&lc);
        let cands = ps.gather(&sel);
        let lw = local.weigh(&cands).expect("local weigh");
        let rw = remote.weigh(&cands).expect("remote weigh");
        assert_eq!(lw, rw, "u64 assignment counts differ");
        assert_eq!(lw.iter().sum::<u64>(), ps.len() as u64);
    }

    // Leg 3: kill worker A mid-run, respawn it on the same port, and
    // require the replay recovery to land on the baseline bits. A serves
    // its ShardLoad, the seed update, the round-0 sample (+ update) and
    // then dies on its next RPC — squarely mid-round.
    {
        let a = spawn_worker(0, Some(4));
        let b = spawn_worker(0, None);
        let endpoints = vec![a.addr.clone(), b.addr.clone()];
        let a_port = a.port;
        let respawner = std::thread::spawn(move || {
            let mut a = a;
            let _ = a.child.wait();
            let deadline = Instant::now() + Duration::from_secs(20);
            loop {
                match try_spawn_worker(a_port, None) {
                    Ok(w) => return w,
                    Err(e) => {
                        assert!(
                            Instant::now() < deadline,
                            "could not respawn worker on port {a_port}: {e}"
                        );
                        std::thread::sleep(Duration::from_millis(100));
                    }
                }
            }
        });
        let dcfg = DistConfig {
            workers: endpoints,
            rounds: pcfg.rounds,
            oversample: pcfg.oversample,
            ..DistConfig::default()
        };
        let mut rng = Pcg64::seed_from(7);
        let got = kmeans_par_dist(&ps, k, &dcfg, &mut rng)
            .unwrap_or_else(|e| panic!("run did not survive the worker crash: {e:#}"));
        assert_eq!(got.indices, base.indices, "post-recovery indices diverged");
        assert_eq!(
            got.centers.flat(),
            base.centers.flat(),
            "post-recovery centers diverged"
        );
        assert_eq!(rng.next_u64(), base_next, "post-recovery RNG diverged");
        let _respawned = respawner.join().expect("respawner thread");
        drop(b);
    }

    // Leg 4: a permanently dead endpoint is a typed error within the
    // deadline, not a hang.
    {
        let port = {
            let l = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
            l.local_addr().unwrap().port()
            // Listener dropped: nobody will ever accept here.
        };
        let dcfg = DistConfig {
            workers: vec![format!("127.0.0.1:{port}")],
            rounds: 2,
            oversample: 2.0,
            rpc_timeout: Duration::from_millis(500),
            round_deadline: Duration::from_millis(1200),
        };
        let t0 = Instant::now();
        let mut rng = Pcg64::seed_from(7);
        let err = kmeans_par_dist(&ps, k, &dcfg, &mut rng)
            .expect_err("a dead fleet must fail, not hang");
        let msg = format!("{err:#}");
        assert!(msg.contains("unreachable"), "untyped failure: {msg}");
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "dead worker stalled the run for {:?}",
            t0.elapsed()
        );
    }

    std::env::remove_var("FKMPP_KERNEL");
}
