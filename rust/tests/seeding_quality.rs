//! Cross-algorithm quality integration tests, two tiers:
//!
//! 1. the original smoke-profile *ordering* checks (the cost orderings
//!    the paper's Tables 4–6 report must hold on the synthetic
//!    stand-ins), and
//! 2. the **statistical acceptance suite**: over 21 fixed RNG seeds on
//!    two synthetic dataset families, the *median* FASTK-MEANS++,
//!    REJECTIONSAMPLING (practical-LSH oracle) and REJECTION-RIGOROUS
//!    (multi-scale LSH oracle) seeding costs must sit within 1.15× of
//!    the median exact k-means++ cost (the paper's "equivalent quality"
//!    claim, Tables 4–6), while median uniform seeding must be
//!    measurably worse.
//!
//! Determinism: every cost below is a pure function of the fixed seeds
//! *within one process*. The paper seeders' dense kernel shapes sit
//! below the kernel autotuner's probe threshold
//! (`rust/src/kernels/tune.rs::SMALL_WORK`), so those run the v1
//! reference path regardless of probe timing; KMEANSPAR's final
//! weights-assignment shape can cross the floor, but its dispatch is
//! resolved once per process on the global shape, so the bitwise
//! determinism and shard-invariance assertions below are
//! timing-independent (cross-process bit-identity additionally needs
//! `FKMPP_KERNEL` pinned — the PR 3 contract). No test here touches
//! `FKMPP_KERNEL`/`FKMPP_THREADS` (kernel results are thread-count
//! invariant by the parity suites' contract). The 1.15× and 2× margins
//! are structural, not tuned: both families are strongly separated
//! mixtures with k > k_true, where every D²-family seeder covers every
//! cluster (cost ≈ within-cluster variance for all of them — ratios near
//! 1), while uniform sampling almost surely misses small/far clusters
//! and pays their full separation-scale mass.

use fastkmeanspp::data::matrix::PointSet;
use fastkmeanspp::data::registry::{DatasetId, Profile};
use fastkmeanspp::data::synth::{gaussian_mixture, separated_grid, SynthSpec};
use fastkmeanspp::lloyd::cost_native;
use fastkmeanspp::rng::Pcg64;
use fastkmeanspp::seeding::SeedingAlgorithm;

/// Average seeding cost over `reps` seeds.
fn avg_cost(
    ps: &fastkmeanspp::data::matrix::PointSet,
    algo: SeedingAlgorithm,
    k: usize,
    reps: u64,
) -> f64 {
    let mut total = 0.0;
    for r in 0..reps {
        let mut rng = Pcg64::seed_from(1000 * (algo as u64 + 1) + r);
        let s = algo.run(ps, k, &mut rng);
        total += cost_native(ps, &s.centers);
    }
    total / reps as f64
}

#[test]
fn d2_family_beats_uniform_on_kdd_sim() {
    // Table 4's qualitative claim: on the heavy-tailed clustered set,
    // uniform seeding is several times worse than every D^2-family
    // seeder.
    let ps = DatasetId::KddSim.generate(Profile::Smoke, 11);
    let k = 50;
    let uniform = avg_cost(&ps, SeedingAlgorithm::Uniform, k, 3);
    for algo in [
        SeedingAlgorithm::KMeansPP,
        SeedingAlgorithm::FastKMeansPP,
        SeedingAlgorithm::Rejection,
        SeedingAlgorithm::Afkmc2,
    ] {
        let c = avg_cost(&ps, algo, k, 3);
        assert!(
            c * 1.5 < uniform,
            "{}: cost {c:.3e} not clearly below uniform {uniform:.3e}",
            algo.name()
        );
    }
}

#[test]
fn tree_seeders_within_tolerance_of_exact() {
    // Tables 4-6: FASTK-MEANS++ / REJECTIONSAMPLING within ~10-15% of
    // K-MEANS++ (we allow 40% slack on the small smoke profile).
    let ps = DatasetId::SongSim.generate(Profile::Smoke, 13);
    let k = 100;
    let exact = avg_cost(&ps, SeedingAlgorithm::KMeansPP, k, 3);
    for algo in [SeedingAlgorithm::FastKMeansPP, SeedingAlgorithm::Rejection] {
        let c = avg_cost(&ps, algo, k, 3);
        assert!(
            c < 1.4 * exact,
            "{}: {c:.4e} vs exact {exact:.4e}",
            algo.name()
        );
    }
}

#[test]
fn rejection_quality_close_to_fast_on_census_sim() {
    let ps = DatasetId::CensusSim.generate(Profile::Smoke, 17);
    let k = 60;
    let fast = avg_cost(&ps, SeedingAlgorithm::FastKMeansPP, k, 3);
    let rej = avg_cost(&ps, SeedingAlgorithm::Rejection, k, 3);
    // Paper: the two are within a few percent of each other; slack 30%.
    assert!(
        rej < 1.3 * fast && fast < 1.3 * rej,
        "fast={fast:.4e} rejection={rej:.4e}"
    );
}

#[test]
fn cost_decreases_with_k() {
    let ps = DatasetId::KddSim.generate(Profile::Smoke, 19);
    let mut prev = f64::INFINITY;
    for k in [10, 50, 150] {
        let c = avg_cost(&ps, SeedingAlgorithm::Rejection, k, 2);
        assert!(c < prev, "cost must decrease in k: k={k} c={c:.4e} prev={prev:.4e}");
        prev = c;
    }
}

// ---------------------------------------------------------------------
// Statistical acceptance suite (kernels-v2 PR): medians over fixed seeds.
// ---------------------------------------------------------------------

/// Fixed RNG seeds per (family, algorithm) cell — the issue's "≥ 20".
const STAT_SEEDS: u64 = 21;

/// One synthetic dataset family of the statistical suite.
struct Family {
    name: &'static str,
    ps: PointSet,
    k: usize,
}

/// Family 1: balanced, hugely separated lattice clusters (spacing 100,
/// within-cluster σ = 0.5). k = 16 > 12 true clusters, so D²-family
/// seeders cover every cluster essentially always.
fn family_separated() -> Family {
    Family {
        name: "separated_grid",
        ps: separated_grid(12, 350, 6, 5),
        k: 16,
    }
}

/// Family 2: KDD-like Zipf-skewed cluster sizes (smallest ≈ 70 points of
/// 4500), strong separation (spread 18 vs σ 1), no outliers. With
/// k = 2·k_true spare draws, every D² seeder covers all clusters with
/// overwhelming probability even under worst-case tree distortion, while
/// uniform sampling misses at least one of the six smallest clusters on
/// ~99% of seeds (each holds < 2.6% of the mass).
fn family_skewed() -> Family {
    Family {
        name: "zipf_skewed",
        ps: gaussian_mixture(
            &SynthSpec {
                n: 4_500,
                d: 8,
                k_true: 15,
                center_spread: 18.0,
                cluster_std: 1.0,
                outlier_frac: 0.0,
                size_skew: 1.1,
                active_dims: 0,
                ..Default::default()
            },
            41,
        ),
        k: 30,
    }
}

fn median(mut xs: Vec<f64>) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let m = xs.len() / 2;
    if xs.len() % 2 == 1 {
        xs[m]
    } else {
        0.5 * (xs[m - 1] + xs[m])
    }
}

/// Seeding cost per fixed seed (deterministic: same seeds every run).
fn seed_costs(fam: &Family, algo: SeedingAlgorithm) -> Vec<f64> {
    (0..STAT_SEEDS)
        .map(|r| {
            let mut rng = Pcg64::seed_from(7_000 + 97 * r + algo as u64);
            let s = algo.run(&fam.ps, fam.k, &mut rng);
            cost_native(&fam.ps, &s.centers)
        })
        .collect()
}

#[test]
fn statistical_tree_seeders_match_exact_within_1_15x() {
    for fam in [family_separated(), family_skewed()] {
        let exact = median(seed_costs(&fam, SeedingAlgorithm::KMeansPP));
        assert!(exact > 0.0, "{}: degenerate exact cost", fam.name);
        for algo in [
            SeedingAlgorithm::FastKMeansPP,
            // LSH-wiring PR: both oracle-backed rejection modes sit the
            // same 1.15x bar as the exact-oracle paper pipeline —
            // `rejection` runs the practical single-scale LSH oracle by
            // default, `rejection-rigorous` the multi-scale stack.
            SeedingAlgorithm::Rejection,
            SeedingAlgorithm::RejectionLshRigorous,
            // Sharded-seeding PR: k-means‖ + weighted recluster joins the
            // acceptance suite with the same 1.15x bar (oversampling
            // covers every cluster on these families, so the weighted
            // recluster sees the full structure).
            SeedingAlgorithm::KMeansPar,
        ] {
            let m = median(seed_costs(&fam, algo));
            assert!(
                m <= 1.15 * exact,
                "{} on {}: median cost {m:.4e} exceeds 1.15x exact median {exact:.4e}",
                algo.name(),
                fam.name
            );
        }
    }
}

#[test]
fn statistical_kmeanspar_deterministic_and_shard_invariant() {
    // ISSUE 4 acceptance: for a fixed seed, KMEANSPAR is bitwise
    // deterministic and invariant to the shard count. Checked on both
    // families across --shards ∈ {1, 4}.
    use fastkmeanspp::shard::kmeanspar::{kmeans_par, KMeansParConfig};
    for fam in [family_separated(), family_skewed()] {
        for r in [0u64, 10] {
            let run = |shards: usize| {
                let mut rng = Pcg64::seed_from(7_000 + 97 * r);
                kmeans_par(
                    &fam.ps,
                    fam.k,
                    &KMeansParConfig {
                        shards,
                        ..Default::default()
                    },
                    &mut rng,
                )
            };
            let s1 = run(1);
            let s1_again = run(1);
            assert_eq!(s1.indices, s1_again.indices, "{}: nondeterministic", fam.name);
            let s4 = run(4);
            assert_eq!(
                s1.indices, s4.indices,
                "{}: shard count changed the seeding (seed offset {r})",
                fam.name
            );
            assert_eq!(s1.centers, s4.centers, "{}", fam.name);
        }
    }
}

#[test]
fn statistical_lsh_quality_holds_past_prefix_cap() {
    // The 1.15x gate above runs at k < PREFIX_CAP (128), where the LSH
    // prefix scan is exact — it cannot catch a broken bucket-probe
    // approximation. This gate reruns both LSH modes at k = 150 > cap on
    // the separated family, so centers 129..150 are accepted against
    // real bucket probes: an oracle whose post-cap answers degrade badly
    // (broken bucket width, radius filter, probe limit) shifts the
    // acceptance distribution toward near-duplicate centers and fails
    // the same 1.15x bar against exact k-means++ at the same k.
    use fastkmeanspp::seeding::rejection::{rejection_sampling, OracleKind, RejectionConfig};
    let fam = family_separated();
    let k = 150;
    let costs = |oracle: Option<OracleKind>| -> Vec<f64> {
        (0..STAT_SEEDS)
            .map(|r| {
                let mut rng = Pcg64::seed_from(11_000 + 131 * r);
                let centers = match oracle {
                    None => SeedingAlgorithm::KMeansPP.run(&fam.ps, k, &mut rng).centers,
                    Some(oracle) => {
                        let cfg = RejectionConfig {
                            oracle,
                            ..Default::default()
                        };
                        rejection_sampling(&fam.ps, k, &cfg, &mut rng).centers
                    }
                };
                cost_native(&fam.ps, &centers)
            })
            .collect()
    };
    let exact = median(costs(None));
    assert!(exact > 0.0);
    for oracle in [OracleKind::LshPractical, OracleKind::LshRigorous] {
        let m = median(costs(Some(oracle)));
        assert!(
            m <= 1.15 * exact,
            "{oracle:?} at k=150 (> PREFIX_CAP): median {m:.4e} exceeds 1.15x exact {exact:.4e}"
        );
    }
}

#[test]
fn statistical_rejection_all_oracles_bitwise_deterministic() {
    // ISSUE 5 acceptance: for a fixed seed, rejection seeding is bitwise
    // deterministic for every ANN oracle (per-round proposal/acceptance
    // RNG stream split). In-process check on both families; the
    // cross-thread-count leg lives in `rust/tests/oracle_determinism.rs`
    // (its own process — it owns FKMPP_THREADS/FKMPP_KERNEL).
    use fastkmeanspp::seeding::rejection::{rejection_sampling, OracleKind, RejectionConfig};
    for fam in [family_separated(), family_skewed()] {
        for oracle in OracleKind::all() {
            let cfg = RejectionConfig {
                oracle,
                ..Default::default()
            };
            let run = |seed: u64| {
                let mut rng = Pcg64::seed_from(seed);
                rejection_sampling(&fam.ps, fam.k, &cfg, &mut rng)
            };
            let (a, b) = (run(4242), run(4242));
            assert_eq!(a.indices, b.indices, "{} {oracle:?}", fam.name);
            assert_eq!(a.centers, b.centers, "{} {oracle:?}", fam.name);
            assert_eq!(a.stats.proposals, b.stats.proposals, "{} {oracle:?}", fam.name);
            assert_eq!(a.stats.rejections, b.stats.rejections, "{} {oracle:?}", fam.name);
        }
    }
}

#[test]
fn statistical_uniform_is_measurably_worse() {
    for fam in [family_separated(), family_skewed()] {
        let exact = median(seed_costs(&fam, SeedingAlgorithm::KMeansPP));
        let uniform = median(seed_costs(&fam, SeedingAlgorithm::Uniform));
        // Structural expectation is >10x on both families (a missed
        // cluster costs separation² per point vs σ²-level baseline);
        // assert a conservative 2x so the bound is nowhere near noise.
        assert!(
            uniform >= 2.0 * exact,
            "uniform on {}: median {uniform:.4e} not measurably worse than exact {exact:.4e}",
            fam.name
        );
    }
}

#[test]
fn statistical_medians_are_deterministic() {
    // The suite's costs are pure functions of the fixed seeds: two
    // evaluations in one process must agree bit-for-bit. (Cross-process
    // determinism additionally holds because these shapes stay below the
    // autotuner probe threshold — see the module docs.)
    let fam = family_skewed();
    let a = seed_costs(&fam, SeedingAlgorithm::Rejection);
    let b = seed_costs(&fam, SeedingAlgorithm::Rejection);
    assert_eq!(a, b);
}

#[test]
fn statistical_dist_transport_matches_in_process() {
    // Distributed-fit PR: the multi-process transport joins the suite.
    // Over the 21 fixed seeds, `kmeans_par_dist` against 2 real
    // `fkmpp worker` subprocesses must reproduce the in-process
    // `kmeans_par` bit-for-bit. No env pinning here (the file
    // discipline above): every dispatch shape at n=9000, d=4 stays
    // below the autotuner probe threshold, so both processes
    // deterministically resolve the same kernels without `FKMPP_KERNEL`.
    use std::io::BufRead;

    use fastkmeanspp::dist::{kmeans_par_dist, DistConfig};
    use fastkmeanspp::shard::kmeanspar::{kmeans_par, KMeansParConfig};

    struct Worker(std::process::Child);
    impl Drop for Worker {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }
    let spawn = || {
        let mut child = std::process::Command::new(env!("CARGO_BIN_EXE_fkmpp"))
            .args(["worker", "--port", "0"])
            .stdout(std::process::Stdio::piped())
            .spawn()
            .expect("spawn fkmpp worker");
        let stdout = child.stdout.take().expect("worker stdout");
        let mut reader = std::io::BufReader::new(stdout);
        let mut line = String::new();
        reader.read_line(&mut line).expect("worker ready line");
        assert!(line.contains("http://"), "bad worker ready line {line:?}");
        let addr = line.rsplit("http://").next().unwrap().trim().to_string();
        // Keep draining stdout so the worker never blocks on a full pipe.
        std::thread::spawn(move || {
            let mut sink = String::new();
            while matches!(reader.read_line(&mut sink), Ok(b) if b > 0) {
                sink.clear();
            }
        });
        (Worker(child), addr)
    };

    let ps = gaussian_mixture(
        &SynthSpec {
            n: 9_000,
            d: 4,
            k_true: 6,
            ..Default::default()
        },
        43,
    );
    let k = 6;
    let pcfg = KMeansParConfig {
        shards: 2,
        rounds: 3,
        oversample: 2.0,
    };
    let (_w1, a1) = spawn();
    let (_w2, a2) = spawn();
    let dcfg = DistConfig {
        workers: vec![a1, a2],
        rounds: pcfg.rounds,
        oversample: pcfg.oversample,
        ..DistConfig::default()
    };
    for r in 0..STAT_SEEDS {
        let mut rng = Pcg64::seed_from(7_000 + 97 * r);
        let base = kmeans_par(&ps, k, &pcfg, &mut rng);
        let mut rng = Pcg64::seed_from(7_000 + 97 * r);
        let got = kmeans_par_dist(&ps, k, &dcfg, &mut rng)
            .unwrap_or_else(|e| panic!("distributed run (seed offset {r}): {e:#}"));
        assert_eq!(got.indices, base.indices, "seed offset {r}: indices diverged");
        assert_eq!(got.centers, base.centers, "seed offset {r}: centers diverged");
    }
}

#[test]
fn quantization_does_not_change_costs_materially() {
    // Appendix F: seeding on quantized coordinates, evaluated on the
    // originals, costs within ~1% of seeding on raw coordinates.
    let ps = DatasetId::SongSim.generate(Profile::Smoke, 23);
    let mut qrng = Pcg64::seed_from(24);
    let q = fastkmeanspp::data::quantize::quantize(&ps, &mut qrng);
    let k = 40;
    let mut raw = 0.0;
    let mut quant = 0.0;
    for r in 0..3u64 {
        let mut r1 = Pcg64::seed_from(100 + r);
        let s1 = SeedingAlgorithm::KMeansPP.run(&ps, k, &mut r1);
        raw += cost_native(&ps, &s1.centers);
        let mut r2 = Pcg64::seed_from(100 + r);
        let s2 = SeedingAlgorithm::KMeansPP.run(&q.points, k, &mut r2);
        quant += cost_native(&ps, &ps.gather(&s2.indices));
    }
    assert!(
        (raw - quant).abs() < 0.15 * raw,
        "raw={raw:.4e} quantized={quant:.4e}"
    );
}
