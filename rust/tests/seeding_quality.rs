//! Cross-algorithm quality integration tests: the cost *orderings* the
//! paper's Tables 4–6 report must hold on the synthetic stand-ins.

use fastkmeanspp::data::registry::{DatasetId, Profile};
use fastkmeanspp::lloyd::cost_native;
use fastkmeanspp::rng::Pcg64;
use fastkmeanspp::seeding::SeedingAlgorithm;

/// Average seeding cost over `reps` seeds.
fn avg_cost(
    ps: &fastkmeanspp::data::matrix::PointSet,
    algo: SeedingAlgorithm,
    k: usize,
    reps: u64,
) -> f64 {
    let mut total = 0.0;
    for r in 0..reps {
        let mut rng = Pcg64::seed_from(1000 * (algo as u64 + 1) + r);
        let s = algo.run(ps, k, &mut rng);
        total += cost_native(ps, &s.centers);
    }
    total / reps as f64
}

#[test]
fn d2_family_beats_uniform_on_kdd_sim() {
    // Table 4's qualitative claim: on the heavy-tailed clustered set,
    // uniform seeding is several times worse than every D^2-family
    // seeder.
    let ps = DatasetId::KddSim.generate(Profile::Smoke, 11);
    let k = 50;
    let uniform = avg_cost(&ps, SeedingAlgorithm::Uniform, k, 3);
    for algo in [
        SeedingAlgorithm::KMeansPP,
        SeedingAlgorithm::FastKMeansPP,
        SeedingAlgorithm::Rejection,
        SeedingAlgorithm::Afkmc2,
    ] {
        let c = avg_cost(&ps, algo, k, 3);
        assert!(
            c * 1.5 < uniform,
            "{}: cost {c:.3e} not clearly below uniform {uniform:.3e}",
            algo.name()
        );
    }
}

#[test]
fn tree_seeders_within_tolerance_of_exact() {
    // Tables 4-6: FASTK-MEANS++ / REJECTIONSAMPLING within ~10-15% of
    // K-MEANS++ (we allow 40% slack on the small smoke profile).
    let ps = DatasetId::SongSim.generate(Profile::Smoke, 13);
    let k = 100;
    let exact = avg_cost(&ps, SeedingAlgorithm::KMeansPP, k, 3);
    for algo in [SeedingAlgorithm::FastKMeansPP, SeedingAlgorithm::Rejection] {
        let c = avg_cost(&ps, algo, k, 3);
        assert!(
            c < 1.4 * exact,
            "{}: {c:.4e} vs exact {exact:.4e}",
            algo.name()
        );
    }
}

#[test]
fn rejection_quality_close_to_fast_on_census_sim() {
    let ps = DatasetId::CensusSim.generate(Profile::Smoke, 17);
    let k = 60;
    let fast = avg_cost(&ps, SeedingAlgorithm::FastKMeansPP, k, 3);
    let rej = avg_cost(&ps, SeedingAlgorithm::Rejection, k, 3);
    // Paper: the two are within a few percent of each other; slack 30%.
    assert!(
        rej < 1.3 * fast && fast < 1.3 * rej,
        "fast={fast:.4e} rejection={rej:.4e}"
    );
}

#[test]
fn cost_decreases_with_k() {
    let ps = DatasetId::KddSim.generate(Profile::Smoke, 19);
    let mut prev = f64::INFINITY;
    for k in [10, 50, 150] {
        let c = avg_cost(&ps, SeedingAlgorithm::Rejection, k, 2);
        assert!(c < prev, "cost must decrease in k: k={k} c={c:.4e} prev={prev:.4e}");
        prev = c;
    }
}

#[test]
fn quantization_does_not_change_costs_materially() {
    // Appendix F: seeding on quantized coordinates, evaluated on the
    // originals, costs within ~1% of seeding on raw coordinates.
    let ps = DatasetId::SongSim.generate(Profile::Smoke, 23);
    let mut qrng = Pcg64::seed_from(24);
    let q = fastkmeanspp::data::quantize::quantize(&ps, &mut qrng);
    let k = 40;
    let mut raw = 0.0;
    let mut quant = 0.0;
    for r in 0..3u64 {
        let mut r1 = Pcg64::seed_from(100 + r);
        let s1 = SeedingAlgorithm::KMeansPP.run(&ps, k, &mut r1);
        raw += cost_native(&ps, &s1.centers);
        let mut r2 = Pcg64::seed_from(100 + r);
        let s2 = SeedingAlgorithm::KMeansPP.run(&q.points, k, &mut r2);
        quant += cost_native(&ps, &ps.gather(&s2.indices));
    }
    assert!(
        (raw - quant).abs() < 0.15 * raw,
        "raw={raw:.4e} quantized={quant:.4e}"
    );
}
